//! **The headline end-to-end driver** (DESIGN.md §6): Algorithm 1 on a
//! real dataset through all three layers, Sea vs direct-PFS, with
//! on-device integrity certification after every iteration.
//!
//! Pipeline per block: read from the rate-limited "Lustre" directory →
//! n × (PJRT `step` executes the AOT-lowered Pallas increment kernel +
//! block-stats → write the iteration file through the VFS under test) →
//! certify `block == base + n`.
//!
//! Reported: makespan for (a) direct PFS, (b) Sea in-memory, (c) Sea
//! flush-all — the real-bytes analogue of paper Fig 3 — plus throughput,
//! per-layer byte counts and the PJRT hot-path profile. Results land in
//! `results/incrementation_e2e.csv` and EXPERIMENTS.md cites this run.
//!
//! ```bash
//! make artifacts && cargo run --release --example incrementation_e2e
//! # env overrides: E2E_BLOCKS, E2E_ITERS, E2E_WORKERS
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use sea::coordinator::{run_pipeline, IoMode, PipelineCfg, PipelineReport};
use sea::placement::RuleSet;
use sea::runtime::Engine;
use sea::util::csv::{f, Csv};
use sea::util::{fmt_bytes, MIB};
use sea::vfs::{DeviceSpec, RateLimitedFs, RealFs, SeaFs, SeaFsConfig, SeaTuning, Vfs};
use sea::workload::{dataset, IncrementationSpec};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct Case {
    name: &'static str,
    report: PipelineReport,
}

fn main() -> sea::Result<()> {
    let blocks = env_usize("E2E_BLOCKS", 24);
    let iterations = env_usize("E2E_ITERS", 5);
    let workers = env_usize("E2E_WORKERS", 3);

    let work = std::env::temp_dir().join("sea_e2e");
    let shm = PathBuf::from("/dev/shm/sea_e2e");
    let _ = std::fs::remove_dir_all(&work);
    let _ = std::fs::remove_dir_all(&shm);

    let engine = Arc::new(Engine::load("artifacts")?);
    let elems = engine.chunk_elems();
    let ds = dataset::generate(&work.join("pfs/inputs"), blocks, elems, 99)?;
    let total = ds.block_bytes() * blocks as u64;
    println!(
        "e2e: {blocks} blocks x {} = {} input, {iterations} iterations, {workers} workers",
        fmt_bytes(ds.block_bytes()),
        fmt_bytes(total),
    );
    println!(
        "volumes: D_m {}, D_f {} (Algorithm 1, read-back on)\n",
        fmt_bytes(total * (iterations as u64 - 1)),
        fmt_bytes(total)
    );

    // "Lustre": single shared rate-limited directory (Table 2 speeds)
    let pfs = |work: &PathBuf| -> sea::Result<Arc<dyn Vfs>> {
        Ok(Arc::new(RateLimitedFs::new(
            RealFs::new(work.join("pfs"))?,
            1381.0 * MIB as f64,
            121.0 * MIB as f64,
        )))
    };
    let sea_mount = |rules: RuleSet, work: &PathBuf| -> sea::Result<Arc<dyn Vfs>> {
        Ok(Arc::new(SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![
                DeviceSpec::dir(shm.clone(), 0, 1024 * MIB)?,
                DeviceSpec::dir(work.join("disk0"), 1, 8192 * MIB)?,
                DeviceSpec::dir(work.join("disk1"), 1, 8192 * MIB)?,
            ],
            pfs: pfs(work)?,
            max_file_size: ds.block_bytes(),
            parallel_procs: workers as u64,
            rules,
            seed: 3,
            tuning: SeaTuning::default(),
        })?))
    };

    let run = |vfs: Arc<dyn Vfs>, prefix: &str| -> sea::Result<PipelineReport> {
        run_pipeline(&PipelineCfg {
            engine: engine.clone(),
            vfs,
            dataset: ds.clone(),
            mount_prefix: PathBuf::from(prefix),
            iterations,
            workers,
            read_back: true,
            verify: true,
            cleanup_intermediate: true,
            max_open_outputs: 0,
            io_mode: IoMode::Streamed,
            page_cache: None,
        })
    };

    let mut cases = Vec::new();
    println!("--- direct PFS (the paper's Lustre baseline)");
    cases.push(Case { name: "direct-pfs", report: run(pfs(&work)?, "")? });

    println!("--- sea in-memory (flush+evict final iteration only)");
    cases.push(Case {
        name: "sea-in-memory",
        report: run(
            sea_mount(RuleSet::in_memory(IncrementationSpec::final_glob()), &work)?,
            "/sea",
        )?,
    });

    println!("--- sea flush-all (copy everything to the PFS)");
    cases.push(Case {
        name: "sea-flush-all",
        report: run(sea_mount(RuleSet::copy_all(), &work)?, "/sea")?,
    });

    let direct = cases[0].report.makespan;
    let mut csv = Csv::new(vec![
        "case", "makespan_s", "app_s", "speedup_vs_direct", "read", "written",
        "pjrt_calls", "pjrt_mean_ms",
    ]);
    println!("\n{:<16} {:>10} {:>10} {:>9} {:>12} {:>12}", "case", "makespan", "app", "speedup", "read", "written");
    for c in &cases {
        let r = &c.report;
        println!(
            "{:<16} {:>9.2}s {:>9.2}s {:>8.2}x {:>12} {:>12}",
            c.name,
            r.makespan,
            r.app_time,
            direct / r.makespan,
            fmt_bytes(r.bytes_read),
            fmt_bytes(r.bytes_written),
        );
        csv.row(vec![
            c.name.to_string(),
            f(r.makespan),
            f(r.app_time),
            f(direct / r.makespan),
            r.bytes_read.to_string(),
            r.bytes_written.to_string(),
            r.pjrt_calls.to_string(),
            f(r.pjrt_mean_s * 1e3),
        ]);
    }
    csv.write_to("results/incrementation_e2e.csv")?;
    println!("\nwrote results/incrementation_e2e.csv");
    println!(
        "integrity: every block certified base+{iterations} on-device ({} PJRT calls)",
        cases.iter().map(|c| c.report.pjrt_calls).max().unwrap_or(0)
    );

    let _ = std::fs::remove_dir_all(&shm);
    let _ = std::fs::remove_dir_all(&work);
    Ok(())
}
