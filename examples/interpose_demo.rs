//! Drive the `LD_PRELOAD` glibc interposer (the paper's actual
//! mechanism) against unmodified system binaries.
//!
//! ```bash
//! cargo build -p sea-interpose   # builds target/<profile>/libsea_interpose.so
//! cargo run --release --example interpose_demo
//! ```
//!
//! Spawns `/bin/cat`, `ls` and a shell redirection with the shim
//! preloaded and `SEA_MOUNT=/sea` pointing at a managed directory;
//! verifies each child saw translated paths. Skips politely when the
//! cdylib hasn't been built.

use std::path::PathBuf;
use std::process::Command;

fn find_shim() -> Option<PathBuf> {
    for profile in ["release", "debug"] {
        let p = PathBuf::from(format!("target/{profile}/libsea_interpose.so"));
        if p.exists() {
            return Some(p);
        }
    }
    None
}

fn main() {
    let Some(shim) = find_shim() else {
        println!(
            "libsea_interpose.so not built — run `cargo build -p sea-interpose` first (skipping)"
        );
        return;
    };
    let shim = std::fs::canonicalize(&shim).expect("canonicalize shim");
    let target = std::env::temp_dir().join("sea_interpose_demo");
    let _ = std::fs::remove_dir_all(&target);
    std::fs::create_dir_all(&target).expect("mk target");
    std::fs::write(target.join("hello.txt"), b"translated read OK\n").expect("seed file");

    let run = |cmd: &str| -> (bool, String) {
        let out = Command::new("sh")
            .arg("-c")
            .arg(cmd)
            .env("LD_PRELOAD", &shim)
            .env("SEA_MOUNT", "/sea")
            .env("SEA_TARGET", &target)
            .output()
            .expect("spawn child");
        (
            out.status.success(),
            String::from_utf8_lossy(&out.stdout).into_owned(),
        )
    };

    println!("shim: {}", shim.display());
    println!("SEA_MOUNT=/sea -> SEA_TARGET={}\n", target.display());

    // 1. read through the mount with cat
    let (ok, out) = run("cat /sea/hello.txt");
    print!("$ cat /sea/hello.txt\n{out}");
    assert!(ok && out.contains("translated read OK"), "cat through the shim");

    // 2. write through the mount with a shell redirection
    let (ok, _) = run("echo written-via-shim > /sea/out.txt");
    assert!(ok, "redirect through the shim");
    let back = std::fs::read_to_string(target.join("out.txt")).expect("file landed in target");
    println!("$ echo written-via-shim > /sea/out.txt");
    println!("  -> {}/out.txt: {back}", target.display());
    assert!(back.contains("written-via-shim"));

    // 3. list the mount
    let (ok, out) = run("ls /sea");
    println!("$ ls /sea\n{out}");
    assert!(ok && out.contains("hello.txt") && out.contains("out.txt"), "ls through the shim");

    // 4. paths outside the mount are untouched
    let (ok, out) = run("cat /etc/hostname 2>/dev/null || echo no-hostname");
    assert!(ok, "non-mount paths pass through");
    print!("$ cat /etc/hostname  (untranslated)\n{out}");

    println!("\ninterposer demo OK: unmodified binaries, translated I/O");
    let _ = std::fs::remove_dir_all(&target);
}
