//! Table 1 semantics demo on a real directory tree: the four
//! memory-management modes (Copy / Remove / Move / Keep) plus prefetch,
//! driven by actual `.sea_flushlist` / `.sea_evictlist` /
//! `.sea_prefetchlist` files parsed from disk.
//!
//! ```bash
//! cargo run --release --example flush_modes
//! ```

use std::path::{Path, PathBuf};
use std::sync::Arc;

use sea::placement::{MgmtMode, RuleSet};
use sea::util::MIB;
use sea::vfs::{DeviceSpec, RealFs, SeaFs, SeaFsConfig, SeaTuning, Vfs};

fn main() -> sea::Result<()> {
    let work = std::env::temp_dir().join("sea_flush_modes");
    let _ = std::fs::remove_dir_all(&work);
    std::fs::create_dir_all(&work).expect("mk work dir");

    // write the three rule dot-files exactly as a user would
    std::fs::write(
        work.join(".sea_flushlist"),
        "# persist results and shared checkpoints\nresults/**\ncheckpoints/**\n",
    )
    .expect("flushlist");
    std::fs::write(
        work.join(".sea_evictlist"),
        "# drop scratch; checkpoints move (flush+evict)\nscratch/**\ncheckpoints/**\n",
    )
    .expect("evictlist");
    std::fs::write(work.join(".sea_prefetchlist"), "inputs/*.dat\n").expect("prefetchlist");
    let rules = RuleSet::load_dir(&work)?;

    println!("Table 1 mode resolution:");
    for p in [
        "results/stats.csv",      // flush only            -> Copy
        "scratch/tmp_0.log",      // evict only            -> Remove
        "checkpoints/ckpt_1.bin", // both                  -> Move
        "working/partial.dat",    // neither               -> Keep
    ] {
        println!("  {p:<24} -> {:?}", rules.mode_for(p));
    }
    assert_eq!(rules.mode_for("results/stats.csv"), MgmtMode::Copy);
    assert_eq!(rules.mode_for("scratch/tmp_0.log"), MgmtMode::Remove);
    assert_eq!(rules.mode_for("checkpoints/ckpt_1.bin"), MgmtMode::Move);
    assert_eq!(rules.mode_for("working/partial.dat"), MgmtMode::Keep);

    // mount and exercise each mode with real files
    let pfs = Arc::new(RealFs::new(work.join("pfs"))?);
    pfs.write(Path::new("inputs/vol0.dat"), &vec![1u8; MIB as usize])?;
    pfs.write(Path::new("inputs/vol1.dat"), &vec![2u8; MIB as usize])?;
    let sea = SeaFs::mount(SeaFsConfig {
        mountpoint: PathBuf::from("/sea"),
        devices: vec![
            DeviceSpec::dir(work.join("tier0_shm"), 0, 64 * MIB)?,
            DeviceSpec::dir(work.join("tier1_disk"), 1, 256 * MIB)?,
        ],
        pfs: pfs.clone(),
        max_file_size: MIB,
        parallel_procs: 2,
        rules,
        seed: 5,
        tuning: SeaTuning::default(),
    })?;

    let n = sea.prefetch_dir("inputs")?;
    println!("\nprefetched {n} input files into fast tiers");
    assert_eq!(n, 2);

    let payload = vec![9u8; MIB as usize];
    sea.write(Path::new("/sea/results/stats.csv"), &payload)?; // Copy
    sea.write(Path::new("/sea/scratch/tmp_0.log"), &payload)?; // Remove
    sea.write(Path::new("/sea/checkpoints/ckpt_1.bin"), &payload)?; // Move
    sea.write(Path::new("/sea/working/partial.dat"), &payload)?; // Keep
    sea.sync_mgmt()?;

    println!("\nafter the flush-and-evict daemon has drained:");
    let show = |rel: &str| {
        let local = sea.device_of(rel).map(|d| {
            Path::new(&d).file_name().map(|f| f.to_string_lossy().into_owned()).unwrap_or(d)
        });
        println!(
            "  {rel:<24} local={:<12} pfs={}",
            local.unwrap_or_else(|| "-".into()),
            pfs.exists(Path::new(rel)),
        );
    };
    show("results/stats.csv");
    show("scratch/tmp_0.log");
    show("checkpoints/ckpt_1.bin");
    show("working/partial.dat");

    // verify Table 1 outcomes
    assert!(sea.device_of("results/stats.csv").is_some(), "Copy keeps local");
    assert!(pfs.exists(Path::new("results/stats.csv")), "Copy persists");
    assert!(sea.device_of("scratch/tmp_0.log").is_none(), "Remove drops local");
    assert!(!pfs.exists(Path::new("scratch/tmp_0.log")), "Remove never persists");
    assert!(sea.device_of("checkpoints/ckpt_1.bin").is_none(), "Move drops local");
    assert!(pfs.exists(Path::new("checkpoints/ckpt_1.bin")), "Move persists");
    assert!(sea.device_of("working/partial.dat").is_some(), "Keep stays local");
    assert!(!pfs.exists(Path::new("working/partial.dat")), "Keep never persists");

    let (flushes, evictions) = sea.mgmt_counters();
    println!("\ndaemon counters: {flushes} flushes, {evictions} evictions");
    println!("all Table 1 semantics verified OK");

    let _ = std::fs::remove_dir_all(&work);
    Ok(())
}
