//! Quickstart: mount Sea over a tmpfs + disk hierarchy, run a tiny
//! incrementation workload with REAL bytes and PJRT compute, and print
//! the placement map and the speedup against writing straight to the
//! (rate-limited) PFS.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use sea::coordinator::{run_pipeline, IoMode, PipelineCfg};
use sea::placement::RuleSet;
use sea::runtime::Engine;
use sea::util::{fmt_bytes, MIB};
use sea::vfs::{DeviceSpec, RateLimitedFs, RealFs, SeaFs, SeaFsConfig, SeaTuning, Vfs};
use sea::workload::{dataset, IncrementationSpec};

fn main() -> sea::Result<()> {
    let work = std::env::temp_dir().join("sea_quickstart");
    let _ = std::fs::remove_dir_all(&work);

    // Layer 2/1: the AOT-compiled JAX+Pallas compute, loaded via PJRT.
    let engine = Arc::new(Engine::load("artifacts")?);
    println!("loaded artifacts: {:?}", engine.manifest().names());

    // a small real dataset (12 blocks at the lowered chunk geometry)
    let ds = dataset::generate(&work.join("pfs/inputs"), 12, engine.chunk_elems(), 1)?;
    println!(
        "dataset: {} blocks x {}",
        ds.blocks.len(),
        fmt_bytes(ds.block_bytes())
    );

    // the "PFS": a directory rate-limited to lustre-ish speeds
    let pfs = || -> sea::Result<Arc<dyn Vfs>> {
        Ok(Arc::new(RateLimitedFs::new(
            RealFs::new(work.join("pfs"))?,
            1381.0 * MIB as f64,
            121.0 * MIB as f64,
        )))
    };

    // baseline: write everything through the PFS
    let direct = run_pipeline(&PipelineCfg {
        engine: engine.clone(),
        vfs: pfs()?,
        dataset: ds.clone(),
        mount_prefix: PathBuf::new(),
        iterations: 3,
        workers: 2,
        read_back: true,
        verify: true,
        cleanup_intermediate: true,
        max_open_outputs: 0,
        io_mode: IoMode::Streamed,
        page_cache: None,
    })?;
    println!("direct PFS : {:.2}s", direct.makespan);

    // Sea: tmpfs tier + one disk tier over the same PFS, in-memory rules
    let sea = SeaFs::mount(SeaFsConfig {
        mountpoint: PathBuf::from("/sea"),
        devices: vec![
            DeviceSpec::dir(PathBuf::from("/dev/shm/sea_quickstart"), 0, 512 * MIB)?,
            DeviceSpec::dir(work.join("disk0"), 1, 4096 * MIB)?,
        ],
        pfs: pfs()?,
        max_file_size: ds.block_bytes(),
        parallel_procs: 2,
        rules: RuleSet::in_memory(IncrementationSpec::final_glob()),
        seed: 7,
        tuning: SeaTuning::default(),
    })?;
    let report = run_pipeline(&PipelineCfg {
        engine: engine.clone(),
        vfs: Arc::new(sea),
        dataset: ds.clone(),
        mount_prefix: PathBuf::from("/sea"),
        iterations: 3,
        workers: 2,
        read_back: true,
        verify: true,
        cleanup_intermediate: true,
        max_open_outputs: 0,
        io_mode: IoMode::Streamed,
        page_cache: None,
    })?;
    println!("sea        : {:.2}s", report.makespan);
    println!("speedup    : {:.2}x", direct.makespan / report.makespan);
    println!(
        "I/O        : {} read, {} written, {} PJRT calls (mean {:.2} ms)",
        fmt_bytes(report.bytes_read),
        fmt_bytes(report.bytes_written),
        report.pjrt_calls,
        report.pjrt_mean_s * 1e3
    );

    let _ = std::fs::remove_dir_all("/dev/shm/sea_quickstart");
    let _ = std::fs::remove_dir_all(&work);
    Ok(())
}
