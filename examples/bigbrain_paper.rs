//! Paper-scale reproduction: every Fig 2 sweep plus Fig 3 on the
//! simulated paper cluster (1000 × 617 MiB BigBrain blocks), with the
//! analytic model bounds shaded on each chart.
//!
//! ```bash
//! cargo run --release --example bigbrain_paper              # full scale
//! SEA_SCALE=0.1 cargo run --release --example bigbrain_paper # 1/10 blocks
//! ```
//!
//! Output: `results/fig2{a,b,c,d}.{csv,txt}`, `results/fig3.csv`, and a
//! summary table comparing measured speedups with the paper's claims.

use sea::report::{self, Scale};
use sea::sim::spec::ClusterSpec;
use sea::util::csv::{f, Csv};

fn main() -> sea::Result<()> {
    let scale = Scale {
        blocks: std::env::var("SEA_SCALE")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0),
    };
    let spec = ClusterSpec::paper_default();
    let seed = 42;
    let out = std::path::Path::new("results");

    println!("bigbrain_paper: scale {} (1.0 = 1000 blocks x 617 MiB)\n", scale.blocks);

    let paper_claims = [
        ("fig2a", "max speedup ~2.4x at 5 nodes"),
        ("fig2b", "max speedup ~2x at 6 disks; Sea slower at 1 disk"),
        ("fig2c", "max speedup ~2.6x at 10 iterations; parity at 1"),
        ("fig2d", "max speedup ~3x at 32 procs"),
    ];

    let t0 = std::time::Instant::now();
    let figs = vec![
        report::fig2a(&spec, scale, &[1, 2, 3, 4, 5, 6, 7, 8], seed)?,
        report::fig2b(&spec, scale, &[1, 2, 3, 4, 5, 6], seed)?,
        report::fig2c(&spec, scale, &[1, 5, 10, 15], seed)?,
        report::fig2d(&spec, scale, &[1, 2, 4, 8, 16, 32, 64], seed)?,
    ];
    let mut summary = Csv::new(vec!["figure", "max_speedup", "paper_claim"]);
    for fig in &figs {
        fig.write_to(out)?;
        println!("{}", fig.to_ascii());
        let claim = paper_claims
            .iter()
            .find(|(id, _)| *id == fig.id)
            .map(|(_, c)| *c)
            .unwrap_or("");
        println!("  max speedup: {:.2}x   (paper: {claim})\n", fig.max_speedup());
        summary.row(vec![fig.id.clone(), f(fig.max_speedup()), claim.to_string()]);
    }

    // Fig 3: mode comparison at fixed conditions
    let rows = report::fig3(&spec, scale, seed)?;
    let mut fig3csv = Csv::new(vec!["mode", "makespan_s", "app_done_s"]);
    println!("Fig 3 (5 nodes / 6 procs / 6 disks / 5 iterations):");
    for (name, r) in &rows {
        println!("  {name:<16} {:>8.1} s", r.makespan);
        fig3csv.row(vec![name.clone(), f(r.makespan), f(r.app_done)]);
    }
    fig3csv.write_to(out.join("fig3.csv"))?;
    let get = |m: &str| rows.iter().find(|(n, _)| n == m).map(|(_, r)| r.makespan).unwrap_or(f64::NAN);
    println!(
        "  flush-all / in-memory = {:.2}x (paper: 3.5x);  flush-all / lustre = {:.2}x (paper: 1.3x)",
        get("sea-flush-all") / get("sea-in-memory"),
        get("sea-flush-all") / get("lustre"),
    );
    summary.row(vec![
        "fig3".to_string(),
        f(get("lustre") / get("sea-in-memory")),
        "flush-all 3.5x slower than in-memory, 1.3x slower than lustre".to_string(),
    ]);
    summary.write_to(out.join("paper_summary.csv"))?;
    println!("\nall figures written to results/ in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
