"""Layer-2 JAX model: the incrementation application's compute graph.

The paper's synthetic application (Algorithm 1) reads an image chunk,
increments it n times, and saves every iteration. The Rust coordinator
drives the file-system side; the per-iteration compute is this module's
``step`` function (one increment + integrity stats), lowered ONCE at build
time to HLO text and executed from Rust via PJRT for every chunk-iteration.

Exported entry points (see aot.py for the artifact list):

- ``step(x)``            -> (x+1, stats)    the request-path hot function
- ``step_n(x)``          -> (x+n, stats)    fused n-iteration variant
  (in-memory end of the model; n baked at lowering time)
- ``blend(x, y)``        -> 0.5x + 0.5y     multi-stage pipeline's merge op
- ``stats(x)``           -> f32[3]          standalone integrity check

Chunks are canonically shaped ``(rows, LANES)`` f32. The Rust side memmaps
flat chunk bytes and reinterprets them with this layout; ``CHUNK_ROWS``
below is the default lowering shape (examples override via aot.py flags).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import block_stats, increment, increment_n, saxpby
from compile.kernels.increment import LANES

# Default lowering geometry: 4096 x 256 f32 = 4 MiB per chunk. This is the
# real-bytes end-to-end default; the simulator models paper-scale 617 MiB
# blocks analytically, while real runs use chunks this size (DESIGN.md §2).
CHUNK_ROWS = 4096


def step(x: jax.Array, *, block_rows=None):
    """One Algorithm-1 iteration: increment the chunk, return stats too.

    Returning ``(sum, min, max)`` with the chunk keeps integrity checking
    on-device and costs one extra pass over a VMEM-resident tile stream —
    XLA fuses it with the add under jit.

    ``block_rows`` selects the Pallas tile height at lowering time:
    ``None`` keeps the TPU-canonical 256-row tiles; the CPU AOT path
    lowers with ``block_rows=rows`` (see kernels/increment.py).
    """
    y = increment(x, block_rows=block_rows)
    return y, block_stats(y, block_rows=block_rows)


def step_n(x: jax.Array, *, n: int, block_rows=None):
    """n fused iterations (no intermediate materialization)."""
    y = increment_n(x, n, block_rows=block_rows)
    return y, block_stats(y, block_rows=block_rows)


def blend(x: jax.Array, y: jax.Array, *, block_rows=None):
    """Merge step of the multi-stage example workload: mean of two chunks."""
    z = saxpby(x, y, a=0.5, b=0.5, block_rows=block_rows)
    return z, block_stats(z, block_rows=block_rows)


def stats(x: jax.Array, *, block_rows=None):
    """Standalone integrity statistics."""
    return (block_stats(x, block_rows=block_rows),)


def chunk_spec(rows: int = CHUNK_ROWS) -> jax.ShapeDtypeStruct:
    """The canonical chunk ShapeDtypeStruct used for lowering."""
    return jax.ShapeDtypeStruct((rows, LANES), jnp.float32)
