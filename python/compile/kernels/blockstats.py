"""Pallas block-statistics kernel (Layer 1).

The end-to-end driver verifies data integrity after n increment rounds
(checksum: every element of the output must equal input + n). Computing
``(sum, min, max)`` of a chunk on the PJRT device instead of in Rust keeps
the verification on the same compute path as the increments.

Implemented as a grid reduction: each grid step reduces one
``(BLOCK_ROWS, LANES)`` tile into a running partial carried in the output
ref; Pallas guarantees sequential grid execution on TPU, so the
accumulate-into-output pattern is the canonical reduction idiom.

Partial final tiles: when ``rows % BLOCK_ROWS != 0`` the last tile is
padded by Pallas with *undefined* values, so every reduction masks rows
``>= rows - i*BLOCK_ROWS`` with its neutral element (0 / +inf / -inf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.increment import BLOCK_ROWS, LANES


def _stats_kernel(x_ref, o_ref, *, rows, block_rows):
    i = pl.program_id(0)
    tile = x_ref[...]
    # Mask away padded rows of the final partial tile (neutral elements).
    row_ids = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 0)
    valid = row_ids < (rows - i * block_rows)
    tile_sum = jnp.sum(jnp.where(valid, tile, 0.0), dtype=jnp.float32)
    tile_min = jnp.min(jnp.where(valid, tile, jnp.inf))
    tile_max = jnp.max(jnp.where(valid, tile, -jnp.inf))

    @pl.when(i == 0)
    def _init():
        o_ref[0] = tile_sum
        o_ref[1] = tile_min
        o_ref[2] = tile_max

    @pl.when(i != 0)
    def _acc():
        o_ref[0] = o_ref[0] + tile_sum
        o_ref[1] = jnp.minimum(o_ref[1], tile_min)
        o_ref[2] = jnp.maximum(o_ref[2], tile_max)


def block_stats(x: jax.Array, *, block_rows=None) -> jax.Array:
    """Return ``[sum, min, max]`` (f32[3]) of a (rows, LANES) chunk.

    ``block_rows`` as in :func:`compile.kernels.increment.increment`:
    None = TPU-canonical tiles, rows = single-step grid for CPU interpret.
    """
    if x.ndim != 2 or x.shape[1] != LANES:
        raise ValueError(f"block_stats expects (rows, {LANES}), got {x.shape}")
    from compile.kernels.increment import _block_rows_for

    br = _block_rows_for(x.shape, block_rows)
    grid = (pl.cdiv(x.shape[0], br),)
    kernel = functools.partial(_stats_kernel, rows=x.shape[0], block_rows=br)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, LANES), lambda i: (i, 0))],
        # The 3-element stats vector lives whole in VMEM across grid steps.
        out_specs=pl.BlockSpec((3,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((3,), jnp.float32),
        interpret=True,
    )(x)
