"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the correctness ground truth: pytest (and hypothesis sweeps)
assert the Pallas kernels match these to within exact / float tolerance.
They deliberately avoid Pallas, BlockSpec, or any tiling — just jnp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def increment_ref(x: jax.Array, *, amount=1) -> jax.Array:
    """Oracle for kernels.increment: elementwise x + amount."""
    return x + jnp.asarray(amount, dtype=x.dtype)


def increment_n_ref(x: jax.Array, n: int, *, amount=1) -> jax.Array:
    """Oracle for kernels.increment_n: x + n*amount."""
    return x + jnp.asarray(n, dtype=x.dtype) * jnp.asarray(amount, dtype=x.dtype)


def saxpby_ref(x: jax.Array, y: jax.Array, *, a=1.0, b=1.0) -> jax.Array:
    """Oracle for kernels.saxpby."""
    return jnp.asarray(a, dtype=x.dtype) * x + jnp.asarray(b, dtype=y.dtype) * y


def block_stats_ref(x: jax.Array) -> jax.Array:
    """Oracle for kernels.block_stats: f32[3] = [sum, min, max]."""
    return jnp.stack(
        [
            jnp.sum(x, dtype=jnp.float32),
            jnp.min(x).astype(jnp.float32),
            jnp.max(x).astype(jnp.float32),
        ]
    )
