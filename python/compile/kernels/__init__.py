"""Layer-1 Pallas kernels for the Sea reproduction.

The incrementation application (paper Algorithm 1) is elementwise,
memory-bound work over large image chunks. Kernels here are written with
``jax.experimental.pallas`` and tiled via ``BlockSpec`` so that, on a real
TPU, each grid step streams one VMEM-resident block HBM->VMEM, applies the
VPU op, and streams it back. On this CPU-only image they are lowered with
``interpret=True`` (real-TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot execute) — correctness is asserted against the pure-jnp
oracles in :mod:`compile.kernels.ref`.
"""

from compile.kernels.increment import increment, increment_n, saxpby
from compile.kernels.blockstats import block_stats

__all__ = ["increment", "increment_n", "saxpby", "block_stats"]
