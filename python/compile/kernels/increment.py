"""Pallas increment kernels (Layer 1).

Algorithm 1 of the paper increments an image chunk ``n`` times, saving each
iteration to the file system. The per-iteration compute hot-spot is a
single elementwise ``chunk + 1`` over a ~617 MiB block; here it is expressed
as a Pallas kernel tiled into VMEM-sized blocks.

TPU adaptation (DESIGN.md §3): this workload has no matmul, so the MXU is
idle and the roofline is memory bandwidth — exactly the paper's own framing.
The ``BlockSpec`` tiling expresses the HBM<->VMEM streaming schedule: a 1-D
grid walks ``(BLOCK_ROWS, LANES)`` tiles; each tile is far below the ~16 MiB
VMEM budget so double-buffering can overlap DMA with the VPU add.

All ``pallas_call``s use ``interpret=True`` — mandatory on this CPU-only
image (see kernels/__init__.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile geometry. The last dim is kept at 256 lanes (a multiple of the 128
# TPU lane width); 256x256 f32 = 256 KiB per in/out tile, comfortably
# within VMEM with room for double buffering.
LANES = 256
BLOCK_ROWS = 256


def _increment_kernel(x_ref, o_ref, *, amount):
    """One grid step: o = x + amount over a VMEM-resident tile."""
    o_ref[...] = x_ref[...] + amount


def _block_rows_for(shape, block_rows):
    """Resolve the tile height.

    ``block_rows=None`` selects the TPU-canonical ``BLOCK_ROWS``; on the
    CPU-interpret path callers pass ``block_rows=rows`` (grid of 1): the
    interpret-mode grid lowers to an XLA while-loop whose every step
    copies the *full* output via dynamic_update_slice, so small tiles
    cost ~26x on CPU while being mandatory on real TPU VMEM
    (EXPERIMENTS.md §Perf records the measurement).
    """
    return min(shape[0], block_rows or BLOCK_ROWS)


def _grid_for(shape, block_rows):
    """1-D grid over row-blocks of a 2-D (rows, LANES) array."""
    return (pl.cdiv(shape[0], block_rows),)


def increment(x: jax.Array, *, amount=1, block_rows=None) -> jax.Array:
    """Elementwise ``x + amount`` via a tiled Pallas kernel.

    ``x`` must be 2-D with trailing dim ``LANES`` (the L2 model reshapes
    flat chunks into this canonical layout).
    """
    if x.ndim != 2 or x.shape[1] != LANES:
        raise ValueError(f"increment expects (rows, {LANES}), got {x.shape}")
    br = _block_rows_for(x.shape, block_rows)
    kernel = functools.partial(_increment_kernel, amount=amount)
    return pl.pallas_call(
        kernel,
        grid=_grid_for(x.shape, br),
        in_specs=[pl.BlockSpec((br, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def increment_n(x: jax.Array, n: int, *, amount=1, block_rows=None) -> jax.Array:
    """``n`` fused increment steps (compute-graph view of Algorithm 1's
    inner loop when no intermediate is materialized).

    The paper's app writes every iteration to the file system, so the
    runtime usually calls the single-step executable n times; this fused
    variant exists for the in-memory end of the model (and as an XLA
    fusion sanity check: n static steps must lower to one add).
    """
    if n < 0:
        raise ValueError("n must be >= 0")
    # n is static at trace time: the adds fold into a single `+ n*amount`,
    # which is what XLA does anyway — keep the loop explicit so the HLO
    # cost analysis in tests can verify the fusion actually happened.
    y = x
    for _ in range(n):
        y = increment(y, amount=amount, block_rows=block_rows)
    return y


def _saxpby_kernel(x_ref, y_ref, o_ref, *, a, b):
    o_ref[...] = a * x_ref[...] + b * y_ref[...]


def saxpby(x: jax.Array, y: jax.Array, *, a=1.0, b=1.0, block_rows=None) -> jax.Array:
    """``a*x + b*y`` tiled kernel — used by the multi-stage example
    workload (stencil-free blend step) to give the pipeline a second,
    two-input compute shape."""
    if x.shape != y.shape:
        raise ValueError(f"shape mismatch {x.shape} vs {y.shape}")
    if x.ndim != 2 or x.shape[1] != LANES:
        raise ValueError(f"saxpby expects (rows, {LANES}), got {x.shape}")
    br = _block_rows_for(x.shape, block_rows)
    kernel = functools.partial(_saxpby_kernel, a=a, b=b)
    spec = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=_grid_for(x.shape, br),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, y)
