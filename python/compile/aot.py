"""AOT lowering: JAX (L2) -> HLO text artifacts consumed by the Rust runtime.

HLO *text* is the interchange format, NOT ``lowered.compile().serialize()``
and NOT a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids which the xla crate's bundled xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``). The text parser on the Rust side reassigns
ids, so text round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Usage (from python/):
    python -m compile.aot --out ../artifacts/model.hlo.txt [--rows 4096]

Emits, next to --out:
    model.hlo.txt        step(x)      the request-path single iteration
    step5.hlo.txt        step_n(x,5)  fused 5-iteration variant
    blend.hlo.txt        blend(x, y)
    stats.hlo.txt        stats(x)
    manifest.txt         name -> file, rows, lanes, dtype (parsed by Rust)
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", required=True, help="path of the primary artifact")
    ap.add_argument("--rows", type=int, default=model.CHUNK_ROWS,
                    help="chunk rows at lowering time (cols fixed at LANES)")
    ap.add_argument("--fused-n", type=int, default=5,
                    help="n for the fused step_n artifact")
    ap.add_argument("--block-rows", type=int, default=0,
                    help="Pallas tile height; 0 = whole chunk (grid of 1), "
                         "the fast choice for CPU-interpret execution. Use "
                         "256 for the TPU-canonical VMEM tiling.")
    args = ap.parse_args()
    block_rows = args.block_rows if args.block_rows > 0 else args.rows

    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(outdir, exist_ok=True)
    spec = model.chunk_spec(args.rows)

    entries = {
        # primary artifact keeps the --out name for Makefile compatibility
        os.path.basename(args.out): (
            functools.partial(model.step, block_rows=block_rows), (spec,)),
        f"step{args.fused_n}.hlo.txt": (
            functools.partial(model.step_n, n=args.fused_n, block_rows=block_rows),
            (spec,)),
        "blend.hlo.txt": (
            functools.partial(model.blend, block_rows=block_rows), (spec, spec)),
        "stats.hlo.txt": (
            functools.partial(model.stats, block_rows=block_rows), (spec,)),
    }

    manifest = [f"# name\tfile\trows\tlanes\tdtype"]
    logical = {os.path.basename(args.out): "step",
               f"step{args.fused_n}.hlo.txt": f"step_n:{args.fused_n}",
               "blend.hlo.txt": "blend", "stats.hlo.txt": "stats"}
    for fname, (fn, ex) in entries.items():
        text = lower_entry(fn, ex)
        path = os.path.join(outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        from compile.kernels.increment import LANES
        manifest.append(f"{logical[fname]}\t{fname}\t{args.rows}\t{LANES}\tf32")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(outdir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
