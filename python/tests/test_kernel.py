"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel is checked against its pure-jnp oracle in
compile.kernels.ref, exactly (integer-valued data) or to float tolerance,
across shapes, paddings, and value regimes. Hypothesis sweeps shapes and
dtypes in test_kernel_properties.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import block_stats, increment, increment_n, saxpby
from compile.kernels import ref
from compile.kernels.increment import BLOCK_ROWS, LANES


def chunk(rows, seed=0, dtype=jnp.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.standard_normal((rows, LANES)).astype(np.float32) * scale, dtype=dtype
    )


# --- increment -----------------------------------------------------------

@pytest.mark.parametrize("rows", [BLOCK_ROWS, 2 * BLOCK_ROWS, 8 * BLOCK_ROWS])
def test_increment_matches_ref(rows):
    x = chunk(rows)
    np.testing.assert_array_equal(increment(x), ref.increment_ref(x))


def test_increment_exact_on_integral_values():
    # f32 holds integers exactly up to 2**24: Algorithm 1's uint16-style
    # data stays integral through every iteration.
    x = jnp.arange(BLOCK_ROWS * LANES, dtype=jnp.float32).reshape(BLOCK_ROWS, LANES)
    y = increment(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x) + 1.0)


def test_increment_amount():
    x = chunk(BLOCK_ROWS, seed=1)
    np.testing.assert_array_equal(
        increment(x, amount=7), ref.increment_ref(x, amount=7)
    )


def test_increment_rejects_bad_shapes():
    with pytest.raises(ValueError):
        increment(jnp.zeros((4, 4), jnp.float32))
    with pytest.raises(ValueError):
        increment(jnp.zeros((LANES,), jnp.float32))


@pytest.mark.parametrize("rows", [1, 3, BLOCK_ROWS - 1, BLOCK_ROWS + 1])
def test_increment_ragged_rows(rows):
    # rows not divisible by BLOCK_ROWS exercise the padded final tile.
    x = chunk(rows, seed=2)
    np.testing.assert_array_equal(increment(x), ref.increment_ref(x))


# --- increment_n ---------------------------------------------------------

@pytest.mark.parametrize("n", [0, 1, 2, 5, 10])
def test_increment_n_matches_ref(n):
    x = chunk(BLOCK_ROWS, seed=3)
    np.testing.assert_allclose(
        increment_n(x, n), ref.increment_n_ref(x, n), rtol=0, atol=1e-5
    )


def test_increment_n_integral_exact():
    x = jnp.full((BLOCK_ROWS, LANES), 5.0, jnp.float32)
    np.testing.assert_array_equal(np.asarray(increment_n(x, 10)), 15.0)


def test_increment_n_negative_rejected():
    with pytest.raises(ValueError):
        increment_n(chunk(BLOCK_ROWS), -1)


# --- saxpby --------------------------------------------------------------

@pytest.mark.parametrize("a,b", [(1.0, 1.0), (0.5, 0.5), (2.0, -1.0)])
def test_saxpby_matches_ref(a, b):
    x, y = chunk(2 * BLOCK_ROWS, seed=4), chunk(2 * BLOCK_ROWS, seed=5)
    np.testing.assert_allclose(
        saxpby(x, y, a=a, b=b), ref.saxpby_ref(x, y, a=a, b=b), rtol=1e-6
    )


def test_saxpby_shape_mismatch_rejected():
    with pytest.raises(ValueError):
        saxpby(chunk(BLOCK_ROWS), chunk(2 * BLOCK_ROWS))


# --- block_stats ---------------------------------------------------------

@pytest.mark.parametrize("rows", [BLOCK_ROWS, 3 * BLOCK_ROWS, 8 * BLOCK_ROWS])
def test_block_stats_matches_ref(rows):
    x = chunk(rows, seed=6, scale=10.0)
    got, want = block_stats(x), ref.block_stats_ref(x)
    # sum over ~2M elements: allow accumulation-order tolerance
    np.testing.assert_allclose(got[0], want[0], rtol=1e-5)
    np.testing.assert_array_equal(got[1:], want[1:])


def test_block_stats_constant_field():
    x = jnp.full((2 * BLOCK_ROWS, LANES), 3.0, jnp.float32)
    s = np.asarray(block_stats(x))
    assert s[0] == pytest.approx(3.0 * x.size)
    assert s[1] == 3.0 and s[2] == 3.0


def test_block_stats_detects_single_outlier():
    # the e2e integrity check relies on min/max catching any corrupt value
    x = np.zeros((2 * BLOCK_ROWS, LANES), np.float32)
    x[BLOCK_ROWS + 17, 31] = -42.0
    s = np.asarray(block_stats(jnp.asarray(x)))
    assert s[1] == -42.0 and s[2] == 0.0


# --- end-to-end kernel contract used by the Rust driver -------------------

def test_algorithm1_invariant_via_kernels():
    """After n single-step increments, stats must certify x0 + n exactly."""
    x = jnp.zeros((BLOCK_ROWS, LANES), jnp.float32)
    n = 7
    for _ in range(n):
        x = increment(x)
    s = np.asarray(block_stats(x))
    assert s[1] == n and s[2] == n and s[0] == pytest.approx(n * x.size)
