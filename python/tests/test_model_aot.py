"""L2 model shape/semantics tests + AOT lowering contract.

These lock in the things the Rust runtime depends on:
- entry signatures ((chunk) -> (chunk, f32[3]) etc.),
- HLO text that xla_extension 0.5.1 can parse (no 64-bit-id proto path),
- the n-static-steps graph folding to a single fused add (XLA fusion check).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref
from compile.kernels.increment import LANES


def x_of(rows=512, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((rows, LANES)).astype(np.float32))


# --- model semantics ------------------------------------------------------

def test_step_signature_and_values():
    x = x_of()
    y, s = model.step(x)
    assert y.shape == x.shape and y.dtype == x.dtype
    assert s.shape == (3,) and s.dtype == jnp.float32
    np.testing.assert_array_equal(y, ref.increment_ref(x))
    np.testing.assert_allclose(s, ref.block_stats_ref(y), rtol=1e-5)


def test_step_n_equals_n_steps():
    x = x_of(seed=1)
    y5, _ = model.step_n(x, n=5)
    y = x
    for _ in range(5):
        y, _ = model.step(y)
    np.testing.assert_allclose(y5, y, atol=1e-5)


def test_blend_is_mean():
    a, b = x_of(seed=2), x_of(seed=3)
    z, s = model.blend(a, b)
    np.testing.assert_allclose(z, (np.asarray(a) + np.asarray(b)) / 2, rtol=1e-6)
    assert s.shape == (3,)


def test_chunk_spec_geometry():
    spec = model.chunk_spec(1024)
    assert spec.shape == (1024, LANES) and spec.dtype == jnp.float32


# --- AOT lowering contract -------------------------------------------------

def lower_text(fn, *specs):
    return aot.to_hlo_text(jax.jit(fn).lower(*specs))


def test_hlo_text_is_emitted_and_looks_like_hlo():
    text = lower_text(model.step, model.chunk_spec(512))
    assert text.startswith("HloModule")
    assert "f32[512,256]" in text
    # return_tuple=True => tuple-rooted entry, which Rust unwraps
    assert "(f32[512,256]" in text


def test_hlo_entry_layout_matches_manifest_geometry():
    rows = 768
    text = lower_text(model.step, model.chunk_spec(rows))
    assert f"f32[{rows},256]" in text


def test_step_n_semantics_and_compiles():
    """L2 contract for the fused variant: n static increments produce
    exactly x + n, and the lowered module compiles under jit.

    Note: with interpret=True each pallas_call lowers to a while-loop over
    the grid, so static fusion introspection on the optimized HLO is not
    meaningful on this CPU-only image (on TPU the adds fuse; DESIGN.md
    §Hardware-Adaptation). The numerical contract is the testable part.
    """
    x = x_of(seed=4)
    y, _ = model.step_n(x, n=6)
    # six sequential f32 +1's round differently from a single +6 on
    # non-integral data — tolerance, not bit equality
    np.testing.assert_allclose(y, np.asarray(x) + 6.0, atol=1e-5)
    compiled = jax.jit(lambda v: model.step_n(v, n=6)).lower(
        model.chunk_spec(512)).compile()
    assert compiled.as_text().startswith("HloModule")


def test_artifacts_on_disk_when_present():
    """If `make artifacts` has run, validate the manifest/file contract."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.txt")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built")
    rows = []
    with open(manifest) as f:
        for line in f:
            if line.startswith("#") or not line.strip():
                continue
            name, fname, r, lanes, dtype = line.strip().split("\t")
            rows.append((name, fname))
            path = os.path.join(art, fname)
            assert os.path.exists(path), f"missing artifact {fname}"
            with open(path) as g:
                assert g.read(9) == "HloModule"
            assert int(lanes) == LANES and dtype == "f32"
    names = {n for n, _ in rows}
    assert {"step", "blend", "stats"} <= names
