"""Hypothesis property sweeps over the Pallas kernels (shapes / values).

The guide's L1 requirement: hypothesis sweeps the kernel's shapes/dtypes and
assert_allclose against ref.py. Shapes are bounded to keep interpret-mode
runtime reasonable; the deadline is disabled because interpret=True tracing
dominates wall time on first example.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import block_stats, increment, increment_n, saxpby
from compile.kernels import ref
from compile.kernels.increment import LANES

SETTINGS = dict(max_examples=25, deadline=None)

rows_st = st.integers(min_value=1, max_value=640)
seed_st = st.integers(min_value=0, max_value=2**31 - 1)
amount_st = st.integers(min_value=-8, max_value=8)


def mk(rows, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((rows, LANES)).astype(np.float32) * scale)


@given(rows=rows_st, seed=seed_st, amount=amount_st)
@settings(**SETTINGS)
def test_increment_property(rows, seed, amount):
    x = mk(rows, seed)
    np.testing.assert_array_equal(
        increment(x, amount=amount), ref.increment_ref(x, amount=amount)
    )


@given(rows=rows_st, seed=seed_st, n=st.integers(min_value=0, max_value=6))
@settings(**SETTINGS)
def test_increment_n_property(rows, seed, n):
    x = mk(rows, seed)
    np.testing.assert_allclose(
        increment_n(x, n), ref.increment_n_ref(x, n), rtol=0, atol=1e-5
    )


@given(
    rows=rows_st,
    seed=seed_st,
    a=st.floats(min_value=-4, max_value=4, allow_nan=False),
    b=st.floats(min_value=-4, max_value=4, allow_nan=False),
)
@settings(**SETTINGS)
def test_saxpby_property(rows, seed, a, b):
    x, y = mk(rows, seed), mk(rows, seed + 1)
    np.testing.assert_allclose(
        saxpby(x, y, a=a, b=b), ref.saxpby_ref(x, y, a=a, b=b),
        rtol=1e-5, atol=1e-5,
    )


@given(rows=rows_st, seed=seed_st, scale=st.sampled_from([1.0, 100.0, 1e4]))
@settings(**SETTINGS)
def test_block_stats_property(rows, seed, scale):
    x = mk(rows, seed, scale)
    got, want = np.asarray(block_stats(x)), np.asarray(ref.block_stats_ref(x))
    np.testing.assert_allclose(got[0], want[0], rtol=1e-4, atol=1e-3)
    np.testing.assert_array_equal(got[1:], want[1:])
