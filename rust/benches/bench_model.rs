//! Analytic-model evaluation throughput (Eqs 1–11): the model is called
//! at every figure sweep point; it must be effectively free.

mod common;

use sea::bench::Harness;
use sea::model::{lustre_bounds, sea_bounds, ModelParams};
use sea::util::MIB;
use sea::workload::IncrementationSpec;

fn main() {
    let mut h = Harness::new("model").with_reps(1, 5);
    let spec = common::paper_spec();
    let params = ModelParams::from_spec(&spec, 617 * MIB);

    h.case("bounds_100k_evals", || {
        let mut acc = 0.0;
        for i in 0..100_000u64 {
            let w = IncrementationSpec {
                blocks: 100 + (i % 900) as usize,
                file_size: 617 * MIB,
                iterations: 1 + (i % 15) as usize,
                compute_per_iter: 0.0,
                read_back: true,
            };
            let v = w.volume();
            let lb = lustre_bounds(&params, &v);
            let sb = sea_bounds(&params, &v);
            acc += lb.upper + sb.lower;
        }
        assert!(acc.is_finite());
    });
    let results = h.finish();
    let per = results[0].summary().mean / 100_000.0 * 1e9;
    println!("per bounds-pair evaluation: {per:.1} ns");
}
