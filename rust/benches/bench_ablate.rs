//! Ablations of Sea's design choices (DESIGN.md §5 extras):
//!
//! * placement policy — fastest-with-space (the paper) vs Lustre-always;
//! * eviction — in-memory rules (flush+evict finals) vs keep-everything
//!   vs flush-all;
//! * the `p·F` reservation — paper config vs a 64-proc reservation that
//!   disqualifies tmpfs (the §3.1.2 "minimum space" rule's cost).

mod common;

use sea::coordinator::{run_experiment, ExperimentCfg, Mode};
use sea::bench::Harness;
use sea::placement::RuleSet;
use sea::workload::IncrementationSpec;

fn run(mode: Mode, blocks: usize, procs: usize) -> f64 {
    let mut spec = common::paper_spec();
    spec.procs_per_node = procs;
    let mut w = IncrementationSpec::paper_default();
    w.blocks = blocks;
    w.iterations = 5;
    run_experiment(&ExperimentCfg { spec, workload: w, mode, seed: common::SEED })
        .expect("sim")
        .makespan
}

fn main() {
    let mut h = Harness::new("ablate").with_reps(0, 1);
    let blocks = (1000.0 * common::bench_scale().blocks).round().max(1.0) as usize;

    // placement policy ablation
    let lustre = run(Mode::Lustre, blocks, 6);
    let sea = run(Mode::SeaInMemory, blocks, 6);
    h.record("policy_lustre_always", vec![lustre], "baseline placement");
    h.record("policy_fastest_with_space", vec![sea], format!("{:.2}x", lustre / sea));

    // eviction ablation: keep-everything (no rules) vs in-memory vs all
    let keep = run(Mode::SeaCustom(RuleSet::default()), blocks, 6);
    let flush_all = run(Mode::SeaCopyAll, blocks, 6);
    h.record("evict_in_memory_rules", vec![sea], "flush+evict finals");
    h.record("evict_keep_everything", vec![keep], format!("{:.2}x vs in-mem", keep / sea));
    h.record("evict_flush_all", vec![flush_all], format!("{:.2}x vs in-mem", flush_all / sea));

    // reservation ablation: heavy p·F reservation starves tmpfs
    let sea_64 = run(Mode::SeaInMemory, blocks, 64);
    let lustre_64 = run(Mode::Lustre, blocks, 64);
    h.record("reserve_p6_speedup", vec![lustre / sea], "p*F = 3.6 GiB/node");
    h.record(
        "reserve_p64_speedup",
        vec![lustre_64 / sea_64],
        "p*F = 38.6 GiB/node (tmpfs mostly reserved)",
    );

    println!(
        "\npolicy {:.2}x | keep {:.2}x | flush-all {:.2}x | p=64 speedup {:.2}x",
        lustre / sea,
        keep / sea,
        flush_all / sea,
        lustre_64 / sea_64
    );
    h.finish();
}
