//! Shared helpers for the figure benches.
//!
//! Each `bench_fig*` target regenerates one paper artifact: it *times*
//! the regeneration (host cost) and *records* the simulated makespans
//! (the paper's measured quantity) plus the analytic bounds, writing
//! `results/<fig>.csv` / `.txt` like `sea experiment` does.
//!
//! `SEA_BENCH_SCALE` (default 0.1) scales the block count; 1.0 is the
//! paper's full 1000 x 617 MiB dataset.

use sea::report::Scale;
use sea::sim::spec::ClusterSpec;

/// Scale from the environment (default quick).
pub fn bench_scale() -> Scale {
    Scale {
        blocks: std::env::var("SEA_BENCH_SCALE")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.1),
    }
}

/// The paper cluster (always the figure baseline).
pub fn paper_spec() -> ClusterSpec {
    ClusterSpec::paper_default()
}

/// Deterministic bench seed.
pub const SEED: u64 = 42;
