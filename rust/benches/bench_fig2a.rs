//! Fig 2a: Lustre vs Sea in-memory, varying the node count (10 iters).

mod common;

use sea::bench::Harness;
use sea::report;

fn main() {
    let scale = common::bench_scale();
    let mut h = Harness::new("fig2a").with_reps(0, 1);
    let mut fig = None;
    h.case("sweep_nodes_1..8", || {
        let f = report::fig2a(&common::paper_spec(), scale, &[1, 2, 3, 4, 5, 6, 7, 8], common::SEED)
            .expect("fig2a");
        fig = Some(f);
    });
    let fig = fig.expect("ran");
    for p in &fig.points {
        h.record(
            &format!("nodes_{}", p.x as usize),
            vec![p.lustre, p.sea],
            format!("lustre {:.1}s sea {:.1}s speedup {:.2}x", p.lustre, p.sea, p.speedup()),
        );
    }
    fig.write_to(std::path::Path::new("results")).expect("write fig2a");
    println!("{}", fig.to_ascii());
    println!("fig2a max speedup {:.2}x (paper: ~2.4x at 5 nodes)", fig.max_speedup());
    h.finish();
}
