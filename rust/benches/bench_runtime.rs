//! PJRT hot path: per-call latency and effective bandwidth of the AOT
//! compiled `step` / fused `step_n` / `stats` executables (the L3→L2→L1
//! request path of the end-to-end driver).

mod common;

use sea::bench::Harness;
use sea::runtime::Engine;
use sea::util::MIB;

fn main() {
    let engine = match Engine::load("artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("bench_runtime: artifacts not built ({e}); run `make artifacts`");
            return;
        }
    };
    let elems = engine.chunk_elems();
    let bytes = (elems * 4) as f64;
    let mut h = Harness::new("runtime").with_reps(2, 10);

    let mut buf = vec![1f32; elems];
    h.case("step_4mib_chunk", || {
        engine.step(&mut buf).expect("step");
    });
    let mut buf2 = vec![1f32; elems];
    h.case("step_fused_n", || {
        engine.step_fused(&mut buf2).expect("fused");
    });
    let buf3 = vec![1f32; elems];
    h.case("stats_only", || {
        engine.stats(&buf3).expect("stats");
    });
    let mut a = vec![1f32; elems];
    let b = vec![2f32; elems];
    h.case("blend", || {
        engine.blend(&mut a, &b).expect("blend");
    });

    let results = h.finish();
    for r in &results {
        let s = r.summary();
        // step moves the chunk in + out ≈ 2x bytes per call
        println!(
            "{:<28} {:>8.1} MiB/s effective",
            r.name,
            2.0 * bytes / MIB as f64 / s.mean
        );
    }
    let t = engine.timings();
    println!(
        "\ncumulative: {} calls, mean {:.3} ms, payload bandwidth {:.1} MiB/s",
        t.calls,
        t.mean().as_secs_f64() * 1e3,
        t.bandwidth() / MIB as f64
    );
}
