//! Fig 2c: Lustre vs Sea in-memory, varying iterations (D_m volume).
//! Paper shape: parity at 1 iteration, ~2.6x at 10.

mod common;

use sea::bench::Harness;
use sea::report;

fn main() {
    let scale = common::bench_scale();
    let mut h = Harness::new("fig2c").with_reps(0, 1);
    let mut fig = None;
    h.case("sweep_iters_1-15", || {
        let f = report::fig2c(&common::paper_spec(), scale, &[1, 5, 10, 15], common::SEED)
            .expect("fig2c");
        fig = Some(f);
    });
    let fig = fig.expect("ran");
    for p in &fig.points {
        h.record(
            &format!("iters_{}", p.x as usize),
            vec![p.lustre, p.sea],
            format!("lustre {:.1}s sea {:.1}s speedup {:.2}x", p.lustre, p.sea, p.speedup()),
        );
    }
    fig.write_to(std::path::Path::new("results")).expect("write fig2c");
    println!("{}", fig.to_ascii());
    println!("fig2c max speedup {:.2}x (paper: ~2.6x at 10 iterations)", fig.max_speedup());
    h.finish();
}
