//! Fig 2d: Lustre vs Sea in-memory, varying parallel processes (5 iters).
//! Paper shape: ~3x at 32 procs; Lustre *exceeds* its bandwidth-model
//! bounds above ~30 procs/node as the MDS saturates.

mod common;

use sea::bench::Harness;
use sea::model::{lustre_bounds, ModelParams};
use sea::report;
use sea::workload::IncrementationSpec;

fn main() {
    let scale = common::bench_scale();
    let mut h = Harness::new("fig2d").with_reps(0, 1);
    let mut fig = None;
    h.case("sweep_procs_1..64", || {
        let f = report::fig2d(
            &common::paper_spec(),
            scale,
            &[1, 2, 4, 8, 16, 32, 64],
            common::SEED,
        )
        .expect("fig2d");
        fig = Some(f);
    });
    let fig = fig.expect("ran");
    for p in &fig.points {
        h.record(
            &format!("procs_{}", p.x as usize),
            vec![p.lustre, p.sea],
            format!("lustre {:.1}s sea {:.1}s speedup {:.2}x", p.lustre, p.sea, p.speedup()),
        );
    }
    fig.write_to(std::path::Path::new("results")).expect("write fig2d");
    println!("{}", fig.to_ascii());
    println!("fig2d max speedup {:.2}x (paper: ~3x at 32 procs)", fig.max_speedup());

    // the paper's Fig 2d observation: at high process counts Lustre's
    // measured makespan escapes the bandwidth-only model's upper bound
    let mut w = IncrementationSpec::paper_default();
    w.iterations = 5;
    w.blocks = ((w.blocks as f64 * scale.blocks).round() as usize).max(1);
    if let Some(p) = fig.points.iter().find(|p| p.x as usize == 64) {
        let mut spec = common::paper_spec();
        spec.procs_per_node = 64;
        let bounds = lustre_bounds(&ModelParams::from_spec(&spec, w.file_size), &w.volume());
        println!(
            "procs=64: lustre measured {:.1}s vs model upper bound {:.1}s (escape ratio {:.2})",
            p.lustre,
            bounds.upper,
            p.lustre / bounds.upper
        );
    }
    h.finish();
}
