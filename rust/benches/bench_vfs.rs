//! Interception overhead: the paper claims glibc interception cost is
//! "minimal, and negligible compared to system call interception and
//! file systems such as FUSE". Measure the library-level analogue —
//! SeaFs path translation + registry vs a plain RealFs — per operation.

mod common;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use sea::bench::Harness;
use sea::placement::RuleSet;
use sea::util::{KIB, MIB};
use sea::vfs::{RealFs, SeaFs, SeaFsConfig, Vfs};

fn main() {
    let work = std::env::temp_dir().join("sea_bench_vfs");
    let _ = std::fs::remove_dir_all(&work);
    let mut h = Harness::new("vfs").with_reps(1, 5);

    let plain = RealFs::new(work.join("plain")).expect("plain");
    let pfs = Arc::new(RealFs::new(work.join("pfs")).expect("pfs"));
    let sea = SeaFs::mount(SeaFsConfig {
        mountpoint: PathBuf::from("/sea"),
        devices: vec![(work.join("dev0"), 0, 4096 * MIB)],
        pfs,
        max_file_size: MIB,
        parallel_procs: 4,
        rules: RuleSet::default(),
        seed: 1,
    })
    .expect("mount");

    const N: usize = 200;
    let payload4k = vec![7u8; 4 * KIB as usize];
    let payload1m = vec![7u8; MIB as usize];

    h.case("realfs_write_4k_x200", || {
        for i in 0..N {
            plain.write(Path::new(&format!("w/{i}.dat")), &payload4k).unwrap();
        }
    });
    h.case("seafs_write_4k_x200", || {
        for i in 0..N {
            sea.write(Path::new(&format!("/sea/w/{i}.dat")), &payload4k).unwrap();
        }
    });
    h.case("realfs_write_1m_x200", || {
        for i in 0..N {
            plain.write(Path::new(&format!("m/{i}.dat")), &payload1m).unwrap();
        }
    });
    h.case("seafs_write_1m_x200", || {
        for i in 0..N {
            sea.write(Path::new(&format!("/sea/m/{i}.dat")), &payload1m).unwrap();
        }
    });
    h.case("realfs_read_1m_x200", || {
        for i in 0..N {
            let _ = plain.read(Path::new(&format!("m/{i}.dat"))).unwrap();
        }
    });
    h.case("seafs_read_1m_x200", || {
        for i in 0..N {
            let _ = sea.read(Path::new(&format!("/sea/m/{i}.dat"))).unwrap();
        }
    });
    h.case("seafs_stat_x200", || {
        for i in 0..N {
            let _ = sea.size(Path::new(&format!("/sea/m/{i}.dat"))).unwrap();
        }
    });

    let results = h.finish();
    // derive the per-op interception overhead from the 4k pair
    let mean = |name: &str| {
        results
            .iter()
            .find(|r| r.name.ends_with(name))
            .map(|r| r.summary().mean)
            .unwrap_or(f64::NAN)
    };
    let overhead =
        (mean("seafs_write_4k_x200") - mean("realfs_write_4k_x200")) / N as f64 * 1e6;
    println!("\nper-write interception overhead (4k): {overhead:.2} µs");
    let _ = std::fs::remove_dir_all(&work);
}
