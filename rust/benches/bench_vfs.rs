//! Interception overhead: the paper claims glibc interception cost is
//! "minimal, and negligible compared to system call interception and
//! file systems such as FUSE". Measure the library-level analogue —
//! SeaFs path translation + registry vs a plain RealFs — per operation,
//! plus the handle API's partial-read path (64 KiB strides from 1 MiB
//! blocks) and the flush pool's concurrent drain throughput.

mod common;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use sea::bench::Harness;
use sea::placement::{EngineKind, RuleSet};
use sea::util::{KIB, MIB};
use sea::vfs::{
    DeviceSpec, OpenMode, RealFs, SeaFs, SeaFsConfig, SeaTuning, StripedFs, Vfs, VfsFile,
};

fn main() {
    let work = std::env::temp_dir().join("sea_bench_vfs");
    let _ = std::fs::remove_dir_all(&work);
    let mut h = Harness::new("vfs").with_reps(1, 5);

    let plain = RealFs::new(work.join("plain")).expect("plain");
    let pfs = Arc::new(RealFs::new(work.join("pfs")).expect("pfs"));
    let sea = SeaFs::mount(SeaFsConfig {
        mountpoint: PathBuf::from("/sea"),
        devices: vec![DeviceSpec::dir(work.join("dev0"), 0, 4096 * MIB).expect("dev")],
        pfs,
        max_file_size: MIB,
        parallel_procs: 4,
        rules: RuleSet::default(),
        seed: 1,
        tuning: SeaTuning::default(),
    })
    .expect("mount");

    const N: usize = 200;
    let payload4k = vec![7u8; 4 * KIB as usize];
    let payload1m = vec![7u8; MIB as usize];

    h.case("realfs_write_4k_x200", || {
        for i in 0..N {
            plain.write(Path::new(&format!("w/{i}.dat")), &payload4k).unwrap();
        }
    });
    h.case("seafs_write_4k_x200", || {
        for i in 0..N {
            sea.write(Path::new(&format!("/sea/w/{i}.dat")), &payload4k).unwrap();
        }
    });
    h.case("realfs_write_1m_x200", || {
        for i in 0..N {
            plain.write(Path::new(&format!("m/{i}.dat")), &payload1m).unwrap();
        }
    });
    h.case("seafs_write_1m_x200", || {
        for i in 0..N {
            sea.write(Path::new(&format!("/sea/m/{i}.dat")), &payload1m).unwrap();
        }
    });
    h.case("realfs_read_1m_x200", || {
        for i in 0..N {
            let _ = plain.read(Path::new(&format!("m/{i}.dat"))).unwrap();
        }
    });
    h.case("seafs_read_1m_x200", || {
        for i in 0..N {
            let _ = sea.read(Path::new(&format!("/sea/m/{i}.dat"))).unwrap();
        }
    });
    h.case("seafs_stat_x200", || {
        for i in 0..N {
            let _ = sea.size(Path::new(&format!("/sea/m/{i}.dat"))).unwrap();
        }
    });

    // partial reads: 16 x 64 KiB strides from each 1 MiB block, through
    // an offset-addressed handle (no whole-file materialization)
    let strides = (MIB / (64 * KIB)) as u64;
    h.case("realfs_pread_64k_strides_x200", || {
        let mut buf = vec![0u8; 64 * KIB as usize];
        for i in 0..N {
            let mut f = plain
                .open(Path::new(&format!("m/{i}.dat")), OpenMode::Read)
                .unwrap();
            for k in 0..strides {
                f.pread_exact(&mut buf, k * 64 * KIB).unwrap();
            }
        }
    });
    h.case("seafs_pread_64k_strides_x200", || {
        let mut buf = vec![0u8; 64 * KIB as usize];
        for i in 0..N {
            let mut f = sea
                .open(Path::new(&format!("/sea/m/{i}.dat")), OpenMode::Read)
                .unwrap();
            for k in 0..strides {
                f.pread_exact(&mut buf, k * 64 * KIB).unwrap();
            }
        }
    });

    // concurrent flush: 4 writer threads x 16 Move-mode files, drained by
    // the flush pool (the seed's single daemon serialized this)
    static FLUSH_REP: AtomicU64 = AtomicU64::new(0);
    h.case("seafs_concurrent_flush_64x256k", || {
        let rep = FLUSH_REP.fetch_add(1, Ordering::Relaxed);
        let root = work.join(format!("flush_{rep}"));
        let pfs = Arc::new(RealFs::new(root.join("pfs")).expect("pfs"));
        let mount = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("dev0"), 0, 1024 * MIB).expect("dev")],
            pfs,
            max_file_size: MIB,
            parallel_procs: 4,
            rules: RuleSet::from_texts("**", "**", ""), // move everything
            seed: rep + 1,
            tuning: SeaTuning::default(),
        })
        .expect("mount");
        let mount = Arc::new(mount);
        let payload = vec![5u8; 256 * KIB as usize];
        std::thread::scope(|scope| {
            for t in 0..4 {
                let mount = mount.clone();
                let payload = &payload;
                scope.spawn(move || {
                    for i in 0..16 {
                        let p = PathBuf::from(format!("/sea/t{t}/f{i}.dat"));
                        let mut f = mount.open(&p, OpenMode::Write).unwrap();
                        f.pwrite_all(payload, 0).unwrap();
                    }
                });
            }
        });
        mount.sync_mgmt().expect("drain");
        let (fl, ev) = mount.mgmt_counters();
        assert_eq!((fl, ev), (64, 64));
        let _ = std::fs::remove_dir_all(&root);
    });

    // flush-pool scaling: workers × per-member concurrency over a
    // 4-member striped PFS (each member individually rate-limited, like
    // OSTs); measures time for the pool to drain a batch of Move-mode
    // files and emits BENCH_flush_scaling.json for curve tooling
    const MEMBERS: usize = 4;
    const SCALE_FILES: usize = 32;
    const SCALE_KIB: u64 = 256;
    let mut grid: Vec<(usize, usize, f64, Vec<usize>)> = Vec::new();
    for &workers in &[1usize, 2, 4, 8] {
        for &per_member in &[1usize, 2, 4] {
            let root = work.join(format!("scale_w{workers}_m{per_member}"));
            let members: Vec<Arc<dyn Vfs>> = (0..MEMBERS)
                .map(|i| {
                    Arc::new(sea::vfs::RateLimitedFs::new(
                        RealFs::new(root.join(format!("ost{i}"))).expect("ost"),
                        1e9,
                        16.0 * MIB as f64, // per-member write cap
                    )) as Arc<dyn Vfs>
                })
                .collect();
            let pfs: Arc<dyn Vfs> = Arc::new(StripedFs::new(members).expect("striped"));
            let mount = SeaFs::mount(SeaFsConfig {
                mountpoint: PathBuf::from("/sea"),
                devices: vec![DeviceSpec::dir(root.join("dev0"), 0, 1024 * MIB).expect("dev")],
                pfs,
                max_file_size: MIB,
                parallel_procs: 4,
                rules: RuleSet::from_texts("**", "**", ""), // move everything
                seed: 42,
                tuning: SeaTuning {
                    flush_workers: workers,
                    registry_shards: 16,
                    per_member_concurrency: per_member,
                    ..SeaTuning::default()
                },
            })
            .expect("mount");
            let payload = vec![1u8; (SCALE_KIB * KIB) as usize];
            let t0 = std::time::Instant::now();
            for i in 0..SCALE_FILES {
                let p = PathBuf::from(format!("/sea/s/f{i:02}.dat"));
                let mut fh = mount.open(&p, OpenMode::Write).expect("open");
                fh.pwrite_all(&payload, 0).expect("write");
            }
            mount.sync_mgmt().expect("drain");
            let drain_s = t0.elapsed().as_secs_f64();
            let (fl, ev) = mount.mgmt_counters();
            assert_eq!((fl, ev), (SCALE_FILES as u64, SCALE_FILES as u64));
            let peaks = mount.flush_member_peaks().unwrap_or_default();
            assert!(peaks.iter().all(|&p| p <= per_member), "gate violated: {peaks:?}");
            h.record(
                &format!("flush_scaling_w{workers}_m{per_member}"),
                vec![drain_s],
                format!("member peaks {peaks:?}"),
            );
            grid.push((workers, per_member, drain_s, peaks));
            let _ = std::fs::remove_dir_all(&root);
        }
    }
    let mut json = String::from("{\n  \"target\": \"vfs/flush_scaling\",\n");
    json.push_str(&format!(
        "  \"members\": {MEMBERS},\n  \"files\": {SCALE_FILES},\n  \"file_kib\": {SCALE_KIB},\n  \"grid\": [\n"
    ));
    for (i, (w, m, s, peaks)) in grid.iter().enumerate() {
        let peaks_json = peaks
            .iter()
            .map(|p| p.to_string())
            .collect::<Vec<_>>()
            .join(", ");
        json.push_str(&format!(
            "    {{\"workers\": {w}, \"per_member\": {m}, \"drain_s\": {s:.6}, \"member_peaks\": [{peaks_json}]}}{}\n",
            if i + 1 == grid.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_flush_scaling.json", &json) {
        Ok(()) => println!("wrote BENCH_flush_scaling.json ({} combos)", grid.len()),
        Err(e) => eprintln!("bench: could not write BENCH_flush_scaling.json: {e}"),
    }

    // engine comparison: a hot streaming writer over a small device with
    // cold resident files — the paper engine spills the writer itself;
    // the temperature engine spills the cold residents (the writer stays
    // on the fast device) and promotes them back once space frees.
    // Emits BENCH_engine_compare.json.
    let mut engine_rows: Vec<(&str, f64, sea::vfs::MgmtCounters)> = Vec::new();
    for kind in [EngineKind::Paper, EngineKind::Temperature] {
        let root = work.join(format!("engine_{}", kind.name()));
        let pfs = Arc::new(RealFs::new(root.join("pfs")).expect("pfs"));
        let mount = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("dev0"), 0, 8 * MIB).expect("dev")],
            pfs,
            max_file_size: MIB,
            parallel_procs: 4,
            rules: RuleSet::default(), // keep-all: residency managed by pressure
            seed: 7,
            tuning: SeaTuning { engine: kind, ..SeaTuning::default() },
        })
        .expect("mount");
        let t0 = std::time::Instant::now();
        for i in 0..4u8 {
            mount
                .write(Path::new(&format!("/sea/cold{i}.dat")), &vec![i; MIB as usize])
                .expect("cold");
        }
        {
            let mut f = mount
                .open(Path::new("/sea/hot.dat"), OpenMode::Write)
                .expect("hot");
            let chunk = vec![9u8; 256 * KIB as usize];
            for k in 0..32u64 {
                f.pwrite_all(&chunk, k * 256 * KIB).expect("stream");
            }
        }
        for i in 0..4u8 {
            // re-heat the spilled/resident cold files
            let _ = mount.read(Path::new(&format!("/sea/cold{i}.dat"))).expect("reheat");
        }
        mount.unlink(Path::new("/sea/hot.dat")).expect("unlink");
        mount.sync_mgmt().expect("drain");
        let elapsed = t0.elapsed().as_secs_f64();
        let c = mount.counters();
        match kind {
            EngineKind::Paper => {
                assert!(
                    c.self_spills >= 1 && c.victim_spills == 0 && c.promotions == 0,
                    "paper engine spills the writer: {c:?}"
                );
            }
            EngineKind::Temperature => {
                assert!(c.victim_spills >= 1, "temperature picks victims: {c:?}");
                assert!(c.promotions >= 1, "freed space promotes: {c:?}");
            }
        }
        h.record(
            &format!("engine_compare_{}", kind.name()),
            vec![elapsed],
            format!(
                "spills self {} victim {} promotions {}",
                c.self_spills, c.victim_spills, c.promotions
            ),
        );
        engine_rows.push((kind.name(), elapsed, c));
        let _ = std::fs::remove_dir_all(&root);
    }
    let mut ejson = String::from("{\n  \"target\": \"vfs/engine_compare\",\n  \"engines\": [\n");
    for (i, (name, s, c)) in engine_rows.iter().enumerate() {
        ejson.push_str(&format!(
            "    {{\"engine\": \"{name}\", \"elapsed_s\": {s:.6}, \"flushes\": {}, \
             \"evictions\": {}, \"self_spills\": {}, \"victim_spills\": {}, \
             \"promotions\": {}}}{}\n",
            c.flushes,
            c.evictions,
            c.self_spills,
            c.victim_spills,
            c.promotions,
            if i + 1 == engine_rows.len() { "" } else { "," }
        ));
    }
    ejson.push_str("  ]\n}\n");
    match std::fs::write("BENCH_engine_compare.json", &ejson) {
        Ok(()) => println!("wrote BENCH_engine_compare.json ({} engines)", engine_rows.len()),
        Err(e) => eprintln!("bench: could not write BENCH_engine_compare.json: {e}"),
    }

    let results = h.finish();
    // derive the per-op interception overhead from the 4k pair
    let mean = |name: &str| {
        results
            .iter()
            .find(|r| r.name.ends_with(name))
            .map(|r| r.summary().mean)
            .unwrap_or(f64::NAN)
    };
    let overhead =
        (mean("seafs_write_4k_x200") - mean("realfs_write_4k_x200")) / N as f64 * 1e6;
    println!("\nper-write interception overhead (4k): {overhead:.2} µs");
    let _ = std::fs::remove_dir_all(&work);
}
