//! Interception overhead: the paper claims glibc interception cost is
//! "minimal, and negligible compared to system call interception and
//! file systems such as FUSE". Measure the library-level analogue —
//! SeaFs path translation + registry vs a plain RealFs — per operation,
//! plus the handle API's partial-read path (64 KiB strides from 1 MiB
//! blocks), the flush pool's concurrent drain throughput, the
//! streaming DataMover (streamed-vs-wholefile sweep over file size ×
//! chunk_bytes × copy_window, emitting `BENCH_datamover.json`), the
//! PageCache (mapped-vs-pread sweep over page size × budget on a
//! rate-limited striped PFS, emitting `BENCH_pagecache.json`), the
//! cold-tier codec stage (on/off × corpus × chunk size, emitting
//! `BENCH_compress.json`), and the service transport (the same mount
//! pread in-process, over the `sea serve` wire, and through an
//! `SCM_RIGHTS` fd lease, plus pipelined-vs-serialized handles on one
//! connection, emitting `BENCH_remote.json`), and the observability
//! layer itself (histogram-enabled vs -disabled pread overhead plus a
//! traced flush/spill workload, emitting `BENCH_obs.json`; every sweep
//! row also carries per-combo latency percentiles diffed from the
//! `sea::obs` histograms, and `SEA_TRACE=FILE` dumps the flight
//! recorder as Chrome trace JSON on the way out).
//!
//! `SEA_BENCH_SMOKE=1` runs only the tiny DataMover + PageCache +
//! compress + remote + obs sweeps — the CI smoke invocation that keeps
//! the bench harness compiling and running and asserts the histogram
//! overhead bound.

mod common;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use sea::bench::Harness;
use sea::placement::{EngineKind, RuleSet};
use sea::serve::{ServeCfg, Server};
use sea::util::{KIB, MIB};
use sea::vfs::{
    compress, CodecMode, CompressedReader, DataMover, DeviceSpec, MapMode, MovePath, MoverCfg,
    MoverMetrics, OpenMode, PageCache, RateLimitedFs, RealFs, RemoteFs, SeaFs, SeaFsConfig,
    SeaTuning, StripedFs, Vfs, VfsFile,
};

/// Mapped-vs-pread sweep over a rate-limited chunk-striped PFS
/// (budget × page size grid; cold pass faults, warm pass hits), plus a
/// multi-view scenario: V concurrent views of one file share frames,
/// so the fault count stays flat in V while later views ride
/// `shared_hits`. Emits `BENCH_pagecache.json`, and asserts the
/// PageCache's bounded-memory claim: peak resident bytes never exceed
/// the budget.
fn pagecache_sweep(work: &Path, h: &mut Harness, smoke: bool) {
    let file_size: u64 = if smoke { 256 * KIB } else { 8 * MIB };
    let stripe: u64 = if smoke { 32 * KIB } else { 256 * KIB };
    let member_cap = if smoke { 1e9 } else { 512.0 * MIB as f64 };
    let members: Vec<Arc<dyn Vfs>> = (0..4)
        .map(|i| {
            Arc::new(RateLimitedFs::new(
                RealFs::new(work.join(format!("pc_ost{i}"))).expect("ost"),
                member_cap,
                1e9,
            )) as Arc<dyn Vfs>
        })
        .collect();
    let pfs = StripedFs::striped(members, stripe).expect("striped");
    let payload: Vec<u8> = (0..file_size as usize).map(|k| (k % 241) as u8).collect();
    pfs.write(Path::new("blk.dat"), &payload).expect("payload");
    let stride = (64 * KIB).min(file_size / 4) as usize;
    let page_sizes: Vec<usize> = if smoke {
        vec![(16 * KIB) as usize]
    } else {
        vec![(64 * KIB) as usize, (256 * KIB) as usize]
    };
    let budgets: Vec<u64> = if smoke {
        vec![4 * 16 * KIB] // 4 pages — far below the file
    } else {
        vec![MIB, 4 * MIB]
    };
    let mut rows: Vec<(usize, u64, f64, f64, f64, u64, u64, u64, u64, (u64, u64, u64, u64))> =
        Vec::new();
    for &page in &page_sizes {
        for &budget in &budgets {
            // baseline: strided pread through a plain handle, two passes
            let t0 = Instant::now();
            {
                let mut f = pfs.open(Path::new("blk.dat"), OpenMode::Read).expect("open");
                let mut buf = vec![0u8; stride];
                for _pass in 0..2 {
                    let mut off = 0u64;
                    while off < file_size {
                        f.pread_exact(&mut buf, off).expect("pread");
                        off += stride as u64;
                    }
                }
            }
            let pread_s = t0.elapsed().as_secs_f64();
            // mapped: cold pass faults pages in, warm pass hits (or
            // re-faults when the budget is smaller than the file)
            let cache = Arc::new(PageCache::new(page, budget));
            let mut f = pfs.open(Path::new("blk.dat"), OpenMode::Read).expect("open");
            let mut view = f.map(&cache, 0, file_size, MapMode::Read).expect("map");
            let mut buf = vec![0u8; stride];
            let obs0 = sea::obs::snapshot();
            let t0 = Instant::now();
            let mut off = 0u64;
            while off < file_size {
                let n = view.read_at(&mut buf, off).expect("read_at");
                assert_eq!(n, stride);
                off += stride as u64;
            }
            let cold_s = t0.elapsed().as_secs_f64();
            let t0 = Instant::now();
            let mut off = 0u64;
            while off < file_size {
                view.read_at(&mut buf, off).expect("read_at");
                off += stride as u64;
            }
            let warm_s = t0.elapsed().as_secs_f64();
            let fill_lat = lat_delta(&obs0, sea::obs::Metric::PageFaultFill);
            let st = cache.stats();
            assert!(
                st.peak_resident_bytes <= cache.budget(),
                "peak {} exceeds budget {}",
                st.peak_resident_bytes,
                cache.budget()
            );
            h.record(
                &format!("pagecache_p{page}_b{budget}"),
                vec![cold_s],
                format!(
                    "pread {pread_s:.6}s warm {warm_s:.6}s, {} faults {} hits peak {}B",
                    st.faults, st.hits, st.peak_resident_bytes
                ),
            );
            rows.push((
                page,
                budget,
                pread_s,
                cold_s,
                warm_s,
                st.faults,
                st.hits,
                st.evictions,
                st.peak_resident_bytes,
                fill_lat,
            ));
        }
    }
    // multi-view scenario (ISSUE 6): V handles of one file mapped
    // through one cache. Frames are shared by file identity, so the
    // fault count must stay flat in V — every page faults once, and
    // each later view's pass is all shared hits.
    let mv_page = if smoke { (16 * KIB) as usize } else { (64 * KIB) as usize };
    let mut mv_rows: Vec<(usize, f64, u64, u64, u64, u64)> = Vec::new();
    for &nviews in &[1usize, 2, 4] {
        let cache = Arc::new(PageCache::new(mv_page, 4 * file_size)); // roomy budget
        let mut handles: Vec<Box<dyn VfsFile>> = (0..nviews)
            .map(|_| pfs.open(Path::new("blk.dat"), OpenMode::Read).expect("open"))
            .collect();
        let mut views = Vec::new();
        for f in handles.iter_mut() {
            views.push(f.map(&cache, 0, file_size, MapMode::Read).expect("map"));
        }
        let mut buf = vec![0u8; stride];
        let t0 = Instant::now();
        for view in views.iter_mut() {
            let mut off = 0u64;
            while off < file_size {
                view.read_at(&mut buf, off).expect("read_at");
                off += stride as u64;
            }
        }
        let passes_s = t0.elapsed().as_secs_f64();
        let st = cache.stats();
        let pages = (file_size + mv_page as u64 - 1) / mv_page as u64;
        assert_eq!(
            st.faults, pages,
            "fault count grew with the view count (frames not shared)"
        );
        if nviews > 1 {
            assert!(st.shared_hits > 0, "later views hit the first view's frames");
        }
        h.record(
            &format!("pagecache_multiview_v{nviews}"),
            vec![passes_s],
            format!(
                "{} faults {} shared_hits {} deduped",
                st.faults, st.shared_hits, st.frames_deduped
            ),
        );
        mv_rows.push((nviews, passes_s, st.faults, st.hits, st.shared_hits, st.frames_deduped));
    }
    let mut json = String::from("{\n  \"target\": \"vfs/pagecache\",\n");
    json.push_str(&format!(
        "  \"file_bytes\": {file_size},\n  \"stripe_bytes\": {stripe},\n  \"members\": 4,\n  \"sweep\": [\n"
    ));
    for (i, (page, budget, pread_s, cold_s, warm_s, faults, hits, ev, peak, lat)) in
        rows.iter().enumerate()
    {
        json.push_str(&format!(
            "    {{\"page_bytes\": {page}, \"budget_bytes\": {budget}, \
             \"pread_s\": {pread_s:.6}, \"mapped_cold_s\": {cold_s:.6}, \
             \"mapped_warm_s\": {warm_s:.6}, \"faults\": {faults}, \"hits\": {hits}, \
             \"evictions\": {ev}, \"peak_resident_bytes\": {peak}, {}}}{}\n",
            lat_json("fill", *lat),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str(&format!("  ],\n  \"multiview_page_bytes\": {mv_page},\n  \"multiview\": [\n"));
    for (i, (v, s, faults, hits, shared, deduped)) in mv_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"views\": {v}, \"passes_s\": {s:.6}, \"faults\": {faults}, \
             \"hits\": {hits}, \"shared_hits\": {shared}, \"frames_deduped\": {deduped}}}{}\n",
            if i + 1 == mv_rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_pagecache.json", &json) {
        Ok(()) => println!("wrote BENCH_pagecache.json ({} combos)", rows.len()),
        Err(e) => eprintln!("bench: could not write BENCH_pagecache.json: {e}"),
    }
}

/// Streamed-vs-wholefile sweep: the same bytes moved (a) as one
/// whole-file `Vec` (the seed's management path) and (b) through the
/// DataMover at each (chunk_bytes × copy_window) combo, plus an
/// OST-fan-out case over a chunk-striped PFS with per-member bandwidth
/// caps. Emits `BENCH_datamover.json`.
fn datamover_sweep(work: &Path, h: &mut Harness, smoke: bool) {
    let sizes: Vec<u64> = if smoke { vec![768 * KIB] } else { vec![4 * MIB, 32 * MIB] };
    let chunks: Vec<usize> = if smoke {
        vec![(64 * KIB) as usize]
    } else {
        vec![(256 * KIB) as usize, MIB as usize]
    };
    let windows: Vec<usize> = if smoke { vec![2] } else { vec![1, 2, 4] };
    let src_fs = RealFs::new(work.join("dm_src")).expect("src");
    let dst_fs = RealFs::new(work.join("dm_dst")).expect("dst");
    let mut rows: Vec<(u64, usize, usize, f64, f64, u64, (u64, u64, u64, u64))> = Vec::new();
    for &size in &sizes {
        let name = format!("f{size}.dat");
        src_fs
            .write(Path::new(&name), &vec![0x5Au8; size as usize])
            .expect("payload");
        // legacy path: whole-file materialization (one Vec of `size`)
        let t0 = Instant::now();
        let data = src_fs.read(Path::new(&name)).expect("read");
        dst_fs.write(Path::new("whole.dat"), &data).expect("write");
        drop(data);
        let whole_s = t0.elapsed().as_secs_f64();
        for &chunk in &chunks {
            for &window in &windows {
                let metrics = MoverMetrics::default();
                let mut src = src_fs.open(Path::new(&name), OpenMode::Read).expect("open");
                let mut dst = dst_fs
                    .open(Path::new("streamed.dat"), OpenMode::Write)
                    .expect("open");
                let obs0 = sea::obs::snapshot();
                let t0 = Instant::now();
                let n = DataMover::new(
                    MoverCfg { chunk_bytes: chunk, copy_window: window, ..MoverCfg::default() },
                    MovePath::Flush,
                )
                .with_metrics(&metrics)
                .copy(src.as_mut(), dst.as_mut(), size)
                .expect("copy");
                let streamed_s = t0.elapsed().as_secs_f64();
                let chunk_lat = lat_delta(&obs0, sea::obs::Metric::MoverChunk);
                assert_eq!(n, size);
                let peak = metrics.peak_buffer_bytes();
                assert!(
                    peak <= (chunk * window) as u64,
                    "window breached: peak {peak} > {chunk} x {window}"
                );
                h.record(
                    &format!("datamover_{size}b_c{chunk}_w{window}"),
                    vec![streamed_s],
                    format!("wholefile {whole_s:.6}s, peak buffers {peak}B"),
                );
                rows.push((size, chunk, window, whole_s, streamed_s, peak, chunk_lat));
            }
        }
    }
    // OST fan-out: one large file against a chunk-striped PFS whose
    // members are individually rate-limited — stripe-aligned chunks
    // round-robin the members, so the streamed copy aggregates their
    // write bandwidth instead of queuing on one
    let fan_size: u64 = if smoke { 512 * KIB } else { 8 * MIB };
    let fan_stripe: u64 = if smoke { 64 * KIB } else { 256 * KIB };
    let member_cap = if smoke { 1e9 } else { 64.0 * MIB as f64 };
    let members: Vec<Arc<dyn Vfs>> = (0..4)
        .map(|i| {
            Arc::new(RateLimitedFs::new(
                RealFs::new(work.join(format!("dm_ost{i}"))).expect("ost"),
                1e9,
                member_cap,
            )) as Arc<dyn Vfs>
        })
        .collect();
    let striped = StripedFs::striped(members, fan_stripe).expect("striped");
    src_fs
        .write(Path::new("fan.dat"), &vec![1u8; fan_size as usize])
        .expect("fan payload");
    let cfg = MoverCfg { chunk_bytes: MIB as usize, copy_window: 2, ..MoverCfg::default() }
        .aligned_to(striped.stripe_bytes());
    let mut src = src_fs.open(Path::new("fan.dat"), OpenMode::Read).expect("open");
    let mut dst = striped.open(Path::new("fan.dat"), OpenMode::Write).expect("open");
    let t0 = Instant::now();
    let n = DataMover::new(cfg, MovePath::Flush)
        .copy(src.as_mut(), dst.as_mut(), fan_size)
        .expect("fan copy");
    let fan_s = t0.elapsed().as_secs_f64();
    assert_eq!(n, fan_size);
    assert_eq!(striped.read(Path::new("fan.dat")).expect("fan read").len(), fan_size as usize);
    h.record(
        "datamover_striped_fanout",
        vec![fan_s],
        format!("{fan_size}B over 4 members, stripe {fan_stripe}B"),
    );
    let mut json = String::from("{\n  \"target\": \"vfs/datamover\",\n  \"sweep\": [\n");
    for (i, (size, chunk, window, whole_s, streamed_s, peak, lat)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"file_bytes\": {size}, \"chunk_bytes\": {chunk}, \"copy_window\": {window}, \
             \"wholefile_s\": {whole_s:.6}, \"streamed_s\": {streamed_s:.6}, \
             \"peak_buffer_bytes\": {peak}, {}}}{}\n",
            lat_json("chunk", *lat),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str(&format!(
        "  ],\n  \"striped_fanout\": {{\"file_bytes\": {fan_size}, \"stripe_bytes\": {fan_stripe}, \
         \"members\": 4, \"streamed_s\": {fan_s:.6}}}\n}}\n"
    ));
    match std::fs::write("BENCH_datamover.json", &json) {
        Ok(()) => println!("wrote BENCH_datamover.json ({} combos + fanout)", rows.len()),
        Err(e) => eprintln!("bench: could not write BENCH_datamover.json: {e}"),
    }
}

/// Codec-stage sweep: the same bytes moved with the codec off and on
/// (level 1 / 3), over a compressible and an incompressible corpus,
/// into a rate-limited chunk-striped PFS — the shape a flush or spill
/// sees. Measures wall time and physical bytes written, verifies every
/// destination reads back byte-identical (decoding through the frame
/// index when a container was written), and emits `BENCH_compress.json`.
fn compress_sweep(work: &Path, h: &mut Harness, smoke: bool) {
    let size: u64 = if smoke { 768 * KIB } else { 8 * MIB };
    let chunks: Vec<usize> = if smoke {
        vec![(64 * KIB) as usize]
    } else {
        vec![(256 * KIB) as usize, MIB as usize]
    };
    let codecs: Vec<(&str, CodecMode)> = if smoke {
        vec![
            ("off", CodecMode::Off),
            ("lz_l1", CodecMode::Encode { level: 1, min_ratio_pct: 100 }),
        ]
    } else {
        vec![
            ("off", CodecMode::Off),
            ("lz_l1", CodecMode::Encode { level: 1, min_ratio_pct: 100 }),
            ("lz_l3", CodecMode::Encode { level: 3, min_ratio_pct: 100 }),
        ]
    };
    let src_fs = RealFs::new(work.join("cz_src")).expect("src");
    let stripe: u64 = if smoke { 64 * KIB } else { 256 * KIB };
    let member_cap = if smoke { 1e9 } else { 128.0 * MIB as f64 };
    let members: Vec<Arc<dyn Vfs>> = (0..4)
        .map(|i| {
            Arc::new(RateLimitedFs::new(
                RealFs::new(work.join(format!("cz_ost{i}"))).expect("ost"),
                1e9,
                member_cap,
            )) as Arc<dyn Vfs>
        })
        .collect();
    let dst_fs = StripedFs::striped(members, stripe).expect("striped");
    // banded bytes squeeze hard; an LCG stream does not compress at all
    let mut lcg = 0x9E37_79B9u64;
    let corpora: Vec<(&str, Vec<u8>)> = vec![
        ("compressible", (0..size as usize).map(|k| (k / 1024) as u8).collect()),
        (
            "incompressible",
            (0..size as usize)
                .map(|_| {
                    lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    (lcg >> 33) as u8
                })
                .collect(),
        ),
    ];
    let mut rows: Vec<(String, usize, String, f64, u64, (u64, u64, u64, u64))> = Vec::new();
    for (label, data) in &corpora {
        let name = format!("{label}.dat");
        src_fs.write(Path::new(&name), data).expect("payload");
        for &chunk in &chunks {
            for (cname, codec) in &codecs {
                let out = format!("{label}_{cname}_c{chunk}.dat");
                let metrics = MoverMetrics::default();
                let mut src = src_fs.open(Path::new(&name), OpenMode::Read).expect("open");
                let mut dst = dst_fs.open(Path::new(&out), OpenMode::Write).expect("open");
                let cfg = MoverCfg { chunk_bytes: chunk, copy_window: 2, codec: *codec }
                    .aligned_to(dst_fs.stripe_bytes());
                let obs0 = sea::obs::snapshot();
                let t0 = Instant::now();
                let (n, phys) = DataMover::new(cfg, MovePath::Flush)
                    .with_metrics(&metrics)
                    .copy_counted(src.as_mut(), dst.as_mut(), size)
                    .expect("copy");
                let wall_s = t0.elapsed().as_secs_f64();
                let chunk_lat = lat_delta(&obs0, sea::obs::Metric::MoverChunk);
                assert_eq!(n, size);
                // every destination reads back byte-identical
                let mut f = dst_fs.open(Path::new(&out), OpenMode::Read).expect("open");
                let mut reader: Box<dyn VfsFile> =
                    match compress::probe(f.as_mut()).expect("probe") {
                        Some(meta) => Box::new(CompressedReader::new(f, meta)),
                        None => f,
                    };
                let mut got = vec![0u8; size as usize];
                let mut done = 0usize;
                while done < got.len() {
                    let r = reader.pread(&mut got[done..], done as u64).expect("pread");
                    assert!(r > 0, "read stalled at {done}");
                    done += r;
                }
                assert_eq!(&got, data, "{out} corrupted");
                match codec {
                    CodecMode::Off => assert_eq!(phys, size),
                    CodecMode::Encode { .. } => {
                        // worst case: store frames + index + trailer
                        // (cfg.chunk_bytes: aligned_to may have widened it)
                        let fchunk = cfg.chunk_bytes as u64;
                        let frames = (size.max(1) + fchunk - 1) / fchunk;
                        assert!(
                            phys <= size + frames * (13 + 16) + 44,
                            "{out}: passthrough overhead {phys} vs {size}"
                        );
                        if *label == "compressible" {
                            assert!(phys < size / 2, "{out}: no shrink ({phys})");
                        }
                    }
                }
                h.record(
                    &format!("compress_{label}_{cname}_c{chunk}"),
                    vec![wall_s],
                    format!("{size}B logical, {phys}B physical"),
                );
                rows.push((label.to_string(), chunk, cname.to_string(), wall_s, phys, chunk_lat));
            }
        }
    }
    let mut json = String::from("{\n  \"target\": \"vfs/compress\",\n");
    json.push_str(&format!(
        "  \"file_bytes\": {size},\n  \"stripe_bytes\": {stripe},\n  \"members\": 4,\n  \"sweep\": [\n"
    ));
    for (i, (label, chunk, cname, wall_s, phys, lat)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"corpus\": \"{label}\", \"chunk_bytes\": {chunk}, \"codec\": \"{cname}\", \
             \"wall_s\": {wall_s:.6}, \"logical_bytes\": {size}, \"physical_bytes\": {phys}, \
             {}}}{}\n",
            lat_json("chunk", *lat),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_compress.json", &json) {
        Ok(()) => println!("wrote BENCH_compress.json ({} combos)", rows.len()),
        Err(e) => eprintln!("bench: could not write BENCH_compress.json: {e}"),
    }
}

/// Service-transport sweep: one Sea mount pread three ways — in-process
/// (library calls), through a `sea serve` daemon with fd leases
/// disabled (every pread is a framed round trip on the Unix socket),
/// and through the default daemon where a read-only open of the
/// tier-0-resident file hands back an `SCM_RIGHTS` fd lease and every
/// pread becomes a local `pread(2)`. Same offsets, same sizes
/// {4 KiB, 64 KiB, 1 MiB}. A fourth scenario measures the pipelined
/// wire protocol: the same total op count issued serially on one
/// handle vs concurrently on 8 handles multiplexed over one
/// connection. Emits `BENCH_remote.json`.
///
/// Under `SEA_BENCH_SMOKE=1` the sweep doubles as the data-plane
/// acceptance gate: leased preads must land within 1.5x of in-process
/// reads, the 8-way pipelined run must beat the serialized one, and
/// the daemon must have observed overlapping in-flight ops.
fn remote_sweep(work: &Path, h: &mut Harness, smoke: bool) {
    let root = work.join("remote");
    let file_size: u64 = 2 * MIB;
    let reps: usize = if smoke { 8 } else { 64 };
    let pfs = Arc::new(RealFs::new(root.join("pfs")).expect("pfs"));
    let sea = Arc::new(
        SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("dev0"), 0, 64 * MIB).expect("dev")],
            pfs,
            // keep the served file resident on the tier-0 device so the
            // default daemon can lease its fd
            max_file_size: 4 * MIB,
            parallel_procs: 1,
            rules: RuleSet::default(),
            seed: 3,
            tuning: SeaTuning::default(),
        })
        .expect("mount"),
    );
    let payload: Vec<u8> = (0..file_size as usize).map(|k| (k % 251) as u8).collect();
    sea.write(Path::new("/sea/served.dat"), &payload).expect("payload");

    // Two daemons over the same mount: the default one leases read fds,
    // the other pins every read to the wire (`--no-leases`).
    let sock_lease = root.join("bench_lease.sock");
    let sock_wire = root.join("bench_wire.sock");
    let srv_lease = Server::spawn(sea.clone(), ServeCfg::new(&sock_lease)).expect("serve");
    let mut wire_cfg = ServeCfg::new(&sock_wire);
    wire_cfg.lease_fds = false;
    let srv_wire = Server::spawn(sea.clone(), wire_cfg).expect("serve");
    let leased = RemoteFs::connect(&sock_lease).expect("connect leased");
    let wire = RemoteFs::connect(&sock_wire).expect("connect wire");

    let sizes: [u64; 3] = [4 * KIB, 64 * KIB, MIB];
    let mut rows: Vec<(u64, f64, f64, f64, (u64, u64, u64, u64))> = Vec::new();
    for &size in &sizes {
        let mut buf = vec![0u8; size as usize];
        let span = file_size - size; // keep every pread in-bounds
        let off_at = |i: usize| (i as u64 * size) % (span + 1);
        // in-process: straight through the library
        let mut f = sea.open(Path::new("/sea/served.dat"), OpenMode::Read).expect("open");
        let t0 = Instant::now();
        for i in 0..reps {
            f.pread_exact(&mut buf, off_at(i)).expect("local pread");
        }
        let inproc_s = t0.elapsed().as_secs_f64();
        // wire: identical preads, each a framed round trip
        let mut rf = wire
            .open(Path::new("/sea/served.dat"), OpenMode::Read)
            .expect("wire open");
        let obs0 = sea::obs::snapshot();
        let t0 = Instant::now();
        for i in 0..reps {
            rf.pread_exact(&mut buf, off_at(i)).expect("wire pread");
        }
        let wire_s = t0.elapsed().as_secs_f64();
        let wire_lat = lat_delta(&obs0, sea::obs::Metric::WireRtt);
        // leased: identical preads served by pread(2) on the leased fd
        let mut lf = leased
            .open_remote(Path::new("/sea/served.dat"), OpenMode::Read)
            .expect("leased open");
        assert!(lf.has_lease(), "read-only open of a resident file should carry a lease");
        let t0 = Instant::now();
        for i in 0..reps {
            lf.pread_exact(&mut buf, off_at(i)).expect("leased pread");
        }
        let leased_s = t0.elapsed().as_secs_f64();
        h.record(
            &format!("remote_pread_{size}b"),
            vec![wire_s],
            format!("inprocess {inproc_s:.6}s, leased {leased_s:.6}s over {reps} preads"),
        );
        if smoke {
            // Acceptance bound: a leased pread is a pread(2) plus a
            // little bookkeeping, so it must stay within 1.5x of the
            // in-process path (+1 ms of timer slack — smoke reps are
            // tiny and both sides sit near clock granularity).
            assert!(
                leased_s <= inproc_s * 1.5 + 1e-3,
                "leased preads ({leased_s:.6}s) exceed 1.5x in-process \
                 ({inproc_s:.6}s) at {size}b"
            );
        }
        rows.push((size, inproc_s, wire_s, leased_s, wire_lat));
    }

    // Pipelining: the same 8 x ops 64 KiB scattered preads issued two
    // ways through the wire daemon — one handle, one round trip at a
    // time, vs 8 handles multiplexed over the one shared connection
    // with their requests in flight concurrently.
    let ops: usize = if smoke { 32 } else { 256 };
    let psize = 64 * KIB;
    let pages = file_size / psize;
    let mut sf = wire
        .open(Path::new("/sea/served.dat"), OpenMode::Read)
        .expect("serial open");
    let mut pbuf = vec![0u8; psize as usize];
    let t0 = Instant::now();
    for i in 0..8 * ops {
        let off = ((i as u64 * 37) % pages) * psize;
        sf.pread_exact(&mut pbuf, off).expect("serial pread");
    }
    let serial_s = t0.elapsed().as_secs_f64();
    let handles: Vec<_> = (0..8)
        .map(|_| {
            wire.open_remote(Path::new("/sea/served.dat"), OpenMode::Read)
                .expect("mux open")
        })
        .collect();
    let t0 = Instant::now();
    let threads: Vec<_> = handles
        .into_iter()
        .enumerate()
        .map(|(t, mut fh)| {
            std::thread::spawn(move || {
                let mut b = vec![0u8; psize as usize];
                for k in 0..ops {
                    let off = (((k * 37 + t * 101) as u64) % pages) * psize;
                    fh.pread_exact(&mut b, off).expect("mux pread");
                }
            })
        })
        .collect();
    for th in threads {
        th.join().expect("mux thread");
    }
    let pipelined_s = t0.elapsed().as_secs_f64();
    h.record(
        "remote_pipeline_8x",
        vec![pipelined_s],
        format!("serialized {serial_s:.6}s for the same {} preads", 8 * ops),
    );
    let wire_counters = wire.counters().expect("wire counters");
    let lease_counters = leased.counters().expect("lease counters");
    if smoke {
        assert!(
            pipelined_s < serial_s,
            "8 pipelined handles ({pipelined_s:.6}s) should beat one serialized \
             handle ({serial_s:.6}s)"
        );
        assert!(
            wire_counters.inflight_peak >= 2,
            "the mux run should overlap requests on one connection \
             (inflight_peak = {})",
            wire_counters.inflight_peak
        );
        assert!(
            lease_counters.leases_granted >= sizes.len() as u64,
            "every leased open should have been granted a lease \
             (leases_granted = {})",
            lease_counters.leases_granted
        );
    }
    drop(sf);
    drop(leased);
    drop(wire);
    srv_lease.shutdown().expect("shutdown");
    srv_wire.shutdown().expect("shutdown");

    let mut json = String::from("{\n  \"target\": \"serve/remote\",\n");
    json.push_str(&format!(
        "  \"file_bytes\": {file_size},\n  \"preads_per_size\": {reps},\n  \"sweep\": [\n"
    ));
    for (i, (size, inproc_s, wire_s, leased_s, lat)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"pread_bytes\": {size}, \"inprocess_s\": {inproc_s:.6}, \
             \"wire_s\": {wire_s:.6}, \"leased_s\": {leased_s:.6}, {}}}{}\n",
            lat_json("wire", *lat),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"pipeline\": {{\"handles\": 8, \"preads\": {}, \"pread_bytes\": {psize}, \
         \"serialized_s\": {serial_s:.6}, \"pipelined_s\": {pipelined_s:.6}, \
         \"inflight_peak\": {}}},\n",
        8 * ops,
        wire_counters.inflight_peak
    ));
    json.push_str(&format!(
        "  \"leases_granted\": {}\n}}\n",
        lease_counters.leases_granted
    ));
    match std::fs::write("BENCH_remote.json", &json) {
        Ok(()) => println!("wrote BENCH_remote.json ({} sizes)", rows.len()),
        Err(e) => eprintln!("bench: could not write BENCH_remote.json: {e}"),
    }
}

/// Per-combo latency percentiles from the obs histograms: diff the
/// registry against a snapshot taken before the combo and return
/// `(n, p50, p95, p99)` nanoseconds for `metric` (zeros when nothing
/// was recorded — e.g. histograms disabled).
fn lat_delta(before: &sea::obs::ObsSnapshot, metric: sea::obs::Metric) -> (u64, u64, u64, u64) {
    let d = sea::obs::snapshot().diff(before);
    match d.get(metric) {
        Some(h) => (h.count, h.p50(), h.p95(), h.p99()),
        None => (0, 0, 0, 0),
    }
}

/// JSON fragment for one [`lat_delta`] quad, prefixed `"{key}_..."`.
fn lat_json(key: &str, q: (u64, u64, u64, u64)) -> String {
    format!(
        "\"{key}_n\": {}, \"{key}_p50_ns\": {}, \"{key}_p95_ns\": {}, \"{key}_p99_ns\": {}",
        q.0, q.1, q.2, q.3
    )
}

/// Histogram-overhead sweep (the observability acceptance gate): the
/// same strided 64 KiB pread workload through a Sea mount with latency
/// histograms enabled vs disabled, min-of-reps; under
/// `SEA_BENCH_SMOKE=1` the enabled run must stay within 5% of the
/// disabled one (+5 ms of timer slack for clock granularity). Also
/// runs a tiny flush-then-spill management workload so a `SEA_TRACE`'d
/// bench run captures full lifecycles in its dump. Emits
/// `BENCH_obs.json` with wall-time percentiles of both modes and the
/// enabled run's per-metric latency percentiles.
fn obs_sweep(work: &Path, h: &mut Harness, smoke: bool) {
    let root = work.join("obs");
    let file_size: u64 = 2 * MIB;
    let reps: usize = if smoke { 5 } else { 9 };
    let passes: usize = if smoke { 4 } else { 16 };
    let pfs = Arc::new(RealFs::new(root.join("pfs")).expect("pfs"));
    let sea = SeaFs::mount(SeaFsConfig {
        mountpoint: PathBuf::from("/sea"),
        devices: vec![DeviceSpec::dir(root.join("dev0"), 0, 64 * MIB).expect("dev")],
        pfs,
        max_file_size: 4 * MIB,
        parallel_procs: 1,
        rules: RuleSet::default(),
        seed: 5,
        tuning: SeaTuning::default(),
    })
    .expect("mount");
    let payload: Vec<u8> = (0..file_size as usize).map(|k| (k % 249) as u8).collect();
    sea.write(Path::new("/sea/obs.dat"), &payload).expect("payload");
    let stride = (64 * KIB) as usize;
    let time_mode = |on: bool| -> Vec<f64> {
        sea::obs::set_enabled(on);
        let mut f = sea.open(Path::new("/sea/obs.dat"), OpenMode::Read).expect("open");
        let mut buf = vec![0u8; stride];
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            for _pass in 0..passes {
                let mut off = 0u64;
                while off < file_size {
                    f.pread_exact(&mut buf, off).expect("pread");
                    off += stride as u64;
                }
            }
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples
    };
    let _ = time_mode(false); // warm both the page cache and the code path
    let off_samples = time_mode(false);
    sea::obs::reset();
    let empty = sea::obs::snapshot();
    let on_samples = time_mode(true);
    let pread_lat = lat_delta(&empty, sea::obs::Metric::PreadTier0);
    let snap = sea::obs::snapshot();
    sea::obs::set_enabled(true); // later sweeps emit their percentiles
    let min_of = |v: &[f64]| v.iter().cloned().fold(f64::INFINITY, f64::min);
    let (off_s, on_s) = (min_of(&off_samples), min_of(&on_samples));
    assert!(
        pread_lat.0 >= (reps * passes * (file_size as usize / stride)) as u64,
        "enabled run must have recorded every pread ({} samples)",
        pread_lat.0
    );
    h.record(
        "obs_pread_64k_hist_on",
        on_samples.clone(),
        format!("disabled min {off_s:.6}s"),
    );
    h.record("obs_pread_64k_hist_off", off_samples.clone(), String::new());
    if smoke {
        // Acceptance bound: recording is 4 relaxed atomic RMWs + two
        // clock reads per op, so the enabled path must stay within 5%
        // of the disabled one (+5 ms slack — smoke runs sit near
        // clock granularity).
        assert!(
            on_s <= off_s * 1.05 + 5e-3,
            "histogram overhead breached 5%: enabled {on_s:.6}s vs disabled {off_s:.6}s"
        );
    }
    // a tiny flush-then-spill management workload: under SEA_TRACE the
    // dump then covers both lifecycles end to end
    let mroot = work.join("obs_mgmt");
    let mpfs = Arc::new(RealFs::new(mroot.join("pfs")).expect("pfs"));
    let mgmt = SeaFs::mount(SeaFsConfig {
        mountpoint: PathBuf::from("/sea"),
        devices: vec![DeviceSpec::dir(mroot.join("dev0"), 0, 2 * MIB).expect("dev")],
        pfs: mpfs,
        max_file_size: MIB,
        parallel_procs: 1,
        rules: RuleSet::from_texts("**_final.dat", "**_final.dat", ""),
        seed: 5,
        tuning: SeaTuning::default(),
    })
    .expect("mount");
    mgmt.write(Path::new("/sea/a_final.dat"), &vec![1u8; (512 * KIB) as usize])
        .expect("flush payload");
    mgmt.sync_mgmt().expect("flush drain"); // flush + evict: device empties
    {
        // a streaming writer overruns the 2 MiB device mid-write, so
        // management must spill to make room (placement fallback alone
        // would never record a spill lifecycle)
        let mut f = mgmt.open(Path::new("/sea/hot.dat"), OpenMode::Write).expect("hot");
        let chunk = vec![9u8; (256 * KIB) as usize];
        for k in 0..12u64 {
            f.pwrite_all(&chunk, k * 256 * KIB).expect("stream");
        }
    }
    mgmt.sync_mgmt().expect("spill drain");
    let mc = mgmt.counters();
    assert!(mc.flushes >= 1, "mgmt workload must flush: {mc:?}");
    assert!(
        mc.self_spills + mc.victim_spills >= 1,
        "mgmt workload must spill: {mc:?}"
    );

    let off_sum = sea::util::Summary::of(&off_samples).expect("samples");
    let on_sum = sea::util::Summary::of(&on_samples).expect("samples");
    let mut json = String::from("{\n  \"target\": \"vfs/obs\",\n");
    json.push_str(&format!(
        "  \"file_bytes\": {file_size},\n  \"stride_bytes\": {stride},\n  \
         \"passes\": {passes},\n  \"reps\": {reps},\n"
    ));
    json.push_str(&format!(
        "  \"overhead\": {{\"off_min_s\": {off_s:.6}, \"on_min_s\": {on_s:.6}, \
         \"on_over_off\": {:.4}, \"off_p95_s\": {:.6}, \"off_p99_s\": {:.6}, \
         \"on_p95_s\": {:.6}, \"on_p99_s\": {:.6}}},\n",
        on_s / off_s.max(1e-12),
        off_sum.p95,
        off_sum.p99,
        on_sum.p95,
        on_sum.p99
    ));
    json.push_str("  \"latency_ns\": [\n");
    for (i, (idx, hs)) in snap.metrics.iter().enumerate() {
        let name = sea::obs::Metric::from_index(*idx as usize)
            .map(|m| m.name().to_string())
            .unwrap_or_else(|| format!("metric#{idx}"));
        json.push_str(&format!(
            "    {{\"metric\": \"{name}\", \"n\": {}, \"p50\": {}, \"p95\": {}, \
             \"p99\": {}, \"max\": {}}}{}\n",
            hs.count,
            hs.p50(),
            hs.p95(),
            hs.p99(),
            hs.max,
            if i + 1 == snap.metrics.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_obs.json", &json) {
        Ok(()) => println!("wrote BENCH_obs.json ({} metrics)", snap.metrics.len()),
        Err(e) => eprintln!("bench: could not write BENCH_obs.json: {e}"),
    }
}

/// `SEA_TRACE=FILE` support for bench runs: dump the flight recorder
/// on the way out (both the smoke and full paths).
fn dump_trace(path: &Option<PathBuf>) {
    if let Some(p) = path {
        match sea::obs::trace::dump_to(p) {
            Ok(n) => println!("wrote {} ({n} trace events)", p.display()),
            Err(e) => eprintln!("bench: could not write {}: {e}", p.display()),
        }
    }
}

fn main() {
    let work = std::env::temp_dir().join("sea_bench_vfs");
    let _ = std::fs::remove_dir_all(&work);
    let trace_path = std::env::var("SEA_TRACE").ok().map(PathBuf::from);
    if trace_path.is_some() {
        sea::obs::trace::set_enabled(true);
    }
    // histograms default on (SEA_OBS), but make it explicit: every
    // sweep's JSON carries percentile fields derived from them
    sea::obs::set_enabled(true);
    if std::env::var("SEA_BENCH_SMOKE").is_ok() {
        // CI smoke: tiny DataMover + PageCache + codec + remote + obs
        // sweeps only — proves the harness still builds, runs, emits
        // its JSON files, and keeps the histogram overhead bounded
        let mut h = Harness::new("vfs").with_reps(1, 1);
        datamover_sweep(&work, &mut h, true);
        pagecache_sweep(&work, &mut h, true);
        compress_sweep(&work, &mut h, true);
        remote_sweep(&work, &mut h, true);
        obs_sweep(&work, &mut h, true);
        let _ = h.finish();
        dump_trace(&trace_path);
        let _ = std::fs::remove_dir_all(&work);
        return;
    }
    let mut h = Harness::new("vfs").with_reps(1, 5);

    let plain = RealFs::new(work.join("plain")).expect("plain");
    let pfs = Arc::new(RealFs::new(work.join("pfs")).expect("pfs"));
    let sea = SeaFs::mount(SeaFsConfig {
        mountpoint: PathBuf::from("/sea"),
        devices: vec![DeviceSpec::dir(work.join("dev0"), 0, 4096 * MIB).expect("dev")],
        pfs,
        max_file_size: MIB,
        parallel_procs: 4,
        rules: RuleSet::default(),
        seed: 1,
        tuning: SeaTuning::default(),
    })
    .expect("mount");

    const N: usize = 200;
    let payload4k = vec![7u8; 4 * KIB as usize];
    let payload1m = vec![7u8; MIB as usize];

    h.case("realfs_write_4k_x200", || {
        for i in 0..N {
            plain.write(Path::new(&format!("w/{i}.dat")), &payload4k).unwrap();
        }
    });
    h.case("seafs_write_4k_x200", || {
        for i in 0..N {
            sea.write(Path::new(&format!("/sea/w/{i}.dat")), &payload4k).unwrap();
        }
    });
    h.case("realfs_write_1m_x200", || {
        for i in 0..N {
            plain.write(Path::new(&format!("m/{i}.dat")), &payload1m).unwrap();
        }
    });
    h.case("seafs_write_1m_x200", || {
        for i in 0..N {
            sea.write(Path::new(&format!("/sea/m/{i}.dat")), &payload1m).unwrap();
        }
    });
    h.case("realfs_read_1m_x200", || {
        for i in 0..N {
            let _ = plain.read(Path::new(&format!("m/{i}.dat"))).unwrap();
        }
    });
    h.case("seafs_read_1m_x200", || {
        for i in 0..N {
            let _ = sea.read(Path::new(&format!("/sea/m/{i}.dat"))).unwrap();
        }
    });
    h.case("seafs_stat_x200", || {
        for i in 0..N {
            let _ = sea.size(Path::new(&format!("/sea/m/{i}.dat"))).unwrap();
        }
    });

    // partial reads: 16 x 64 KiB strides from each 1 MiB block, through
    // an offset-addressed handle (no whole-file materialization)
    let strides = (MIB / (64 * KIB)) as u64;
    h.case("realfs_pread_64k_strides_x200", || {
        let mut buf = vec![0u8; 64 * KIB as usize];
        for i in 0..N {
            let mut f = plain
                .open(Path::new(&format!("m/{i}.dat")), OpenMode::Read)
                .unwrap();
            for k in 0..strides {
                f.pread_exact(&mut buf, k * 64 * KIB).unwrap();
            }
        }
    });
    h.case("seafs_pread_64k_strides_x200", || {
        let mut buf = vec![0u8; 64 * KIB as usize];
        for i in 0..N {
            let mut f = sea
                .open(Path::new(&format!("/sea/m/{i}.dat")), OpenMode::Read)
                .unwrap();
            for k in 0..strides {
                f.pread_exact(&mut buf, k * 64 * KIB).unwrap();
            }
        }
    });

    // concurrent flush: 4 writer threads x 16 Move-mode files, drained by
    // the flush pool (the seed's single daemon serialized this)
    static FLUSH_REP: AtomicU64 = AtomicU64::new(0);
    h.case("seafs_concurrent_flush_64x256k", || {
        let rep = FLUSH_REP.fetch_add(1, Ordering::Relaxed);
        let root = work.join(format!("flush_{rep}"));
        let pfs = Arc::new(RealFs::new(root.join("pfs")).expect("pfs"));
        let mount = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("dev0"), 0, 1024 * MIB).expect("dev")],
            pfs,
            max_file_size: MIB,
            parallel_procs: 4,
            rules: RuleSet::from_texts("**", "**", ""), // move everything
            seed: rep + 1,
            tuning: SeaTuning::default(),
        })
        .expect("mount");
        let mount = Arc::new(mount);
        let payload = vec![5u8; 256 * KIB as usize];
        std::thread::scope(|scope| {
            for t in 0..4 {
                let mount = mount.clone();
                let payload = &payload;
                scope.spawn(move || {
                    for i in 0..16 {
                        let p = PathBuf::from(format!("/sea/t{t}/f{i}.dat"));
                        let mut f = mount.open(&p, OpenMode::Write).unwrap();
                        f.pwrite_all(payload, 0).unwrap();
                    }
                });
            }
        });
        mount.sync_mgmt().expect("drain");
        let (fl, ev) = mount.mgmt_counters();
        assert_eq!((fl, ev), (64, 64));
        let _ = std::fs::remove_dir_all(&root);
    });

    // flush-pool scaling: workers × per-member concurrency over a
    // 4-member striped PFS (each member individually rate-limited, like
    // OSTs); measures time for the pool to drain a batch of Move-mode
    // files and emits BENCH_flush_scaling.json for curve tooling
    const MEMBERS: usize = 4;
    const SCALE_FILES: usize = 32;
    const SCALE_KIB: u64 = 256;
    let mut grid: Vec<(usize, usize, f64, Vec<usize>, (u64, u64, u64, u64))> = Vec::new();
    for &workers in &[1usize, 2, 4, 8] {
        for &per_member in &[1usize, 2, 4] {
            let root = work.join(format!("scale_w{workers}_m{per_member}"));
            let members: Vec<Arc<dyn Vfs>> = (0..MEMBERS)
                .map(|i| {
                    Arc::new(sea::vfs::RateLimitedFs::new(
                        RealFs::new(root.join(format!("ost{i}"))).expect("ost"),
                        1e9,
                        16.0 * MIB as f64, // per-member write cap
                    )) as Arc<dyn Vfs>
                })
                .collect();
            let pfs: Arc<dyn Vfs> = Arc::new(StripedFs::new(members).expect("striped"));
            let mount = SeaFs::mount(SeaFsConfig {
                mountpoint: PathBuf::from("/sea"),
                devices: vec![DeviceSpec::dir(root.join("dev0"), 0, 1024 * MIB).expect("dev")],
                pfs,
                max_file_size: MIB,
                parallel_procs: 4,
                rules: RuleSet::from_texts("**", "**", ""), // move everything
                seed: 42,
                tuning: SeaTuning {
                    flush_workers: workers,
                    registry_shards: 16,
                    per_member_concurrency: per_member,
                    ..SeaTuning::default()
                },
            })
            .expect("mount");
            let payload = vec![1u8; (SCALE_KIB * KIB) as usize];
            let obs0 = sea::obs::snapshot();
            let t0 = std::time::Instant::now();
            for i in 0..SCALE_FILES {
                let p = PathBuf::from(format!("/sea/s/f{i:02}.dat"));
                let mut fh = mount.open(&p, OpenMode::Write).expect("open");
                fh.pwrite_all(&payload, 0).expect("write");
            }
            mount.sync_mgmt().expect("drain");
            let drain_s = t0.elapsed().as_secs_f64();
            let chunk_lat = lat_delta(&obs0, sea::obs::Metric::MoverChunk);
            let (fl, ev) = mount.mgmt_counters();
            assert_eq!((fl, ev), (SCALE_FILES as u64, SCALE_FILES as u64));
            let peaks = mount.flush_member_peaks().unwrap_or_default();
            assert!(peaks.iter().all(|&p| p <= per_member), "gate violated: {peaks:?}");
            h.record(
                &format!("flush_scaling_w{workers}_m{per_member}"),
                vec![drain_s],
                format!("member peaks {peaks:?}"),
            );
            grid.push((workers, per_member, drain_s, peaks, chunk_lat));
            let _ = std::fs::remove_dir_all(&root);
        }
    }
    let mut json = String::from("{\n  \"target\": \"vfs/flush_scaling\",\n");
    json.push_str(&format!(
        "  \"members\": {MEMBERS},\n  \"files\": {SCALE_FILES},\n  \"file_kib\": {SCALE_KIB},\n  \"grid\": [\n"
    ));
    for (i, (w, m, s, peaks, lat)) in grid.iter().enumerate() {
        let peaks_json = peaks
            .iter()
            .map(|p| p.to_string())
            .collect::<Vec<_>>()
            .join(", ");
        json.push_str(&format!(
            "    {{\"workers\": {w}, \"per_member\": {m}, \"drain_s\": {s:.6}, \"member_peaks\": [{peaks_json}], {}}}{}\n",
            lat_json("chunk", *lat),
            if i + 1 == grid.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_flush_scaling.json", &json) {
        Ok(()) => println!("wrote BENCH_flush_scaling.json ({} combos)", grid.len()),
        Err(e) => eprintln!("bench: could not write BENCH_flush_scaling.json: {e}"),
    }

    // engine comparison: a hot streaming writer over a small device with
    // cold resident files — the paper engine spills the writer itself;
    // the temperature engine spills the cold residents (the writer stays
    // on the fast device) and promotes them back once space frees.
    // Emits BENCH_engine_compare.json.
    let mut engine_rows: Vec<(&str, f64, sea::vfs::MgmtCounters, (u64, u64, u64, u64))> =
        Vec::new();
    for kind in [EngineKind::Paper, EngineKind::Temperature] {
        let root = work.join(format!("engine_{}", kind.name()));
        let pfs = Arc::new(RealFs::new(root.join("pfs")).expect("pfs"));
        let mount = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("dev0"), 0, 8 * MIB).expect("dev")],
            pfs,
            max_file_size: MIB,
            parallel_procs: 4,
            rules: RuleSet::default(), // keep-all: residency managed by pressure
            seed: 7,
            tuning: SeaTuning { engine: kind, ..SeaTuning::default() },
        })
        .expect("mount");
        let obs0 = sea::obs::snapshot();
        let t0 = std::time::Instant::now();
        for i in 0..4u8 {
            mount
                .write(Path::new(&format!("/sea/cold{i}.dat")), &vec![i; MIB as usize])
                .expect("cold");
        }
        {
            let mut f = mount
                .open(Path::new("/sea/hot.dat"), OpenMode::Write)
                .expect("hot");
            let chunk = vec![9u8; 256 * KIB as usize];
            for k in 0..32u64 {
                f.pwrite_all(&chunk, k * 256 * KIB).expect("stream");
            }
        }
        for i in 0..4u8 {
            // re-heat the spilled/resident cold files
            let _ = mount.read(Path::new(&format!("/sea/cold{i}.dat"))).expect("reheat");
        }
        mount.unlink(Path::new("/sea/hot.dat")).expect("unlink");
        mount.sync_mgmt().expect("drain");
        let elapsed = t0.elapsed().as_secs_f64();
        let chunk_lat = lat_delta(&obs0, sea::obs::Metric::MoverChunk);
        let c = mount.counters();
        match kind {
            EngineKind::Paper => {
                assert!(
                    c.self_spills >= 1 && c.victim_spills == 0 && c.promotions == 0,
                    "paper engine spills the writer: {c:?}"
                );
            }
            EngineKind::Temperature => {
                assert!(c.victim_spills >= 1, "temperature picks victims: {c:?}");
                assert!(c.promotions >= 1, "freed space promotes: {c:?}");
            }
        }
        h.record(
            &format!("engine_compare_{}", kind.name()),
            vec![elapsed],
            format!(
                "spills self {} victim {} promotions {}",
                c.self_spills, c.victim_spills, c.promotions
            ),
        );
        engine_rows.push((kind.name(), elapsed, c, chunk_lat));
        let _ = std::fs::remove_dir_all(&root);
    }
    let mut ejson = String::from("{\n  \"target\": \"vfs/engine_compare\",\n  \"engines\": [\n");
    for (i, (name, s, c, lat)) in engine_rows.iter().enumerate() {
        ejson.push_str(&format!(
            "    {{\"engine\": \"{name}\", \"elapsed_s\": {s:.6}, \"flushes\": {}, \
             \"evictions\": {}, \"self_spills\": {}, \"victim_spills\": {}, \
             \"promotions\": {}, {}}}{}\n",
            c.flushes,
            c.evictions,
            c.self_spills,
            c.victim_spills,
            c.promotions,
            lat_json("chunk", *lat),
            if i + 1 == engine_rows.len() { "" } else { "," }
        ));
    }
    ejson.push_str("  ]\n}\n");
    match std::fs::write("BENCH_engine_compare.json", &ejson) {
        Ok(()) => println!("wrote BENCH_engine_compare.json ({} engines)", engine_rows.len()),
        Err(e) => eprintln!("bench: could not write BENCH_engine_compare.json: {e}"),
    }

    // streamed-vs-wholefile sweep (BENCH_datamover.json)
    datamover_sweep(&work, &mut h, false);

    // mapped-vs-pread sweep over the rate-limited striped PFS
    // (BENCH_pagecache.json)
    pagecache_sweep(&work, &mut h, false);

    // codec on/off over compressible + incompressible corpora
    // (BENCH_compress.json)
    compress_sweep(&work, &mut h, false);

    // in-process vs served-over-a-socket preads (BENCH_remote.json)
    remote_sweep(&work, &mut h, false);

    // histogram overhead on/off + per-metric percentiles (BENCH_obs.json)
    obs_sweep(&work, &mut h, false);

    let results = h.finish();
    // derive the per-op interception overhead from the 4k pair
    let mean = |name: &str| {
        results
            .iter()
            .find(|r| r.name.ends_with(name))
            .map(|r| r.summary().mean)
            .unwrap_or(f64::NAN)
    };
    let overhead =
        (mean("seafs_write_4k_x200") - mean("realfs_write_4k_x200")) / N as f64 * 1e6;
    println!("\nper-write interception overhead (4k): {overhead:.2} µs");
    dump_trace(&trace_path);
    let _ = std::fs::remove_dir_all(&work);
}
