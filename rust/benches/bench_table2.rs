//! Table 2: per-tier storage bandwidths.
//!
//! Two parts: (a) the simulator calibration echo — single-stream
//! simulated dd per tier must land on the Table 2 numbers (a calibration
//! regression test); (b) a real dd-style micro-benchmark of this
//! machine's tmpfs and disk (informational — absolute numbers are
//! host-specific).

mod common;

use sea::bench::Harness;
use sea::sim::engine::{ProcId, Process, Sim, Step};
use sea::sim::stack::Stack;
use sea::sim::topology::Location;
use sea::util::{MIB};
use sea::vfs::{RealFs, Vfs};

/// Simulated single-stream dd: returns seconds to move `bytes`.
fn sim_dd(write: bool, loc: Location, bytes: u64) -> f64 {
    struct Dd {
        loc: Location,
        bytes: u64,
        write: bool,
        started: bool,
        done: std::rc::Rc<std::cell::Cell<f64>>,
        stack: Stack,
    }
    impl Process for Dd {
        fn resume(&mut self, sim: &mut Sim, pid: ProcId) -> Step {
            if !self.started {
                self.started = true;
                if self.write {
                    self.stack.write(sim, 0, 1, self.bytes, self.loc, pid).expect("write");
                } else {
                    self.stack.register_file(1, self.bytes, self.loc);
                    self.stack.read(sim, 0, 1, pid).expect("read");
                }
                Step::Waiting
            } else {
                self.done.set(sim.now());
                Step::Done
            }
        }
    }
    let mut spec = common::paper_spec();
    // avoid page-cache absorption so the device speed is visible
    spec.dirty_ratio = 0.0;
    let mut sim = Sim::new();
    let stack = Stack::new(&mut sim, &spec);
    let done = std::rc::Rc::new(std::cell::Cell::new(-1.0));
    sim.spawn(Box::new(Dd {
        loc,
        bytes,
        write,
        started: false,
        done: done.clone(),
        stack: stack.clone(),
    }));
    sim.run(1e9).expect("sim dd");
    done.get()
}

fn main() {
    let mut h = Harness::new("table2").with_reps(0, 1);
    let size = 4096 * MIB; // 4 GiB simulated stream

    println!("simulated single-stream dd (calibration echo of Table 2):");
    let cases = [
        ("tmpfs_write", true, Location::Tmpfs { node: 0 }, 2560.0),
        ("tmpfs_read", false, Location::Tmpfs { node: 0 }, 6676.0),
        ("disk_write", true, Location::Disk { node: 0, disk: 0 }, 426.0),
        ("disk_read", false, Location::Disk { node: 0, disk: 0 }, 501.7),
        ("lustre_write", true, Location::Lustre, 121.0),
        ("lustre_read", false, Location::Lustre, 1381.14),
    ];
    for (name, write, loc, table2_mibs) in cases {
        let secs = sim_dd(write, loc, size);
        let mibs = size as f64 / MIB as f64 / secs;
        println!(
            "  {name:<14} {mibs:>9.1} MiB/s  (Table 2: {table2_mibs:>7.1} MiB/s, ratio {:.3})",
            mibs / table2_mibs
        );
        // calibration must match within 10% (MDS latency perturbs lustre)
        assert!(
            (mibs / table2_mibs - 1.0).abs() < 0.10,
            "{name}: simulated {mibs:.1} vs Table 2 {table2_mibs:.1}"
        );
        h.record(name, vec![secs], format!("{mibs:.1} MiB/s vs Table2 {table2_mibs} MiB/s"));
    }

    println!("\nreal dd-style on this host (informational):");
    for (name, dir) in [("host_shm", "/dev/shm/sea_t2"), ("host_tmp", "/tmp/sea_t2")] {
        let fs_ = RealFs::new(dir).expect("mk");
        let payload = vec![0xA5u8; (256 * MIB) as usize];
        let t0 = std::time::Instant::now();
        fs_.write(std::path::Path::new("dd.dat"), &payload).expect("write");
        let w = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let _ = fs_.read(std::path::Path::new("dd.dat")).expect("read");
        let r = t0.elapsed().as_secs_f64();
        println!(
            "  {name:<14} write {:>8.1} MiB/s  cached read {:>8.1} MiB/s",
            256.0 / w,
            256.0 / r
        );
        h.record(name, vec![w, r], format!("w {:.0} / r {:.0} MiB/s", 256.0 / w, 256.0 / r));
        let _ = std::fs::remove_dir_all(dir);
    }
    h.finish();
}
