//! Fig 2b: Lustre vs Sea in-memory, varying local disks (5 iters).
//! Paper shape: Sea loses at 1 disk, wins ~2x by 6 disks.

mod common;

use sea::bench::Harness;
use sea::report;

fn main() {
    let scale = common::bench_scale();
    let mut h = Harness::new("fig2b").with_reps(0, 1);
    let mut fig = None;
    h.case("sweep_disks_1..6", || {
        let f = report::fig2b(&common::paper_spec(), scale, &[1, 2, 3, 4, 5, 6], common::SEED)
            .expect("fig2b");
        fig = Some(f);
    });
    let fig = fig.expect("ran");
    for p in &fig.points {
        h.record(
            &format!("disks_{}", p.x as usize),
            vec![p.lustre, p.sea],
            format!("lustre {:.1}s sea {:.1}s speedup {:.2}x", p.lustre, p.sea, p.speedup()),
        );
    }
    fig.write_to(std::path::Path::new("results")).expect("write fig2b");
    println!("{}", fig.to_ascii());
    println!("fig2b max speedup {:.2}x (paper: ~2x at 6 disks)", fig.max_speedup());
    h.finish();
}
