//! Simulator engine throughput (the perf-pass subject for L3): completed
//! flows per host-second and rate-recomputations per host-second on the
//! paper-scale fig2c point (5 nodes, 6 procs, 10 iterations).

mod common;

use sea::bench::Harness;
use sea::coordinator::{run_experiment, ExperimentCfg, Mode};
use sea::workload::IncrementationSpec;

fn main() {
    let mut h = Harness::new("sim").with_reps(1, 3);
    for (name, blocks) in [("blocks_100", 100), ("blocks_250", 250)] {
        let mut flows = 0u64;
        let mut recomputes = 0u64;
        h.case(name, || {
            let mut w = IncrementationSpec::paper_default();
            w.blocks = blocks;
            let r = run_experiment(&ExperimentCfg {
                spec: common::paper_spec(),
                workload: w,
                mode: Mode::SeaInMemory,
                seed: common::SEED,
            })
            .expect("sim");
            flows = r.flows;
            recomputes = r.recomputes;
        });
        let last = h
            .case(&format!("{name}_lustre"), || {
                let mut w = IncrementationSpec::paper_default();
                w.blocks = blocks;
                run_experiment(&ExperimentCfg {
                    spec: common::paper_spec(),
                    workload: w,
                    mode: Mode::Lustre,
                    seed: common::SEED,
                })
                .expect("sim");
            })
            .summary()
            .mean;
        println!(
            "{name}: {flows} flows, {recomputes} reallocations; lustre-mode host time {last:.2}s"
        );
    }
    let results = h.finish();
    for r in &results {
        println!("{:<24} mean {:.3}s", r.name, r.summary().mean);
    }
}
