//! Fig 3: the three modes at the fixed conditions (5 nodes, 6 procs,
//! 6 disks, 5 iterations). Paper: flush-all is 3.5x slower than
//! in-memory and 1.3x slower than plain Lustre.

mod common;

use sea::bench::Harness;
use sea::report;

fn main() {
    let scale = common::bench_scale();
    let mut h = Harness::new("fig3").with_reps(0, 1);
    let mut rows = None;
    h.case("three_modes", || {
        rows = Some(report::fig3(&common::paper_spec(), scale, common::SEED).expect("fig3"));
    });
    let rows = rows.expect("ran");
    for (name, r) in &rows {
        h.record(
            name,
            vec![r.makespan],
            format!("app {:.1}s total {:.1}s", r.app_done, r.makespan),
        );
    }
    let get = |m: &str| rows.iter().find(|(n, _)| n == m).map(|(_, r)| r.makespan).unwrap();
    println!(
        "flush-all/in-memory = {:.2}x (paper 3.5x) ; flush-all/lustre = {:.2}x (paper 1.3x)",
        get("sea-flush-all") / get("sea-in-memory"),
        get("sea-flush-all") / get("lustre"),
    );
    h.finish();
}
