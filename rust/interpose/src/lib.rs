//! `LD_PRELOAD` glibc interposer — the paper's actual mechanism (§3.1.2),
//! as a `cdylib` loadable into *unmodified* dynamically-linked binaries.
//!
//! The paper's Sea wraps "every glibc function accepting a file path" and
//! rewrites paths under the Sea mountpoint to the best storage device.
//! This shim demonstrates that mechanism end-to-end on real processes:
//! every wrapped call rewrites `SEA_MOUNT`-prefixed paths to
//! `SEA_TARGET`-prefixed ones and forwards to the real glibc symbol via
//! `dlsym(RTLD_NEXT)`.
//!
//! Policy (device selection, flush/evict rules) lives in the `sea`
//! library; keeping the shim to pure prefix translation keeps it tiny,
//! dependency-free and safe to inject into arbitrary binaries — the demo
//! (`examples/interpose_demo.rs`) points `SEA_TARGET` at a directory the
//! library manages.
//!
//! Environment:
//! * `SEA_MOUNT`  — logical mountpoint prefix (default `/sea`).
//! * `SEA_TARGET` — directory that backs the mountpoint.
//!
//! Wrapped symbols: `open`, `open64`, `openat`, `creat`, `creat64`,
//! `fopen`, `fopen64`, `stat`, `lstat`, `access`, `unlink`, `mkdir`,
//! `rename` (both arguments), `opendir`, `remove`, `truncate`,
//! `truncate64`, `chdir`, plus the mapping family below (`mmap`,
//! `mmap64`, `msync`, `munmap`).
//!
//! Offset-addressed I/O (`pread`/`pwrite`/`pread64`/`pwrite64`,
//! `lseek`/`lseek64`) is also interposed: these operate on descriptors
//! whose *path* was already translated at `open`, so no rewriting is
//! needed — the wrappers forward to the real symbols, keeping the whole
//! request path (open → positioned I/O → close) inside the shim. This
//! mirrors the library-level `VfsFile` handle API: translation happens
//! once at open, every subsequent request is offset-addressed against
//! the translated target.
//!
//! Statically-linked binaries and direct syscalls bypass the shim —
//! the same documented limitation as the paper's library.
//!
//! `mmap(2)` **is** wrapped: a non-executable mapping of a regular
//! file under `SEA_TARGET` (i.e. an fd the shim translated at `open`)
//! is *emulated* instead of forwarded — the shim carves an anonymous
//! region, fills it from a process-wide page pool keyed by
//! `(device, inode, 64 KiB page)` (the out-of-process analogue of the
//! library's shared `vfs::pages` frame pool: two mappings of one file
//! fill from the same pooled pages, faulting each page once), and
//! hands the region to the caller. `MAP_PRIVATE` read-only mappings
//! are sealed with `mprotect`; writable `MAP_SHARED` mappings keep a
//! duplicated descriptor plus a snapshot of the fill, and on
//! `msync`/`munmap` write back only the byte ranges that differ from
//! the snapshot (per 64 KiB page), invalidating the file's pooled
//! pages when anything was written — a mapping that is only ever read
//! writes nothing, and concurrent updates to the file through other
//! descriptors or processes survive outside the dirtied ranges.
//! Everything else — anonymous, `MAP_FIXED`, executable, non-Sea fds
//! — forwards straight to the kernel (`SEA_MMAP=0` disables the
//! emulation entirely). Partial `munmap` of an emulated region is
//! honored: the released sub-range is flushed and returned to the
//! kernel, and the bookkeeping is trimmed (a middle cut splits the
//! region in two, each half with its own descriptor and snapshot
//! slice). Remaining gaps: the snapshot doubles
//! the memory of a writable shared mapping; a concurrent external
//! write landing *inside* a byte range this mapping also dirtied is
//! still clobbered at sync (deferred-write semantics, vs. real
//! `MAP_SHARED`'s store-granularity merge); and pages filled before a
//! *kernel-side* writer changed the file are only invalidated by a
//! shim-side write-back.
//!
//! * `SEA_MMAP`        — set to `0` to forward every `mmap` untouched.
//! * `SEA_MMAP_BUDGET` — pool budget in bytes (default 64 MiB).

use std::cell::Cell;
use std::collections::{HashMap, VecDeque};
use std::ffi::{CStr, CString, OsStr};
use std::os::raw::{c_char, c_int, c_void};
use std::os::unix::ffi::OsStrExt;
use std::sync::{Mutex, OnceLock};

// --- env + translation ------------------------------------------------------

fn env_or(name: &str, default: &str) -> Vec<u8> {
    std::env::var_os(name)
        .map(|v| v.as_bytes().to_vec())
        .unwrap_or_else(|| default.as_bytes().to_vec())
}

/// Translate `path` if it lies under `SEA_MOUNT`; returns the rewritten
/// C string (kept alive by the caller's scope).
fn translate(path: &CStr) -> Option<CString> {
    let mount = env_or("SEA_MOUNT", "/sea");
    let target = env_or("SEA_TARGET", "/tmp/sea_target");
    let bytes = path.to_bytes();
    if !bytes.starts_with(&mount) {
        return None;
    }
    // exact prefix or prefix + '/'
    let rest = &bytes[mount.len()..];
    if !(rest.is_empty() || rest[0] == b'/') {
        return None;
    }
    let mut out = target;
    out.extend_from_slice(rest);
    CString::new(out).ok()
}

/// Flag a missing real symbol to the caller: libc contracts promise a
/// meaningful errno alongside the error return.
unsafe fn no_sym<T>(ret: T) -> T {
    *libc::__errno_location() = libc::ENOSYS;
    ret
}

/// Resolve the next (real) definition of `$name`, caching the lookup so
/// hot paths (pread/pwrite) don't pay a dlsym string search per call.
macro_rules! real {
    ($name:literal, $ty:ty) => {{
        static SYM: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
        let addr = *SYM.get_or_init(|| unsafe {
            libc::dlsym(libc::RTLD_NEXT, $name.as_ptr() as *const c_char) as usize
        });
        if addr == 0 {
            None
        } else {
            Some(unsafe { std::mem::transmute::<usize, $ty>(addr) })
        }
    }};
}

/// Wrap a single-path function: translate arg 0, forward the rest.
macro_rules! wrap_path_fn {
    ($name:ident, $cname:literal, ($($arg:ident : $argty:ty),*), $ret:ty, $errno_ret:expr) => {
        /// glibc interposer: translate Sea-mounted paths, forward to libc.
        ///
        /// # Safety
        /// Called by arbitrary C code with C ABI invariants; `path` must
        /// be a valid NUL-terminated string (as libc requires).
        #[no_mangle]
        pub unsafe extern "C" fn $name(path: *const c_char $(, $arg: $argty)*) -> $ret {
            type Fn = unsafe extern "C" fn(*const c_char $(, $argty)*) -> $ret;
            let Some(real) = real!($cname, Fn) else { return no_sym($errno_ret); };
            if path.is_null() {
                return real(path $(, $arg)*);
            }
            let c = CStr::from_ptr(path);
            match translate(c) {
                Some(t) => real(t.as_ptr() $(, $arg)*),
                None => real(path $(, $arg)*),
            }
        }
    };
}

/// Wrap an fd-based function: no path to translate (the descriptor's
/// path was rewritten at `open`), just forward through the shim.
macro_rules! wrap_fd_fn {
    ($name:ident, $cname:literal, ($($arg:ident : $argty:ty),*), $ret:ty, $errno_ret:expr) => {
        /// glibc interposer: forward an fd-granular call to libc (the
        /// descriptor was opened through the translating `open` wrapper).
        ///
        /// # Safety
        /// Called by arbitrary C code with C ABI invariants; pointer
        /// arguments must be valid per the libc contract.
        #[no_mangle]
        pub unsafe extern "C" fn $name(fd: c_int $(, $arg: $argty)*) -> $ret {
            type Fn = unsafe extern "C" fn(c_int $(, $argty)*) -> $ret;
            let Some(real) = real!($cname, Fn) else { return no_sym($errno_ret); };
            real(fd $(, $arg)*)
        }
    };
}

// open/creat family (mode passed through variadically-safe fixed arg)
wrap_path_fn!(open, b"open\0", (flags: c_int, mode: libc::mode_t), c_int, -1);
wrap_path_fn!(open64, b"open64\0", (flags: c_int, mode: libc::mode_t), c_int, -1);
wrap_path_fn!(creat, b"creat\0", (mode: libc::mode_t), c_int, -1);
wrap_path_fn!(creat64, b"creat64\0", (mode: libc::mode_t), c_int, -1);
wrap_path_fn!(unlink, b"unlink\0", (), c_int, -1);
wrap_path_fn!(mkdir, b"mkdir\0", (mode: libc::mode_t), c_int, -1);
wrap_path_fn!(truncate, b"truncate\0", (len: libc::off_t), c_int, -1);
wrap_path_fn!(truncate64, b"truncate64\0", (len: libc::off64_t), c_int, -1);
wrap_path_fn!(chdir, b"chdir\0", (), c_int, -1);
wrap_path_fn!(remove, b"remove\0", (), c_int, -1);
wrap_path_fn!(access, b"access\0", (mode: c_int), c_int, -1);

// offset-addressed I/O on already-translated descriptors: the same
// request granularity as the library's `VfsFile::pread`/`pwrite`
wrap_fd_fn!(pread, b"pread\0",
    (buf: *mut c_void, count: libc::size_t, offset: libc::off_t),
    libc::ssize_t, -1);
wrap_fd_fn!(pread64, b"pread64\0",
    (buf: *mut c_void, count: libc::size_t, offset: libc::off64_t),
    libc::ssize_t, -1);
wrap_fd_fn!(pwrite, b"pwrite\0",
    (buf: *const c_void, count: libc::size_t, offset: libc::off_t),
    libc::ssize_t, -1);
wrap_fd_fn!(pwrite64, b"pwrite64\0",
    (buf: *const c_void, count: libc::size_t, offset: libc::off64_t),
    libc::ssize_t, -1);
wrap_fd_fn!(lseek, b"lseek\0", (offset: libc::off_t, whence: c_int), libc::off_t, -1);
wrap_fd_fn!(lseek64, b"lseek64\0",
    (offset: libc::off64_t, whence: c_int), libc::off64_t, -1);
wrap_fd_fn!(ftruncate, b"ftruncate\0", (len: libc::off_t), c_int, -1);
wrap_fd_fn!(ftruncate64, b"ftruncate64\0", (len: libc::off64_t), c_int, -1);

/// `openat`: translate the path argument (position 1).
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn openat(
    dirfd: c_int,
    path: *const c_char,
    flags: c_int,
    mode: libc::mode_t,
) -> c_int {
    type Fn = unsafe extern "C" fn(c_int, *const c_char, c_int, libc::mode_t) -> c_int;
    let Some(real) = real!(b"openat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(dirfd, path, flags, mode);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(dirfd, t.as_ptr(), flags, mode),
        None => real(dirfd, path, flags, mode),
    }
}

/// `fopen`: translate the path argument.
///
/// # Safety
/// C ABI; `path`/`modes` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn fopen(path: *const c_char, modes: *const c_char) -> *mut libc::FILE {
    type Fn = unsafe extern "C" fn(*const c_char, *const c_char) -> *mut libc::FILE;
    let Some(real) = real!(b"fopen\0", Fn) else { return no_sym(std::ptr::null_mut()) };
    if path.is_null() {
        return real(path, modes);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr(), modes),
        None => real(path, modes),
    }
}

/// `fopen64`: translate the path argument.
///
/// # Safety
/// C ABI; `path`/`modes` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn fopen64(path: *const c_char, modes: *const c_char) -> *mut libc::FILE {
    type Fn = unsafe extern "C" fn(*const c_char, *const c_char) -> *mut libc::FILE;
    let Some(real) = real!(b"fopen64\0", Fn) else { return no_sym(std::ptr::null_mut()) };
    if path.is_null() {
        return real(path, modes);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr(), modes),
        None => real(path, modes),
    }
}

/// `stat`: translate the path argument.
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn stat(path: *const c_char, buf: *mut libc::stat) -> c_int {
    type Fn = unsafe extern "C" fn(*const c_char, *mut libc::stat) -> c_int;
    let Some(real) = real!(b"stat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, buf);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr(), buf),
        None => real(path, buf),
    }
}

/// `lstat`: translate the path argument.
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn lstat(path: *const c_char, buf: *mut libc::stat) -> c_int {
    type Fn = unsafe extern "C" fn(*const c_char, *mut libc::stat) -> c_int;
    let Some(real) = real!(b"lstat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, buf);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr(), buf),
        None => real(path, buf),
    }
}

/// `rename`: translate *both* arguments.
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn rename(from: *const c_char, to: *const c_char) -> c_int {
    type Fn = unsafe extern "C" fn(*const c_char, *const c_char) -> c_int;
    let Some(real) = real!(b"rename\0", Fn) else { return no_sym(-1) };
    let tf = if from.is_null() { None } else { translate(CStr::from_ptr(from)) };
    let tt = if to.is_null() { None } else { translate(CStr::from_ptr(to)) };
    let fp = tf.as_ref().map(|c| c.as_ptr()).unwrap_or(from);
    let tp = tt.as_ref().map(|c| c.as_ptr()).unwrap_or(to);
    real(fp, tp)
}

/// `statx`: translate the path argument (modern coreutils stat path).
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn statx(
    dirfd: c_int,
    path: *const c_char,
    flags: c_int,
    mask: libc::c_uint,
    buf: *mut libc::statx,
) -> c_int {
    type Fn = unsafe extern "C" fn(
        c_int,
        *const c_char,
        c_int,
        libc::c_uint,
        *mut libc::statx,
    ) -> c_int;
    let Some(real) = real!(b"statx\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(dirfd, path, flags, mask, buf);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(dirfd, t.as_ptr(), flags, mask, buf),
        None => real(dirfd, path, flags, mask, buf),
    }
}

/// `fstatat` (a.k.a. `newfstatat`): translate the path argument.
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn fstatat(
    dirfd: c_int,
    path: *const c_char,
    buf: *mut libc::stat,
    flags: c_int,
) -> c_int {
    type Fn = unsafe extern "C" fn(c_int, *const c_char, *mut libc::stat, c_int) -> c_int;
    let Some(real) = real!(b"fstatat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(dirfd, path, buf, flags);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(dirfd, t.as_ptr(), buf, flags),
        None => real(dirfd, path, buf, flags),
    }
}

/// `opendir`: translate the path argument.
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn opendir(path: *const c_char) -> *mut libc::DIR {
    type Fn = unsafe extern "C" fn(*const c_char) -> *mut libc::DIR;
    let Some(real) = real!(b"opendir\0", Fn) else { return no_sym(std::ptr::null_mut()) };
    if path.is_null() {
        return real(path);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr()),
        None => real(path),
    }
}

// --- mmap interposition ------------------------------------------------------
//
// The shim-side analogue of the library's shared PageCache: emulated
// mappings of Sea-translated descriptors fill from one process-wide
// pool keyed by (device, inode, page), so two mappings of a file fault
// each page once. Forwards go through raw syscalls, not the dlsym'd
// symbol: malloc itself allocates with anonymous mmap (and frees with
// munmap), so the forward path must not allocate or re-enter the
// symbol resolver.

/// Pool page size: matches the library's `DEFAULT_PAGE_BYTES`.
const MMAP_POOL_PAGE: usize = 64 * 1024;

/// Default pool budget (bytes), overridable via `SEA_MMAP_BUDGET`.
const MMAP_POOL_BUDGET: usize = 64 * 1024 * 1024;

struct MmapPool {
    /// `(device, inode, page index)` → page bytes (zero-padded tail).
    pages: HashMap<(u64, u64, u64), Vec<u8>>,
    /// FIFO eviction order (simple and allocation-light; the pool is a
    /// fill accelerator, not a correctness structure).
    fifo: VecDeque<(u64, u64, u64)>,
    budget_pages: usize,
    hits: u64,
    faults: u64,
}

fn pool() -> &'static Mutex<MmapPool> {
    static POOL: OnceLock<Mutex<MmapPool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let budget = std::env::var_os("SEA_MMAP_BUDGET")
            .and_then(|v| v.to_str().and_then(|s| s.parse::<usize>().ok()))
            .unwrap_or(MMAP_POOL_BUDGET);
        Mutex::new(MmapPool {
            pages: HashMap::new(),
            fifo: VecDeque::new(),
            budget_pages: (budget / MMAP_POOL_PAGE).max(1),
            hits: 0,
            faults: 0,
        })
    })
}

/// Cumulative pool gauges `(hits, faults)` — pages served from the
/// shared pool vs. preads that filled a page.
pub fn mmap_pool_counters() -> (u64, u64) {
    let p = pool().lock().unwrap_or_else(|e| e.into_inner());
    (p.hits, p.faults)
}

/// One emulated mapping.
struct MapInfo {
    len: usize,
    /// File offset the region mirrors (mmap's `offset` argument).
    offset: u64,
    /// Writable `MAP_SHARED` emulation state; `None` for private
    /// mappings (no write-back).
    wb: Option<WriteBack>,
}

/// Write-back state of a writable `MAP_SHARED` emulated region.
struct WriteBack {
    /// Duplicated descriptor (the caller may close theirs).
    fd: c_int,
    dev: u64,
    ino: u64,
    /// The region's bytes as of the fill, refreshed after every
    /// write-back: `msync`/`munmap` diff the live region against it
    /// and pwrite only the byte ranges the caller actually changed.
    /// Without the diff the sync would rewrite the entire region —
    /// clobbering any concurrent update made to the file through
    /// another descriptor, process, or mapping with this region's
    /// stale snapshot, and rewriting the whole file even for a
    /// mapping that was only ever read. Costs one extra copy of the
    /// region per writable shared mapping.
    snapshot: Vec<u8>,
}

fn maps() -> &'static Mutex<HashMap<usize, MapInfo>> {
    static MAPS: OnceLock<Mutex<HashMap<usize, MapInfo>>> = OnceLock::new();
    MAPS.get_or_init(|| Mutex::new(HashMap::new()))
}

std::thread_local! {
    /// Re-entrancy guard: while the shim itself allocates (pool fill,
    /// map-table insert), malloc may legitimately call mmap/munmap —
    /// those inner calls must forward raw instead of taking the same
    /// locks again.
    static IN_SHIM: Cell<bool> = const { Cell::new(false) };
}

unsafe fn sys_mmap(
    addr: *mut c_void,
    len: libc::size_t,
    prot: c_int,
    flags: c_int,
    fd: c_int,
    offset: i64,
) -> *mut c_void {
    libc::syscall(libc::SYS_mmap, addr, len, prot, flags, fd, offset) as *mut c_void
}

unsafe fn sys_munmap(addr: *mut c_void, len: libc::size_t) -> c_int {
    libc::syscall(libc::SYS_munmap, addr, len) as c_int
}

unsafe fn sys_msync(addr: *mut c_void, len: libc::size_t, flags: c_int) -> c_int {
    libc::syscall(libc::SYS_msync, addr, len, flags) as c_int
}

/// Should this mapping be emulated? Yes when the emulation is enabled,
/// `fd` is a regular file living under `SEA_TARGET` (a path the shim
/// translated at `open`), and the protection/flags are a shape the
/// emulation preserves: non-executable, and either private or
/// writable-shared. Returns the file's `(device, inode)`.
unsafe fn sea_mappable(fd: c_int, flags: c_int, prot: c_int) -> Option<(u64, u64)> {
    if std::env::var_os("SEA_MMAP").is_some_and(|v| v == "0") {
        return None;
    }
    if prot & libc::PROT_EXEC != 0 {
        return None; // never emulate code mappings (dlopen et al.)
    }
    let shared = flags & libc::MAP_SHARED != 0;
    let writable = prot & libc::PROT_WRITE != 0;
    if shared && !writable {
        return None; // read-only shared: the kernel mapping is fine
    }
    let mut st: libc::stat = std::mem::zeroed();
    if libc::fstat(fd, &mut st) != 0 || st.st_mode & libc::S_IFMT != libc::S_IFREG {
        return None;
    }
    // resolve the descriptor back to its path: only Sea-translated
    // files (under SEA_TARGET) go through the pool
    let link = format!("/proc/self/fd/{fd}\0");
    let mut buf = [0u8; libc::PATH_MAX as usize];
    let n = libc::readlink(
        link.as_ptr() as *const c_char,
        buf.as_mut_ptr() as *mut c_char,
        buf.len(),
    );
    if n <= 0 {
        return None;
    }
    let path = &buf[..n as usize];
    let target = env_or("SEA_TARGET", "/tmp/sea_target");
    if !path.starts_with(&target) {
        return None;
    }
    let rest = &path[target.len()..];
    if !(rest.is_empty() || rest[0] == b'/') {
        return None;
    }
    Some((st.st_dev as u64, st.st_ino as u64))
}

/// Copy `[offset, offset + out.len())` of `fd` into `out` through the
/// shared page pool: pooled pages are memcpy'd, missing ones are
/// pread (outside the pool lock) and inserted under the FIFO budget.
unsafe fn fill_from_pool(out: &mut [u8], fd: c_int, offset: u64, dev: u64, ino: u64) -> bool {
    let pb = MMAP_POOL_PAGE as u64;
    let mut done = 0usize;
    while done < out.len() {
        let fo = offset + done as u64;
        let idx = fo / pb;
        let intra = (fo % pb) as usize;
        let span = (MMAP_POOL_PAGE - intra).min(out.len() - done);
        let key = (dev, ino, idx);
        let pooled = {
            let mut p = pool().lock().unwrap_or_else(|e| e.into_inner());
            if let Some(page) = p.pages.get(&key) {
                out[done..done + span].copy_from_slice(&page[intra..intra + span]);
                p.hits += 1;
                true
            } else {
                false
            }
        };
        if !pooled {
            let mut page = vec![0u8; MMAP_POOL_PAGE];
            let mut filled = 0usize;
            while filled < MMAP_POOL_PAGE {
                let n = libc::pread(
                    fd,
                    page[filled..].as_mut_ptr() as *mut c_void,
                    MMAP_POOL_PAGE - filled,
                    (idx * pb + filled as u64) as libc::off_t,
                );
                if n < 0 {
                    return false;
                }
                if n == 0 {
                    break; // past EOF: the tail stays zero
                }
                filled += n as usize;
            }
            out[done..done + span].copy_from_slice(&page[intra..intra + span]);
            let mut p = pool().lock().unwrap_or_else(|e| e.into_inner());
            p.faults += 1;
            if !p.pages.contains_key(&key) {
                while p.pages.len() >= p.budget_pages {
                    match p.fifo.pop_front() {
                        Some(old) => {
                            p.pages.remove(&old);
                        }
                        None => break,
                    }
                }
                p.fifo.push_back(key);
                p.pages.insert(key, page);
            }
        }
        done += span;
    }
    true
}

/// Build an emulated mapping: an anonymous region filled through the
/// pool, standing in for `[offset, offset + len)` of the file.
unsafe fn emulate_map(
    len: libc::size_t,
    prot: c_int,
    flags: c_int,
    fd: c_int,
    offset: u64,
    dev: u64,
    ino: u64,
) -> *mut c_void {
    let region = sys_mmap(
        std::ptr::null_mut(),
        len,
        libc::PROT_READ | libc::PROT_WRITE,
        libc::MAP_PRIVATE | libc::MAP_ANONYMOUS,
        -1,
        0,
    );
    if region == libc::MAP_FAILED {
        return region;
    }
    let out = std::slice::from_raw_parts_mut(region as *mut u8, len);
    if !fill_from_pool(out, fd, offset, dev, ino) {
        sys_munmap(region, len);
        *libc::__errno_location() = libc::EIO;
        return libc::MAP_FAILED;
    }
    let wb = if flags & libc::MAP_SHARED != 0 {
        // writable shared mapping: keep a descriptor of our own (the
        // caller may close theirs) for msync/munmap write-back, and a
        // snapshot of the fill as the write-back diff base
        let dup = libc::fcntl(fd, libc::F_DUPFD_CLOEXEC, 0);
        if dup < 0 {
            sys_munmap(region, len);
            return libc::MAP_FAILED; // fcntl left errno
        }
        Some(WriteBack { fd: dup, dev, ino, snapshot: out.to_vec() })
    } else {
        if prot & libc::PROT_WRITE == 0 {
            // seal the private read-only mapping now that it is filled
            libc::mprotect(region, len, prot);
        }
        None
    };
    maps()
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .insert(region as usize, MapInfo { len, offset, wb });
    region
}

/// Write all of `buf` to `fd` at `off`, raw; `false` on any error.
unsafe fn pwrite_all_raw(fd: c_int, buf: &[u8], off: u64) -> bool {
    let mut done = 0usize;
    while done < buf.len() {
        let n = libc::pwrite(
            fd,
            buf[done..].as_ptr() as *const c_void,
            buf.len() - done,
            (off + done as u64) as libc::off_t,
        );
        if n <= 0 {
            return false;
        }
        done += n as usize;
    }
    true
}

/// Diff `[lo0, hi0)` of the live emulated region at `base` against its
/// fill snapshot and pwrite only the changed byte range of each pool
/// page through the duplicated descriptor (writable shared mappings —
/// a range the caller never stored to writes nothing back, so
/// concurrent updates to the file through other descriptors/processes
/// survive outside the dirtied ranges), invalidating the file's pooled
/// pages when anything was written. On a write error the snapshot
/// stays stale for that range, so a later msync (or the unmap flush)
/// retries the write; returns -1 then, 0 otherwise. Private mappings
/// are a no-op. Caller holds the maps lock.
unsafe fn write_back_range(base: usize, info: &mut MapInfo, lo0: usize, hi0: usize) -> c_int {
    let Some(wb) = info.wb.as_mut() else { return 0 };
    let region = std::slice::from_raw_parts(base as *const u8, info.len);
    let mut ret = 0;
    let mut wrote = false;
    let mut lo = lo0;
    while lo < hi0 {
        let hi = (lo + MMAP_POOL_PAGE).min(hi0);
        let (cur, old) = (&region[lo..hi], &wb.snapshot[lo..hi]);
        if cur != old {
            // narrow to the changed byte range of this page
            let a = cur.iter().zip(old).position(|(c, o)| c != o).unwrap_or(0);
            let b = cur
                .iter()
                .zip(old)
                .rposition(|(c, o)| c != o)
                .map_or(cur.len(), |k| k + 1);
            if !pwrite_all_raw(wb.fd, &cur[a..b], info.offset + (lo + a) as u64) {
                ret = -1;
                break;
            }
            wb.snapshot[lo + a..lo + b].copy_from_slice(&cur[a..b]);
            wrote = true;
        }
        lo = hi;
    }
    if wrote {
        // the file changed under its pooled pages: drop them so
        // later mappings re-read instead of serving pre-write bytes
        let (dev, ino) = (wb.dev, wb.ino);
        let mut p = pool().lock().unwrap_or_else(|e| e.into_inner());
        p.fifo.retain(|k| k.0 != dev || k.1 != ino);
        p.pages.retain(|k, _| k.0 != dev || k.1 != ino);
    }
    ret
}

/// `msync` back half for emulated regions: write the whole region's
/// dirty ranges back ([`write_back_range`]). `None` when `addr` is not
/// an emulated region. The maps lock is held across the write-back:
/// concurrent syncs of one region cannot interleave diff passes, and
/// re-entrant allocator mmap/munmap calls forward raw under `IN_SHIM`
/// without touching the table (the pool lock only ever nests *inside*
/// the maps lock).
unsafe fn emulated_sync(addr: *mut c_void) -> Option<c_int> {
    let mut m = maps().lock().unwrap_or_else(|e| e.into_inner());
    let mut info = m.remove(&(addr as usize))?;
    let ret = write_back_range(addr as usize, &mut info, 0, info.len);
    m.insert(addr as usize, info);
    Some(ret)
}

/// `munmap` back half for emulated regions, sub-ranges included: flush
/// only the dirty pages inside `[addr, addr + len)` (page-granular,
/// like the kernel), release exactly those pages, and trim the
/// bookkeeping — a prefix cut re-keys the region, a suffix cut shrinks
/// it, a middle cut splits it in two (the right half gets its own
/// duplicated descriptor and snapshot tail, acquired *before* anything
/// is released so a failure leaves the region intact, like the
/// kernel's own ENOMEM on a VMA split). `None` when the range is not
/// inside an emulated region.
unsafe fn emulated_unmap(addr: *mut c_void, len: libc::size_t) -> Option<c_int> {
    if len == 0 {
        return None; // kernel's EINVAL path
    }
    let a = addr as usize;
    let page = libc::sysconf(libc::_SC_PAGESIZE).max(1) as usize;
    let mut m = maps().lock().unwrap_or_else(|e| e.into_inner());
    let base = m
        .iter()
        .find(|(b, i)| **b <= a && a < **b + i.len)
        .map(|(b, _)| *b)?;
    if a % page != 0 {
        *libc::__errno_location() = libc::EINVAL;
        return Some(-1);
    }
    let mut info = m.remove(&base).expect("region found above");
    let total = info.len;
    let lo = a - base;
    // munmap lengths round up to page granularity; a range running
    // past the region end clamps to it (the kernel would release any
    // following mappings too — the emulation never places one there)
    let hi = match len.checked_add(page - 1) {
        Some(l) => a.saturating_add(l & !(page - 1)).min(base + total) - base,
        None => total,
    };
    // flush only the dirty pages inside the released range
    let mut ret = write_back_range(base, &mut info, lo, hi);
    if lo == 0 && hi == total {
        // full teardown
        if let Some(wb) = info.wb.as_ref() {
            libc::close(wb.fd);
        }
        let r = sys_munmap(base as *mut c_void, total);
        if r != 0 {
            ret = r;
        }
        return Some(ret);
    }
    // a middle cut needs a second descriptor for the right half —
    // acquire it before releasing anything
    let right_fd = if lo > 0 && hi < total {
        match info.wb.as_ref() {
            None => None,
            Some(wb) => {
                let dup = libc::fcntl(wb.fd, libc::F_DUPFD_CLOEXEC, 0);
                if dup < 0 {
                    m.insert(base, info);
                    return Some(-1); // fcntl left errno
                }
                Some(dup)
            }
        }
    } else {
        None
    };
    let r = sys_munmap((base + lo) as *mut c_void, hi - lo);
    if r != 0 {
        // nothing was released: keep the bookkeeping intact
        if let Some(fd) = right_fd {
            libc::close(fd);
        }
        m.insert(base, info);
        return Some(r);
    }
    if lo == 0 {
        // prefix cut: the region now starts (and mirrors the file) at
        // `hi` bytes further in
        if let Some(wb) = info.wb.as_mut() {
            wb.snapshot.drain(..hi);
        }
        info.len = total - hi;
        info.offset += hi as u64;
        m.insert(base + hi, info);
    } else if hi == total {
        // suffix cut: shrink in place
        if let Some(wb) = info.wb.as_mut() {
            wb.snapshot.truncate(lo);
        }
        info.len = lo;
        m.insert(base, info);
    } else {
        // middle cut: left keeps the original descriptor, right gets
        // the duplicate and the snapshot tail
        let mut left = info;
        let right_wb = match (left.wb.as_mut(), right_fd) {
            (Some(wb), Some(fd)) => {
                let tail = wb.snapshot.split_off(hi);
                Some(WriteBack { fd, dev: wb.dev, ino: wb.ino, snapshot: tail })
            }
            _ => None,
        };
        let right = MapInfo {
            len: total - hi,
            offset: left.offset + hi as u64,
            wb: right_wb,
        };
        if let Some(wb) = left.wb.as_mut() {
            wb.snapshot.truncate(lo);
        }
        left.len = lo;
        m.insert(base, left);
        m.insert(base + hi, right);
    }
    Some(ret)
}

/// `mmap`: emulate Sea-file mappings through the shared pool, forward
/// everything else raw.
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn mmap(
    addr: *mut c_void,
    len: libc::size_t,
    prot: c_int,
    flags: c_int,
    fd: c_int,
    offset: libc::off_t,
) -> *mut c_void {
    // the allocator's own requests (anonymous), placement-constrained
    // ones (MAP_FIXED*) and re-entrant calls forward before the shim
    // allocates anything
    if fd < 0
        || len == 0
        || flags & libc::MAP_ANONYMOUS != 0
        || flags & (libc::MAP_FIXED | libc::MAP_FIXED_NOREPLACE) != 0
        || IN_SHIM.with(|g| g.get())
    {
        return sys_mmap(addr, len, prot, flags, fd, offset as i64);
    }
    IN_SHIM.with(|g| g.set(true));
    let ret = match sea_mappable(fd, flags, prot) {
        Some((dev, ino)) => emulate_map(len, prot, flags, fd, offset as u64, dev, ino),
        None => sys_mmap(addr, len, prot, flags, fd, offset as i64),
    };
    IN_SHIM.with(|g| g.set(false));
    ret
}

/// `mmap64`: identical to [`mmap`] with a 64-bit offset.
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn mmap64(
    addr: *mut c_void,
    len: libc::size_t,
    prot: c_int,
    flags: c_int,
    fd: c_int,
    offset: libc::off64_t,
) -> *mut c_void {
    if fd < 0
        || len == 0
        || flags & libc::MAP_ANONYMOUS != 0
        || flags & (libc::MAP_FIXED | libc::MAP_FIXED_NOREPLACE) != 0
        || IN_SHIM.with(|g| g.get())
    {
        return sys_mmap(addr, len, prot, flags, fd, offset);
    }
    IN_SHIM.with(|g| g.set(true));
    let ret = match sea_mappable(fd, flags, prot) {
        Some((dev, ino)) => emulate_map(len, prot, flags, fd, offset as u64, dev, ino),
        None => sys_mmap(addr, len, prot, flags, fd, offset),
    };
    IN_SHIM.with(|g| g.set(false));
    ret
}

/// `msync`: write an emulated region back through its duplicated
/// descriptor; forward kernel mappings raw.
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn msync(addr: *mut c_void, len: libc::size_t, flags: c_int) -> c_int {
    if !IN_SHIM.with(|g| g.get()) {
        IN_SHIM.with(|g| g.set(true));
        let handled = emulated_sync(addr);
        IN_SHIM.with(|g| g.set(false));
        if let Some(r) = handled {
            return r;
        }
    }
    sys_msync(addr, len, flags)
}

/// `munmap`: release an emulated region or any sub-range of one
/// (write-back of the released range first when it is a writable
/// shared mapping, then a prefix/suffix/middle trim of the
/// bookkeeping); forward kernel mappings — including the allocator's
/// own frees — raw.
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn munmap(addr: *mut c_void, len: libc::size_t) -> c_int {
    if !IN_SHIM.with(|g| g.get()) {
        IN_SHIM.with(|g| g.set(true));
        let handled = emulated_unmap(addr, len);
        IN_SHIM.with(|g| g.set(false));
        if let Some(r) = handled {
            return r;
        }
    }
    sys_munmap(addr, len)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `translate` and the mmap gate both read `SEA_MOUNT`/`SEA_TARGET`
    /// from the environment — tests that set them must not interleave.
    static ENV_LOCK: Mutex<()> = Mutex::new(());

    fn t(mount: &str, target: &str, path: &str) -> Option<String> {
        std::env::set_var("SEA_MOUNT", mount);
        std::env::set_var("SEA_TARGET", target);
        let c = CString::new(path).unwrap();
        translate(&c).map(|s| s.to_string_lossy().into_owned())
    }

    #[test]
    fn prefix_translation() {
        let _env = ENV_LOCK.lock().unwrap();
        assert_eq!(
            t("/sea", "/data", "/sea/x/y.dat").as_deref(),
            Some("/data/x/y.dat")
        );
        assert_eq!(t("/sea", "/data", "/sea").as_deref(), Some("/data"));
        assert_eq!(t("/sea", "/data", "/seaside/x"), None);
        assert_eq!(t("/sea", "/data", "/other/x"), None);
    }

    fn scratch_target(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("sea_shim_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("SEA_MOUNT", "/sea");
        std::env::set_var("SEA_TARGET", &dir);
        std::env::remove_var("SEA_MMAP");
        dir
    }

    fn c_path(p: &std::path::Path) -> CString {
        CString::new(p.as_os_str().as_bytes()).unwrap()
    }

    #[test]
    fn private_read_mappings_fill_from_the_shared_pool() {
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("mmap_ro");
        let path = dir.join("m.dat");
        let data: Vec<u8> = (0..200_000usize).map(|k| (k.wrapping_mul(31) % 251) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let c = c_path(&path);
        unsafe {
            let fd = libc::open(c.as_ptr(), libc::O_RDONLY);
            assert!(fd >= 0);
            let (h0, f0) = mmap_pool_counters();
            let a = mmap(std::ptr::null_mut(), data.len(), libc::PROT_READ, libc::MAP_PRIVATE, fd, 0);
            assert_ne!(a, libc::MAP_FAILED, "emulated mapping failed");
            assert_eq!(std::slice::from_raw_parts(a as *const u8, data.len()), &data[..]);
            let (_, f1) = mmap_pool_counters();
            assert!(f1 > f0, "first mapping pread pool pages in");
            // a second mapping of the same file fills from the pool:
            // no new faults, only hits
            let b = mmap(std::ptr::null_mut(), data.len(), libc::PROT_READ, libc::MAP_PRIVATE, fd, 0);
            assert_ne!(b, libc::MAP_FAILED);
            assert_eq!(std::slice::from_raw_parts(b as *const u8, data.len()), &data[..]);
            let (h2, f2) = mmap_pool_counters();
            assert_eq!(f2, f1, "second mapping faulted nothing");
            assert!(h2 > h0, "second mapping hit pooled pages");
            assert_eq!(munmap(a, data.len()), 0);
            assert_eq!(munmap(b, data.len()), 0);
            libc::close(fd);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shared_writable_mappings_write_back_on_msync_and_munmap() {
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("mmap_rw");
        let path = dir.join("w.dat");
        std::fs::write(&path, vec![0u8; 8192]).unwrap();
        let c = c_path(&path);
        unsafe {
            let fd = libc::open(c.as_ptr(), libc::O_RDWR);
            assert!(fd >= 0);
            let a = mmap(
                std::ptr::null_mut(),
                8192,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                fd,
                0,
            );
            assert_ne!(a, libc::MAP_FAILED, "emulated writable mapping failed");
            let buf = std::slice::from_raw_parts_mut(a as *mut u8, 8192);
            buf[100..105].copy_from_slice(b"hello");
            // stores live only in the region until msync
            assert_eq!(&std::fs::read(&path).unwrap()[100..105], &[0u8; 5]);
            assert_eq!(msync(a, 8192, libc::MS_SYNC), 0);
            assert_eq!(&std::fs::read(&path).unwrap()[100..105], b"hello");
            // a post-msync store reaches the file via the unmap flush
            buf[0] = 9;
            assert_eq!(munmap(a, 8192), 0);
            libc::close(fd);
        }
        assert_eq!(std::fs::read(&path).unwrap()[0], 9, "munmap wrote the region back");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unmodified_shared_mappings_do_not_clobber_external_writes() {
        // review regression: write-back diffs against the fill
        // snapshot — a writable MAP_SHARED region the caller never
        // stored to (or only partly dirtied) must not rewrite the
        // whole file at sync, or it would revert concurrent updates
        // made through other descriptors to the mapping's stale bytes
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("mmap_noclobber");
        let path = dir.join("nc.dat");
        std::fs::write(&path, vec![0u8; 2 * MMAP_POOL_PAGE]).unwrap();
        let c = c_path(&path);
        unsafe {
            let fd = libc::open(c.as_ptr(), libc::O_RDWR);
            assert!(fd >= 0);
            let a = mmap(
                std::ptr::null_mut(),
                2 * MMAP_POOL_PAGE,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                fd,
                0,
            );
            assert_ne!(a, libc::MAP_FAILED, "emulated writable mapping failed");
            // dirty a few bytes in page 0 only; page 1 stays pristine
            let buf = std::slice::from_raw_parts_mut(a as *mut u8, 2 * MMAP_POOL_PAGE);
            buf[10..13].copy_from_slice(b"map");
            // meanwhile the file is updated through a plain descriptor:
            // one byte the mapping never touched, in the pristine page
            let external_off = MMAP_POOL_PAGE + 50;
            let mut on_disk = std::fs::read(&path).unwrap();
            on_disk[external_off] = 0xEE;
            std::fs::write(&path, &on_disk).unwrap();
            assert_eq!(munmap(a, 2 * MMAP_POOL_PAGE), 0);
            libc::close(fd);
        }
        let after = std::fs::read(&path).unwrap();
        assert_eq!(&after[10..13], b"map", "dirtied bytes were written back");
        assert_eq!(
            after[MMAP_POOL_PAGE + 50],
            0xEE,
            "external write outside the dirtied ranges survived the sync"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn partial_munmap_flushes_only_the_released_range() {
        // satellite regression: munmap of a sub-range must flush the
        // dirty pages inside that range only, hand the pages back to
        // the kernel, and keep tracking the surviving remainder
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("mmap_partial");
        let path = dir.join("p.dat");
        std::fs::write(&path, vec![0u8; 2 * MMAP_POOL_PAGE]).unwrap();
        let c = c_path(&path);
        unsafe {
            let fd = libc::open(c.as_ptr(), libc::O_RDWR);
            assert!(fd >= 0);
            let a = mmap(
                std::ptr::null_mut(),
                2 * MMAP_POOL_PAGE,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                fd,
                0,
            );
            assert_ne!(a, libc::MAP_FAILED, "emulated writable mapping failed");
            let buf = std::slice::from_raw_parts_mut(a as *mut u8, 2 * MMAP_POOL_PAGE);
            buf[10..14].copy_from_slice(b"head");
            buf[MMAP_POOL_PAGE + 10..MMAP_POOL_PAGE + 14].copy_from_slice(b"tail");
            // release only the first half (a prefix cut)
            assert_eq!(munmap(a, MMAP_POOL_PAGE), 0);
            let disk = std::fs::read(&path).unwrap();
            assert_eq!(&disk[10..14], b"head", "released prefix flushed");
            assert_eq!(
                &disk[MMAP_POOL_PAGE + 10..MMAP_POOL_PAGE + 14],
                &[0u8; 4],
                "surviving half is not flushed by the prefix unmap"
            );
            // the survivor is still live, still tracked at its new
            // base, and its stores land at the right file offset
            let rest = std::slice::from_raw_parts_mut(
                (a as usize + MMAP_POOL_PAGE) as *mut u8,
                MMAP_POOL_PAGE,
            );
            rest[20..24].copy_from_slice(b"more");
            libc::close(fd); // write-back runs on the duplicated fd
            assert_eq!(
                munmap((a as usize + MMAP_POOL_PAGE) as *mut c_void, MMAP_POOL_PAGE),
                0
            );
        }
        let disk = std::fs::read(&path).unwrap();
        assert_eq!(&disk[MMAP_POOL_PAGE + 10..MMAP_POOL_PAGE + 14], b"tail");
        assert_eq!(&disk[MMAP_POOL_PAGE + 20..MMAP_POOL_PAGE + 24], b"more");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn middle_munmap_splits_the_region_in_two() {
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("mmap_split");
        let path = dir.join("s.dat");
        std::fs::write(&path, vec![0u8; 3 * MMAP_POOL_PAGE]).unwrap();
        let c = c_path(&path);
        unsafe {
            let fd = libc::open(c.as_ptr(), libc::O_RDWR);
            assert!(fd >= 0);
            let a = mmap(
                std::ptr::null_mut(),
                3 * MMAP_POOL_PAGE,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                fd,
                0,
            );
            assert_ne!(a, libc::MAP_FAILED, "emulated writable mapping failed");
            let buf = std::slice::from_raw_parts_mut(a as *mut u8, 3 * MMAP_POOL_PAGE);
            buf[5..9].copy_from_slice(b"left");
            buf[MMAP_POOL_PAGE + 5..MMAP_POOL_PAGE + 8].copy_from_slice(b"mid");
            buf[2 * MMAP_POOL_PAGE + 5..2 * MMAP_POOL_PAGE + 9].copy_from_slice(b"rght");
            // cut the middle page out: it flushes, the halves do not
            assert_eq!(
                munmap((a as usize + MMAP_POOL_PAGE) as *mut c_void, MMAP_POOL_PAGE),
                0
            );
            let disk = std::fs::read(&path).unwrap();
            assert_eq!(&disk[MMAP_POOL_PAGE + 5..MMAP_POOL_PAGE + 8], b"mid");
            assert_eq!(&disk[5..9], &[0u8; 4], "left half not flushed by the cut");
            assert_eq!(&disk[2 * MMAP_POOL_PAGE + 5..2 * MMAP_POOL_PAGE + 9], &[0u8; 4]);
            // both survivors sync independently: the left at the old
            // base, the right at its new base through a duplicated fd
            assert_eq!(msync(a, MMAP_POOL_PAGE, libc::MS_SYNC), 0);
            assert_eq!(&std::fs::read(&path).unwrap()[5..9], b"left");
            let right = (a as usize + 2 * MMAP_POOL_PAGE) as *mut c_void;
            assert_eq!(munmap(right, MMAP_POOL_PAGE), 0);
            assert_eq!(
                &std::fs::read(&path).unwrap()[2 * MMAP_POOL_PAGE + 5..2 * MMAP_POOL_PAGE + 9],
                b"rght"
            );
            assert_eq!(munmap(a, MMAP_POOL_PAGE), 0);
            libc::close(fd);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn non_sea_fds_forward_to_the_kernel_mapping_path() {
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("mmap_fwd");
        // point SEA_TARGET elsewhere so the file is NOT Sea-managed
        std::env::set_var("SEA_TARGET", dir.join("elsewhere"));
        let path = dir.join("plain.dat");
        std::fs::write(&path, vec![7u8; 4096]).unwrap();
        let c = c_path(&path);
        unsafe {
            let fd = libc::open(c.as_ptr(), libc::O_RDONLY);
            assert!(fd >= 0);
            let (h0, f0) = mmap_pool_counters();
            let a = mmap(std::ptr::null_mut(), 4096, libc::PROT_READ, libc::MAP_PRIVATE, fd, 0);
            assert_ne!(a, libc::MAP_FAILED);
            assert!(std::slice::from_raw_parts(a as *const u8, 4096).iter().all(|&b| b == 7));
            assert_eq!((h0, f0), mmap_pool_counters(), "pool untouched by a kernel mapping");
            assert_eq!(munmap(a, 4096), 0);
            libc::close(fd);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
