//! `LD_PRELOAD` glibc interposer — the paper's actual mechanism (§3.1.2),
//! as a `cdylib` loadable into *unmodified* dynamically-linked binaries.
//!
//! The paper's Sea wraps "every glibc function accepting a file path" and
//! rewrites paths under the Sea mountpoint to the best storage device.
//! This shim demonstrates that mechanism end-to-end on real processes:
//! every wrapped call rewrites `SEA_MOUNT`-prefixed paths to
//! `SEA_TARGET`-prefixed ones and forwards to the real glibc symbol via
//! `dlsym(RTLD_NEXT)`.
//!
//! Policy (device selection, flush/evict rules) lives in the `sea`
//! library; keeping the shim to pure prefix translation keeps it tiny
//! and safe to inject into arbitrary binaries — the demo
//! (`examples/interpose_demo.rs`) points `SEA_TARGET` at a directory the
//! library manages.
//!
//! ## Remote transport (`SEA_SOCKET`)
//!
//! When `SEA_SOCKET` names a `sea serve` Unix socket, intercepted calls
//! on `SEA_MOUNT` paths are routed through the Sea service wire
//! protocol instead of prefix translation: `open` asks the daemon for a
//! handle (reserving a real descriptor number via `/dev/null` so the
//! virtual fd can never collide with a kernel one), and
//! `read`/`write`/`pread`/`pwrite`/`lseek`/`ftruncate`/`fsync`/
//! `fstat`/`close` on that fd become protocol round trips, as do
//! `stat`/`access`/`unlink`/`rename`/`truncate` on mount paths. Every
//! daemon response piggybacks the file's map generation; a bump
//! (another client's write spilled the file to a different device)
//! purges the file's pooled mmap pages, so one process's spill
//! invalidates every other client's emulated mappings at their next
//! fill. Mappings of remote fds are always emulated (there is no local
//! file to hand the kernel); writable `MAP_SHARED` regions write back
//! through an independently opened daemon handle. Gaps, by design:
//! `fopen`/`opendir`/`mkdir` on mount paths keep local translation,
//! `dup` of a remote fd is not tracked, and remote *read-only* shared
//! mappings are point-in-time snapshots.
//!
//! Environment:
//! * `SEA_MOUNT`  — logical mountpoint prefix (default `/sea`).
//! * `SEA_TARGET` — directory that backs the mountpoint.
//! * `SEA_SOCKET` — `sea serve` socket; routes mount paths through the
//!   daemon instead of translating them.
//! * `SEA_TRACE`  — arm the library's flight recorder at load and dump
//!   the host process's client-side events (lease revocations, …) as
//!   Chrome trace-event JSON to this path at exit. Daemon-side
//!   lifecycles land in the *daemon's* `SEA_TRACE` dump, not here.
//! * `SEA_OBS`    — set to `0` to disable the wire-RTT latency
//!   histograms the remote transport records.
//!
//! Wrapped symbols: `open`, `open64`, `openat`, `creat`, `creat64`,
//! `fopen`, `fopen64`, `stat`, `lstat`, `access`, `unlink`, `mkdir`,
//! `rename` (both arguments), `opendir`, `remove`, `truncate`,
//! `truncate64`, `chdir`, plus the mapping family below (`mmap`,
//! `mmap64`, `msync`, `munmap`).
//!
//! Offset-addressed I/O (`pread`/`pwrite`/`pread64`/`pwrite64`,
//! `lseek`/`lseek64`) is also interposed: these operate on descriptors
//! whose *path* was already translated at `open`, so no rewriting is
//! needed — the wrappers forward to the real symbols, keeping the whole
//! request path (open → positioned I/O → close) inside the shim. This
//! mirrors the library-level `VfsFile` handle API: translation happens
//! once at open, every subsequent request is offset-addressed against
//! the translated target.
//!
//! Statically-linked binaries and direct syscalls bypass the shim —
//! the same documented limitation as the paper's library.
//!
//! `mmap(2)` **is** wrapped: a non-executable mapping of a regular
//! file under `SEA_TARGET` (i.e. an fd the shim translated at `open`)
//! is *emulated* instead of forwarded — the shim carves an anonymous
//! region, fills it from a process-wide page pool keyed by
//! `(device, inode, 64 KiB page)` (the out-of-process analogue of the
//! library's shared `vfs::pages` frame pool: two mappings of one file
//! fill from the same pooled pages, faulting each page once), and
//! hands the region to the caller. `MAP_PRIVATE` read-only mappings
//! are sealed with `mprotect`; writable `MAP_SHARED` mappings keep a
//! duplicated descriptor plus a snapshot of the fill, and on
//! `msync`/`munmap` write back only the byte ranges that differ from
//! the snapshot (per 64 KiB page), invalidating the file's pooled
//! pages when anything was written — a mapping that is only ever read
//! writes nothing, and concurrent updates to the file through other
//! descriptors or processes survive outside the dirtied ranges.
//! Everything else — anonymous, `MAP_FIXED`, executable, non-Sea fds
//! — forwards straight to the kernel (`SEA_MMAP=0` disables the
//! emulation entirely). Partial `munmap` of an emulated region is
//! honored: the released sub-range is flushed and returned to the
//! kernel, and the bookkeeping is trimmed (a middle cut splits the
//! region in two, each half with its own descriptor and snapshot
//! slice). Remaining gaps: the snapshot doubles
//! the memory of a writable shared mapping; a concurrent external
//! write landing *inside* a byte range this mapping also dirtied is
//! still clobbered at sync (deferred-write semantics, vs. real
//! `MAP_SHARED`'s store-granularity merge); and pages filled before a
//! *kernel-side* writer changed the file are only invalidated by a
//! shim-side write-back.
//!
//! * `SEA_MMAP`        — set to `0` to forward every `mmap` untouched.
//! * `SEA_MMAP_BUDGET` — pool budget in bytes (default 64 MiB).

use std::cell::Cell;
use std::collections::{HashMap, VecDeque};
use std::ffi::{CStr, CString, OsStr};
use std::os::raw::{c_char, c_int, c_void};
use std::os::unix::ffi::OsStrExt;
use std::sync::{Arc, Mutex, OnceLock};

use sea::error::Error as SeaError;
use sea::vfs::{OpenMode, RemoteFile, RemoteFs, RetryCfg, Vfs, VfsFile};

// --- env + translation ------------------------------------------------------

fn env_or(name: &str, default: &str) -> Vec<u8> {
    std::env::var_os(name)
        .map(|v| v.as_bytes().to_vec())
        .unwrap_or_else(|| default.as_bytes().to_vec())
}

/// Is `path` the `SEA_MOUNT` prefix itself or a child of it?
fn under_mount(bytes: &[u8]) -> bool {
    let mount = env_or("SEA_MOUNT", "/sea");
    if !bytes.starts_with(&mount) {
        return false;
    }
    // exact prefix or prefix + '/'
    let rest = &bytes[mount.len()..];
    rest.is_empty() || rest[0] == b'/'
}

/// Translate `path` if it lies under `SEA_MOUNT`; returns the rewritten
/// C string (kept alive by the caller's scope).
fn translate(path: &CStr) -> Option<CString> {
    let bytes = path.to_bytes();
    if !under_mount(bytes) {
        return None;
    }
    let mount = env_or("SEA_MOUNT", "/sea");
    let mut out = env_or("SEA_TARGET", "/tmp/sea_target");
    out.extend_from_slice(&bytes[mount.len()..]);
    CString::new(out).ok()
}

/// Flag a missing real symbol to the caller: libc contracts promise a
/// meaningful errno alongside the error return.
unsafe fn no_sym<T>(ret: T) -> T {
    *libc::__errno_location() = libc::ENOSYS;
    ret
}

/// Resolve the next (real) definition of `$name`, caching the lookup so
/// hot paths (pread/pwrite) don't pay a dlsym string search per call.
macro_rules! real {
    ($name:literal, $ty:ty) => {{
        static SYM: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
        let addr = *SYM.get_or_init(|| unsafe {
            libc::dlsym(libc::RTLD_NEXT, $name.as_ptr() as *const c_char) as usize
        });
        if addr == 0 {
            None
        } else {
            Some(unsafe { std::mem::transmute::<usize, $ty>(addr) })
        }
    }};
}

/// Wrap a single-path function: translate arg 0, forward the rest.
macro_rules! wrap_path_fn {
    ($name:ident, $cname:literal, ($($arg:ident : $argty:ty),*), $ret:ty, $errno_ret:expr) => {
        /// glibc interposer: translate Sea-mounted paths, forward to libc.
        ///
        /// # Safety
        /// Called by arbitrary C code with C ABI invariants; `path` must
        /// be a valid NUL-terminated string (as libc requires).
        #[no_mangle]
        pub unsafe extern "C" fn $name(path: *const c_char $(, $arg: $argty)*) -> $ret {
            type Fn = unsafe extern "C" fn(*const c_char $(, $argty)*) -> $ret;
            let Some(real) = real!($cname, Fn) else { return no_sym($errno_ret); };
            if path.is_null() {
                return real(path $(, $arg)*);
            }
            let c = CStr::from_ptr(path);
            match translate(c) {
                Some(t) => real(t.as_ptr() $(, $arg)*),
                None => real(path $(, $arg)*),
            }
        }
    };
}

// path functions with no remote-transport meaning keep the pure
// translation macro; the open/stat/unlink/mkdir families below are
// written out by hand so they can try the SEA_SOCKET route first
wrap_path_fn!(chdir, b"chdir\0", (), c_int, -1);

/// `mkdir`: mount paths are created through the daemon (the backend
/// decides what a directory means — `RealFs` trees create for real,
/// virtual namespaces no-op), so workloads laying out output trees
/// under `/sea` work unchanged. `mode` only reaches the local
/// fallback: the daemon's files are daemon-owned and its `RealFs`
/// creates directories with its own umask.
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn mkdir(path: *const c_char, mode: libc::mode_t) -> c_int {
    if let Some(r) = remote_path_op(path, |fs, p| match fs.mkdir(p) {
        Ok(()) => 0,
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    }) {
        return r;
    }
    type Fn = unsafe extern "C" fn(*const c_char, libc::mode_t) -> c_int;
    let Some(real) = real!(b"mkdir\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, mode);
    }
    match translate(CStr::from_ptr(path)) {
        Some(t) => real(t.as_ptr(), mode),
        None => real(path, mode),
    }
}

// --- flight recorder (SEA_TRACE) --------------------------------------------

/// Arm the library's flight recorder when `SEA_TRACE` names a dump
/// path. Runs from `.init_array` — after libc is up, before `main` —
/// so events from the host process's very first intercepted call are
/// captured; the dump is registered with `atexit(3)`.
extern "C" {
    fn atexit(cb: extern "C" fn()) -> c_int;
}

extern "C" fn sea_trace_init() {
    if std::env::var_os("SEA_TRACE").is_some() {
        sea::obs::trace::set_enabled(true);
        unsafe { atexit(sea_trace_dump) };
    }
}

extern "C" fn sea_trace_dump() {
    if let Some(p) = std::env::var_os("SEA_TRACE") {
        let _ = sea::obs::trace::dump_to(std::path::Path::new(&p));
    }
}

#[used]
#[link_section = ".init_array"]
static SEA_TRACE_CTOR: extern "C" fn() = sea_trace_init;

// --- remote transport (SEA_SOCKET) ------------------------------------------
//
// With a `sea serve` daemon on the other end of `SEA_SOCKET`, mount
// paths stop being *translated* and start being *served*: the daemon
// owns the one SeaFs (registry, ledger, page cache), and every client
// process's intercepted calls become wire-protocol round trips. The
// descriptor table below maps real fd numbers (reserved on /dev/null)
// to daemon handles; per-entry mutexes keep the table lock itself off
// the socket's critical path, so an in-process daemon thread passing
// through these wrappers can never deadlock against a client call.

/// Remote routing is live only when the env var is present.
fn remote_enabled() -> bool {
    std::env::var_os("SEA_SOCKET").is_some()
}

/// Process-wide daemon client, dialed on first use. A changed
/// `SEA_SOCKET` re-dials (tests); a failed dial is not cached, so a
/// daemon that comes up later is still reachable.
fn remote_client() -> Option<Arc<RemoteFs>> {
    let sock = std::env::var_os("SEA_SOCKET")?;
    static CLIENT: OnceLock<Mutex<Option<Arc<RemoteFs>>>> = OnceLock::new();
    let cell = CLIENT.get_or_init(|| Mutex::new(None));
    let mut g = cell.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(c) = g.as_ref() {
        if c.socket() == std::path::Path::new(&sock) {
            return Some(c.clone());
        }
    }
    // snappier than the library default: a shim open should fail fast
    // when no daemon is listening, not stall the host program
    let retry = RetryCfg {
        attempts: 3,
        base: std::time::Duration::from_millis(20),
        cap: std::time::Duration::from_millis(200),
    };
    match RemoteFs::connect_with(std::path::PathBuf::from(&sock), retry) {
        Ok(fs) => {
            let fs = Arc::new(fs);
            *g = Some(fs.clone());
            Some(fs)
        }
        Err(_) => {
            *g = None;
            None
        }
    }
}

/// One daemon-backed descriptor: the wire handle plus the cursor
/// (`read`/`write`/`lseek` need one) and the last observed map
/// generation for pool invalidation.
struct RemoteFd {
    file: RemoteFile,
    pos: u64,
    path: Vec<u8>,
    gen: u64,
}

/// fd → daemon handle. Entries are `Arc<Mutex<..>>` so the table lock
/// is only ever held for a lookup, never across socket I/O.
fn remote_fds() -> &'static Mutex<HashMap<c_int, Arc<Mutex<RemoteFd>>>> {
    static FDS: OnceLock<Mutex<HashMap<c_int, Arc<Mutex<RemoteFd>>>>> = OnceLock::new();
    FDS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Set errno from safe code (closures passed into the routing helpers).
fn set_errno(code: c_int) {
    unsafe { *libc::__errno_location() = code };
}

/// Map a sea error onto the errno the equivalent syscall would set.
fn set_sea_errno(e: &SeaError) {
    set_errno(match e {
        SeaError::NotFound(_) => libc::ENOENT,
        SeaError::NoSpace { .. } => libc::ENOSPC,
        SeaError::OutsideMount(_) => libc::EACCES,
        SeaError::InvalidArg(_) => libc::EINVAL,
        _ => libc::EIO,
    });
}

/// `open(2)` flags → the library's handle mode. `O_WRONLY` without
/// `O_TRUNC` maps to `ReadWrite`: positioned writes must preserve the
/// existing bytes even though the caller never reads.
fn mode_from_flags(flags: c_int) -> OpenMode {
    if flags & libc::O_APPEND != 0 {
        OpenMode::Append
    } else if flags & libc::O_ACCMODE == libc::O_RDONLY {
        OpenMode::Read
    } else if flags & libc::O_TRUNC != 0 {
        OpenMode::Write
    } else {
        OpenMode::ReadWrite
    }
}

/// Pool key for a remote file: the daemon-reported frame-sharing
/// identity when it names one, else a hash of the logical path (two
/// FNV-1a streams with different bases).
fn remote_pool_key(r: &RemoteFd) -> (u64, u64) {
    if let Some(id) = r.file.identity() {
        return ((id >> 64) as u64, id as u64);
    }
    let mut a: u64 = 0xcbf2_9ce4_8422_2325;
    let mut b: u64 = 0x8422_2325_cbf2_9ce4;
    for &x in &r.path {
        a = (a ^ x as u64).wrapping_mul(0x100_0000_01b3);
        b = (b ^ x as u64).wrapping_mul(0x100_0000_01b3);
    }
    (a, b)
}

/// Observe the piggybacked daemon map generation: a bump means another
/// client's write spilled the file to a different device — purge its
/// pooled pages so later mapping fills re-read through the daemon
/// instead of serving pre-spill bytes.
fn note_remote_gen(r: &mut RemoteFd) {
    let g = r.file.generation();
    if g == r.gen {
        return;
    }
    r.gen = g;
    let (hi, lo) = remote_pool_key(r);
    let mut p = pool().lock().unwrap_or_else(|e| e.into_inner());
    p.fifo.retain(|k| k.0 != hi || k.1 != lo);
    p.pages.retain(|k, _| k.0 != hi || k.1 != lo);
}

/// Run `f` against the remote entry for `fd` with the re-entrancy
/// guard held (the entry's socket I/O re-enters `read`/`write` below,
/// which must forward raw). `None` = not a remote fd; fall through.
unsafe fn with_remote_fd<R>(fd: c_int, f: impl FnOnce(&mut RemoteFd) -> R) -> Option<R> {
    if !remote_enabled() || IN_SHIM.with(|g| g.get()) {
        return None;
    }
    let entry = {
        let m = remote_fds().lock().unwrap_or_else(|e| e.into_inner());
        m.get(&fd).cloned()
    }?;
    IN_SHIM.with(|g| g.set(true));
    let out = {
        let mut e = entry.lock().unwrap_or_else(|e| e.into_inner());
        f(&mut e)
    };
    IN_SHIM.with(|g| g.set(false));
    Some(out)
}

/// Route a path-addressed call through the daemon when `SEA_SOCKET` is
/// set and `path` lies under `SEA_MOUNT`; `None` falls through to the
/// local translation path.
unsafe fn remote_path_op(
    path: *const c_char,
    f: impl FnOnce(&RemoteFs, &std::path::Path) -> c_int,
) -> Option<c_int> {
    if path.is_null() || !remote_enabled() || IN_SHIM.with(|g| g.get()) {
        return None;
    }
    let bytes = CStr::from_ptr(path).to_bytes();
    if !under_mount(bytes) {
        return None;
    }
    IN_SHIM.with(|g| g.set(true));
    let ret = match remote_client() {
        Some(fs) => f(&fs, std::path::Path::new(OsStr::from_bytes(bytes))),
        None => {
            *libc::__errno_location() = libc::ECONNREFUSED;
            -1
        }
    };
    IN_SHIM.with(|g| g.set(false));
    Some(ret)
}

/// Reserve a real descriptor number (on /dev/null) so a virtual remote
/// fd can never collide with one the kernel hands out later.
fn reserve_fd_slot() -> c_int {
    unsafe {
        libc::open(
            b"/dev/null\0".as_ptr() as *const c_char,
            libc::O_RDONLY | libc::O_CLOEXEC,
        )
    }
}

/// The remote half of the `open` family: ask the daemon for a handle
/// and pin a real descriptor number to it.
unsafe fn remote_open(path: *const c_char, flags: c_int) -> Option<c_int> {
    remote_path_op(path, |fs, p| match fs.open_remote(p, mode_from_flags(flags)) {
        Ok(file) => {
            let placeholder = reserve_fd_slot();
            if placeholder < 0 {
                return -1; // open(2) left errno
            }
            let gen = file.generation();
            let entry = Arc::new(Mutex::new(RemoteFd {
                file,
                pos: 0,
                path: p.as_os_str().as_bytes().to_vec(),
                gen,
            }));
            remote_fds()
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .insert(placeholder, entry);
            placeholder
        }
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    })
}

fn remote_pread_at(r: &mut RemoteFd, buf: *mut u8, count: usize, off: u64) -> libc::ssize_t {
    if count == 0 {
        return 0;
    }
    // safe-fn wrapper over the caller's (validated) libc buffer so the
    // routing closures stay free of lexical unsafety
    let out = unsafe { std::slice::from_raw_parts_mut(buf, count) };
    match r.file.pread(out, off) {
        Ok(n) => {
            note_remote_gen(r);
            n as libc::ssize_t
        }
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    }
}

fn remote_pwrite_at(r: &mut RemoteFd, buf: *const u8, count: usize, off: u64) -> libc::ssize_t {
    if count == 0 {
        return 0;
    }
    let data = unsafe { std::slice::from_raw_parts(buf, count) };
    // the wire clamps to one frame; a short count back is valid POSIX
    match r.file.pwrite(data, off) {
        Ok(n) => {
            note_remote_gen(r);
            n as libc::ssize_t
        }
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    }
}

/// Fill a zeroed stat buffer as a regular file of `len` bytes (the
/// daemon's answer has no kernel inode behind it). The `allow` keeps
/// the expansion valid in both safe and already-unsafe contexts.
macro_rules! fill_remote_stat {
    ($buf:expr, $len:expr) => {{
        #[allow(unused_unsafe)]
        unsafe {
            std::ptr::write_bytes($buf, 0, 1);
            let st = &mut *$buf;
            st.st_mode = libc::S_IFREG | 0o644;
            st.st_nlink = 1;
            st.st_size = $len as _;
            st.st_blksize = 4096;
            st.st_blocks = $len.div_ceil(512) as _;
            st.st_uid = libc::getuid();
            st.st_gid = libc::getgid();
        }
    }};
}

/// glibc interposer: route Sea-mounted paths to the daemon
/// (`SEA_SOCKET`) or translate the prefix, then forward to libc.
///
/// # Safety
/// Called by arbitrary C code with C ABI invariants; `path` must be a
/// valid NUL-terminated string (as libc requires).
#[no_mangle]
pub unsafe extern "C" fn open(path: *const c_char, flags: c_int, mode: libc::mode_t) -> c_int {
    if let Some(fd) = remote_open(path, flags) {
        return fd;
    }
    type Fn = unsafe extern "C" fn(*const c_char, c_int, libc::mode_t) -> c_int;
    let Some(real) = real!(b"open\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, flags, mode);
    }
    match translate(CStr::from_ptr(path)) {
        Some(t) => real(t.as_ptr(), flags, mode),
        None => real(path, flags, mode),
    }
}

/// `open64`: identical to [`open`].
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn open64(path: *const c_char, flags: c_int, mode: libc::mode_t) -> c_int {
    if let Some(fd) = remote_open(path, flags) {
        return fd;
    }
    type Fn = unsafe extern "C" fn(*const c_char, c_int, libc::mode_t) -> c_int;
    let Some(real) = real!(b"open64\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, flags, mode);
    }
    match translate(CStr::from_ptr(path)) {
        Some(t) => real(t.as_ptr(), flags, mode),
        None => real(path, flags, mode),
    }
}

/// `creat` ≡ `open(path, O_WRONLY|O_CREAT|O_TRUNC, mode)`.
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn creat(path: *const c_char, mode: libc::mode_t) -> c_int {
    if let Some(fd) = remote_open(path, libc::O_WRONLY | libc::O_CREAT | libc::O_TRUNC) {
        return fd;
    }
    type Fn = unsafe extern "C" fn(*const c_char, libc::mode_t) -> c_int;
    let Some(real) = real!(b"creat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, mode);
    }
    match translate(CStr::from_ptr(path)) {
        Some(t) => real(t.as_ptr(), mode),
        None => real(path, mode),
    }
}

/// `creat64`: identical to [`creat`].
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn creat64(path: *const c_char, mode: libc::mode_t) -> c_int {
    if let Some(fd) = remote_open(path, libc::O_WRONLY | libc::O_CREAT | libc::O_TRUNC) {
        return fd;
    }
    type Fn = unsafe extern "C" fn(*const c_char, libc::mode_t) -> c_int;
    let Some(real) = real!(b"creat64\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, mode);
    }
    match translate(CStr::from_ptr(path)) {
        Some(t) => real(t.as_ptr(), mode),
        None => real(path, mode),
    }
}

/// `unlink`: remote mount paths unlink through the daemon's registry.
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn unlink(path: *const c_char) -> c_int {
    if let Some(r) = remote_path_op(path, |fs, p| match fs.unlink(p) {
        Ok(()) => 0,
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    }) {
        return r;
    }
    type Fn = unsafe extern "C" fn(*const c_char) -> c_int;
    let Some(real) = real!(b"unlink\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path);
    }
    match translate(CStr::from_ptr(path)) {
        Some(t) => real(t.as_ptr()),
        None => real(path),
    }
}

/// `remove`: for files this is `unlink`; route the same way.
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn remove(path: *const c_char) -> c_int {
    if let Some(r) = remote_path_op(path, |fs, p| match fs.unlink(p) {
        Ok(()) => 0,
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    }) {
        return r;
    }
    type Fn = unsafe extern "C" fn(*const c_char) -> c_int;
    let Some(real) = real!(b"remove\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path);
    }
    match translate(CStr::from_ptr(path)) {
        Some(t) => real(t.as_ptr()),
        None => real(path),
    }
}

/// `access`: the daemon has no permission model — existence answers
/// every probe mode.
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn access(path: *const c_char, amode: c_int) -> c_int {
    if let Some(r) = remote_path_op(path, |fs, p| {
        if fs.exists(p) {
            0
        } else {
            set_errno(libc::ENOENT);
            -1
        }
    }) {
        return r;
    }
    type Fn = unsafe extern "C" fn(*const c_char, c_int) -> c_int;
    let Some(real) = real!(b"access\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, amode);
    }
    match translate(CStr::from_ptr(path)) {
        Some(t) => real(t.as_ptr(), amode),
        None => real(path, amode),
    }
}

/// `truncate`: a remote path resolves to open + set_len + close.
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn truncate(path: *const c_char, len: libc::off_t) -> c_int {
    if let Some(r) = remote_truncate(path, len as i64) {
        return r;
    }
    type Fn = unsafe extern "C" fn(*const c_char, libc::off_t) -> c_int;
    let Some(real) = real!(b"truncate\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, len);
    }
    match translate(CStr::from_ptr(path)) {
        Some(t) => real(t.as_ptr(), len),
        None => real(path, len),
    }
}

/// `truncate64`: identical to [`truncate`].
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn truncate64(path: *const c_char, len: libc::off64_t) -> c_int {
    if let Some(r) = remote_truncate(path, len) {
        return r;
    }
    type Fn = unsafe extern "C" fn(*const c_char, libc::off64_t) -> c_int;
    let Some(real) = real!(b"truncate64\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, len);
    }
    match translate(CStr::from_ptr(path)) {
        Some(t) => real(t.as_ptr(), len),
        None => real(path, len),
    }
}

/// Remote `rename`: both names under the mount route as one protocol
/// op; a mixed pair is a cross-device link (`EXDEV`), exactly like a
/// rename across real file systems.
unsafe fn remote_rename(from: *const c_char, to: *const c_char) -> Option<c_int> {
    if from.is_null() || to.is_null() || !remote_enabled() || IN_SHIM.with(|g| g.get()) {
        return None;
    }
    let fb = CStr::from_ptr(from).to_bytes();
    let tb = CStr::from_ptr(to).to_bytes();
    let (fu, tu) = (under_mount(fb), under_mount(tb));
    if !fu && !tu {
        return None;
    }
    IN_SHIM.with(|g| g.set(true));
    let ret = if fu != tu {
        set_errno(libc::EXDEV);
        -1
    } else {
        match remote_client() {
            None => {
                set_errno(libc::ECONNREFUSED);
                -1
            }
            Some(fs) => {
                let f = std::path::Path::new(OsStr::from_bytes(fb));
                let t = std::path::Path::new(OsStr::from_bytes(tb));
                match fs.rename(f, t) {
                    Ok(()) => 0,
                    Err(e) => {
                        set_sea_errno(&e);
                        -1
                    }
                }
            }
        }
    };
    IN_SHIM.with(|g| g.set(false));
    Some(ret)
}

unsafe fn remote_truncate(path: *const c_char, len: i64) -> Option<c_int> {
    remote_path_op(path, |fs, p| {
        if len < 0 {
            set_errno(libc::EINVAL);
            return -1;
        }
        match fs
            .open(p, OpenMode::ReadWrite)
            .and_then(|mut f| f.set_len(len as u64))
        {
            Ok(()) => 0,
            Err(e) => {
                set_sea_errno(&e);
                -1
            }
        }
    })
}

/// `pread`: remote fds round-trip the daemon, everything else forwards
/// (the descriptor's path was translated at `open`).
///
/// # Safety
/// C ABI; pointer arguments must be valid per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn pread(
    fd: c_int,
    buf: *mut c_void,
    count: libc::size_t,
    offset: libc::off_t,
) -> libc::ssize_t {
    if let Some(r) = with_remote_fd(fd, |r| remote_pread_at(r, buf as *mut u8, count, offset as u64))
    {
        return r;
    }
    type Fn = unsafe extern "C" fn(c_int, *mut c_void, libc::size_t, libc::off_t) -> libc::ssize_t;
    let Some(real) = real!(b"pread\0", Fn) else { return no_sym(-1) };
    real(fd, buf, count, offset)
}

/// `pread64`: identical to [`pread`].
///
/// # Safety
/// C ABI; pointer arguments must be valid per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn pread64(
    fd: c_int,
    buf: *mut c_void,
    count: libc::size_t,
    offset: libc::off64_t,
) -> libc::ssize_t {
    if let Some(r) = with_remote_fd(fd, |r| remote_pread_at(r, buf as *mut u8, count, offset as u64))
    {
        return r;
    }
    type Fn =
        unsafe extern "C" fn(c_int, *mut c_void, libc::size_t, libc::off64_t) -> libc::ssize_t;
    let Some(real) = real!(b"pread64\0", Fn) else { return no_sym(-1) };
    real(fd, buf, count, offset)
}

/// `pwrite`: remote fds round-trip the daemon.
///
/// # Safety
/// C ABI; pointer arguments must be valid per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn pwrite(
    fd: c_int,
    buf: *const c_void,
    count: libc::size_t,
    offset: libc::off_t,
) -> libc::ssize_t {
    if let Some(r) =
        with_remote_fd(fd, |r| remote_pwrite_at(r, buf as *const u8, count, offset as u64))
    {
        return r;
    }
    type Fn =
        unsafe extern "C" fn(c_int, *const c_void, libc::size_t, libc::off_t) -> libc::ssize_t;
    let Some(real) = real!(b"pwrite\0", Fn) else { return no_sym(-1) };
    real(fd, buf, count, offset)
}

/// `pwrite64`: identical to [`pwrite`].
///
/// # Safety
/// C ABI; pointer arguments must be valid per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn pwrite64(
    fd: c_int,
    buf: *const c_void,
    count: libc::size_t,
    offset: libc::off64_t,
) -> libc::ssize_t {
    if let Some(r) =
        with_remote_fd(fd, |r| remote_pwrite_at(r, buf as *const u8, count, offset as u64))
    {
        return r;
    }
    type Fn =
        unsafe extern "C" fn(c_int, *const c_void, libc::size_t, libc::off64_t) -> libc::ssize_t;
    let Some(real) = real!(b"pwrite64\0", Fn) else { return no_sym(-1) };
    real(fd, buf, count, offset)
}

/// `read`: remote fds read at the tracked cursor.
///
/// # Safety
/// C ABI; pointer arguments must be valid per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn read(fd: c_int, buf: *mut c_void, count: libc::size_t) -> libc::ssize_t {
    if let Some(r) = with_remote_fd(fd, |r| {
        let pos = r.pos;
        let n = remote_pread_at(r, buf as *mut u8, count, pos);
        if n > 0 {
            r.pos = pos + n as u64;
        }
        n
    }) {
        return r;
    }
    type Fn = unsafe extern "C" fn(c_int, *mut c_void, libc::size_t) -> libc::ssize_t;
    let Some(real) = real!(b"read\0", Fn) else { return no_sym(-1) };
    real(fd, buf, count)
}

/// `write`: remote fds write at the tracked cursor (append handles
/// resolve their real offset daemon-side, under the registry lock).
///
/// # Safety
/// C ABI; pointer arguments must be valid per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn write(
    fd: c_int,
    buf: *const c_void,
    count: libc::size_t,
) -> libc::ssize_t {
    if let Some(r) = with_remote_fd(fd, |r| {
        let pos = r.pos;
        let n = remote_pwrite_at(r, buf as *const u8, count, pos);
        if n > 0 {
            r.pos = pos + n as u64;
        }
        n
    }) {
        return r;
    }
    type Fn = unsafe extern "C" fn(c_int, *const c_void, libc::size_t) -> libc::ssize_t;
    let Some(real) = real!(b"write\0", Fn) else { return no_sym(-1) };
    real(fd, buf, count)
}

/// `lseek`: remote fds move the local cursor (`SEEK_END` asks the
/// daemon for the live length).
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn lseek(fd: c_int, offset: libc::off_t, whence: c_int) -> libc::off_t {
    if let Some(r) = with_remote_fd(fd, |r| remote_seek(r, offset as i64, whence)) {
        return r as libc::off_t;
    }
    type Fn = unsafe extern "C" fn(c_int, libc::off_t, c_int) -> libc::off_t;
    let Some(real) = real!(b"lseek\0", Fn) else { return no_sym(-1) };
    real(fd, offset, whence)
}

/// `lseek64`: identical to [`lseek`].
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn lseek64(fd: c_int, offset: libc::off64_t, whence: c_int) -> libc::off64_t {
    if let Some(r) = with_remote_fd(fd, |r| remote_seek(r, offset, whence)) {
        return r;
    }
    type Fn = unsafe extern "C" fn(c_int, libc::off64_t, c_int) -> libc::off64_t;
    let Some(real) = real!(b"lseek64\0", Fn) else { return no_sym(-1) };
    real(fd, offset, whence)
}

fn remote_seek(r: &mut RemoteFd, offset: i64, whence: c_int) -> i64 {
    let base = match whence {
        libc::SEEK_SET => 0,
        libc::SEEK_CUR => r.pos as i64,
        libc::SEEK_END => match r.file.len() {
            Ok(n) => n as i64,
            Err(e) => {
                set_sea_errno(&e);
                return -1;
            }
        },
        _ => {
            set_errno(libc::EINVAL);
            return -1;
        }
    };
    match base.checked_add(offset) {
        Some(t) if t >= 0 => {
            r.pos = t as u64;
            t
        }
        _ => {
            set_errno(libc::EINVAL);
            -1
        }
    }
}

/// `ftruncate`: remote fds set the daemon-side length.
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn ftruncate(fd: c_int, len: libc::off_t) -> c_int {
    if let Some(r) = with_remote_fd(fd, |r| remote_ftruncate(r, len as i64)) {
        return r;
    }
    type Fn = unsafe extern "C" fn(c_int, libc::off_t) -> c_int;
    let Some(real) = real!(b"ftruncate\0", Fn) else { return no_sym(-1) };
    real(fd, len)
}

/// `ftruncate64`: identical to [`ftruncate`].
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn ftruncate64(fd: c_int, len: libc::off64_t) -> c_int {
    if let Some(r) = with_remote_fd(fd, |r| remote_ftruncate(r, len)) {
        return r;
    }
    type Fn = unsafe extern "C" fn(c_int, libc::off64_t) -> c_int;
    let Some(real) = real!(b"ftruncate64\0", Fn) else { return no_sym(-1) };
    real(fd, len)
}

fn remote_ftruncate(r: &mut RemoteFd, len: i64) -> c_int {
    if len < 0 {
        set_errno(libc::EINVAL);
        return -1;
    }
    match r.file.set_len(len as u64) {
        Ok(()) => {
            note_remote_gen(r);
            0
        }
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    }
}

/// `fsync`: remote fds flush through the daemon.
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn fsync(fd: c_int) -> c_int {
    if let Some(r) = with_remote_fd(fd, |r| match r.file.fsync() {
        Ok(()) => 0,
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    }) {
        return r;
    }
    type Fn = unsafe extern "C" fn(c_int) -> c_int;
    let Some(real) = real!(b"fsync\0", Fn) else { return no_sym(-1) };
    real(fd)
}

/// `fdatasync`: the daemon makes no data/metadata distinction.
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn fdatasync(fd: c_int) -> c_int {
    if let Some(r) = with_remote_fd(fd, |r| match r.file.fsync() {
        Ok(()) => 0,
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    }) {
        return r;
    }
    type Fn = unsafe extern "C" fn(c_int) -> c_int;
    let Some(real) = real!(b"fdatasync\0", Fn) else { return no_sym(-1) };
    real(fd)
}

/// `fstat`: remote fds report the daemon-side length as a plain
/// regular file (the placeholder fd is a char device — never expose
/// its stat).
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn fstat(fd: c_int, buf: *mut libc::stat) -> c_int {
    if let Some(r) = with_remote_fd(fd, |r| match r.file.len() {
        Ok(n) => {
            fill_remote_stat!(buf, n);
            0
        }
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    }) {
        return r;
    }
    type Fn = unsafe extern "C" fn(c_int, *mut libc::stat) -> c_int;
    let Some(real) = real!(b"fstat\0", Fn) else { return no_sym(-1) };
    real(fd, buf)
}

/// `fstat64`: identical to [`fstat`].
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn fstat64(fd: c_int, buf: *mut libc::stat64) -> c_int {
    if let Some(r) = with_remote_fd(fd, |r| match r.file.len() {
        Ok(n) => {
            fill_remote_stat!(buf, n);
            0
        }
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    }) {
        return r;
    }
    type Fn = unsafe extern "C" fn(c_int, *mut libc::stat64) -> c_int;
    let Some(real) = real!(b"fstat64\0", Fn) else { return no_sym(-1) };
    real(fd, buf)
}

/// `close`: dropping the table entry sends the protocol `Close`; the
/// placeholder descriptor is then released for real.
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn close(fd: c_int) -> c_int {
    if remote_enabled() && !IN_SHIM.with(|g| g.get()) {
        IN_SHIM.with(|g| g.set(true));
        let taken = remote_fds().lock().unwrap_or_else(|e| e.into_inner()).remove(&fd);
        // drop outside the table lock: the protocol Close round-trips
        drop(taken);
        IN_SHIM.with(|g| g.set(false));
    }
    type Fn = unsafe extern "C" fn(c_int) -> c_int;
    let Some(real) = real!(b"close\0", Fn) else { return no_sym(-1) };
    real(fd)
}

/// `openat`: translate the path argument (position 1).
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn openat(
    dirfd: c_int,
    path: *const c_char,
    flags: c_int,
    mode: libc::mode_t,
) -> c_int {
    // mount paths are absolute, so dirfd is irrelevant per POSIX
    if let Some(fd) = remote_open(path, flags) {
        return fd;
    }
    type Fn = unsafe extern "C" fn(c_int, *const c_char, c_int, libc::mode_t) -> c_int;
    let Some(real) = real!(b"openat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(dirfd, path, flags, mode);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(dirfd, t.as_ptr(), flags, mode),
        None => real(dirfd, path, flags, mode),
    }
}

/// `fopen`: translate the path argument.
///
/// # Safety
/// C ABI; `path`/`modes` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn fopen(path: *const c_char, modes: *const c_char) -> *mut libc::FILE {
    type Fn = unsafe extern "C" fn(*const c_char, *const c_char) -> *mut libc::FILE;
    let Some(real) = real!(b"fopen\0", Fn) else { return no_sym(std::ptr::null_mut()) };
    if path.is_null() {
        return real(path, modes);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr(), modes),
        None => real(path, modes),
    }
}

/// `fopen64`: translate the path argument.
///
/// # Safety
/// C ABI; `path`/`modes` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn fopen64(path: *const c_char, modes: *const c_char) -> *mut libc::FILE {
    type Fn = unsafe extern "C" fn(*const c_char, *const c_char) -> *mut libc::FILE;
    let Some(real) = real!(b"fopen64\0", Fn) else { return no_sym(std::ptr::null_mut()) };
    if path.is_null() {
        return real(path, modes);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr(), modes),
        None => real(path, modes),
    }
}

/// `stat`: translate the path argument.
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn stat(path: *const c_char, buf: *mut libc::stat) -> c_int {
    if let Some(r) = remote_path_op(path, |fs, p| match fs.size(p) {
        Ok(n) => {
            fill_remote_stat!(buf, n);
            0
        }
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    }) {
        return r;
    }
    type Fn = unsafe extern "C" fn(*const c_char, *mut libc::stat) -> c_int;
    let Some(real) = real!(b"stat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, buf);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr(), buf),
        None => real(path, buf),
    }
}

/// `lstat`: translate the path argument.
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn lstat(path: *const c_char, buf: *mut libc::stat) -> c_int {
    // the daemon namespace has no symlinks: lstat ≡ stat there
    if let Some(r) = remote_path_op(path, |fs, p| match fs.size(p) {
        Ok(n) => {
            fill_remote_stat!(buf, n);
            0
        }
        Err(e) => {
            set_sea_errno(&e);
            -1
        }
    }) {
        return r;
    }
    type Fn = unsafe extern "C" fn(*const c_char, *mut libc::stat) -> c_int;
    let Some(real) = real!(b"lstat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, buf);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr(), buf),
        None => real(path, buf),
    }
}

/// `rename`: translate *both* arguments.
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn rename(from: *const c_char, to: *const c_char) -> c_int {
    if let Some(r) = remote_rename(from, to) {
        return r;
    }
    type Fn = unsafe extern "C" fn(*const c_char, *const c_char) -> c_int;
    let Some(real) = real!(b"rename\0", Fn) else { return no_sym(-1) };
    let tf = if from.is_null() { None } else { translate(CStr::from_ptr(from)) };
    let tt = if to.is_null() { None } else { translate(CStr::from_ptr(to)) };
    let fp = tf.as_ref().map(|c| c.as_ptr()).unwrap_or(from);
    let tp = tt.as_ref().map(|c| c.as_ptr()).unwrap_or(to);
    real(fp, tp)
}

/// `statx`: translate the path argument (modern coreutils stat path).
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn statx(
    dirfd: c_int,
    path: *const c_char,
    flags: c_int,
    mask: libc::c_uint,
    buf: *mut libc::statx,
) -> c_int {
    type Fn = unsafe extern "C" fn(
        c_int,
        *const c_char,
        c_int,
        libc::c_uint,
        *mut libc::statx,
    ) -> c_int;
    let Some(real) = real!(b"statx\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(dirfd, path, flags, mask, buf);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(dirfd, t.as_ptr(), flags, mask, buf),
        None => real(dirfd, path, flags, mask, buf),
    }
}

/// `fstatat` (a.k.a. `newfstatat`): translate the path argument.
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn fstatat(
    dirfd: c_int,
    path: *const c_char,
    buf: *mut libc::stat,
    flags: c_int,
) -> c_int {
    type Fn = unsafe extern "C" fn(c_int, *const c_char, *mut libc::stat, c_int) -> c_int;
    let Some(real) = real!(b"fstatat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(dirfd, path, buf, flags);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(dirfd, t.as_ptr(), buf, flags),
        None => real(dirfd, path, buf, flags),
    }
}

/// `opendir`: translate the path argument.
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn opendir(path: *const c_char) -> *mut libc::DIR {
    type Fn = unsafe extern "C" fn(*const c_char) -> *mut libc::DIR;
    let Some(real) = real!(b"opendir\0", Fn) else { return no_sym(std::ptr::null_mut()) };
    if path.is_null() {
        return real(path);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr()),
        None => real(path),
    }
}

// --- mmap interposition ------------------------------------------------------
//
// The shim-side analogue of the library's shared PageCache: emulated
// mappings of Sea-translated descriptors fill from one process-wide
// pool keyed by (device, inode, page), so two mappings of a file fault
// each page once. Forwards go through raw syscalls, not the dlsym'd
// symbol: malloc itself allocates with anonymous mmap (and frees with
// munmap), so the forward path must not allocate or re-enter the
// symbol resolver.

/// Pool page size: matches the library's `DEFAULT_PAGE_BYTES`.
const MMAP_POOL_PAGE: usize = 64 * 1024;

/// Default pool budget (bytes), overridable via `SEA_MMAP_BUDGET`.
const MMAP_POOL_BUDGET: usize = 64 * 1024 * 1024;

struct MmapPool {
    /// `(device, inode, page index)` → page bytes (zero-padded tail).
    pages: HashMap<(u64, u64, u64), Vec<u8>>,
    /// FIFO eviction order (simple and allocation-light; the pool is a
    /// fill accelerator, not a correctness structure).
    fifo: VecDeque<(u64, u64, u64)>,
    budget_pages: usize,
    hits: u64,
    faults: u64,
}

fn pool() -> &'static Mutex<MmapPool> {
    static POOL: OnceLock<Mutex<MmapPool>> = OnceLock::new();
    POOL.get_or_init(|| {
        let budget = std::env::var_os("SEA_MMAP_BUDGET")
            .and_then(|v| v.to_str().and_then(|s| s.parse::<usize>().ok()))
            .unwrap_or(MMAP_POOL_BUDGET);
        Mutex::new(MmapPool {
            pages: HashMap::new(),
            fifo: VecDeque::new(),
            budget_pages: (budget / MMAP_POOL_PAGE).max(1),
            hits: 0,
            faults: 0,
        })
    })
}

/// Cumulative pool gauges `(hits, faults)` — pages served from the
/// shared pool vs. preads that filled a page.
pub fn mmap_pool_counters() -> (u64, u64) {
    let p = pool().lock().unwrap_or_else(|e| e.into_inner());
    (p.hits, p.faults)
}

/// One emulated mapping.
struct MapInfo {
    len: usize,
    /// File offset the region mirrors (mmap's `offset` argument).
    offset: u64,
    /// Writable `MAP_SHARED` emulation state; `None` for private
    /// mappings (no write-back).
    wb: Option<WriteBack>,
}

/// Where write-back bytes go: a duplicated real descriptor, or an
/// independently opened daemon handle (correct across spills — the
/// daemon-side handle follows the registry to the file's new device).
enum WbSink {
    Fd(c_int),
    Remote(Box<RemoteFile>),
}

impl WbSink {
    /// Write all of `buf` at `off`; `false` on any error.
    fn pwrite_all(&mut self, buf: &[u8], off: u64) -> bool {
        match self {
            WbSink::Fd(fd) => unsafe { pwrite_all_raw(*fd, buf, off) },
            WbSink::Remote(f) => f.pwrite_all(buf, off).is_ok(),
        }
    }

    /// A second, independent sink for the same file (middle-cut
    /// split). Errno is set on failure.
    fn acquire_sibling(&self) -> Option<WbSink> {
        match self {
            WbSink::Fd(fd) => {
                let dup = unsafe { libc::fcntl(*fd, libc::F_DUPFD_CLOEXEC, 0) };
                if dup < 0 {
                    None // fcntl left errno
                } else {
                    Some(WbSink::Fd(dup))
                }
            }
            WbSink::Remote(f) => match f.sibling(OpenMode::ReadWrite) {
                Ok(nf) => Some(WbSink::Remote(Box::new(nf))),
                Err(_) => {
                    set_errno(libc::EIO);
                    None
                }
            },
        }
    }

    /// Release the sink (close the dup / protocol-Close the handle).
    fn release(self) {
        match self {
            WbSink::Fd(fd) => unsafe {
                libc::close(fd);
            },
            WbSink::Remote(f) => drop(f),
        }
    }
}

/// Write-back state of a writable `MAP_SHARED` emulated region.
struct WriteBack {
    /// Outlives the caller's descriptor (they may close theirs).
    sink: WbSink,
    dev: u64,
    ino: u64,
    /// The region's bytes as of the fill, refreshed after every
    /// write-back: `msync`/`munmap` diff the live region against it
    /// and pwrite only the byte ranges the caller actually changed.
    /// Without the diff the sync would rewrite the entire region —
    /// clobbering any concurrent update made to the file through
    /// another descriptor, process, or mapping with this region's
    /// stale snapshot, and rewriting the whole file even for a
    /// mapping that was only ever read. Costs one extra copy of the
    /// region per writable shared mapping.
    snapshot: Vec<u8>,
}

fn maps() -> &'static Mutex<HashMap<usize, MapInfo>> {
    static MAPS: OnceLock<Mutex<HashMap<usize, MapInfo>>> = OnceLock::new();
    MAPS.get_or_init(|| Mutex::new(HashMap::new()))
}

std::thread_local! {
    /// Re-entrancy guard: while the shim itself allocates (pool fill,
    /// map-table insert), malloc may legitimately call mmap/munmap —
    /// those inner calls must forward raw instead of taking the same
    /// locks again.
    static IN_SHIM: Cell<bool> = const { Cell::new(false) };
}

unsafe fn sys_mmap(
    addr: *mut c_void,
    len: libc::size_t,
    prot: c_int,
    flags: c_int,
    fd: c_int,
    offset: i64,
) -> *mut c_void {
    libc::syscall(libc::SYS_mmap, addr, len, prot, flags, fd, offset) as *mut c_void
}

unsafe fn sys_munmap(addr: *mut c_void, len: libc::size_t) -> c_int {
    libc::syscall(libc::SYS_munmap, addr, len) as c_int
}

unsafe fn sys_msync(addr: *mut c_void, len: libc::size_t, flags: c_int) -> c_int {
    libc::syscall(libc::SYS_msync, addr, len, flags) as c_int
}

/// Should this mapping be emulated? Yes when the emulation is enabled,
/// `fd` is a regular file living under `SEA_TARGET` (a path the shim
/// translated at `open`), and the protection/flags are a shape the
/// emulation preserves: non-executable, and either private or
/// writable-shared. Returns the file's `(device, inode)`.
unsafe fn sea_mappable(fd: c_int, flags: c_int, prot: c_int) -> Option<(u64, u64)> {
    if std::env::var_os("SEA_MMAP").is_some_and(|v| v == "0") {
        return None;
    }
    if prot & libc::PROT_EXEC != 0 {
        return None; // never emulate code mappings (dlopen et al.)
    }
    let shared = flags & libc::MAP_SHARED != 0;
    let writable = prot & libc::PROT_WRITE != 0;
    if shared && !writable {
        return None; // read-only shared: the kernel mapping is fine
    }
    let mut st: libc::stat = std::mem::zeroed();
    if libc::fstat(fd, &mut st) != 0 || st.st_mode & libc::S_IFMT != libc::S_IFREG {
        return None;
    }
    // resolve the descriptor back to its path: only Sea-translated
    // files (under SEA_TARGET) go through the pool
    let link = format!("/proc/self/fd/{fd}\0");
    let mut buf = [0u8; libc::PATH_MAX as usize];
    let n = libc::readlink(
        link.as_ptr() as *const c_char,
        buf.as_mut_ptr() as *mut c_char,
        buf.len(),
    );
    if n <= 0 {
        return None;
    }
    let path = &buf[..n as usize];
    let target = env_or("SEA_TARGET", "/tmp/sea_target");
    if !path.starts_with(&target) {
        return None;
    }
    let rest = &path[target.len()..];
    if !(rest.is_empty() || rest[0] == b'/') {
        return None;
    }
    Some((st.st_dev as u64, st.st_ino as u64))
}

/// Whole-page reader over a real descriptor (zero-padded past EOF).
fn read_page_raw(fd: c_int, page: &mut [u8], off: u64) -> bool {
    let mut filled = 0usize;
    while filled < page.len() {
        let n = unsafe {
            libc::pread(
                fd,
                page[filled..].as_mut_ptr() as *mut c_void,
                page.len() - filled,
                (off + filled as u64) as libc::off_t,
            )
        };
        if n < 0 {
            return false;
        }
        if n == 0 {
            break; // past EOF: the tail stays zero
        }
        filled += n as usize;
    }
    true
}

/// Copy `[offset, offset + out.len())` of a file into `out` through
/// the shared page pool: pooled pages are memcpy'd, missing ones are
/// read via `read_page` (a raw pread or a daemon round trip, depending
/// on the caller) and inserted under the FIFO budget.
fn fill_from_pool(
    out: &mut [u8],
    offset: u64,
    (dev, ino): (u64, u64),
    read_page: &mut dyn FnMut(&mut [u8], u64) -> bool,
) -> bool {
    let pb = MMAP_POOL_PAGE as u64;
    let mut done = 0usize;
    while done < out.len() {
        let fo = offset + done as u64;
        let idx = fo / pb;
        let intra = (fo % pb) as usize;
        let span = (MMAP_POOL_PAGE - intra).min(out.len() - done);
        let key = (dev, ino, idx);
        let pooled = {
            let mut p = pool().lock().unwrap_or_else(|e| e.into_inner());
            if let Some(page) = p.pages.get(&key) {
                out[done..done + span].copy_from_slice(&page[intra..intra + span]);
                p.hits += 1;
                true
            } else {
                false
            }
        };
        if !pooled {
            let mut page = vec![0u8; MMAP_POOL_PAGE];
            if !read_page(&mut page, idx * pb) {
                return false;
            }
            out[done..done + span].copy_from_slice(&page[intra..intra + span]);
            let mut p = pool().lock().unwrap_or_else(|e| e.into_inner());
            p.faults += 1;
            if !p.pages.contains_key(&key) {
                while p.pages.len() >= p.budget_pages {
                    match p.fifo.pop_front() {
                        Some(old) => {
                            p.pages.remove(&old);
                        }
                        None => break,
                    }
                }
                p.fifo.push_back(key);
                p.pages.insert(key, page);
            }
        }
        done += span;
    }
    true
}

/// Build an emulated mapping: an anonymous region filled through the
/// pool, standing in for `[offset, offset + len)` of the file. `sink`
/// must be `Some` exactly when the mapping is writable `MAP_SHARED`
/// (it becomes the write-back target and is released on failure).
unsafe fn emulate_map(
    len: libc::size_t,
    prot: c_int,
    offset: u64,
    key: (u64, u64),
    sink: Option<WbSink>,
    read_page: &mut dyn FnMut(&mut [u8], u64) -> bool,
) -> *mut c_void {
    let region = sys_mmap(
        std::ptr::null_mut(),
        len,
        libc::PROT_READ | libc::PROT_WRITE,
        libc::MAP_PRIVATE | libc::MAP_ANONYMOUS,
        -1,
        0,
    );
    if region == libc::MAP_FAILED {
        if let Some(s) = sink {
            s.release();
        }
        return region;
    }
    let out = std::slice::from_raw_parts_mut(region as *mut u8, len);
    if !fill_from_pool(out, offset, key, read_page) {
        sys_munmap(region, len);
        if let Some(s) = sink {
            s.release();
        }
        *libc::__errno_location() = libc::EIO;
        return libc::MAP_FAILED;
    }
    let (dev, ino) = key;
    let wb = match sink {
        // writable shared mapping: the sink outlives the caller's
        // descriptor, and the fill snapshot is the write-back diff base
        Some(sink) => Some(WriteBack { sink, dev, ino, snapshot: out.to_vec() }),
        None => {
            if prot & libc::PROT_WRITE == 0 {
                // seal the private read-only mapping now that it is filled
                libc::mprotect(region, len, prot);
            }
            None
        }
    };
    maps()
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .insert(region as usize, MapInfo { len, offset, wb });
    region
}

/// [`emulate_map`] over a real (kernel) descriptor: dup the fd for
/// write-back and read pages with raw preads.
unsafe fn emulate_map_real(
    len: libc::size_t,
    prot: c_int,
    flags: c_int,
    fd: c_int,
    offset: u64,
    dev: u64,
    ino: u64,
) -> *mut c_void {
    let sink = if flags & libc::MAP_SHARED != 0 {
        let dup = libc::fcntl(fd, libc::F_DUPFD_CLOEXEC, 0);
        if dup < 0 {
            return libc::MAP_FAILED; // fcntl left errno
        }
        Some(WbSink::Fd(dup))
    } else {
        None
    };
    let mut reader = |page: &mut [u8], off: u64| read_page_raw(fd, page, off);
    emulate_map(len, prot, offset, (dev, ino), sink, &mut reader)
}

/// [`emulate_map`] over a daemon handle (`SEA_SOCKET` transport):
/// pages fill via protocol preads keyed by the daemon-side identity,
/// and writable shared regions write back through a sibling handle.
/// Called with the entry locked and the re-entrancy guard held.
fn emulate_map_remote(
    r: &mut RemoteFd,
    len: libc::size_t,
    prot: c_int,
    flags: c_int,
    offset: u64,
) -> *mut c_void {
    if prot & libc::PROT_EXEC != 0 {
        // no local file to hand the kernel: code mappings can't work
        set_errno(libc::ENODEV);
        return libc::MAP_FAILED;
    }
    // refresh the daemon-side generation first: a bump (another
    // client's spill) purges this file's pooled pages, so the fill
    // below re-reads post-spill bytes instead of serving stale ones
    let _ = r.file.map_sync();
    note_remote_gen(r);
    let key = remote_pool_key(r);
    let sink = if flags & libc::MAP_SHARED != 0 && prot & libc::PROT_WRITE != 0 {
        match r.file.sibling(OpenMode::ReadWrite) {
            Ok(f) => Some(WbSink::Remote(Box::new(f))),
            Err(e) => {
                set_sea_errno(&e);
                return libc::MAP_FAILED;
            }
        }
    } else {
        None
    };
    let file = &mut r.file;
    let mut reader = |page: &mut [u8], off: u64| -> bool {
        let mut filled = 0usize;
        while filled < page.len() {
            match file.pread(&mut page[filled..], off + filled as u64) {
                Ok(0) => break, // past EOF: the tail stays zero
                Ok(n) => filled += n,
                Err(_) => return false,
            }
        }
        true
    };
    unsafe { emulate_map(len, prot, offset, key, sink, &mut reader) }
}

/// The remote branch of the `mmap` wrappers: `Some` when `fd` is a
/// daemon-backed descriptor (manages the re-entrancy guard itself).
unsafe fn remote_map(
    len: libc::size_t,
    prot: c_int,
    flags: c_int,
    fd: c_int,
    offset: u64,
) -> Option<*mut c_void> {
    if !remote_enabled() || IN_SHIM.with(|g| g.get()) {
        return None;
    }
    let entry = {
        let m = remote_fds().lock().unwrap_or_else(|e| e.into_inner());
        m.get(&fd).cloned()
    }?;
    IN_SHIM.with(|g| g.set(true));
    let ret = {
        let mut e = entry.lock().unwrap_or_else(|e| e.into_inner());
        emulate_map_remote(&mut e, len, prot, flags, offset)
    };
    IN_SHIM.with(|g| g.set(false));
    Some(ret)
}

/// Write all of `buf` to `fd` at `off`, raw; `false` on any error.
unsafe fn pwrite_all_raw(fd: c_int, buf: &[u8], off: u64) -> bool {
    let mut done = 0usize;
    while done < buf.len() {
        let n = libc::pwrite(
            fd,
            buf[done..].as_ptr() as *const c_void,
            buf.len() - done,
            (off + done as u64) as libc::off_t,
        );
        if n <= 0 {
            return false;
        }
        done += n as usize;
    }
    true
}

/// Diff `[lo0, hi0)` of the live emulated region at `base` against its
/// fill snapshot and pwrite only the changed byte range of each pool
/// page through the region's write-back sink (writable shared mappings —
/// a range the caller never stored to writes nothing back, so
/// concurrent updates to the file through other descriptors/processes
/// survive outside the dirtied ranges), invalidating the file's pooled
/// pages when anything was written. On a write error the snapshot
/// stays stale for that range, so a later msync (or the unmap flush)
/// retries the write; returns -1 then, 0 otherwise. Private mappings
/// are a no-op. Caller holds the maps lock.
unsafe fn write_back_range(base: usize, info: &mut MapInfo, lo0: usize, hi0: usize) -> c_int {
    let Some(wb) = info.wb.as_mut() else { return 0 };
    let region = std::slice::from_raw_parts(base as *const u8, info.len);
    let mut ret = 0;
    let mut wrote = false;
    let mut lo = lo0;
    while lo < hi0 {
        let hi = (lo + MMAP_POOL_PAGE).min(hi0);
        let (cur, old) = (&region[lo..hi], &wb.snapshot[lo..hi]);
        if cur != old {
            // narrow to the changed byte range of this page
            let a = cur.iter().zip(old).position(|(c, o)| c != o).unwrap_or(0);
            let b = cur
                .iter()
                .zip(old)
                .rposition(|(c, o)| c != o)
                .map_or(cur.len(), |k| k + 1);
            if !wb.sink.pwrite_all(&cur[a..b], info.offset + (lo + a) as u64) {
                ret = -1;
                break;
            }
            wb.snapshot[lo + a..lo + b].copy_from_slice(&cur[a..b]);
            wrote = true;
        }
        lo = hi;
    }
    if wrote {
        // the file changed under its pooled pages: drop them so
        // later mappings re-read instead of serving pre-write bytes
        let (dev, ino) = (wb.dev, wb.ino);
        let mut p = pool().lock().unwrap_or_else(|e| e.into_inner());
        p.fifo.retain(|k| k.0 != dev || k.1 != ino);
        p.pages.retain(|k, _| k.0 != dev || k.1 != ino);
    }
    ret
}

/// `msync` back half for emulated regions: write the whole region's
/// dirty ranges back ([`write_back_range`]). `None` when `addr` is not
/// an emulated region. The maps lock is held across the write-back:
/// concurrent syncs of one region cannot interleave diff passes, and
/// re-entrant allocator mmap/munmap calls forward raw under `IN_SHIM`
/// without touching the table (the pool lock only ever nests *inside*
/// the maps lock).
unsafe fn emulated_sync(addr: *mut c_void) -> Option<c_int> {
    let mut m = maps().lock().unwrap_or_else(|e| e.into_inner());
    let mut info = m.remove(&(addr as usize))?;
    let ret = write_back_range(addr as usize, &mut info, 0, info.len);
    m.insert(addr as usize, info);
    Some(ret)
}

/// `munmap` back half for emulated regions, sub-ranges included: flush
/// only the dirty pages inside `[addr, addr + len)` (page-granular,
/// like the kernel), release exactly those pages, and trim the
/// bookkeeping — a prefix cut re-keys the region, a suffix cut shrinks
/// it, a middle cut splits it in two (the right half gets its own
/// write-back sink and snapshot tail, acquired *before* anything
/// is released so a failure leaves the region intact, like the
/// kernel's own ENOMEM on a VMA split). `None` when the range is not
/// inside an emulated region.
unsafe fn emulated_unmap(addr: *mut c_void, len: libc::size_t) -> Option<c_int> {
    if len == 0 {
        return None; // kernel's EINVAL path
    }
    let a = addr as usize;
    let page = libc::sysconf(libc::_SC_PAGESIZE).max(1) as usize;
    let mut m = maps().lock().unwrap_or_else(|e| e.into_inner());
    let base = m
        .iter()
        .find(|(b, i)| **b <= a && a < **b + i.len)
        .map(|(b, _)| *b)?;
    if a % page != 0 {
        *libc::__errno_location() = libc::EINVAL;
        return Some(-1);
    }
    let mut info = m.remove(&base).expect("region found above");
    let total = info.len;
    let lo = a - base;
    // munmap lengths round up to page granularity; a range running
    // past the region end clamps to it (the kernel would release any
    // following mappings too — the emulation never places one there)
    let hi = match len.checked_add(page - 1) {
        Some(l) => a.saturating_add(l & !(page - 1)).min(base + total) - base,
        None => total,
    };
    // flush only the dirty pages inside the released range
    let mut ret = write_back_range(base, &mut info, lo, hi);
    if lo == 0 && hi == total {
        // full teardown
        if let Some(wb) = info.wb.take() {
            wb.sink.release();
        }
        let r = sys_munmap(base as *mut c_void, total);
        if r != 0 {
            ret = r;
        }
        return Some(ret);
    }
    // a middle cut needs a second write-back sink for the right half —
    // acquire it before releasing anything
    let right_sink = if lo > 0 && hi < total {
        match info.wb.as_ref() {
            None => None,
            Some(wb) => match wb.sink.acquire_sibling() {
                Some(s) => Some(s),
                None => {
                    m.insert(base, info);
                    return Some(-1); // acquire_sibling left errno
                }
            },
        }
    } else {
        None
    };
    let r = sys_munmap((base + lo) as *mut c_void, hi - lo);
    if r != 0 {
        // nothing was released: keep the bookkeeping intact
        if let Some(s) = right_sink {
            s.release();
        }
        m.insert(base, info);
        return Some(r);
    }
    if lo == 0 {
        // prefix cut: the region now starts (and mirrors the file) at
        // `hi` bytes further in
        if let Some(wb) = info.wb.as_mut() {
            wb.snapshot.drain(..hi);
        }
        info.len = total - hi;
        info.offset += hi as u64;
        m.insert(base + hi, info);
    } else if hi == total {
        // suffix cut: shrink in place
        if let Some(wb) = info.wb.as_mut() {
            wb.snapshot.truncate(lo);
        }
        info.len = lo;
        m.insert(base, info);
    } else {
        // middle cut: left keeps the original sink, right gets the
        // sibling and the snapshot tail
        let mut left = info;
        let right_wb = match (left.wb.as_mut(), right_sink) {
            (Some(wb), Some(sink)) => {
                let tail = wb.snapshot.split_off(hi);
                Some(WriteBack { sink, dev: wb.dev, ino: wb.ino, snapshot: tail })
            }
            _ => None,
        };
        let right = MapInfo {
            len: total - hi,
            offset: left.offset + hi as u64,
            wb: right_wb,
        };
        if let Some(wb) = left.wb.as_mut() {
            wb.snapshot.truncate(lo);
        }
        left.len = lo;
        m.insert(base, left);
        m.insert(base + hi, right);
    }
    Some(ret)
}

/// `mmap`: emulate Sea-file mappings through the shared pool, forward
/// everything else raw.
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn mmap(
    addr: *mut c_void,
    len: libc::size_t,
    prot: c_int,
    flags: c_int,
    fd: c_int,
    offset: libc::off_t,
) -> *mut c_void {
    // the allocator's own requests (anonymous), placement-constrained
    // ones (MAP_FIXED*) and re-entrant calls forward before the shim
    // allocates anything
    if fd < 0
        || len == 0
        || flags & libc::MAP_ANONYMOUS != 0
        || flags & (libc::MAP_FIXED | libc::MAP_FIXED_NOREPLACE) != 0
        || IN_SHIM.with(|g| g.get())
    {
        return sys_mmap(addr, len, prot, flags, fd, offset as i64);
    }
    if let Some(ret) = remote_map(len, prot, flags, fd, offset as u64) {
        return ret;
    }
    IN_SHIM.with(|g| g.set(true));
    let ret = match sea_mappable(fd, flags, prot) {
        Some((dev, ino)) => emulate_map_real(len, prot, flags, fd, offset as u64, dev, ino),
        None => sys_mmap(addr, len, prot, flags, fd, offset as i64),
    };
    IN_SHIM.with(|g| g.set(false));
    ret
}

/// `mmap64`: identical to [`mmap`] with a 64-bit offset.
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn mmap64(
    addr: *mut c_void,
    len: libc::size_t,
    prot: c_int,
    flags: c_int,
    fd: c_int,
    offset: libc::off64_t,
) -> *mut c_void {
    if fd < 0
        || len == 0
        || flags & libc::MAP_ANONYMOUS != 0
        || flags & (libc::MAP_FIXED | libc::MAP_FIXED_NOREPLACE) != 0
        || IN_SHIM.with(|g| g.get())
    {
        return sys_mmap(addr, len, prot, flags, fd, offset);
    }
    if let Some(ret) = remote_map(len, prot, flags, fd, offset as u64) {
        return ret;
    }
    IN_SHIM.with(|g| g.set(true));
    let ret = match sea_mappable(fd, flags, prot) {
        Some((dev, ino)) => emulate_map_real(len, prot, flags, fd, offset as u64, dev, ino),
        None => sys_mmap(addr, len, prot, flags, fd, offset),
    };
    IN_SHIM.with(|g| g.set(false));
    ret
}

/// `msync`: write an emulated region back through its write-back sink
/// (a duplicated descriptor, or a daemon handle for remote regions);
/// forward kernel mappings raw.
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn msync(addr: *mut c_void, len: libc::size_t, flags: c_int) -> c_int {
    if !IN_SHIM.with(|g| g.get()) {
        IN_SHIM.with(|g| g.set(true));
        let handled = emulated_sync(addr);
        IN_SHIM.with(|g| g.set(false));
        if let Some(r) = handled {
            return r;
        }
    }
    sys_msync(addr, len, flags)
}

/// `munmap`: release an emulated region or any sub-range of one
/// (write-back of the released range first when it is a writable
/// shared mapping, then a prefix/suffix/middle trim of the
/// bookkeeping); forward kernel mappings — including the allocator's
/// own frees — raw.
///
/// # Safety
/// C ABI; arguments per the libc contract.
#[no_mangle]
pub unsafe extern "C" fn munmap(addr: *mut c_void, len: libc::size_t) -> c_int {
    if !IN_SHIM.with(|g| g.get()) {
        IN_SHIM.with(|g| g.set(true));
        let handled = emulated_unmap(addr, len);
        IN_SHIM.with(|g| g.set(false));
        if let Some(r) = handled {
            return r;
        }
    }
    sys_munmap(addr, len)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `translate` and the mmap gate both read `SEA_MOUNT`/`SEA_TARGET`
    /// from the environment — tests that set them must not interleave.
    static ENV_LOCK: Mutex<()> = Mutex::new(());

    fn t(mount: &str, target: &str, path: &str) -> Option<String> {
        std::env::set_var("SEA_MOUNT", mount);
        std::env::set_var("SEA_TARGET", target);
        let c = CString::new(path).unwrap();
        translate(&c).map(|s| s.to_string_lossy().into_owned())
    }

    #[test]
    fn prefix_translation() {
        let _env = ENV_LOCK.lock().unwrap();
        assert_eq!(
            t("/sea", "/data", "/sea/x/y.dat").as_deref(),
            Some("/data/x/y.dat")
        );
        assert_eq!(t("/sea", "/data", "/sea").as_deref(), Some("/data"));
        assert_eq!(t("/sea", "/data", "/seaside/x"), None);
        assert_eq!(t("/sea", "/data", "/other/x"), None);
    }

    fn scratch_target(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("sea_shim_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("SEA_MOUNT", "/sea");
        std::env::set_var("SEA_TARGET", &dir);
        std::env::remove_var("SEA_MMAP");
        std::env::remove_var("SEA_SOCKET");
        dir
    }

    fn c_path(p: &std::path::Path) -> CString {
        CString::new(p.as_os_str().as_bytes()).unwrap()
    }

    #[test]
    fn private_read_mappings_fill_from_the_shared_pool() {
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("mmap_ro");
        let path = dir.join("m.dat");
        let data: Vec<u8> = (0..200_000usize).map(|k| (k.wrapping_mul(31) % 251) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let c = c_path(&path);
        unsafe {
            let fd = libc::open(c.as_ptr(), libc::O_RDONLY);
            assert!(fd >= 0);
            let (h0, f0) = mmap_pool_counters();
            let a = mmap(std::ptr::null_mut(), data.len(), libc::PROT_READ, libc::MAP_PRIVATE, fd, 0);
            assert_ne!(a, libc::MAP_FAILED, "emulated mapping failed");
            assert_eq!(std::slice::from_raw_parts(a as *const u8, data.len()), &data[..]);
            let (_, f1) = mmap_pool_counters();
            assert!(f1 > f0, "first mapping pread pool pages in");
            // a second mapping of the same file fills from the pool:
            // no new faults, only hits
            let b = mmap(std::ptr::null_mut(), data.len(), libc::PROT_READ, libc::MAP_PRIVATE, fd, 0);
            assert_ne!(b, libc::MAP_FAILED);
            assert_eq!(std::slice::from_raw_parts(b as *const u8, data.len()), &data[..]);
            let (h2, f2) = mmap_pool_counters();
            assert_eq!(f2, f1, "second mapping faulted nothing");
            assert!(h2 > h0, "second mapping hit pooled pages");
            assert_eq!(munmap(a, data.len()), 0);
            assert_eq!(munmap(b, data.len()), 0);
            libc::close(fd);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shared_writable_mappings_write_back_on_msync_and_munmap() {
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("mmap_rw");
        let path = dir.join("w.dat");
        std::fs::write(&path, vec![0u8; 8192]).unwrap();
        let c = c_path(&path);
        unsafe {
            let fd = libc::open(c.as_ptr(), libc::O_RDWR);
            assert!(fd >= 0);
            let a = mmap(
                std::ptr::null_mut(),
                8192,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                fd,
                0,
            );
            assert_ne!(a, libc::MAP_FAILED, "emulated writable mapping failed");
            let buf = std::slice::from_raw_parts_mut(a as *mut u8, 8192);
            buf[100..105].copy_from_slice(b"hello");
            // stores live only in the region until msync
            assert_eq!(&std::fs::read(&path).unwrap()[100..105], &[0u8; 5]);
            assert_eq!(msync(a, 8192, libc::MS_SYNC), 0);
            assert_eq!(&std::fs::read(&path).unwrap()[100..105], b"hello");
            // a post-msync store reaches the file via the unmap flush
            buf[0] = 9;
            assert_eq!(munmap(a, 8192), 0);
            libc::close(fd);
        }
        assert_eq!(std::fs::read(&path).unwrap()[0], 9, "munmap wrote the region back");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unmodified_shared_mappings_do_not_clobber_external_writes() {
        // review regression: write-back diffs against the fill
        // snapshot — a writable MAP_SHARED region the caller never
        // stored to (or only partly dirtied) must not rewrite the
        // whole file at sync, or it would revert concurrent updates
        // made through other descriptors to the mapping's stale bytes
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("mmap_noclobber");
        let path = dir.join("nc.dat");
        std::fs::write(&path, vec![0u8; 2 * MMAP_POOL_PAGE]).unwrap();
        let c = c_path(&path);
        unsafe {
            let fd = libc::open(c.as_ptr(), libc::O_RDWR);
            assert!(fd >= 0);
            let a = mmap(
                std::ptr::null_mut(),
                2 * MMAP_POOL_PAGE,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                fd,
                0,
            );
            assert_ne!(a, libc::MAP_FAILED, "emulated writable mapping failed");
            // dirty a few bytes in page 0 only; page 1 stays pristine
            let buf = std::slice::from_raw_parts_mut(a as *mut u8, 2 * MMAP_POOL_PAGE);
            buf[10..13].copy_from_slice(b"map");
            // meanwhile the file is updated through a plain descriptor:
            // one byte the mapping never touched, in the pristine page
            let external_off = MMAP_POOL_PAGE + 50;
            let mut on_disk = std::fs::read(&path).unwrap();
            on_disk[external_off] = 0xEE;
            std::fs::write(&path, &on_disk).unwrap();
            assert_eq!(munmap(a, 2 * MMAP_POOL_PAGE), 0);
            libc::close(fd);
        }
        let after = std::fs::read(&path).unwrap();
        assert_eq!(&after[10..13], b"map", "dirtied bytes were written back");
        assert_eq!(
            after[MMAP_POOL_PAGE + 50],
            0xEE,
            "external write outside the dirtied ranges survived the sync"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn partial_munmap_flushes_only_the_released_range() {
        // satellite regression: munmap of a sub-range must flush the
        // dirty pages inside that range only, hand the pages back to
        // the kernel, and keep tracking the surviving remainder
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("mmap_partial");
        let path = dir.join("p.dat");
        std::fs::write(&path, vec![0u8; 2 * MMAP_POOL_PAGE]).unwrap();
        let c = c_path(&path);
        unsafe {
            let fd = libc::open(c.as_ptr(), libc::O_RDWR);
            assert!(fd >= 0);
            let a = mmap(
                std::ptr::null_mut(),
                2 * MMAP_POOL_PAGE,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                fd,
                0,
            );
            assert_ne!(a, libc::MAP_FAILED, "emulated writable mapping failed");
            let buf = std::slice::from_raw_parts_mut(a as *mut u8, 2 * MMAP_POOL_PAGE);
            buf[10..14].copy_from_slice(b"head");
            buf[MMAP_POOL_PAGE + 10..MMAP_POOL_PAGE + 14].copy_from_slice(b"tail");
            // release only the first half (a prefix cut)
            assert_eq!(munmap(a, MMAP_POOL_PAGE), 0);
            let disk = std::fs::read(&path).unwrap();
            assert_eq!(&disk[10..14], b"head", "released prefix flushed");
            assert_eq!(
                &disk[MMAP_POOL_PAGE + 10..MMAP_POOL_PAGE + 14],
                &[0u8; 4],
                "surviving half is not flushed by the prefix unmap"
            );
            // the survivor is still live, still tracked at its new
            // base, and its stores land at the right file offset
            let rest = std::slice::from_raw_parts_mut(
                (a as usize + MMAP_POOL_PAGE) as *mut u8,
                MMAP_POOL_PAGE,
            );
            rest[20..24].copy_from_slice(b"more");
            libc::close(fd); // write-back runs on the duplicated fd
            assert_eq!(
                munmap((a as usize + MMAP_POOL_PAGE) as *mut c_void, MMAP_POOL_PAGE),
                0
            );
        }
        let disk = std::fs::read(&path).unwrap();
        assert_eq!(&disk[MMAP_POOL_PAGE + 10..MMAP_POOL_PAGE + 14], b"tail");
        assert_eq!(&disk[MMAP_POOL_PAGE + 20..MMAP_POOL_PAGE + 24], b"more");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn middle_munmap_splits_the_region_in_two() {
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("mmap_split");
        let path = dir.join("s.dat");
        std::fs::write(&path, vec![0u8; 3 * MMAP_POOL_PAGE]).unwrap();
        let c = c_path(&path);
        unsafe {
            let fd = libc::open(c.as_ptr(), libc::O_RDWR);
            assert!(fd >= 0);
            let a = mmap(
                std::ptr::null_mut(),
                3 * MMAP_POOL_PAGE,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                fd,
                0,
            );
            assert_ne!(a, libc::MAP_FAILED, "emulated writable mapping failed");
            let buf = std::slice::from_raw_parts_mut(a as *mut u8, 3 * MMAP_POOL_PAGE);
            buf[5..9].copy_from_slice(b"left");
            buf[MMAP_POOL_PAGE + 5..MMAP_POOL_PAGE + 8].copy_from_slice(b"mid");
            buf[2 * MMAP_POOL_PAGE + 5..2 * MMAP_POOL_PAGE + 9].copy_from_slice(b"rght");
            // cut the middle page out: it flushes, the halves do not
            assert_eq!(
                munmap((a as usize + MMAP_POOL_PAGE) as *mut c_void, MMAP_POOL_PAGE),
                0
            );
            let disk = std::fs::read(&path).unwrap();
            assert_eq!(&disk[MMAP_POOL_PAGE + 5..MMAP_POOL_PAGE + 8], b"mid");
            assert_eq!(&disk[5..9], &[0u8; 4], "left half not flushed by the cut");
            assert_eq!(&disk[2 * MMAP_POOL_PAGE + 5..2 * MMAP_POOL_PAGE + 9], &[0u8; 4]);
            // both survivors sync independently: the left at the old
            // base, the right at its new base through a duplicated fd
            assert_eq!(msync(a, MMAP_POOL_PAGE, libc::MS_SYNC), 0);
            assert_eq!(&std::fs::read(&path).unwrap()[5..9], b"left");
            let right = (a as usize + 2 * MMAP_POOL_PAGE) as *mut c_void;
            assert_eq!(munmap(right, MMAP_POOL_PAGE), 0);
            assert_eq!(
                &std::fs::read(&path).unwrap()[2 * MMAP_POOL_PAGE + 5..2 * MMAP_POOL_PAGE + 9],
                b"rght"
            );
            assert_eq!(munmap(a, MMAP_POOL_PAGE), 0);
            libc::close(fd);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Spawn an in-process daemon over a `RealFs` rooted in
    /// `dir/served` and point `SEA_SOCKET` at it. The daemon thread's
    /// own file I/O passes back through these wrappers and falls
    /// through (its paths are not under `SEA_MOUNT`, its fds are not
    /// in the remote table) — exactly the re-entrancy the fd table's
    /// per-entry locking is designed for.
    fn spawn_shim_daemon(dir: &std::path::Path) -> sea::serve::Server {
        let sock = dir.join("sea.sock");
        let fs = std::sync::Arc::new(sea::vfs::RealFs::new(dir.join("served")).unwrap());
        let server =
            sea::serve::Server::spawn_vfs(fs, None, sea::serve::ServeCfg::new(&sock)).unwrap();
        std::env::set_var("SEA_SOCKET", &sock);
        server
    }

    #[test]
    fn sea_socket_routes_fd_io_through_a_daemon() {
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("remote_fd");
        let server = spawn_shim_daemon(&dir);
        let c = CString::new("/sea/remote.dat").unwrap();
        unsafe {
            let fd = open(c.as_ptr(), libc::O_RDWR | libc::O_CREAT, 0o644);
            assert!(fd >= 0, "remote open failed");
            let hello = b"hello daemon";
            assert_eq!(
                pwrite(fd, hello.as_ptr() as *const c_void, hello.len(), 0),
                hello.len() as libc::ssize_t
            );
            let mut back = [0u8; 12];
            assert_eq!(
                pread(fd, back.as_mut_ptr() as *mut c_void, back.len(), 0),
                back.len() as libc::ssize_t
            );
            assert_eq!(&back, hello);
            // cursor I/O: seek to the end, append through write(2)
            assert_eq!(lseek(fd, 0, libc::SEEK_END), hello.len() as libc::off_t);
            let more = b"!";
            assert_eq!(write(fd, more.as_ptr() as *const c_void, 1), 1);
            let mut st: libc::stat = std::mem::zeroed();
            assert_eq!(fstat(fd, &mut st), 0);
            assert_eq!(st.st_size, (hello.len() + 1) as libc::off_t);
            assert_eq!(close(fd), 0);
            // path-addressed calls round-trip the daemon too
            assert_eq!(access(c.as_ptr(), libc::F_OK), 0);
            let mut st2: libc::stat = std::mem::zeroed();
            assert_eq!(stat(c.as_ptr(), &mut st2), 0);
            assert_eq!(st2.st_size, (hello.len() + 1) as libc::off_t);
        }
        // the bytes landed in the daemon's backing tree, not under
        // SEA_TARGET: the mount path was served, never translated
        let served = dir.join("served/sea/remote.dat");
        assert_eq!(std::fs::read(&served).unwrap(), b"hello daemon!");
        assert!(!dir.join("remote.dat").exists());
        unsafe {
            assert_eq!(unlink(c.as_ptr()), 0);
            assert_eq!(access(c.as_ptr(), libc::F_OK), -1);
            assert_eq!(*libc::__errno_location(), libc::ENOENT);
        }
        assert!(!served.exists(), "unlink reached the daemon's tree");
        std::env::remove_var("SEA_SOCKET");
        server.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sea_socket_mappings_fill_remotely_and_write_back() {
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("remote_map");
        // seed the served tree before the daemon comes up
        let served = dir.join("served/sea/m.dat");
        std::fs::create_dir_all(served.parent().unwrap()).unwrap();
        let data: Vec<u8> = (0..150_000usize).map(|k| (k.wrapping_mul(17) % 251) as u8).collect();
        std::fs::write(&served, &data).unwrap();
        let server = spawn_shim_daemon(&dir);
        let c = CString::new("/sea/m.dat").unwrap();
        unsafe {
            let fd = open(c.as_ptr(), libc::O_RDWR, 0);
            assert!(fd >= 0, "remote open failed");
            let a = mmap(
                std::ptr::null_mut(),
                data.len(),
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_SHARED,
                fd,
                0,
            );
            assert_ne!(a, libc::MAP_FAILED, "remote emulated mapping failed");
            let buf = std::slice::from_raw_parts_mut(a as *mut u8, data.len());
            assert_eq!(buf, &data[..], "fill round-tripped the daemon");
            // stores write back through the sibling daemon handle
            buf[100..107].copy_from_slice(b"remoted");
            assert_eq!(msync(a, data.len(), libc::MS_SYNC), 0);
            assert_eq!(&std::fs::read(&served).unwrap()[100..107], b"remoted");
            // a post-msync store reaches the file via the unmap flush
            buf[0] = 0xAB;
            assert_eq!(munmap(a, data.len()), 0);
            assert_eq!(std::fs::read(&served).unwrap()[0], 0xAB);
            assert_eq!(close(fd), 0);
        }
        std::env::remove_var("SEA_SOCKET");
        server.shutdown().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn non_sea_fds_forward_to_the_kernel_mapping_path() {
        let _env = ENV_LOCK.lock().unwrap();
        let dir = scratch_target("mmap_fwd");
        // point SEA_TARGET elsewhere so the file is NOT Sea-managed
        std::env::set_var("SEA_TARGET", dir.join("elsewhere"));
        let path = dir.join("plain.dat");
        std::fs::write(&path, vec![7u8; 4096]).unwrap();
        let c = c_path(&path);
        unsafe {
            let fd = libc::open(c.as_ptr(), libc::O_RDONLY);
            assert!(fd >= 0);
            let (h0, f0) = mmap_pool_counters();
            let a = mmap(std::ptr::null_mut(), 4096, libc::PROT_READ, libc::MAP_PRIVATE, fd, 0);
            assert_ne!(a, libc::MAP_FAILED);
            assert!(std::slice::from_raw_parts(a as *const u8, 4096).iter().all(|&b| b == 7));
            assert_eq!((h0, f0), mmap_pool_counters(), "pool untouched by a kernel mapping");
            assert_eq!(munmap(a, 4096), 0);
            libc::close(fd);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
