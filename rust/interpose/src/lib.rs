//! `LD_PRELOAD` glibc interposer — the paper's actual mechanism (§3.1.2),
//! as a `cdylib` loadable into *unmodified* dynamically-linked binaries.
//!
//! The paper's Sea wraps "every glibc function accepting a file path" and
//! rewrites paths under the Sea mountpoint to the best storage device.
//! This shim demonstrates that mechanism end-to-end on real processes:
//! every wrapped call rewrites `SEA_MOUNT`-prefixed paths to
//! `SEA_TARGET`-prefixed ones and forwards to the real glibc symbol via
//! `dlsym(RTLD_NEXT)`.
//!
//! Policy (device selection, flush/evict rules) lives in the `sea`
//! library; keeping the shim to pure prefix translation keeps it tiny,
//! dependency-free and safe to inject into arbitrary binaries — the demo
//! (`examples/interpose_demo.rs`) points `SEA_TARGET` at a directory the
//! library manages.
//!
//! Environment:
//! * `SEA_MOUNT`  — logical mountpoint prefix (default `/sea`).
//! * `SEA_TARGET` — directory that backs the mountpoint.
//!
//! Wrapped symbols: `open`, `open64`, `openat`, `creat`, `creat64`,
//! `fopen`, `fopen64`, `stat`, `lstat`, `access`, `unlink`, `mkdir`,
//! `rename` (both arguments), `opendir`, `remove`, `truncate`,
//! `truncate64`, `chdir`.
//!
//! Offset-addressed I/O (`pread`/`pwrite`/`pread64`/`pwrite64`,
//! `lseek`/`lseek64`) is also interposed: these operate on descriptors
//! whose *path* was already translated at `open`, so no rewriting is
//! needed — the wrappers forward to the real symbols, keeping the whole
//! request path (open → positioned I/O → close) inside the shim. This
//! mirrors the library-level `VfsFile` handle API: translation happens
//! once at open, every subsequent request is offset-addressed against
//! the translated target.
//!
//! Statically-linked binaries and direct syscalls bypass the shim —
//! the same documented limitation as the paper's library.
//!
//! `mmap(2)` is **not** wrapped (a stub gap): a mapping made on an
//! already-translated descriptor works, but mapped pages bypass the
//! shim entirely, so Sea sees none of those accesses. The library-level
//! equivalent — `VfsFile::map` windowed views over the `vfs::pages`
//! PageCache — covers the mapped-workload scenario for in-process
//! consumers; wiring a real `mmap` wrapper through the shim remains
//! open (ROADMAP).

use std::ffi::{CStr, CString, OsStr};
use std::os::raw::{c_char, c_int, c_void};
use std::os::unix::ffi::OsStrExt;

// --- env + translation ------------------------------------------------------

fn env_or(name: &str, default: &str) -> Vec<u8> {
    std::env::var_os(name)
        .map(|v| v.as_bytes().to_vec())
        .unwrap_or_else(|| default.as_bytes().to_vec())
}

/// Translate `path` if it lies under `SEA_MOUNT`; returns the rewritten
/// C string (kept alive by the caller's scope).
fn translate(path: &CStr) -> Option<CString> {
    let mount = env_or("SEA_MOUNT", "/sea");
    let target = env_or("SEA_TARGET", "/tmp/sea_target");
    let bytes = path.to_bytes();
    if !bytes.starts_with(&mount) {
        return None;
    }
    // exact prefix or prefix + '/'
    let rest = &bytes[mount.len()..];
    if !(rest.is_empty() || rest[0] == b'/') {
        return None;
    }
    let mut out = target;
    out.extend_from_slice(rest);
    CString::new(out).ok()
}

/// Flag a missing real symbol to the caller: libc contracts promise a
/// meaningful errno alongside the error return.
unsafe fn no_sym<T>(ret: T) -> T {
    *libc::__errno_location() = libc::ENOSYS;
    ret
}

/// Resolve the next (real) definition of `$name`, caching the lookup so
/// hot paths (pread/pwrite) don't pay a dlsym string search per call.
macro_rules! real {
    ($name:literal, $ty:ty) => {{
        static SYM: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
        let addr = *SYM.get_or_init(|| unsafe {
            libc::dlsym(libc::RTLD_NEXT, $name.as_ptr() as *const c_char) as usize
        });
        if addr == 0 {
            None
        } else {
            Some(unsafe { std::mem::transmute::<usize, $ty>(addr) })
        }
    }};
}

/// Wrap a single-path function: translate arg 0, forward the rest.
macro_rules! wrap_path_fn {
    ($name:ident, $cname:literal, ($($arg:ident : $argty:ty),*), $ret:ty, $errno_ret:expr) => {
        /// glibc interposer: translate Sea-mounted paths, forward to libc.
        ///
        /// # Safety
        /// Called by arbitrary C code with C ABI invariants; `path` must
        /// be a valid NUL-terminated string (as libc requires).
        #[no_mangle]
        pub unsafe extern "C" fn $name(path: *const c_char $(, $arg: $argty)*) -> $ret {
            type Fn = unsafe extern "C" fn(*const c_char $(, $argty)*) -> $ret;
            let Some(real) = real!($cname, Fn) else { return no_sym($errno_ret); };
            if path.is_null() {
                return real(path $(, $arg)*);
            }
            let c = CStr::from_ptr(path);
            match translate(c) {
                Some(t) => real(t.as_ptr() $(, $arg)*),
                None => real(path $(, $arg)*),
            }
        }
    };
}

/// Wrap an fd-based function: no path to translate (the descriptor's
/// path was rewritten at `open`), just forward through the shim.
macro_rules! wrap_fd_fn {
    ($name:ident, $cname:literal, ($($arg:ident : $argty:ty),*), $ret:ty, $errno_ret:expr) => {
        /// glibc interposer: forward an fd-granular call to libc (the
        /// descriptor was opened through the translating `open` wrapper).
        ///
        /// # Safety
        /// Called by arbitrary C code with C ABI invariants; pointer
        /// arguments must be valid per the libc contract.
        #[no_mangle]
        pub unsafe extern "C" fn $name(fd: c_int $(, $arg: $argty)*) -> $ret {
            type Fn = unsafe extern "C" fn(c_int $(, $argty)*) -> $ret;
            let Some(real) = real!($cname, Fn) else { return no_sym($errno_ret); };
            real(fd $(, $arg)*)
        }
    };
}

// open/creat family (mode passed through variadically-safe fixed arg)
wrap_path_fn!(open, b"open\0", (flags: c_int, mode: libc::mode_t), c_int, -1);
wrap_path_fn!(open64, b"open64\0", (flags: c_int, mode: libc::mode_t), c_int, -1);
wrap_path_fn!(creat, b"creat\0", (mode: libc::mode_t), c_int, -1);
wrap_path_fn!(creat64, b"creat64\0", (mode: libc::mode_t), c_int, -1);
wrap_path_fn!(unlink, b"unlink\0", (), c_int, -1);
wrap_path_fn!(mkdir, b"mkdir\0", (mode: libc::mode_t), c_int, -1);
wrap_path_fn!(truncate, b"truncate\0", (len: libc::off_t), c_int, -1);
wrap_path_fn!(truncate64, b"truncate64\0", (len: libc::off64_t), c_int, -1);
wrap_path_fn!(chdir, b"chdir\0", (), c_int, -1);
wrap_path_fn!(remove, b"remove\0", (), c_int, -1);
wrap_path_fn!(access, b"access\0", (mode: c_int), c_int, -1);

// offset-addressed I/O on already-translated descriptors: the same
// request granularity as the library's `VfsFile::pread`/`pwrite`
wrap_fd_fn!(pread, b"pread\0",
    (buf: *mut c_void, count: libc::size_t, offset: libc::off_t),
    libc::ssize_t, -1);
wrap_fd_fn!(pread64, b"pread64\0",
    (buf: *mut c_void, count: libc::size_t, offset: libc::off64_t),
    libc::ssize_t, -1);
wrap_fd_fn!(pwrite, b"pwrite\0",
    (buf: *const c_void, count: libc::size_t, offset: libc::off_t),
    libc::ssize_t, -1);
wrap_fd_fn!(pwrite64, b"pwrite64\0",
    (buf: *const c_void, count: libc::size_t, offset: libc::off64_t),
    libc::ssize_t, -1);
wrap_fd_fn!(lseek, b"lseek\0", (offset: libc::off_t, whence: c_int), libc::off_t, -1);
wrap_fd_fn!(lseek64, b"lseek64\0",
    (offset: libc::off64_t, whence: c_int), libc::off64_t, -1);
wrap_fd_fn!(ftruncate, b"ftruncate\0", (len: libc::off_t), c_int, -1);
wrap_fd_fn!(ftruncate64, b"ftruncate64\0", (len: libc::off64_t), c_int, -1);

/// `openat`: translate the path argument (position 1).
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn openat(
    dirfd: c_int,
    path: *const c_char,
    flags: c_int,
    mode: libc::mode_t,
) -> c_int {
    type Fn = unsafe extern "C" fn(c_int, *const c_char, c_int, libc::mode_t) -> c_int;
    let Some(real) = real!(b"openat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(dirfd, path, flags, mode);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(dirfd, t.as_ptr(), flags, mode),
        None => real(dirfd, path, flags, mode),
    }
}

/// `fopen`: translate the path argument.
///
/// # Safety
/// C ABI; `path`/`modes` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn fopen(path: *const c_char, modes: *const c_char) -> *mut libc::FILE {
    type Fn = unsafe extern "C" fn(*const c_char, *const c_char) -> *mut libc::FILE;
    let Some(real) = real!(b"fopen\0", Fn) else { return no_sym(std::ptr::null_mut()) };
    if path.is_null() {
        return real(path, modes);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr(), modes),
        None => real(path, modes),
    }
}

/// `fopen64`: translate the path argument.
///
/// # Safety
/// C ABI; `path`/`modes` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn fopen64(path: *const c_char, modes: *const c_char) -> *mut libc::FILE {
    type Fn = unsafe extern "C" fn(*const c_char, *const c_char) -> *mut libc::FILE;
    let Some(real) = real!(b"fopen64\0", Fn) else { return no_sym(std::ptr::null_mut()) };
    if path.is_null() {
        return real(path, modes);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr(), modes),
        None => real(path, modes),
    }
}

/// `stat`: translate the path argument.
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn stat(path: *const c_char, buf: *mut libc::stat) -> c_int {
    type Fn = unsafe extern "C" fn(*const c_char, *mut libc::stat) -> c_int;
    let Some(real) = real!(b"stat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, buf);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr(), buf),
        None => real(path, buf),
    }
}

/// `lstat`: translate the path argument.
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn lstat(path: *const c_char, buf: *mut libc::stat) -> c_int {
    type Fn = unsafe extern "C" fn(*const c_char, *mut libc::stat) -> c_int;
    let Some(real) = real!(b"lstat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(path, buf);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr(), buf),
        None => real(path, buf),
    }
}

/// `rename`: translate *both* arguments.
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn rename(from: *const c_char, to: *const c_char) -> c_int {
    type Fn = unsafe extern "C" fn(*const c_char, *const c_char) -> c_int;
    let Some(real) = real!(b"rename\0", Fn) else { return no_sym(-1) };
    let tf = if from.is_null() { None } else { translate(CStr::from_ptr(from)) };
    let tt = if to.is_null() { None } else { translate(CStr::from_ptr(to)) };
    let fp = tf.as_ref().map(|c| c.as_ptr()).unwrap_or(from);
    let tp = tt.as_ref().map(|c| c.as_ptr()).unwrap_or(to);
    real(fp, tp)
}

/// `statx`: translate the path argument (modern coreutils stat path).
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn statx(
    dirfd: c_int,
    path: *const c_char,
    flags: c_int,
    mask: libc::c_uint,
    buf: *mut libc::statx,
) -> c_int {
    type Fn = unsafe extern "C" fn(
        c_int,
        *const c_char,
        c_int,
        libc::c_uint,
        *mut libc::statx,
    ) -> c_int;
    let Some(real) = real!(b"statx\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(dirfd, path, flags, mask, buf);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(dirfd, t.as_ptr(), flags, mask, buf),
        None => real(dirfd, path, flags, mask, buf),
    }
}

/// `fstatat` (a.k.a. `newfstatat`): translate the path argument.
///
/// # Safety
/// C ABI; pointers must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn fstatat(
    dirfd: c_int,
    path: *const c_char,
    buf: *mut libc::stat,
    flags: c_int,
) -> c_int {
    type Fn = unsafe extern "C" fn(c_int, *const c_char, *mut libc::stat, c_int) -> c_int;
    let Some(real) = real!(b"fstatat\0", Fn) else { return no_sym(-1) };
    if path.is_null() {
        return real(dirfd, path, buf, flags);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(dirfd, t.as_ptr(), buf, flags),
        None => real(dirfd, path, buf, flags),
    }
}

/// `opendir`: translate the path argument.
///
/// # Safety
/// C ABI; `path` must be valid per libc contract.
#[no_mangle]
pub unsafe extern "C" fn opendir(path: *const c_char) -> *mut libc::DIR {
    type Fn = unsafe extern "C" fn(*const c_char) -> *mut libc::DIR;
    let Some(real) = real!(b"opendir\0", Fn) else { return no_sym(std::ptr::null_mut()) };
    if path.is_null() {
        return real(path);
    }
    let c = CStr::from_ptr(path);
    match translate(c) {
        Some(t) => real(t.as_ptr()),
        None => real(path),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(mount: &str, target: &str, path: &str) -> Option<String> {
        std::env::set_var("SEA_MOUNT", mount);
        std::env::set_var("SEA_TARGET", target);
        let c = CString::new(path).unwrap();
        translate(&c).map(|s| s.to_string_lossy().into_owned())
    }

    #[test]
    fn prefix_translation() {
        assert_eq!(
            t("/sea", "/data", "/sea/x/y.dat").as_deref(),
            Some("/data/x/y.dat")
        );
        assert_eq!(t("/sea", "/data", "/sea").as_deref(), Some("/data"));
        assert_eq!(t("/sea", "/data", "/seaside/x"), None);
        assert_eq!(t("/sea", "/data", "/other/x"), None);
    }
}
