//! Offline stub of the small `xla` (PJRT bindings) API surface that the
//! `sea` runtime uses.
//!
//! The real dependency is the crates.io `xla` crate backed by the native
//! `xla_extension` runtime, which cannot be vendored into this offline
//! build. This stub keeps the whole workspace compiling and testable:
//! manifest parsing, HLO text loading and literal plumbing all work, but
//! [`PjRtClient::cpu`] reports a runtime error, so artifact-dependent
//! paths fail fast (and the integration tests skip cleanly). To run real
//! PJRT execution, point the root `Cargo.toml`'s `xla` entry at the
//! crates.io crate instead of this path.

use std::fmt;
use std::path::Path;

/// Stub error: carries a message, converts into `sea`'s error type via
/// `Display` just like the real crate's error.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias (mirrors the real crate).
pub type Result<T> = std::result::Result<T, Error>;

const OFFLINE: &str = "offline xla stub: PJRT execution unavailable \
     (swap rust/xla for the real `xla` crate to run compute)";

/// Element dtypes `sea` lowers for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit IEEE float (the only dtype this repo lowers).
    F32,
}

/// Parsed HLO module text.
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read HLO text from a file (the artifact interchange format).
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let p = path.as_ref();
        let text = std::fs::read_to_string(p)
            .map_err(|e| Error::new(format!("read {}: {e}", p.display())))?;
        Ok(HloModuleProto { text })
    }

    /// The raw HLO text.
    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// PJRT client handle. The stub cannot construct one: creation reports
/// the offline error so callers fail fast at load time.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// CPU PJRT client — unavailable in the offline stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(OFFLINE))
    }

    /// Compile a computation — unreachable offline (no client exists).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(OFFLINE))
    }
}

/// Compiled executable handle (never constructed offline).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with literal arguments — unreachable offline.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(OFFLINE))
    }
}

/// Device buffer handle (never constructed offline).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Copy the buffer to a host literal — unreachable offline.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(OFFLINE))
    }
}

/// Host-side literal: shape + raw bytes. Fully functional in the stub so
/// input plumbing (and its unit tests) work without a device.
pub struct Literal {
    bytes: Vec<u8>,
}

impl Literal {
    /// Build a literal from a shape and raw (little-endian) bytes.
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if elems * 4 != data.len() {
            return Err(Error::new(format!(
                "shape {dims:?} wants {} bytes, got {}",
                elems * 4,
                data.len()
            )));
        }
        Ok(Literal { bytes: data.to_vec() })
    }

    /// Decompose a tuple literal — unreachable offline (tuples only come
    /// from device execution).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::new(OFFLINE))
    }

    /// Copy raw contents into `dst` (size-checked).
    pub fn copy_raw_to<T: Copy>(&self, dst: &mut [T]) -> Result<()> {
        let want = std::mem::size_of_val(dst);
        if want != self.bytes.len() {
            return Err(Error::new(format!(
                "copy_raw_to: {} bytes available, {} wanted",
                self.bytes.len(),
                want
            )));
        }
        // SAFETY: dst is a plain-old-data slice of exactly bytes.len()
        // bytes; byte-wise copy cannot produce invalid T for the POD
        // element types (f32) this repo uses.
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.bytes.as_ptr(),
                dst.as_mut_ptr() as *mut u8,
                self.bytes.len(),
            );
        }
        Ok(())
    }

    /// Decode the literal as a vector of `T` (size-checked).
    pub fn to_vec<T: Copy + Default>(&self) -> Result<Vec<T>> {
        let tsz = std::mem::size_of::<T>();
        if tsz == 0 || self.bytes.len() % tsz != 0 {
            return Err(Error::new(format!(
                "to_vec: {} bytes not a multiple of element size {tsz}",
                self.bytes.len()
            )));
        }
        let mut out = vec![T::default(); self.bytes.len() / tsz];
        self.copy_raw_to(&mut out)?;
        Ok(out)
    }

    /// Payload size in bytes.
    pub fn size_bytes(&self) -> usize {
        self.bytes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip() {
        let vals = [1.5f32, -2.0, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let l = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3, 1], &bytes)
            .unwrap();
        assert_eq!(l.size_bytes(), 12);
        assert_eq!(l.to_vec::<f32>().unwrap(), vals);
        let mut dst = [0f32; 3];
        l.copy_raw_to(&mut dst).unwrap();
        assert_eq!(dst, vals);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[0u8; 8])
                .is_err()
        );
    }

    #[test]
    fn client_reports_offline() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("offline xla stub"));
    }
}
