//! Simulation-level integration: experiment reports are physically
//! sensible and the paper's qualitative findings hold at reduced scale.

use sea::coordinator::{run_experiment, ExperimentCfg, Mode};
use sea::model::{lustre_bounds, sea_bounds, ModelParams};
use sea::sim::spec::ClusterSpec;
use sea::util::{GIB, MIB};
use sea::workload::IncrementationSpec;

fn spec() -> ClusterSpec {
    // paper cluster shrunk to 2 nodes with 16 GiB RAM so the workload
    // exceeds page cache (the paper's stated operating regime)
    let mut s = ClusterSpec::paper_default();
    s.nodes = 2;
    s.procs_per_node = 4;
    s.mem_bytes = 16 * GIB;
    s.tmpfs_bytes = 8 * GIB;
    s
}

fn workload(blocks: usize, iters: usize) -> IncrementationSpec {
    IncrementationSpec {
        blocks,
        file_size: 617 * MIB,
        iterations: iters,
        compute_per_iter: 0.0,
        read_back: true,
    }
}

fn run(mode: Mode, blocks: usize, iters: usize) -> sea::coordinator::SimReport {
    run_experiment(&ExperimentCfg {
        spec: spec(),
        workload: workload(blocks, iters),
        mode,
        seed: 7,
    })
    .expect("experiment")
}

#[test]
fn physical_sanity_bytes_conserved() {
    let r = run(Mode::Lustre, 30, 4);
    let lustre = &r.stats.tiers["lustre"];
    let total_in = 30.0 * 617.0 * MIB as f64;
    // reads: D_I from the device at least once (cache may eat re-reads)
    assert!(lustre.read as f64 >= total_in * 0.99, "input must be read");
    // writes: everything written must eventually hit the device
    // (writeback drains before the sim quiesces)
    let written_total = 4.0 * total_in;
    assert!(
        (lustre.written as f64 + lustre.cache_write as f64) >= written_total * 0.99,
        "writes accounted"
    );
    assert!(lustre.written as f64 >= written_total * 0.99, "writeback drained to device");
}

#[test]
fn makespan_not_faster_than_physics() {
    // the simulated makespan can never beat the no-contention bound:
    // writes at full cluster write bandwidth
    let r = run(Mode::Lustre, 30, 4);
    let m = ModelParams::from_spec(&spec(), 617 * MIB);
    let v = workload(30, 4).volume();
    let phys = v.d_i / (m.s * m.n_bw).min(m.d * m.d_r)
        + v.writes() / (m.d * m.d_w).min(m.s * m.n_bw);
    assert!(
        r.makespan >= phys * 0.5,
        "makespan {:.1}s vs physical floor {:.1}s",
        r.makespan,
        phys
    );
}

#[test]
fn lustre_sits_within_or_above_model_bounds() {
    // the model ignores MDS latency, so measured >= lower bound always,
    // and at moderate process counts measured <~ upper bound
    let r = run(Mode::Lustre, 30, 4);
    let m = ModelParams::from_spec(&spec(), 617 * MIB);
    let b = lustre_bounds(&m, &workload(30, 4).volume());
    assert!(
        r.makespan >= b.lower * 0.9,
        "measured {:.1}s below lower bound {:.1}s",
        r.makespan,
        b.lower
    );
    assert!(
        r.makespan <= b.upper * 1.5,
        "measured {:.1}s far above upper bound {:.1}s",
        r.makespan,
        b.upper
    );
}

#[test]
fn sea_within_its_bounds() {
    let r = run(Mode::SeaInMemory, 30, 4);
    let m = ModelParams::from_spec(&spec(), 617 * MIB);
    let b = sea_bounds(&m, &workload(30, 4).volume());
    assert!(r.makespan >= b.lower * 0.9, "{:.1}s vs lower {:.1}s", r.makespan, b.lower);
    assert!(r.makespan <= b.upper * 2.0, "{:.1}s vs upper {:.1}s", r.makespan, b.upper);
}

#[test]
fn mds_pressure_grows_superlinearly_with_procs() {
    // fig 2d's driver: metadata ops per written byte are constant, so
    // MDS ops scale with procs only via parallelism — but *queueing*
    // time compounds; check the makespan degradation beyond bandwidth
    let mut s64 = spec();
    s64.procs_per_node = 48;
    let few = run(Mode::Lustre, 24, 2);
    let many = run_experiment(&ExperimentCfg {
        spec: s64.clone(),
        workload: workload(24, 2),
        mode: Mode::Lustre,
        seed: 7,
    })
    .expect("experiment");
    // same data volume; more parallel streams should NOT make Lustre
    // dramatically faster once disks saturate (and MDS contention bites)
    assert!(
        many.makespan > few.makespan * 0.5,
        "few {:.1}s many {:.1}s",
        few.makespan,
        many.makespan
    );
    assert!(many.stats.mds_ops >= few.stats.mds_ops * 0.99);
}

#[test]
fn eviction_enables_small_tier_reuse() {
    // with flush+evict of every iteration (Move-all), a small tmpfs keeps
    // being recycled: tmpfs write volume exceeds its capacity
    let mut small = spec();
    // keep tmpfs above the p·F eligibility floor (2 procs × 617 MiB)
    small.procs_per_node = 2;
    small.tmpfs_bytes = 4 * GIB;
    small.disks_per_node = 1;
    small.disk_bytes = 8 * GIB;
    let rules = sea::placement::RuleSet::from_texts("**", "**", "");
    let r = run_experiment(&ExperimentCfg {
        spec: small.clone(),
        workload: workload(20, 3),
        mode: Mode::SeaCustom(rules),
        seed: 7,
    })
    .expect("experiment");
    let tmpfs_written = r.stats.tiers.get("tmpfs").map(|t| t.written).unwrap_or(0);
    let capacity = small.tmpfs_bytes * small.nodes as u64;
    assert!(
        tmpfs_written > capacity,
        "tmpfs reuse: wrote {} through {} of capacity",
        tmpfs_written,
        capacity
    );
    assert_eq!(r.flushes, 20 * 3, "every file flushed");
    assert_eq!(r.evictions, 20 * 3, "every file evicted");
}

#[test]
fn compute_masks_flush_overhead() {
    // paper §5.2: with compute comparable to data transfer, flush-all's
    // overhead shrinks
    let data_only_im = run(Mode::SeaInMemory, 16, 3).makespan;
    let data_only_fa = run(Mode::SeaCopyAll, 16, 3).makespan;
    let mut w = workload(16, 3);
    w.compute_per_iter = 20.0; // heavy compute per chunk-iteration
    let compute_im = run_experiment(&ExperimentCfg {
        spec: spec(),
        workload: w.clone(),
        mode: Mode::SeaInMemory,
        seed: 7,
    })
    .unwrap()
    .makespan;
    let compute_fa = run_experiment(&ExperimentCfg {
        spec: spec(),
        workload: w,
        mode: Mode::SeaCopyAll,
        seed: 7,
    })
    .unwrap()
    .makespan;
    let overhead_data = data_only_fa / data_only_im;
    let overhead_compute = compute_fa / compute_im;
    assert!(
        overhead_compute < overhead_data,
        "compute should mask flushing: data {overhead_data:.2}x vs compute {overhead_compute:.2}x"
    );
    assert!(overhead_compute < 1.25, "flush nearly free under compute: {overhead_compute:.2}x");
}

#[test]
fn single_node_single_disk_can_lose_to_lustre() {
    // paper fig 2b at 1 disk: local bandwidth < underused lustre
    let mut s = spec();
    s.nodes = 1;
    s.procs_per_node = 6;
    s.disks_per_node = 1;
    s.tmpfs_bytes = 2 * GIB; // almost everything lands on the single disk
    let lustre = run_experiment(&ExperimentCfg {
        spec: s.clone(),
        workload: workload(20, 5),
        mode: Mode::Lustre,
        seed: 7,
    })
    .unwrap();
    let sea = run_experiment(&ExperimentCfg {
        spec: s,
        workload: workload(20, 5),
        mode: Mode::SeaInMemory,
        seed: 7,
    })
    .unwrap();
    assert!(
        sea.makespan > lustre.makespan * 0.9,
        "1-disk sea should not meaningfully win: sea {:.1}s lustre {:.1}s",
        sea.makespan,
        lustre.makespan
    );
}

#[test]
fn reports_scale_with_workload() {
    let small = run(Mode::Lustre, 10, 2);
    let large = run(Mode::Lustre, 40, 2);
    assert!(large.makespan > small.makespan * 2.0);
    assert!(large.flows > small.flows);
}
