//! End-to-end integration over REAL bytes: the full L3 coordinator →
//! SeaFs placement → PJRT compute path, plus the LD_PRELOAD interposer
//! driven against live system binaries when its cdylib is present.
//!
//! PJRT tests require `make artifacts` and a real `xla` crate; they skip
//! (like the interposer test) when either is unavailable.

use std::path::{Path, PathBuf};
use std::sync::{Arc, OnceLock};

use sea::coordinator::{run_pipeline, IoMode, PipelineCfg};
use sea::placement::RuleSet;
use sea::runtime::Engine;
use sea::util::MIB;
use sea::vfs::{DeviceSpec, RateLimitedFs, RealFs, SeaFs, SeaFsConfig, SeaTuning, Vfs};
use sea::workload::{dataset, IncrementationSpec};

/// The compiled engine, or `None` when artifacts/PJRT are unavailable
/// (offline xla stub, or `make artifacts` not run) — tests then skip.
fn engine() -> Option<&'static Arc<Engine>> {
    static ENGINE: OnceLock<Option<Arc<Engine>>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            match Engine::load(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")) {
                Ok(e) => Some(Arc::new(e)),
                Err(e) => {
                    eprintln!("skipping PJRT pipeline tests: {e}");
                    None
                }
            }
        })
        .as_ref()
}

fn scratch(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("sea_pit_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn small_dataset(dir: &Path, blocks: usize, elems: usize) -> dataset::Dataset {
    dataset::generate(&dir.join("pfs/inputs"), blocks, elems, 5).unwrap()
}

#[test]
fn pipeline_through_plain_dir_verifies_integrity() {
    let Some(engine) = engine() else { return };
    let work = scratch("plain");
    let ds = small_dataset(&work, 3, engine.chunk_elems());
    let r = run_pipeline(&PipelineCfg {
        engine: engine.clone(),
        vfs: Arc::new(RealFs::new(work.join("pfs")).unwrap()),
        dataset: ds,
        mount_prefix: PathBuf::new(),
        iterations: 4,
        workers: 2,
        read_back: true,
        verify: true,
        cleanup_intermediate: false,
        max_open_outputs: 0,
        io_mode: IoMode::Streamed,
        page_cache: None,
    })
    .expect("pipeline");
    assert_eq!(r.blocks, 3);
    assert_eq!(r.pjrt_calls, 3 * 4);
    assert!(r.makespan > 0.0);
    // all intermediate + final files exist (no cleanup)
    let pfs = RealFs::new(work.join("pfs")).unwrap();
    let spec = IncrementationSpec {
        blocks: 3,
        file_size: 0,
        iterations: 4,
        compute_per_iter: 0.0,
        read_back: true,
    };
    for b in 0..3 {
        for i in 1..=4 {
            assert!(
                pfs.exists(Path::new(&spec.iter_path(b, i))),
                "missing {}",
                spec.iter_path(b, i)
            );
        }
    }
    let _ = std::fs::remove_dir_all(&work);
}

#[test]
fn pipeline_through_sea_mount_places_and_flushes() {
    let Some(engine) = engine() else { return };
    let work = scratch("sea");
    let ds = small_dataset(&work, 4, engine.chunk_elems());
    let pfs: Arc<dyn Vfs> = Arc::new(RealFs::new(work.join("pfs")).unwrap());
    let sea = Arc::new(
        SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![
                DeviceSpec::dir(work.join("t0"), 0, 64 * MIB).unwrap(),
                DeviceSpec::dir(work.join("t1"), 1, 512 * MIB).unwrap(),
            ],
            pfs: pfs.clone(),
            max_file_size: ds.block_bytes(),
            parallel_procs: 2,
            rules: RuleSet::in_memory(IncrementationSpec::final_glob()),
            seed: 9,
            tuning: SeaTuning::default(),
        })
        .unwrap(),
    );
    let r = run_pipeline(&PipelineCfg {
        engine: engine.clone(),
        vfs: sea.clone(),
        dataset: ds.clone(),
        mount_prefix: PathBuf::from("/sea"),
        iterations: 3,
        workers: 2,
        read_back: true,
        verify: true,
        cleanup_intermediate: false,
        max_open_outputs: 0,
        io_mode: IoMode::Streamed,
        page_cache: None,
    })
    .expect("pipeline");
    assert_eq!(r.pjrt_calls, 4 * 3);
    // in-memory rules: final files moved to the PFS...
    let (flushes, evictions) = sea.mgmt_counters();
    assert_eq!(flushes, 4, "one flush per block's final file");
    assert_eq!(evictions, 4);
    let direct = RealFs::new(work.join("pfs")).unwrap();
    for b in 0..4 {
        assert!(
            direct.exists(Path::new(&format!("derived/block_{b:04}_final.dat"))),
            "final file persisted to the PFS"
        );
        // ...and intermediates stayed local (Keep)
        assert!(
            sea.device_of(&format!("derived/block_{b:04}_iter01.dat")).is_some(),
            "intermediate kept on a fast tier"
        );
        assert!(!direct.exists(Path::new(&format!("derived/block_{b:04}_iter01.dat"))));
    }
    // read back a final file THROUGH the mount and check contents
    let data = sea.read(Path::new("/sea/derived/block_0000_final.dat")).unwrap();
    let base = ds.base_of(0);
    let first = f32::from_le_bytes([data[0], data[1], data[2], data[3]]);
    assert_eq!(first, base + 3.0, "final = base + iterations");
    let _ = std::fs::remove_dir_all(&work);
}

#[test]
fn pipeline_mapped_io_over_sea_mount_matches_streamed() {
    // ISSUE 5: --io-mode mmap end to end — same integrity-verified
    // results as the streamed path, with faults visible on the mount's
    // page-cache gauges and residency bounded by the budget
    let Some(engine) = engine() else { return };
    let work = scratch("mmap");
    let ds = small_dataset(&work, 3, engine.chunk_elems());
    let pfs: Arc<dyn Vfs> = Arc::new(RealFs::new(work.join("pfs")).unwrap());
    let sea = Arc::new(
        SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(work.join("t0"), 0, 512 * MIB).unwrap()],
            pfs,
            max_file_size: ds.block_bytes(),
            parallel_procs: 2,
            rules: RuleSet::in_memory(IncrementationSpec::final_glob()),
            seed: 9,
            tuning: SeaTuning {
                // a budget far below blocks x workers proves mapped mode
                // never materializes whole files
                page_bytes: 64 * 1024,
                page_budget: 4 * MIB,
                ..SeaTuning::default()
            },
        })
        .unwrap(),
    );
    let r = run_pipeline(&PipelineCfg {
        engine: engine.clone(),
        vfs: sea.clone(),
        dataset: ds.clone(),
        mount_prefix: PathBuf::from("/sea"),
        iterations: 3,
        workers: 2,
        read_back: true,
        verify: true, // on-device stats certify every mapped stride
        cleanup_intermediate: false,
        max_open_outputs: 0,
        io_mode: IoMode::Mmap,
        page_cache: None, // use the mount's cache: gauges land on counters()
    })
    .expect("mapped pipeline");
    assert_eq!(r.pjrt_calls, 3 * 3);
    let c = sea.counters();
    assert!(c.page_faults > 0, "mapped I/O faulted through the mount cache: {c:?}");
    assert!(
        c.page_peak_resident_bytes <= 4 * MIB,
        "peak resident {} exceeds the page budget",
        c.page_peak_resident_bytes
    );
    // final files flushed to the PFS as usual
    let direct = RealFs::new(work.join("pfs")).unwrap();
    for b in 0..3 {
        assert!(direct.exists(Path::new(&format!("derived/block_{b:04}_final.dat"))));
    }
    let _ = std::fs::remove_dir_all(&work);
}

#[test]
fn sea_beats_throttled_pfs_on_data_intensive_runs() {
    let Some(engine) = engine() else { return };
    let work = scratch("race");
    let ds = small_dataset(&work, 8, engine.chunk_elems());
    // throttle hard so the run is I/O-bound even under the debug-profile
    // PJRT path (release uses Table-2-like speeds in the examples)
    let mk_pfs = || -> Arc<dyn Vfs> {
        Arc::new(RateLimitedFs::new(
            RealFs::new(work.join("pfs")).unwrap(),
            300.0 * MIB as f64,
            30.0 * MIB as f64,
        ))
    };
    let direct = run_pipeline(&PipelineCfg {
        engine: engine.clone(),
        vfs: mk_pfs(),
        dataset: ds.clone(),
        mount_prefix: PathBuf::new(),
        iterations: 4,
        workers: 2,
        read_back: true,
        verify: true,
        cleanup_intermediate: true,
        max_open_outputs: 0,
        io_mode: IoMode::Streamed,
        page_cache: None,
    })
    .expect("direct");
    let sea = Arc::new(
        SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(work.join("t0"), 0, 2048 * MIB).unwrap()],
            pfs: mk_pfs(),
            max_file_size: ds.block_bytes(),
            parallel_procs: 2,
            rules: RuleSet::in_memory(IncrementationSpec::final_glob()),
            seed: 2,
            tuning: SeaTuning::default(),
        })
        .unwrap(),
    );
    let sea_run = run_pipeline(&PipelineCfg {
        engine: engine.clone(),
        vfs: sea,
        dataset: ds,
        mount_prefix: PathBuf::from("/sea"),
        iterations: 4,
        workers: 2,
        read_back: true,
        verify: true,
        cleanup_intermediate: true,
        max_open_outputs: 0,
        io_mode: IoMode::Streamed,
        page_cache: None,
    })
    .expect("sea");
    let speedup = direct.makespan / sea_run.makespan;
    assert!(
        speedup > 1.2,
        "sea should beat the throttled PFS: direct {:.2}s sea {:.2}s ({speedup:.2}x)",
        direct.makespan,
        sea_run.makespan
    );
    let _ = std::fs::remove_dir_all(&work);
}

#[test]
fn corruption_is_detected_by_on_device_stats() {
    let Some(engine) = engine() else { return };
    // verify=true must catch a corrupted input dataset
    let work = scratch("corrupt");
    let ds = small_dataset(&work, 2, engine.chunk_elems());
    // corrupt one element of block 1
    let path = &ds.blocks[1];
    let pfs_path = work.join("pfs/inputs").join(path.file_name().unwrap());
    let mut raw = std::fs::read(&pfs_path).unwrap();
    raw[400] ^= 0x3F; // flip bits inside some float
    std::fs::write(&pfs_path, &raw).unwrap();
    let err = run_pipeline(&PipelineCfg {
        engine: engine.clone(),
        vfs: Arc::new(RealFs::new(work.join("pfs")).unwrap()),
        dataset: ds,
        mount_prefix: PathBuf::new(),
        iterations: 2,
        workers: 1,
        read_back: true,
        verify: true,
        cleanup_intermediate: true,
        max_open_outputs: 0,
        io_mode: IoMode::Streamed,
        page_cache: None,
    });
    assert!(err.is_err(), "corruption must fail the integrity check");
    let msg = format!("{}", err.err().unwrap());
    assert!(msg.contains("integrity"), "got: {msg}");
    let _ = std::fs::remove_dir_all(&work);
}

#[test]
fn interposer_translates_for_unmodified_binaries() {
    // drive the LD_PRELOAD cdylib against /bin/cat; skip if not built
    let shim = ["release", "debug"]
        .iter()
        .map(|p| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(format!("target/{p}/libsea_interpose.so")))
        .find(|p| p.exists());
    let Some(shim) = shim else {
        eprintln!("skipping: libsea_interpose.so not built (cargo build -p sea-interpose)");
        return;
    };
    let target = scratch("interpose");
    std::fs::write(target.join("probe.txt"), b"through-the-shim").unwrap();
    let out = std::process::Command::new("cat")
        .arg("/sea/probe.txt")
        .env("LD_PRELOAD", &shim)
        .env("SEA_MOUNT", "/sea")
        .env("SEA_TARGET", &target)
        .output()
        .expect("spawn cat");
    assert!(out.status.success(), "cat failed: {out:?}");
    assert_eq!(String::from_utf8_lossy(&out.stdout), "through-the-shim");
    // write path: shell redirection through the shim
    let st = std::process::Command::new("sh")
        .arg("-c")
        .arg("echo shim-write > /sea/written.txt")
        .env("LD_PRELOAD", &shim)
        .env("SEA_MOUNT", "/sea")
        .env("SEA_TARGET", &target)
        .status()
        .expect("spawn sh");
    assert!(st.success());
    let back = std::fs::read_to_string(target.join("written.txt")).unwrap();
    assert_eq!(back.trim(), "shim-write");
    let _ = std::fs::remove_dir_all(&target);
}
