//! Property-based tests over the coordinator's core invariants
//! (routing, batching, space/state management) using the offline
//! `testkit` (proptest substitute) — DESIGN.md §2.

use std::sync::Arc;

use sea::hierarchy::{select_device, Hierarchy, SelectCfg, SpaceAccountant};
use sea::model::{lustre_bounds, sea_bounds, sea_breakdown, ModelParams, WorkloadVolume};
use sea::placement::{
    glob_match, CloseCtx, Decision, EngineCtx, FileTable, MgmtMode, PaperEngine, PlaceCtx,
    Placement, PlacementEngine, RuleSet,
};
use sea::sim::engine::{ProcId, Process, Sim, Step};
use sea::testkit::{check, Config};
use sea::util::{Rng, MIB};
use sea::workload::IncrementationSpec;

// --- hierarchy / space accounting -------------------------------------------

#[test]
fn prop_space_accounting_never_oversubscribes() {
    check("space accounting conserves capacity", Config::default(), |g| {
        let devices = g.usize(1..6);
        let cap = g.u64(1..1000) * MIB;
        let mut h = Hierarchy::new();
        for d in 0..devices {
            h.add((d % 3) as u8, cap, format!("d{d}"));
        }
        let acc = SpaceAccountant::new(&h);
        let cfg = SelectCfg {
            max_file_size: g.u64(1..8) * MIB,
            parallel_procs: g.u64(1..8),
        };
        let mut rng = Rng::new(g.u64(0..u64::MAX - 1));
        let mut per_dev = vec![0u64; devices];
        for _ in 0..g.usize(1..200) {
            let size = g.u64(1..16) * MIB;
            if let Some(d) = select_device(&h, &acc, &cfg, size, &mut rng) {
                per_dev[d] += size;
                // invariant: what we placed never exceeds capacity
                assert!(per_dev[d] <= cap, "device {d} oversubscribed");
            }
        }
        // ledger agrees with our shadow accounting
        for (d, &used) in per_dev.iter().enumerate() {
            assert_eq!(acc.free(d), cap - used);
        }
    });
}

#[test]
fn prop_selection_prefers_fastest_eligible_tier() {
    check("fastest eligible tier wins", Config::default(), |g| {
        let mut h = Hierarchy::new();
        let fast_cap = g.u64(1..50) * MIB;
        let slow_cap = 1000 * MIB;
        h.add(0, fast_cap, "fast");
        h.add(1, slow_cap, "slow");
        let acc = SpaceAccountant::new(&h);
        let cfg = SelectCfg { max_file_size: MIB, parallel_procs: g.u64(1..4) };
        let mut rng = Rng::new(1);
        let size = MIB;
        let floor = cfg.floor().max(size);
        let d = select_device(&h, &acc, &cfg, size, &mut rng);
        if fast_cap >= floor {
            assert_eq!(d, Some(0), "fast tier eligible -> must be chosen");
        } else {
            assert_eq!(d, Some(1), "fast tier too small -> slow tier");
        }
    });
}

#[test]
fn prop_ledger_conserves_capacity() {
    check("free + used = capacity; debits - credits = used", Config::default(), |g| {
        let mut h = Hierarchy::new();
        let cap = g.u64(10..1000) * MIB;
        h.add(0, cap, "d");
        let acc = SpaceAccountant::new(&h);
        let mut outstanding: Vec<u64> = Vec::new();
        for _ in 0..g.usize(1..100) {
            let sz = g.u64(1..10) * MIB;
            if acc.try_debit(0, sz, 0) {
                outstanding.push(sz);
            }
            if g.bool(0.4) {
                if let Some(s) = outstanding.pop() {
                    acc.credit(0, s);
                }
            }
            let l = acc.lines()[0];
            assert_eq!(l.free + l.used, cap, "capacity conserved");
            assert_eq!(l.debits - l.credits, l.used, "traffic sums to occupancy");
        }
    });
}

#[test]
fn prop_striped_member_mapping_stable() {
    use sea::vfs::StripedFs;
    use std::path::PathBuf;
    let root = std::env::temp_dir().join(format!("sea_prop_striped_{}", std::process::id()));
    let dirs: Vec<PathBuf> = (0..5).map(|i| root.join(format!("m{i}"))).collect();
    let a = StripedFs::from_dirs(dirs.clone()).unwrap();
    let b = StripedFs::from_dirs(dirs).unwrap();
    check(
        "member mapping is bounded, slash-insensitive, instance-independent",
        Config::default(),
        |g| {
            let p = format!("d{}/f{}.dat", g.usize(0..10), g.usize(0..100_000));
            let m = a.member_of(&PathBuf::from(&p));
            assert!(m < 5);
            assert_eq!(m, a.member_of(&PathBuf::from(format!("/{p}"))));
            assert_eq!(m, b.member_of(&PathBuf::from(&p)));
        },
    );
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn prop_credit_debit_roundtrip() {
    check("credit restores exactly", Config::default(), |g| {
        let mut h = Hierarchy::new();
        let cap = g.u64(10..1000) * MIB;
        h.add(0, cap, "d");
        let acc = SpaceAccountant::new(&h);
        let mut outstanding = Vec::new();
        for _ in 0..g.usize(1..64) {
            let size = g.u64(1..10) * MIB;
            if acc.try_debit(0, size, size) {
                outstanding.push(size);
            }
            if g.bool(0.4) {
                if let Some(s) = outstanding.pop() {
                    acc.credit(0, s);
                }
            }
        }
        let used: u64 = outstanding.iter().sum();
        assert_eq!(acc.free(0), cap - used);
    });
}

// --- placement engine parity -------------------------------------------------

#[test]
fn prop_paper_engine_reproduces_legacy_selection_and_modes() {
    // acceptance: PaperEngine must be a bit-for-bit reproduction of the
    // legacy `select_device` + `RuleSet::mode_for` dispatch, across
    // randomized hierarchies, file sizes, and rule lists — same device
    // picks from the same seed, same ledger trajectory, and close
    // decisions that match Table 1 exactly.
    check("PaperEngine ≡ select_device + mode_for", Config::default(), |g| {
        let devices = g.usize(1..6);
        let mut h = Hierarchy::new();
        for d in 0..devices {
            h.add((d % 3) as u8, g.u64(1..200) * MIB, format!("d{d}"));
        }
        let legacy_acc = SpaceAccountant::new(&h);
        let engine_acc = SpaceAccountant::new(&h);
        let cfg = SelectCfg {
            max_file_size: g.u64(1..8) * MIB,
            parallel_procs: g.u64(1..8),
        };
        let seed = g.u64(0..u64::MAX - 1);
        let mut legacy_rng = Rng::new(seed);
        let flush_pat = *g.pick(&["out/**", "**", "scratch/*", ""]);
        let evict_pat = *g.pick(&["scratch/**", "**", "out/*", ""]);
        let rules = RuleSet::from_texts(flush_pat, evict_pat, "");
        let engine = PaperEngine::new(cfg, rules.clone(), seed);
        for i in 0..g.usize(1..100) {
            let dir = *g.pick(&["out", "scratch", "keep"]);
            let rel = format!("{dir}/f{i}");
            let size = g.u64(0..16) * MIB;
            let legacy = select_device(&h, &legacy_acc, &cfg, size, &mut legacy_rng);
            let via_engine = engine.place(
                EngineCtx { hierarchy: &h, accountant: &engine_acc },
                PlaceCtx { rel: &rel, size, prefetch: false },
            );
            match (legacy, via_engine) {
                (Some(a), Placement::Device(b)) => assert_eq!(a, b, "device pick diverged"),
                (None, Placement::Pfs) => {}
                (a, b) => panic!("pick diverged: legacy {a:?} vs engine {b:?}"),
            }
            // close decisions ≡ Table 1 dispatch
            let decisions = engine.on_close(CloseCtx { rel: &rel, dev: legacy, size });
            let flush = decisions
                .iter()
                .any(|d| matches!(d, Decision::Flush { rel: r } if r == &rel));
            let evict = decisions
                .iter()
                .any(|d| matches!(d, Decision::Evict { rel: r } if r == &rel));
            let expect = match rules.mode_for(&rel) {
                MgmtMode::Copy => (true, false),
                MgmtMode::Remove => (false, true),
                MgmtMode::Move => (true, true),
                MgmtMode::Keep => (false, false),
            };
            assert_eq!((flush, evict), expect, "mode diverged for {rel}");
        }
        // identical ledger trajectory on both sides
        assert_eq!(legacy_acc.lines(), engine_acc.lines());
    });
}

// --- rules / glob ------------------------------------------------------------

#[test]
fn prop_table1_mode_matches_membership() {
    check("mode = f(flush member, evict member)", Config::default(), |g| {
        use sea::placement::MgmtMode::*;
        let name = format!("d{}/block_{:04}.dat", g.usize(0..4), g.usize(0..10_000));
        let in_flush = g.bool(0.5);
        let in_evict = g.bool(0.5);
        let rules = RuleSet::from_texts(
            if in_flush { "d*/**" } else { "nomatch/**" },
            if in_evict { "**.dat" } else { "nomatch/**" },
            "",
        );
        let expect = match (in_flush, in_evict) {
            (true, false) => Copy,
            (false, true) => Remove,
            (true, true) => Move,
            (false, false) => Keep,
        };
        assert_eq!(rules.mode_for(&name), expect);
    });
}

#[test]
fn prop_glob_literal_paths_always_match_themselves() {
    check("identity glob", Config::default(), |g| {
        let depth = g.usize(1..5);
        let mut segs = Vec::new();
        for _ in 0..depth {
            segs.push(format!("s{}", g.usize(0..1000)));
        }
        let path = segs.join("/");
        assert!(glob_match(&path, &path));
        assert!(glob_match("**", &path));
        // '*' must not cross separators
        if depth > 1 {
            assert!(!glob_match("*", &path));
        }
    });
}

// --- model --------------------------------------------------------------------

#[test]
fn prop_model_bounds_ordered_and_conserving() {
    check("bounds ordered; tier fill conserves volume", Config::default(), |g| {
        let spec = sea::sim::spec::ClusterSpec {
            nodes: g.usize(1..9),
            procs_per_node: g.usize(1..65),
            disks_per_node: g.usize(1..7),
            ..sea::sim::spec::ClusterSpec::paper_default()
        };
        let blocks = g.usize(1..2000);
        let iters = g.usize(1..16);
        let m = ModelParams::from_spec(&spec, 617 * MIB);
        let v = WorkloadVolume::incrementation(blocks, 617 * MIB, iters);
        let lb = lustre_bounds(&m, &v);
        let sb = sea_bounds(&m, &v);
        assert!(lb.lower <= lb.upper + 1e-9);
        assert!(sb.lower <= sb.upper + 1e-9);
        assert!(lb.lower > 0.0 && sb.lower > 0.0);
        let b = sea_breakdown(&m, &v);
        assert!((b.d_tr + b.d_gr + b.d_lr - v.d_m).abs() < 1.0);
        assert!((b.d_tw + b.d_gw + b.d_lw - (v.d_m + v.d_f)).abs() < 1.0);
        for x in [b.d_tr, b.d_tw, b.d_gr, b.d_gw, b.d_lr, b.d_lw] {
            assert!(x >= 0.0);
        }
    });
}

// --- engine max-min fairness ---------------------------------------------------

#[test]
fn prop_max_min_rates_respect_capacities() {
    struct Spawner {
        paths: Vec<Vec<sea::sim::engine::ResourceId>>,
        units: f64,
        started: bool,
        done: std::rc::Rc<std::cell::Cell<u32>>,
    }
    impl Process for Spawner {
        fn resume(&mut self, sim: &mut Sim, pid: ProcId) -> Step {
            if !self.started {
                self.started = true;
                // one process awaits the first flow only; the others are
                // fire-and-forget (they still occupy bandwidth)
                for (i, p) in self.paths.iter().enumerate() {
                    let waker = if i == 0 { Some(pid) } else { None };
                    sim.start_flow(p.clone(), self.units, f64::INFINITY, waker);
                }
                Step::Waiting
            } else {
                self.done.set(self.done.get() + 1);
                Step::Done
            }
        }
    }
    check("flows complete; work conserved per resource", Config { cases: 32, ..Config::default() }, |g| {
        let mut sim = Sim::new();
        let nres = g.usize(1..6);
        let caps: Vec<f64> = (0..nres).map(|_| g.f64(10.0, 1000.0)).collect();
        let res: Vec<_> = caps
            .iter()
            .enumerate()
            .map(|(i, &c)| sim.add_resource(format!("r{i}"), c))
            .collect();
        let nflows = g.usize(1..12);
        let units = g.f64(1.0, 500.0);
        let mut paths = Vec::new();
        for _ in 0..nflows {
            let len = g.usize(1..nres + 1);
            let mut p = Vec::new();
            for _ in 0..len {
                let r = *g.pick(&res);
                if !p.contains(&r) {
                    p.push(r);
                }
            }
            paths.push(p);
        }
        let done = std::rc::Rc::new(std::cell::Cell::new(0));
        let expected_work: Vec<f64> = res
            .iter()
            .map(|r| {
                paths
                    .iter()
                    .filter(|p| p.contains(r))
                    .count() as f64
                    * units
            })
            .collect();
        sim.spawn(Box::new(Spawner { paths, units, started: false, done: done.clone() }));
        let t = sim.run(1e9).expect("run");
        assert!(t.is_finite());
        // conservation: every resource carried exactly its flows' units
        for (i, r) in res.iter().enumerate() {
            assert!(
                (sim.resource_work(*r) - expected_work[i]).abs() < 1e-3,
                "resource {i}: work {} expected {}",
                sim.resource_work(*r),
                expected_work[i]
            );
        }
        // a lower bound on the makespan: the most loaded resource
        let min_time: f64 = expected_work
            .iter()
            .zip(&caps)
            .map(|(w, c)| w / c)
            .fold(0.0, f64::max);
        assert!(t >= min_time - 1e-6, "t {t} < physical bound {min_time}");
    });
}

// --- workload construction ------------------------------------------------------

#[test]
fn prop_programs_partition_blocks() {
    check("every block appears exactly once", Config::default(), |g| {
        let spec = IncrementationSpec {
            blocks: g.usize(1..200),
            file_size: g.u64(1..10) * MIB,
            iterations: g.usize(1..8),
            compute_per_iter: 0.0,
            read_back: g.bool(0.5),
        };
        let nodes = g.usize(1..6);
        let procs = g.usize(1..8);
        let table = Arc::new(FileTable::new());
        let progs = spec.build_programs(nodes, procs, &table);
        assert_eq!(progs.programs.len(), nodes * procs);
        assert_eq!(progs.inputs.len(), spec.blocks);
        // count input reads across all programs: exactly one per block
        let mut input_reads = 0;
        for p in &progs.programs {
            for i in p {
                if let sea::sim::app::Instr::Read(f) = i {
                    if progs.inputs.iter().any(|(id, _)| id == f) {
                        input_reads += 1;
                    }
                }
            }
        }
        assert_eq!(input_reads, spec.blocks);
        // writes per block = iterations
        let writes: usize = progs
            .programs
            .iter()
            .flatten()
            .filter(|i| matches!(i, sea::sim::app::Instr::Write { .. }))
            .count();
        assert_eq!(writes, spec.blocks * spec.iterations);
    });
}

#[test]
fn prop_filetable_bijective() {
    check("path <-> id bijection", Config::default(), |g| {
        let t = FileTable::new();
        let n = g.usize(1..100);
        let mut ids = std::collections::HashMap::new();
        for i in 0..n {
            let path = format!("p{}/f{}", i % 7, i);
            let id = t.intern(&path);
            ids.insert(path, id);
        }
        for (path, id) in &ids {
            assert_eq!(t.intern(path), *id, "re-intern stable");
            assert_eq!(&t.path(*id), path);
        }
        let distinct: std::collections::HashSet<_> = ids.values().collect();
        assert_eq!(distinct.len(), ids.len(), "ids distinct");
    });
}
