//! Integration: `sea serve` daemon + `RemoteFs` clients over a real
//! Unix socket.
//!
//! The acceptance claim of the service layer is that **separate
//! OS-level connections share one placement brain**: every client's
//! appends serialize behind the daemon's registry shard lock, one
//! client's writes are immediately visible to another, and one
//! client's spill invalidates every other client's mapped views via
//! the map-generation piggyback. Each test spawns the daemon as a
//! background thread on a tempdir socket — a real `UnixListener`,
//! thread-per-connection, exactly the production path minus `fork`.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use sea::error::Error;
use sea::placement::RuleSet;
use sea::serve::{ServeCfg, Server};
use sea::vfs::{
    DeviceSpec, OpenMode, RealFs, RemoteFs, RetryCfg, SeaFs, SeaFsConfig, SeaTuning,
    StripedFs, Vfs, VfsFile,
};

const MIB: u64 = 1024 * 1024;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sea_daemon_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A Sea mount whose PFS is a chunk-striped `StripedFs` (stripe-mode
/// files fan across members), with `tier0_cap` bytes of tier-0.
fn stripe_mount(root: &Path, tier0_cap: u64, rules: RuleSet) -> Arc<SeaFs> {
    let dirs: Vec<PathBuf> = (0..3).map(|i| root.join(format!("ost{i}"))).collect();
    let pfs: Arc<dyn Vfs> = Arc::new(StripedFs::from_dirs_striped(dirs, 256 * 1024).unwrap());
    Arc::new(
        SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![DeviceSpec::dir(root.join("tier0"), 0, tier0_cap).unwrap()],
            pfs,
            max_file_size: MIB,
            parallel_procs: 1,
            rules,
            seed: 11,
            tuning: SeaTuning::default(),
        })
        .unwrap(),
    )
}

/// Snappy client policy: integration tests must fail fast, not ride
/// the generous default backoff.
fn fast_retry() -> RetryCfg {
    RetryCfg {
        attempts: 2,
        base: Duration::from_millis(10),
        cap: Duration::from_millis(50),
    }
}

#[test]
fn eight_client_connections_append_to_one_stripe_mode_file_without_interleaving() {
    // Scenario 1: 8 OS-level connections, one shared append log. Every
    // record must land contiguously — the daemon resolves each
    // append's offset behind the registry shard lock, which is the
    // whole point of serving the mount instead of sharing the library.
    let root = scratch("append");
    let sea = stripe_mount(&root, 64 * MIB, RuleSet::default());
    let sock = root.join("sea.sock");
    let server = Server::spawn(sea, ServeCfg::new(&sock)).unwrap();

    const REC: usize = 64;
    const PER: usize = 50;
    const THREADS: usize = 8;
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let sock = sock.clone();
            scope.spawn(move || {
                // each thread is its own OS-level connection
                let fs = RemoteFs::connect(&sock).unwrap();
                let mut f = fs
                    .open(Path::new("/sea/applog.bin"), OpenMode::Append)
                    .unwrap();
                for _ in 0..PER {
                    f.pwrite_all(&[t as u8 + 1; REC], 0).unwrap();
                }
            });
        }
    });

    // a ninth connection audits the log
    let fs = RemoteFs::connect(&sock).unwrap();
    let total = REC * PER * THREADS;
    assert_eq!(
        fs.size(Path::new("/sea/applog.bin")).unwrap(),
        total as u64,
        "no lost records"
    );
    let mut data = vec![0u8; total];
    let mut f = fs.open(Path::new("/sea/applog.bin"), OpenMode::Read).unwrap();
    f.pread_exact(&mut data, 0).unwrap();
    let mut counts = [0usize; THREADS + 1];
    for rec in data.chunks(REC) {
        assert!(
            rec.iter().all(|&b| b == rec[0]),
            "interleaved record: {:?}",
            &rec[..8]
        );
        counts[rec[0] as usize] += 1;
    }
    for t in 1..=THREADS {
        assert_eq!(counts[t], PER, "client {t} lost records");
    }

    let c = fs.counters().unwrap();
    assert!(c.clients_total >= 9, "daemon saw all connections: {}", c.clients_total);
    drop(f);
    drop(fs);
    server.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn a_write_is_visible_to_another_clients_pread() {
    // Scenario 2: cross-client read-your-writes — both clients resolve
    // the file through the daemon's one registry.
    let root = scratch("visible");
    let sea = stripe_mount(&root, 16 * MIB, RuleSet::default());
    let sock = root.join("sea.sock");
    let server = Server::spawn(sea, ServeCfg::new(&sock)).unwrap();

    let a = RemoteFs::connect(&sock).unwrap();
    let b = RemoteFs::connect(&sock).unwrap();
    let p = Path::new("/sea/shared.dat");
    {
        let mut fa = a.open(p, OpenMode::Write).unwrap();
        fa.pwrite_all(b"written by A, observed by B", 0).unwrap();
        fa.fsync().unwrap();
    } // A's handle closes; the bytes stay with the daemon

    assert!(b.exists(p), "B sees the file A created");
    let mut fb = b.open(p, OpenMode::Read).unwrap();
    let mut got = vec![0u8; 27];
    fb.pread_exact(&mut got, 0).unwrap();
    assert_eq!(&got, b"written by A, observed by B");

    drop(fb);
    server.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn a_spill_invalidates_another_clients_mapped_view() {
    // Scenario 3: A outgrows tier-0 and the file self-spills to the
    // PFS; B — a different OS connection holding a mapped view — must
    // observe the map-generation bump on its next MapSync.
    let root = scratch("spill");
    // 2 MiB of tier-0, flush+evict everything: growing past capacity
    // forces a self-spill mid-write (same mechanics the library's
    // `pwrite_past_device_capacity_spills_to_pfs` proves in-process).
    let sea = stripe_mount(&root, 2 * MIB, RuleSet::from_texts("**", "**", ""));
    let sock = root.join("sea.sock");
    let server = Server::spawn(sea, ServeCfg::new(&sock)).unwrap();

    let a = RemoteFs::connect(&sock).unwrap();
    let b = RemoteFs::connect(&sock).unwrap();
    let p = Path::new("/sea/grow.dat");

    let mut fa = a.open_remote(p, OpenMode::Write).unwrap();
    fa.pwrite_all(&vec![1u8; MIB as usize], 0).unwrap();

    // B maps the (still tier-0-resident) file and snapshots its gen
    let mut fb = b.open_remote(p, OpenMode::Read).unwrap();
    let g0 = fb.map_sync().unwrap();

    // A grows the file past tier-0 capacity: the daemon spills it
    for k in 1..4u64 {
        fa.pwrite_all(&vec![(k + 1) as u8; MIB as usize], k * MIB).unwrap();
    }
    drop(fa);

    let g1 = fb.map_sync().unwrap();
    assert!(
        g1 > g0,
        "B's MapSync must see the spill A caused (gen {g0} -> {g1})"
    );
    let c = b.counters().unwrap();
    assert!(c.counters.self_spills >= 1, "daemon recorded the spill: {:?}", c.counters);

    // and B still reads coherent post-spill bytes
    let mut tail = vec![0u8; MIB as usize];
    fb.pread_exact(&mut tail, 3 * MIB).unwrap();
    assert!(tail.iter().all(|&v| v == 4), "post-spill bytes read back");

    drop(fb);
    server.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn killing_the_daemon_mid_use_is_a_typed_error_not_a_hang() {
    // Scenario 4: the daemon dies under a live client. Mutating ops
    // surface `DaemonGone` immediately; idempotent ops retry with
    // bounded backoff and then surface `DaemonGone` too. Nothing
    // blocks forever.
    let root = scratch("gone");
    let served = root.join("served");
    let sock = root.join("sea.sock");
    let server = Server::spawn_vfs(
        Arc::new(RealFs::new(&served).unwrap()),
        None,
        ServeCfg::new(&sock),
    )
    .unwrap();

    let fs = RemoteFs::connect_with(&sock, fast_retry()).unwrap();
    let p = Path::new("/sea/doomed.dat");
    let mut writer = fs.open(p, OpenMode::ReadWrite).unwrap();
    writer.pwrite_all(b"pre-shutdown", 0).unwrap();
    let mut reader = fs.open(p, OpenMode::Read).unwrap();
    let mut buf = [0u8; 12];
    reader.pread_exact(&mut buf, 0).unwrap();
    assert_eq!(&buf, b"pre-shutdown");

    server.shutdown().unwrap(); // socket file removed, threads joined

    let t0 = std::time::Instant::now();
    match reader.pread(&mut buf, 0) {
        Err(Error::DaemonGone(msg)) => {
            assert!(!msg.is_empty(), "DaemonGone carries context")
        }
        other => panic!("pread against a dead daemon: expected DaemonGone, got {other:?}"),
    }
    match writer.pwrite(b"lost", 0) {
        Err(Error::DaemonGone(_)) => {}
        other => panic!("pwrite against a dead daemon: expected DaemonGone, got {other:?}"),
    }
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "dead-daemon errors must be bounded, took {:?}",
        t0.elapsed()
    );
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn idle_reaped_read_clients_transparently_reconnect() {
    // Satellite: the daemon reaps a client silent past the idle
    // deadline; a read-only handle heals itself (reconnect + reopen by
    // path) on its next request, while a writable handle — whose
    // daemon-side state died with the connection — fails typed.
    let root = scratch("reap");
    let served = root.join("served");
    let sock = root.join("sea.sock");
    let cfg = ServeCfg {
        socket: sock.clone(),
        idle_timeout: Duration::from_millis(100),
        lease_fds: true,
    };
    let server =
        Server::spawn_vfs(Arc::new(RealFs::new(&served).unwrap()), None, cfg).unwrap();

    let fs = RemoteFs::connect_with(&sock, fast_retry()).unwrap();
    let p = Path::new("/sea/nap.dat");
    {
        let mut f = fs.open(p, OpenMode::Write).unwrap();
        f.pwrite_all(b"before the nap", 0).unwrap();
    }
    let mut reader = fs.open(p, OpenMode::Read).unwrap();
    let mut writer = fs.open(p, OpenMode::ReadWrite).unwrap();
    let mut buf = [0u8; 14];
    reader.pread_exact(&mut buf, 0).unwrap();

    // sleep well past the idle deadline: the daemon reaps the
    // connection (and with it both daemon-side handles)
    std::thread::sleep(Duration::from_millis(400));

    reader.pread_exact(&mut buf, 0).unwrap();
    assert_eq!(&buf, b"before the nap", "read handle healed across the reap");
    match writer.pwrite(b"stale", 0) {
        Err(Error::DaemonGone(_)) => {}
        other => panic!("reaped writer: expected DaemonGone, got {other:?}"),
    }

    drop(reader);
    drop(writer);
    server.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn a_spill_revokes_the_fd_lease_but_in_flight_reads_stay_consistent() {
    // Tentpole: B holds an SCM_RIGHTS fd lease on a tier-0-resident
    // file and hammers zero-round-trip preads *while* A grows the file
    // past tier-0 capacity, forcing a self-spill. The spill unlinks
    // the tier-0 replica's *name*, not its inode, so every leased read
    // racing the move returns the consistent pre-spill snapshot; the
    // next response B observes (MapSync) piggybacks the bumped
    // generation and revokes the lease back to the wire path.
    let root = scratch("lease_spill");
    let sea = stripe_mount(&root, 2 * MIB, RuleSet::from_texts("**", "**", ""));
    let sock = root.join("sea.sock");
    let server = Server::spawn(sea, ServeCfg::new(&sock)).unwrap();

    let a = RemoteFs::connect(&sock).unwrap();
    let b = RemoteFs::connect(&sock).unwrap();
    let p = Path::new("/sea/leased.dat");

    let mut fa = a.open_remote(p, OpenMode::Write).unwrap();
    fa.pwrite_all(&vec![1u8; MIB as usize], 0).unwrap();

    let mut fb = b.open_remote(p, OpenMode::Read).unwrap();
    assert!(
        fb.has_lease(),
        "read-only open on a tier-0 (RealFs-backed) resident must come leased"
    );
    let g0 = fb.map_sync().unwrap();

    // Reader thread: leased preads in a tight loop while the spill
    // happens underneath. Every read must return pre-spill bytes.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let reader = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut buf = vec![0u8; 64 * 1024];
            let mut reads = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let off = (reads * 64 * 1024) % MIB;
                fb.pread_exact(&mut buf, off).unwrap();
                assert!(
                    buf.iter().all(|&v| v == 1),
                    "torn leased read at {off} during spill"
                );
                reads += 1;
            }
            (fb, reads)
        })
    };

    // A grows the file past tier-0 capacity: the daemon spills it.
    for k in 1..4u64 {
        fa.pwrite_all(&vec![(k + 1) as u8; MIB as usize], k * MIB).unwrap();
    }
    drop(fa);
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let (mut fb, reads) = reader.join().unwrap();
    assert!(reads > 0, "the reader thread must actually have read");

    // Nothing revoked the lease yet — leased preads never touch the
    // wire, so B has not seen the new generation.
    assert!(fb.has_lease(), "revocation needs an observed response");
    let g1 = fb.map_sync().unwrap();
    assert!(g1 > g0, "B's MapSync must observe the spill (gen {g0} -> {g1})");
    assert!(!fb.has_lease(), "a newer piggybacked gen revokes the lease");

    // Post-revocation reads ride the wire and see post-spill bytes.
    let mut tail = vec![0u8; MIB as usize];
    fb.pread_exact(&mut tail, 3 * MIB).unwrap();
    assert!(tail.iter().all(|&v| v == 4), "wire reads see the spilled replica");

    drop(fb);
    server.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn unlink_by_another_client_leaves_the_lease_snapshot_readable() {
    // Satellite: cross-client unlink-under-lease. A unlinks the file
    // while B holds a leased fd on it; B's reads keep serving the
    // snapshot (the inode outlives its name) even though the namespace
    // entry is gone for everyone.
    let root = scratch("lease_unlink");
    let served = root.join("served");
    let sock = root.join("sea.sock");
    let server = Server::spawn_vfs(
        Arc::new(RealFs::new(&served).unwrap()),
        None,
        ServeCfg::new(&sock),
    )
    .unwrap();

    let a = RemoteFs::connect(&sock).unwrap();
    let b = RemoteFs::connect(&sock).unwrap();
    let p = Path::new("/sea/ephemeral.dat");
    {
        let mut f = a.open(p, OpenMode::Write).unwrap();
        f.pwrite_all(&vec![7u8; 256 * 1024], 0).unwrap();
    }

    let mut fb = b.open_remote(p, OpenMode::Read).unwrap();
    assert!(fb.has_lease());
    a.unlink(p).unwrap();
    assert!(!b.exists(p), "the name is gone for everyone");

    let mut buf = vec![0u8; 256 * 1024];
    fb.pread_exact(&mut buf, 0).unwrap();
    assert!(
        buf.iter().all(|&v| v == 7),
        "leased reads serve the snapshot after unlink"
    );

    drop(fb);
    server.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn eight_leased_handles_read_concurrently_on_one_connection() {
    // Satellite: the mux + lease paths under real thread concurrency —
    // eight handles of ONE RemoteFs, each leased, each hammering raw
    // pread(2)s from its own thread while Open/Close traffic shares
    // the connection. (The wire-path twin of this test lives in
    // `vfs::remote`; both run under TSan in CI.)
    let root = scratch("lease_mux");
    let served = root.join("served");
    std::fs::create_dir_all(&served).unwrap();
    let data: Vec<u8> = (0..1u64 << 20).map(|i| (i % 251) as u8).collect();
    std::fs::write(served.join("big.dat"), &data).unwrap();
    let sock = root.join("sea.sock");
    let server = Server::spawn_vfs(
        Arc::new(RealFs::new(&served).unwrap()),
        None,
        ServeCfg::new(&sock),
    )
    .unwrap();

    let fs = RemoteFs::connect(&sock).unwrap();
    let data = Arc::new(data);
    let mut threads = Vec::new();
    for t in 0..8u64 {
        let mut f = fs.open_remote(Path::new("big.dat"), OpenMode::Read).unwrap();
        assert!(f.has_lease());
        let data = data.clone();
        threads.push(std::thread::spawn(move || {
            let mut buf = vec![0u8; 4096];
            for k in 0..128u64 {
                let page = (k * 53 + t * 97) % 256;
                let off = page * 4096;
                f.pread_exact(&mut buf, off).unwrap();
                assert_eq!(
                    buf[..],
                    data[off as usize..off as usize + 4096],
                    "thread {t} leased read at {off}"
                );
            }
        }));
    }
    for th in threads {
        th.join().unwrap();
    }

    let c = fs.counters().unwrap();
    assert!(c.leases_granted >= 8, "leases_granted gauge: {}", c.leases_granted);
    server.shutdown().unwrap();
    let _ = std::fs::remove_dir_all(&root);
}
