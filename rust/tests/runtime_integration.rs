//! Integration: PJRT runtime over the real AOT artifacts.
//!
//! Requires `make artifacts` and a real `xla` crate (not the offline
//! stub); every test skips cleanly when either is unavailable, mirroring
//! the interposer test. These tests cover the full L3->L2->L1 compute
//! path: HLO text -> xla parse -> PJRT compile -> execute -> host copy.

use std::path::PathBuf;
use std::sync::OnceLock;

use sea::runtime::Engine;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The compiled engine, or `None` when artifacts/PJRT are unavailable
/// (offline xla stub, or `make artifacts` not run) — tests then skip.
fn engine() -> Option<&'static Engine> {
    static ENGINE: OnceLock<Option<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| match Engine::load(artifacts_dir()) {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("skipping PJRT runtime tests: {e}");
                None
            }
        })
        .as_ref()
}

#[test]
fn manifest_lists_expected_entries() {
    let Some(e) = engine() else { return };
    let names = e.manifest().names();
    assert!(names.contains(&"step"));
    assert!(names.contains(&"blend"));
    assert!(names.contains(&"stats"));
}

#[test]
fn step_increments_uniform_chunk() {
    let Some(e) = engine() else { return };
    let n = e.chunk_elems();
    assert!(n > 0);
    let mut buf = vec![0f32; n];
    let stats = e.step(&mut buf).expect("step");
    assert!(buf.iter().all(|&x| x == 1.0));
    stats.certify_uniform(1.0, n).expect("uniform 1");
}

#[test]
fn step_matches_oracle_on_varied_data() {
    let Some(e) = engine() else { return };
    let n = e.chunk_elems();
    let mut buf: Vec<f32> = (0..n).map(|i| (i % 1000) as f32).collect();
    let want: Vec<f32> = buf.iter().map(|x| x + 1.0).collect();
    let stats = e.step(&mut buf).expect("step");
    assert_eq!(buf, want);
    assert_eq!(stats.min, 1.0);
    assert_eq!(stats.max, 1000.0);
}

#[test]
fn algorithm1_invariant_n_steps() {
    let Some(e) = engine() else { return };
    let n = e.chunk_elems();
    let mut buf = vec![3f32; n];
    let iters = 7;
    let mut last = None;
    for _ in 0..iters {
        last = Some(e.step(&mut buf).expect("step"));
    }
    last.unwrap()
        .certify_uniform(3.0 + iters as f32, n)
        .expect("after n steps chunk must be base+n");
}

#[test]
fn fused_step_equals_n_single_steps() {
    let Some(e) = engine() else { return };
    let elems = e.chunk_elems();
    let mut fused = vec![2f32; elems];
    let (n, stats) = e.step_fused(&mut fused).expect("fused");
    assert!(n > 0);
    let mut single = vec![2f32; elems];
    for _ in 0..n {
        e.step(&mut single).expect("step");
    }
    assert_eq!(fused, single);
    stats.certify_uniform(2.0 + n as f32, elems).expect("uniform");
}

#[test]
fn blend_is_elementwise_mean() {
    let Some(e) = engine() else { return };
    let elems = e.chunk_elems();
    let mut a = vec![1f32; elems];
    let b = vec![5f32; elems];
    let stats = e.blend(&mut a, &b).expect("blend");
    assert!(a.iter().all(|&x| x == 3.0));
    stats.certify_uniform(3.0, elems).expect("uniform 3");
}

#[test]
fn stats_detects_outlier() {
    let Some(e) = engine() else { return };
    let elems = e.chunk_elems();
    let mut buf = vec![0f32; elems];
    buf[elems / 2] = -9.0;
    let s = e.stats(&buf).expect("stats");
    assert_eq!(s.min, -9.0);
    assert_eq!(s.max, 0.0);
}

#[test]
fn certify_uniform_rejects_corruption() {
    let Some(e) = engine() else { return };
    let elems = e.chunk_elems();
    let mut buf = vec![1f32; elems];
    buf[17] = 2.0; // corrupt one element
    let s = e.stats(&buf).expect("stats");
    assert!(s.certify_uniform(1.0, elems).is_err());
}

#[test]
fn rejects_wrong_geometry() {
    let Some(e) = engine() else { return };
    let mut tiny = vec![0f32; 16];
    assert!(e.step(&mut tiny).is_err());
}

#[test]
fn timings_accumulate() {
    let Some(e) = engine() else { return };
    let elems = e.chunk_elems();
    let mut buf = vec![0f32; elems];
    let before = e.timings().calls;
    e.step(&mut buf).unwrap();
    let t = e.timings();
    assert!(t.calls > before);
    assert!(t.bytes > 0);
}
