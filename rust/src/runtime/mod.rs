//! PJRT runtime: load AOT-lowered HLO-text artifacts and execute them.
//!
//! This is the only bridge between the Rust request path and the
//! JAX/Pallas compute lowered at build time (`make artifacts`). Python is
//! never on the request path: [`Engine::load`] parses
//! `artifacts/manifest.txt`, reads each `*.hlo.txt` with
//! `HloModuleProto::from_text_file` (HLO *text* — the serialized-proto path
//! is rejected by xla_extension 0.5.1 on jax≥0.5 modules, see DESIGN.md),
//! compiles each entry once on the PJRT CPU client, and serves executions
//! for the lifetime of the process.

mod engine;
mod manifest;

pub use engine::{ChunkStats, Engine, ExecTimer};
pub use manifest::{Manifest, ManifestEntry};
