//! Artifact manifest parsing.
//!
//! `python -m compile.aot` writes `manifest.txt` next to the HLO artifacts:
//! one tab-separated line per entry: `name  file  rows  lanes  dtype`.
//! Logical names are `step`, `step_n:<n>`, `blend`, `stats`.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// One artifact entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Logical name (`step`, `step_n:5`, `blend`, `stats`).
    pub name: String,
    /// HLO text file path (absolute, resolved against the manifest dir).
    pub file: PathBuf,
    /// Chunk rows the entry was lowered for.
    pub rows: usize,
    /// Chunk lanes (always 256 in this repo).
    pub lanes: usize,
    /// Element dtype (always `f32` in this repo).
    pub dtype: String,
}

impl ManifestEntry {
    /// Elements per chunk.
    pub fn elems(&self) -> usize {
        self.rows * self.lanes
    }

    /// Chunk payload size in bytes (f32).
    pub fn chunk_bytes(&self) -> usize {
        self.elems() * 4
    }
}

/// Parsed artifact manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    entries: BTreeMap<String, ManifestEntry>,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.txt");
        let text = fs::read_to_string(&path).map_err(|e| Error::io(&path, e))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` resolves relative artifact file names.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut entries = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 5 {
                return Err(Error::Config(format!(
                    "manifest line {}: expected 5 tab-separated fields, got {}",
                    lineno + 1,
                    cols.len()
                )));
            }
            let rows: usize = cols[2].parse().map_err(|_| {
                Error::Config(format!("manifest line {}: bad rows {:?}", lineno + 1, cols[2]))
            })?;
            let lanes: usize = cols[3].parse().map_err(|_| {
                Error::Config(format!("manifest line {}: bad lanes {:?}", lineno + 1, cols[3]))
            })?;
            let entry = ManifestEntry {
                name: cols[0].to_string(),
                file: dir.join(cols[1]),
                rows,
                lanes,
                dtype: cols[4].to_string(),
            };
            entries.insert(entry.name.clone(), entry);
        }
        if entries.is_empty() {
            return Err(Error::Config("manifest has no entries".into()));
        }
        Ok(Manifest { entries })
    }

    /// Look up an entry by logical name.
    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.get(name)
    }

    /// All entries, name-sorted.
    pub fn entries(&self) -> impl Iterator<Item = &ManifestEntry> {
        self.entries.values()
    }

    /// Names of all entries.
    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    /// The fused-step entry (`step_n:<n>`), if any, with its n.
    pub fn fused_step(&self) -> Option<(usize, &ManifestEntry)> {
        self.entries.iter().find_map(|(name, e)| {
            name.strip_prefix("step_n:")
                .and_then(|n| n.parse::<usize>().ok())
                .map(|n| (n, e))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# name\tfile\trows\tlanes\tdtype\n\
        step\tmodel.hlo.txt\t4096\t256\tf32\n\
        step_n:5\tstep5.hlo.txt\t4096\t256\tf32\n\
        blend\tblend.hlo.txt\t4096\t256\tf32\n\
        stats\tstats.hlo.txt\t4096\t256\tf32\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.names(), vec!["blend", "stats", "step", "step_n:5"]);
        let step = m.get("step").unwrap();
        assert_eq!(step.rows, 4096);
        assert_eq!(step.chunk_bytes(), 4096 * 256 * 4);
        assert_eq!(step.file, Path::new("/a/model.hlo.txt"));
        let (n, e) = m.fused_step().unwrap();
        assert_eq!(n, 5);
        assert_eq!(e.name, "step_n:5");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("a\tb\tc\n", Path::new(".")).is_err());
        assert!(Manifest::parse("step\tf\tx\t256\tf32\n", Path::new(".")).is_err());
        assert!(Manifest::parse("", Path::new(".")).is_err());
        assert!(Manifest::parse("# only comments\n", Path::new(".")).is_err());
    }
}
