//! PJRT execution engine: compile-once, execute-many.

use std::path::Path;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::runtime::manifest::{Manifest, ManifestEntry};

/// On-device integrity statistics of a chunk: `[sum, min, max]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChunkStats {
    /// Sum of all elements (f32 accumulation on device).
    pub sum: f64,
    /// Minimum element.
    pub min: f32,
    /// Maximum element.
    pub max: f32,
}

impl ChunkStats {
    fn from_vec(v: &[f32]) -> Result<ChunkStats> {
        if v.len() != 3 {
            return Err(Error::Runtime(format!("stats arity {} != 3", v.len())));
        }
        Ok(ChunkStats { sum: v[0] as f64, min: v[1], max: v[2] })
    }

    /// Certify the Algorithm-1 invariant: a chunk initialized to `base`
    /// and incremented `n` times must be uniformly `base + n`.
    pub fn certify_uniform(&self, expect: f32, elems: usize) -> Result<()> {
        let ok = self.min == expect
            && self.max == expect
            && (self.sum - expect as f64 * elems as f64).abs()
                <= 1e-3 * (elems as f64).max(1.0);
        if ok {
            Ok(())
        } else {
            Err(Error::Integrity(format!(
                "expected uniform {expect}: got min={} max={} sum={}",
                self.min, self.max, self.sum
            )))
        }
    }
}

/// Cumulative execution timing (hot-path observability for the perf pass).
#[derive(Debug, Default, Clone)]
pub struct ExecTimer {
    /// Executions performed.
    pub calls: u64,
    /// Total wall time inside PJRT execute + host copies.
    pub total: Duration,
    /// Total payload bytes in + out.
    pub bytes: u64,
}

impl ExecTimer {
    fn record(&mut self, dt: Duration, bytes: u64) {
        self.calls += 1;
        self.total += dt;
        self.bytes += bytes;
    }

    /// Mean time per call.
    pub fn mean(&self) -> Duration {
        if self.calls == 0 { Duration::ZERO } else { self.total / self.calls as u32 }
    }

    /// Effective payload bandwidth (bytes/s).
    pub fn bandwidth(&self) -> f64 {
        let s = self.total.as_secs_f64();
        if s > 0.0 { self.bytes as f64 / s } else { 0.0 }
    }
}

struct Compiled {
    entry: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
}

/// Compile-once execute-many PJRT engine over the artifact manifest.
///
/// One engine per process; executions are serialized behind an internal
/// mutex (the CPU PJRT client is effectively single-stream on this box,
/// and worker threads spend their parallelism in I/O, matching the
/// paper's data-intensive regime).
pub struct Engine {
    #[allow(dead_code)] // keeps the client alive for the executables
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: Vec<Compiled>,
    timer: Mutex<ExecTimer>,
    lock: Mutex<()>,
}

// SAFETY: the xla wrapper types are opaque pointers into the PJRT C++
// runtime, which is internally synchronized; the wrapper simply never
// declares Send/Sync. All Engine executions are additionally serialized
// behind `lock`, and the timer behind its own mutex, so no &mut aliasing
// of the underlying handles can occur across threads.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load every artifact in `<dir>/manifest.txt` and compile it.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut compiled = Vec::new();
        for entry in manifest.entries() {
            let proto = xla::HloModuleProto::from_text_file(&entry.file)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            compiled.push(Compiled { entry: entry.clone(), exe });
        }
        Ok(Engine {
            client,
            manifest,
            compiled,
            timer: Mutex::new(ExecTimer::default()),
            lock: Mutex::new(()),
        })
    }

    /// The manifest this engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Chunk element count for the canonical `step` entry.
    pub fn chunk_elems(&self) -> usize {
        self.manifest.get("step").map(|e| e.elems()).unwrap_or(0)
    }

    /// Snapshot of cumulative execution timings.
    pub fn timings(&self) -> ExecTimer {
        self.timer.lock().expect("timer poisoned").clone()
    }

    fn find(&self, name: &str) -> Result<&Compiled> {
        self.compiled
            .iter()
            .find(|c| c.entry.name == name)
            .ok_or_else(|| Error::Runtime(format!("no artifact named {name:?}")))
    }

    fn literal_of(&self, entry: &ManifestEntry, data: &[f32]) -> Result<xla::Literal> {
        if data.len() != entry.elems() {
            return Err(Error::InvalidArg(format!(
                "chunk len {} != lowered geometry {}x{}",
                data.len(),
                entry.rows,
                entry.lanes
            )));
        }
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &[entry.rows, entry.lanes],
            bytes,
        )?)
    }

    fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let c = self.find(name)?;
        let _guard = self.lock.lock().expect("exec lock poisoned");
        let t0 = Instant::now();
        let result = c.exe.execute::<xla::Literal>(inputs)?;
        let root = result[0][0].to_literal_sync()?;
        let parts = root.to_tuple()?;
        let dt = t0.elapsed();
        let bytes: u64 = inputs.iter().map(|l| l.size_bytes() as u64).sum::<u64>()
            + parts.iter().map(|l| l.size_bytes() as u64).sum::<u64>();
        self.timer.lock().expect("timer poisoned").record(dt, bytes);
        Ok(parts)
    }

    /// One Algorithm-1 iteration: increments `data` in place and returns
    /// the post-increment stats.
    pub fn step(&self, data: &mut [f32]) -> Result<ChunkStats> {
        let entry = self.find("step")?.entry.clone();
        let input = self.literal_of(&entry, data)?;
        let parts = self.run("step", &[input])?;
        if parts.len() != 2 {
            return Err(Error::Runtime(format!("step returned {} parts", parts.len())));
        }
        parts[0].copy_raw_to::<f32>(data)?;
        ChunkStats::from_vec(&parts[1].to_vec::<f32>()?)
    }

    /// Fused n-iteration step, if `step_n:<n>` was lowered; returns the
    /// fused n. In-memory fast path for when intermediates are not
    /// materialized (DESIGN.md L2).
    pub fn step_fused(&self, data: &mut [f32]) -> Result<(usize, ChunkStats)> {
        let (n, entry) = self
            .manifest
            .fused_step()
            .map(|(n, e)| (n, e.clone()))
            .ok_or_else(|| Error::Runtime("no fused step artifact".into()))?;
        let input = self.literal_of(&entry, data)?;
        let parts = self.run(&entry.name, &[input])?;
        parts[0].copy_raw_to::<f32>(data)?;
        Ok((n, ChunkStats::from_vec(&parts[1].to_vec::<f32>()?)?))
    }

    /// Blend two chunks (multi-stage workload merge): `0.5a + 0.5b`,
    /// written into `a`.
    pub fn blend(&self, a: &mut [f32], b: &[f32]) -> Result<ChunkStats> {
        let entry = self.find("blend")?.entry.clone();
        let la = self.literal_of(&entry, a)?;
        let lb = self.literal_of(&entry, b)?;
        let parts = self.run("blend", &[la, lb])?;
        parts[0].copy_raw_to::<f32>(a)?;
        ChunkStats::from_vec(&parts[1].to_vec::<f32>()?)
    }

    /// Standalone integrity statistics of a chunk.
    pub fn stats(&self, data: &[f32]) -> Result<ChunkStats> {
        let entry = self.find("stats")?.entry.clone();
        // stats is lowered for the canonical geometry; callers pass chunks
        let mut owned;
        let input = if data.len() == entry.elems() {
            self.literal_of(&entry, data)?
        } else {
            // pad with the first element so min/max are unaffected
            owned = vec![*data.first().unwrap_or(&0.0); entry.elems()];
            owned[..data.len()].copy_from_slice(data);
            self.literal_of(&entry, &owned)?
        };
        let parts = self.run("stats", &[input])?;
        ChunkStats::from_vec(&parts[0].to_vec::<f32>()?)
    }

    /// Measure the single-step compute throughput (chunk-steps/second).
    ///
    /// Used to calibrate the simulator's per-iteration compute cost so
    /// simulated experiments charge a compute time consistent with the
    /// real PJRT hot path (DESIGN.md S6).
    pub fn calibrate_steps_per_sec(&self, reps: usize) -> Result<f64> {
        let elems = self.chunk_elems();
        let mut buf = vec![0f32; elems];
        // warmup
        self.step(&mut buf)?;
        let t0 = Instant::now();
        for _ in 0..reps.max(1) {
            self.step(&mut buf)?;
        }
        Ok(reps.max(1) as f64 / t0.elapsed().as_secs_f64())
    }
}
