//! The paper's analytic performance model (§3.4, Eqs. 1–11).
//!
//! Two makespan models with page-cache upper/lower bounds:
//!
//! * **Lustre** (Eqs. 1–5): bandwidth-bottleneck model with
//!   `L = min(cN, sN, d·min(d, cp))` and the all-cached lower bound.
//! * **Sea** (Eqs. 6–11): three-tier fill model — tmpfs, then local
//!   disks, then Lustre — with the `p·F` reservation subtracted from
//!   each tier's usable space, and the in-memory lower bound.
//!
//! All quantities are f64 bytes and bytes/second; makespans are seconds.
//! The figure benches shade the region between each system's bounds.

mod lustre;
mod sea;
mod volume;

pub use lustre::{lustre_read_bw, lustre_write_bw, makespan_cached, makespan_nocache};
pub use sea::{sea_breakdown, sea_makespan, sea_makespan_cached, SeaBreakdown};
pub use volume::WorkloadVolume;

use crate::sim::spec::ClusterSpec;

/// Model parameters derived from a cluster spec + experiment geometry.
#[derive(Debug, Clone)]
pub struct ModelParams {
    /// Number of compute nodes (`c`).
    pub c: f64,
    /// Parallel application processes per node (`p`).
    pub p: f64,
    /// Network bandwidth per node (`N`), bytes/s.
    pub n_bw: f64,
    /// Number of Lustre storage (OSS) nodes (`s`).
    pub s: f64,
    /// Number of Lustre storage disks (`d`).
    pub d: f64,
    /// Per-disk Lustre read bandwidth (`d_r`), bytes/s.
    pub d_r: f64,
    /// Per-disk Lustre write bandwidth (`d_w`), bytes/s.
    pub d_w: f64,
    /// Page-cache / memory read bandwidth per node (`C_r`), bytes/s.
    pub c_r: f64,
    /// Page-cache / memory write bandwidth per node (`C_w`), bytes/s.
    pub c_w: f64,
    /// tmpfs capacity per node (`t`), bytes.
    pub t: f64,
    /// Local disks per node (`g`).
    pub g: f64,
    /// Capacity per local disk (`r`), bytes.
    pub r: f64,
    /// Local disk read bandwidth (`G_r`), bytes/s.
    pub g_r: f64,
    /// Local disk write bandwidth (`G_w`), bytes/s.
    pub g_w: f64,
    /// Max file size (`F`), bytes.
    pub file: f64,
}

impl ModelParams {
    /// Derive parameters from a [`ClusterSpec`] and the workload's max
    /// file size.
    pub fn from_spec(spec: &ClusterSpec, file_size: u64) -> ModelParams {
        ModelParams {
            c: spec.nodes as f64,
            p: spec.procs_per_node as f64,
            n_bw: spec.nic_bw,
            s: spec.lustre.oss_count as f64,
            d: spec.lustre.ost_count() as f64,
            d_r: spec.lustre.ost_read_bw,
            d_w: spec.lustre.ost_write_bw,
            c_r: spec.mem_read_bw,
            c_w: spec.mem_write_bw,
            t: spec.tmpfs_bytes as f64,
            g: spec.disks_per_node as f64,
            r: spec.disk_bytes as f64,
            g_r: spec.disk_read_bw,
            g_w: spec.disk_write_bw,
            file: file_size as f64,
        }
    }
}

/// A [lower, upper] makespan interval in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bounds {
    /// Best-case makespan.
    pub lower: f64,
    /// Worst-case makespan.
    pub upper: f64,
}

impl Bounds {
    fn ordered(a: f64, b: f64) -> Bounds {
        // the all-cached path (Eq 5/11) is *usually* the lower bound, but
        // when c·C_w is slower than the aggregate PFS bandwidth (1 node,
        // many procs, 44 OSTs) the cached path loses — the figures shade
        // the region between the two curves either way
        Bounds { lower: a.min(b), upper: a.max(b) }
    }
}

/// Lustre bounds: between Eq. 5 (all-cached) and Eq. 1 (no-cache).
pub fn lustre_bounds(m: &ModelParams, v: &WorkloadVolume) -> Bounds {
    Bounds::ordered(makespan_cached(m, v), makespan_nocache(m, v))
}

/// Sea bounds: between Eq. 11 (in-memory) and Eq. 7 (no-cache tiers).
pub fn sea_bounds(m: &ModelParams, v: &WorkloadVolume) -> Bounds {
    Bounds::ordered(sea_makespan_cached(m, v), sea_makespan(m, v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{GIB, MIB};

    fn paper_setup() -> (ModelParams, WorkloadVolume) {
        let spec = ClusterSpec::paper_default();
        let v = WorkloadVolume::incrementation(1000, 617 * MIB, 10);
        (ModelParams::from_spec(&spec, 617 * MIB), v)
    }

    #[test]
    fn bounds_are_ordered() {
        let (m, v) = paper_setup();
        let lb = lustre_bounds(&m, &v);
        assert!(lb.lower <= lb.upper, "{lb:?}");
        let sb = sea_bounds(&m, &v);
        assert!(sb.lower <= sb.upper, "{sb:?}");
    }

    #[test]
    fn sea_and_lustre_share_the_cached_lower_bound_shape() {
        // §3.4: "Sea and Lustre have an identical lower bound" — both are
        // first-read-from-Lustre + everything-else-in-memory.
        let (m, v) = paper_setup();
        let l = makespan_cached(&m, &v);
        let s = sea_makespan_cached(&m, &v);
        assert!((l - s).abs() < 1e-9, "lustre {l} vs sea {s}");
    }

    #[test]
    fn sea_upper_beats_lustre_upper_at_paper_conditions() {
        // the paper's headline: at the fixed conditions Sea's worst case
        // is still far better than Lustre's worst case (write-bound)
        let (m, v) = paper_setup();
        let ml = makespan_nocache(&m, &v);
        let ms = sea_makespan(&m, &v);
        assert!(
            ms < ml,
            "sea {ms:.1}s should beat lustre {ml:.1}s at 5 nodes/6 procs/10 iters"
        );
    }

    #[test]
    fn no_intermediate_data_means_no_sea_advantage() {
        // 1 iteration: D_m = 0; both systems read D_I and write D_f to
        // Lustre, so the models coincide (§4.1: Sea ≈ Lustre at 1 iter)
        let spec = ClusterSpec::paper_default();
        let m = ModelParams::from_spec(&spec, 617 * MIB);
        let v = WorkloadVolume::incrementation(1000, 617 * MIB, 1);
        assert_eq!(v.d_m, 0.0);
        let ml = makespan_nocache(&m, &v);
        let ms = sea_makespan(&m, &v);
        // Sea still writes the final outputs to local disk first in the
        // worst case... but with flush-all semantics the model's M_S only
        // counts tier I/O; D_f fits in tmpfs+disks, so Sea ≈ local write
        // vs Lustre write. The *identical* part is the read; allow Sea to
        // differ on the write side but not be absurdly slower.
        assert!(ms <= ml * 1.5, "sea {ms} vs lustre {ml}");
    }

    #[test]
    fn more_disks_reduce_sea_makespan() {
        let spec = ClusterSpec::paper_default();
        let v = WorkloadVolume::incrementation(1000, 617 * MIB, 5);
        let mut prev = f64::INFINITY;
        for disks in [1usize, 2, 4, 6] {
            let mut s = spec.clone();
            s.disks_per_node = disks;
            let m = ModelParams::from_spec(&s, 617 * MIB);
            let ms = sea_makespan(&m, &v);
            assert!(ms <= prev + 1e-9, "disks {disks}: {ms} vs prev {prev}");
            prev = ms;
        }
    }

    #[test]
    fn lustre_write_bw_min_structure() {
        let (m, _) = paper_setup();
        // at 5 nodes × 6 procs = 30 streams < 44 disks: disk-bound at
        // 30 × d_w
        let lw = lustre_write_bw(&m);
        let expect = m.d_w * 30.0;
        assert!((lw - expect).abs() < 1.0, "lw {lw} expect {expect}");
        // with huge p the cap is d disks or the NICs
        let mut m2 = m.clone();
        m2.p = 1000.0;
        let lw2 = lustre_write_bw(&m2);
        assert!(lw2 <= m2.s * m2.n_bw + 1.0);
        assert!(lw2 <= m2.d * m2.d_w + 1.0);
    }

    #[test]
    fn tmpfs_capacity_limits_in_memory_share() {
        // tiny tmpfs -> most intermediate data must hit disks/lustre
        let spec = ClusterSpec::paper_default();
        let mut s2 = spec.clone();
        s2.tmpfs_bytes = GIB;
        let v = WorkloadVolume::incrementation(1000, 617 * MIB, 10);
        let big = sea_breakdown(&ModelParams::from_spec(&spec, 617 * MIB), &v);
        let small = sea_breakdown(&ModelParams::from_spec(&s2, 617 * MIB), &v);
        assert!(small.d_tw < big.d_tw, "less tmpfs -> fewer tmpfs writes");
        assert!(
            small.d_lw >= big.d_lw,
            "less tmpfs -> at least as much lustre spill"
        );
    }
}
