//! Sea makespan model: Eqs. (6)–(11).

use crate::model::lustre::{lustre_read_bw, lustre_write_bw};
use crate::model::{ModelParams, WorkloadVolume};

/// Per-tier data volumes computed by the fill rule (Eqs. 8–10).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeaBreakdown {
    /// Intermediate bytes read from tmpfs (`D_tr`).
    pub d_tr: f64,
    /// Bytes written to tmpfs (`D_tw`).
    pub d_tw: f64,
    /// Bytes read from local disks (`D_gr`).
    pub d_gr: f64,
    /// Bytes written to local disks (`D_gw`).
    pub d_gw: f64,
    /// Intermediate bytes read from Lustre (`D_Lr`).
    pub d_lr: f64,
    /// Bytes spilled to Lustre (`D_Lw`).
    pub d_lw: f64,
}

/// Apply the tier fill rule of Eqs. (8)–(10).
///
/// Usable space per tier subtracts the `p·F` reservation per node:
/// tmpfs `c·(t − pF)`, disks `c·(g·r − pF)`. Writes fill fastest-first;
/// reads of intermediate data come from wherever it was written.
pub fn sea_breakdown(m: &ModelParams, v: &WorkloadVolume) -> SeaBreakdown {
    let tmpfs_space = m.c * (m.t - m.p * m.file).max(0.0);
    let disk_space = m.c * (m.g * m.r - m.p * m.file).max(0.0);

    // Eq. (8)
    let d_tr = v.d_m.min(tmpfs_space);
    let d_tw = (v.d_m + v.d_f).min(tmpfs_space);
    // Eq. (9)
    let d_gr = (v.d_m - d_tr).max(0.0).min(disk_space);
    let d_gw = (v.d_m + v.d_f - d_tw).max(0.0).min(disk_space);
    // Eq. (10)
    let d_lr = (v.d_m - d_gr - d_tr).max(0.0);
    let d_lw = (v.d_m + v.d_f - d_gw - d_tw).max(0.0);

    SeaBreakdown { d_tr, d_tw, d_gr, d_gw, d_lr, d_lw }
}

/// Eq. (7): `M_S = M_SL + M_Sg + M_St` — the no-cache Sea makespan.
pub fn sea_makespan(m: &ModelParams, v: &WorkloadVolume) -> f64 {
    let b = sea_breakdown(m, v);
    // Eq. (8): tmpfs component
    let m_st = b.d_tr / (m.c * m.c_r) + b.d_tw / (m.c * m.c_w);
    // Eq. (9): local-disk component (g disks per node, c nodes)
    let m_sg = b.d_gr / (m.g * m.c * m.g_r) + b.d_gw / (m.g * m.c * m.g_w);
    // Eq. (10): Lustre component (initial read + spills)
    let m_sl = v.d_i / lustre_read_bw(m)
        + b.d_lr / lustre_read_bw(m)
        + b.d_lw / lustre_write_bw(m);
    m_st + m_sg + m_sl
}

/// Eq. (11): the in-memory Sea lower bound
/// `M_Sc = D_I/L_r + D_m/(c·C_r) + (D_m + D_f)/(c·C_w)`.
pub fn sea_makespan_cached(m: &ModelParams, v: &WorkloadVolume) -> f64 {
    v.d_i / lustre_read_bw(m)
        + v.d_m / (m.c * m.c_r)
        + (v.d_m + v.d_f) / (m.c * m.c_w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::spec::ClusterSpec;
    use crate::util::{GIB, MIB};

    fn m() -> ModelParams {
        ModelParams::from_spec(&ClusterSpec::paper_default(), 617 * MIB)
    }

    #[test]
    fn breakdown_conserves_volume() {
        let p = m();
        for iters in [1usize, 5, 10, 15] {
            let v = WorkloadVolume::incrementation(1000, 617 * MIB, iters);
            let b = sea_breakdown(&p, &v);
            let reads = b.d_tr + b.d_gr + b.d_lr;
            let writes = b.d_tw + b.d_gw + b.d_lw;
            assert!((reads - v.d_m).abs() < 1.0, "iters {iters}: reads");
            assert!((writes - (v.d_m + v.d_f)).abs() < 1.0, "iters {iters}: writes");
            assert!(b.d_tr >= 0.0 && b.d_gr >= 0.0 && b.d_lr >= 0.0);
        }
    }

    #[test]
    fn fill_order_is_tmpfs_first() {
        let p = m();
        // small volume fits entirely in tmpfs: 100 blocks * 617 MiB * 2
        let v = WorkloadVolume::incrementation(100, 617 * MIB, 2);
        let b = sea_breakdown(&p, &v);
        assert_eq!(b.d_gw, 0.0, "no disk writes while tmpfs has room");
        assert_eq!(b.d_lw, 0.0);
        assert!((b.d_tw - v.writes()).abs() < 1.0);
    }

    #[test]
    fn reservation_shrinks_usable_tmpfs() {
        let mut p = m();
        let v = WorkloadVolume::incrementation(1000, 617 * MIB, 10);
        let b1 = sea_breakdown(&p, &v);
        p.p = 64.0; // 64 procs reserve 64·617 MiB ≈ 38.6 GiB per node
        let b2 = sea_breakdown(&p, &v);
        assert!(b2.d_tw < b1.d_tw);
    }

    #[test]
    fn overflow_cascades_to_lustre() {
        let mut p = m();
        p.t = GIB as f64; // tiny tmpfs
        p.r = GIB as f64; // tiny disks
        let v = WorkloadVolume::incrementation(1000, 617 * MIB, 10);
        let b = sea_breakdown(&p, &v);
        assert!(b.d_lw > 0.0, "spill to lustre expected");
        assert!(b.d_lr > 0.0);
    }

    #[test]
    fn hand_computed_tiny_case() {
        let p = ModelParams {
            c: 2.0,
            p: 1.0,
            n_bw: 1e9,
            s: 1.0,
            d: 4.0,
            d_r: 100.0,
            d_w: 50.0,
            c_r: 1000.0,
            c_w: 500.0,
            t: 60.0,
            g: 2.0,
            r: 30.0,
            g_r: 200.0,
            g_w: 100.0,
            file: 10.0,
        };
        let v = WorkloadVolume { d_i: 100.0, d_m: 150.0, d_f: 50.0, file: 10.0 };
        let b = sea_breakdown(&p, &v);
        // tmpfs space = 2*(60-10) = 100; disks = 2*(2*30-10) = 100
        assert_eq!(b.d_tr, 100.0);
        assert_eq!(b.d_tw, 100.0);
        // d_gr = min(150-100, 100) = 50 ; d_gw = min(200-100, 100) = 100
        assert_eq!(b.d_gr, 50.0);
        assert_eq!(b.d_gw, 100.0);
        // d_lr = 150-100-50 = 0 ; d_lw = 200-100-100 = 0
        assert_eq!(b.d_lr, 0.0);
        assert_eq!(b.d_lw, 0.0);
        // M_St = 100/(2*1000) + 100/(2*500) = 0.05 + 0.1 = 0.15
        // M_Sg = 50/(2*2*200) + 100/(2*2*100) = 0.0625 + 0.25 = 0.3125
        // L_r = min(2e9, 1e9, 100*min(4,2)) = 200 ; M_SL = 100/200 = 0.5
        let ms = sea_makespan(&p, &v);
        assert!((ms - (0.15 + 0.3125 + 0.5)).abs() < 1e-9, "ms = {ms}");
    }

    #[test]
    fn cached_bound_is_monotone_in_volume() {
        let p = m();
        let v1 = WorkloadVolume::incrementation(1000, 617 * MIB, 5);
        let v2 = WorkloadVolume::incrementation(1000, 617 * MIB, 10);
        assert!(sea_makespan_cached(&p, &v1) < sea_makespan_cached(&p, &v2));
    }
}
