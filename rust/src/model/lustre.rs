//! Lustre makespan model: Eqs. (1)–(5).

use crate::model::{ModelParams, WorkloadVolume};

/// Eq. (2): `L_r = min(cN, sN, d_r · min(d, cp))`.
pub fn lustre_read_bw(m: &ModelParams) -> f64 {
    (m.c * m.n_bw)
        .min(m.s * m.n_bw)
        .min(m.d_r * m.d.min(m.c * m.p))
}

/// Eq. (3): `L_w = min(cN, sN, d_w · min(d, cp))`.
pub fn lustre_write_bw(m: &ModelParams) -> f64 {
    (m.c * m.n_bw)
        .min(m.s * m.n_bw)
        .min(m.d_w * m.d.min(m.c * m.p))
}

/// Eq. (1): the no-cache Lustre makespan
/// `M_l = D_r/L_r + D_w/L_w`.
pub fn makespan_nocache(m: &ModelParams, v: &WorkloadVolume) -> f64 {
    v.reads() / lustre_read_bw(m) + v.writes() / lustre_write_bw(m)
}

/// Eq. (4): page-cache-only makespan
/// `M_c = D_cr/(c·C_r) + D_cw/(c·C_w)` with `D_cr = D_m`,
/// `D_cw = D_m + D_f` (everything after the first read is cached).
pub fn page_cache_makespan(m: &ModelParams, v: &WorkloadVolume) -> f64 {
    v.d_m / (m.c * m.c_r) + v.writes() / (m.c * m.c_w)
}

/// Eq. (5): the all-cached Lustre lower bound
/// `M_lc = D_I/L_r + M_c`.
pub fn makespan_cached(m: &ModelParams, v: &WorkloadVolume) -> f64 {
    v.d_i / lustre_read_bw(m) + page_cache_makespan(m, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::spec::ClusterSpec;
    use crate::util::MIB;

    fn m() -> ModelParams {
        ModelParams::from_spec(&ClusterSpec::paper_default(), 617 * MIB)
    }

    #[test]
    fn read_bw_bound_by_streams_then_disks() {
        let mut p = m();
        p.p = 1.0;
        p.c = 1.0;
        // one stream: d_r * 1
        assert!((lustre_read_bw(&p) - p.d_r).abs() < 1.0);
        p.c = 5.0;
        p.p = 100.0; // cp = 500 > 44 disks
        let bw = lustre_read_bw(&p);
        assert!(bw <= p.d * p.d_r + 1.0);
        assert!(bw <= p.s * p.n_bw + 1.0, "server NICs cap aggregate reads");
    }

    #[test]
    fn cached_bound_below_nocache() {
        let p = m();
        let v = WorkloadVolume::incrementation(1000, 617 * MIB, 10);
        assert!(makespan_cached(&p, &v) < makespan_nocache(&p, &v));
    }

    #[test]
    fn makespans_scale_with_iterations() {
        let p = m();
        let v5 = WorkloadVolume::incrementation(1000, 617 * MIB, 5);
        let v10 = WorkloadVolume::incrementation(1000, 617 * MIB, 10);
        assert!(makespan_nocache(&p, &v10) > makespan_nocache(&p, &v5));
        assert!(makespan_cached(&p, &v10) > makespan_cached(&p, &v5));
    }

    #[test]
    fn hand_computed_tiny_case() {
        // c=1, p=1, N=100, s=1, d=2, d_r=10, d_w=5, mem 100/50
        let p = ModelParams {
            c: 1.0,
            p: 1.0,
            n_bw: 100.0,
            s: 1.0,
            d: 2.0,
            d_r: 10.0,
            d_w: 5.0,
            c_r: 100.0,
            c_w: 50.0,
            t: 0.0,
            g: 1.0,
            r: 0.0,
            g_r: 1.0,
            g_w: 1.0,
            file: 10.0,
        };
        // L_r = min(100, 100, 10*1) = 10; L_w = min(100,100,5*1) = 5
        assert_eq!(lustre_read_bw(&p), 10.0);
        assert_eq!(lustre_write_bw(&p), 5.0);
        let v = WorkloadVolume { d_i: 100.0, d_m: 50.0, d_f: 100.0, file: 10.0 };
        // M_l = 150/10 + 150/5 = 45
        assert_eq!(makespan_nocache(&p, &v), 45.0);
        // M_c = 50/100 + 150/50 = 3.5 ; M_lc = 100/10 + 3.5 = 13.5
        assert_eq!(makespan_cached(&p, &v), 13.5);
    }
}
