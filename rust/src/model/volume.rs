//! Workload data-volume description used by the analytic model.

/// Data volumes of one experiment run (f64 bytes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadVolume {
    /// Input data read once from the PFS (`D_I`).
    pub d_i: f64,
    /// Intermediate data, written *and* re-read (`D_m`).
    pub d_m: f64,
    /// Final output data (`D_f`).
    pub d_f: f64,
    /// Size of a single file (`F`).
    pub file: f64,
}

impl WorkloadVolume {
    /// Volumes of the incrementation application (Algorithm 1):
    /// `blocks` chunks of `file_size` bytes, `iterations` increment
    /// rounds. Each round writes every chunk; rounds 2..n re-read the
    /// previous round's output, so intermediate data is
    /// `(n-1) · blocks · F` and the final round's output is `blocks · F`.
    pub fn incrementation(blocks: usize, file_size: u64, iterations: usize) -> WorkloadVolume {
        let b = blocks as f64;
        let f = file_size as f64;
        let n = iterations.max(1) as f64;
        WorkloadVolume {
            d_i: b * f,
            d_m: (n - 1.0) * b * f,
            d_f: b * f,
            file: f,
        }
    }

    /// Total bytes read (`D_r = D_I + D_m`).
    pub fn reads(&self) -> f64 {
        self.d_i + self.d_m
    }

    /// Total bytes written (`D_w = D_m + D_f`).
    pub fn writes(&self) -> f64 {
        self.d_m + self.d_f
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    #[test]
    fn incrementation_volumes() {
        let v = WorkloadVolume::incrementation(1000, 617 * MIB, 10);
        let f = (617 * MIB) as f64;
        assert_eq!(v.d_i, 1000.0 * f);
        assert_eq!(v.d_m, 9000.0 * f);
        assert_eq!(v.d_f, 1000.0 * f);
        assert_eq!(v.reads(), 10_000.0 * f);
        assert_eq!(v.writes(), 10_000.0 * f);
    }

    #[test]
    fn single_iteration_has_no_intermediate() {
        let v = WorkloadVolume::incrementation(10, MIB, 1);
        assert_eq!(v.d_m, 0.0);
        assert_eq!(v.writes(), v.d_f);
    }
}
