//! Storage hierarchy: ordered tiers of devices with space accounting and
//! the paper's fastest-with-sufficient-space selection rule (§3.1.2).
//!
//! The hierarchy is *abstract*: a device is an index with a tier rank and
//! a capacity. The simulator maps indices to [`crate::sim::Location`]s and
//! the real-bytes VFS maps them to directories, so the same selection and
//! accounting code drives both (DESIGN.md S8/S9).
//!
//! Selection rule, as in the paper:
//! * walk tiers from fastest to slowest;
//! * within a tier, visit devices in *randomly shuffled* order ("selected
//!   by Sea via a random shuffling", §4.1);
//! * a device is eligible when its free space is at least the
//!   *reservation floor* `p · F` (parallel processes × max file size):
//!   Sea "calculates the minimum space required on a storage device to
//!   write the file to it" from those two user-provided numbers;
//! * the chosen device is debited the actual file size; if no device in
//!   any tier is eligible the caller falls back to the PFS.

mod accountant;
mod select;

pub use accountant::SpaceAccountant;
pub use select::{select_device, SelectCfg};

/// Index of a device within a [`Hierarchy`].
pub type DeviceRef = usize;

/// Static description of one device.
#[derive(Debug, Clone)]
pub struct DeviceInfo {
    /// Tier rank: 0 = fastest. Devices with equal rank are peers.
    pub tier: u8,
    /// Usable capacity in bytes.
    pub capacity: u64,
    /// Display name (diagnostics / reports).
    pub name: String,
}

/// An ordered set of devices forming the Sea hierarchy for one node.
#[derive(Debug, Clone, Default)]
pub struct Hierarchy {
    devices: Vec<DeviceInfo>,
}

impl Hierarchy {
    /// Empty hierarchy.
    pub fn new() -> Hierarchy {
        Hierarchy::default()
    }

    /// Add a device; returns its [`DeviceRef`].
    pub fn add(&mut self, tier: u8, capacity: u64, name: impl Into<String>) -> DeviceRef {
        self.devices.push(DeviceInfo { tier, capacity, name: name.into() });
        self.devices.len() - 1
    }

    /// Device metadata.
    pub fn info(&self, d: DeviceRef) -> &DeviceInfo {
        &self.devices[d]
    }

    /// Number of devices.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// True when no devices are registered.
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Distinct tier ranks, ascending (fastest first).
    pub fn tiers(&self) -> Vec<u8> {
        let mut t: Vec<u8> = self.devices.iter().map(|d| d.tier).collect();
        t.sort_unstable();
        t.dedup();
        t
    }

    /// Devices of a given tier, in insertion order.
    pub fn tier_devices(&self, tier: u8) -> Vec<DeviceRef> {
        self.devices
            .iter()
            .enumerate()
            .filter(|(_, d)| d.tier == tier)
            .map(|(i, _)| i)
            .collect()
    }

    /// Iterate (ref, info) pairs.
    pub fn iter(&self) -> impl Iterator<Item = (DeviceRef, &DeviceInfo)> {
        self.devices.iter().enumerate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::GIB;

    #[test]
    fn tiers_sorted_and_deduped() {
        let mut h = Hierarchy::new();
        h.add(1, GIB, "ssd0");
        h.add(0, GIB, "tmpfs");
        h.add(1, GIB, "ssd1");
        assert_eq!(h.tiers(), vec![0, 1]);
        assert_eq!(h.tier_devices(1).len(), 2);
        assert_eq!(h.info(1).name, "tmpfs");
        assert_eq!(h.len(), 3);
    }
}
