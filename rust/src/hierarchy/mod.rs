//! Storage hierarchy: ordered tiers of devices with space accounting and
//! the paper's fastest-with-sufficient-space selection rule (§3.1.2).
//!
//! The hierarchy is *abstract*: a device is an index with a tier rank and
//! a capacity. The simulator maps indices to [`crate::sim::Location`]s and
//! the real-bytes VFS maps them to **storage backends** — since the
//! backend-stack refactor a device may carry an `Arc<dyn Vfs>` handle
//! ([`Hierarchy::add_backed`]), so `SeaFs` talks to every placement
//! target (tmpfs dir, local disk, striped PFS stand-in) through the same
//! [`crate::vfs::Vfs`] abstraction instead of raw `std::fs` paths. The
//! simulator keeps using backend-less devices ([`Hierarchy::add`]); the
//! same selection and accounting code drives both (DESIGN.md S8/S9).
//!
//! Since the engine refactor neither side calls [`select_device`]
//! directly: both drive a [`crate::placement::PlacementEngine`] (the
//! `paper` engine wraps this module's selection rule verbatim) and the
//! engine debits the accountant on every pick.
//!
//! Selection rule, as in the paper:
//! * walk tiers from fastest to slowest;
//! * within a tier, visit devices in *randomly shuffled* order ("selected
//!   by Sea via a random shuffling", §4.1);
//! * a device is eligible when its free space is at least the
//!   *reservation floor* `p · F` (parallel processes × max file size):
//!   Sea "calculates the minimum space required on a storage device to
//!   write the file to it" from those two user-provided numbers;
//! * the chosen device is debited the actual file size; if no device in
//!   any tier is eligible the caller falls back to the PFS.
//!
//! Accounting flows through the [`SpaceAccountant`]'s per-device ledger
//! ([`LedgerLine`]): every debit and credit is recorded against the
//! device it targets, so diagnostics (and `SeaFs::ledger`) can report
//! occupancy and cumulative traffic per backend.

mod accountant;
mod select;

pub use accountant::{LedgerLine, SpaceAccountant};
pub use select::{select_device, SelectCfg};

use std::fmt;
use std::sync::Arc;

use crate::vfs::Vfs;

/// Index of a device within a [`Hierarchy`].
pub type DeviceRef = usize;

/// Static description of one device.
#[derive(Clone)]
pub struct DeviceInfo {
    /// Tier rank: 0 = fastest. Devices with equal rank are peers.
    pub tier: u8,
    /// Usable capacity in bytes.
    pub capacity: u64,
    /// Display name (diagnostics / reports).
    pub name: String,
    /// Storage backend the device's bytes live on (real-bytes mounts);
    /// `None` for abstract devices (simulator).
    pub backend: Option<Arc<dyn Vfs>>,
}

impl fmt::Debug for DeviceInfo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("DeviceInfo")
            .field("tier", &self.tier)
            .field("capacity", &self.capacity)
            .field("name", &self.name)
            .field("backend", &self.backend.as_ref().map(|_| "<vfs>"))
            .finish()
    }
}

/// An ordered set of devices forming the Sea hierarchy for one node.
#[derive(Debug, Clone, Default)]
pub struct Hierarchy {
    devices: Vec<DeviceInfo>,
}

impl Hierarchy {
    /// Empty hierarchy.
    pub fn new() -> Hierarchy {
        Hierarchy::default()
    }

    /// Add an abstract (backend-less) device; returns its [`DeviceRef`].
    pub fn add(&mut self, tier: u8, capacity: u64, name: impl Into<String>) -> DeviceRef {
        self.devices.push(DeviceInfo {
            tier,
            capacity,
            name: name.into(),
            backend: None,
        });
        self.devices.len() - 1
    }

    /// Add a device whose bytes live on a [`Vfs`] backend.
    pub fn add_backed(
        &mut self,
        tier: u8,
        capacity: u64,
        name: impl Into<String>,
        backend: Arc<dyn Vfs>,
    ) -> DeviceRef {
        self.devices.push(DeviceInfo {
            tier,
            capacity,
            name: name.into(),
            backend: Some(backend),
        });
        self.devices.len() - 1
    }

    /// Device metadata.
    pub fn info(&self, d: DeviceRef) -> &DeviceInfo {
        &self.devices[d]
    }

    /// The device's storage backend, if it has one.
    pub fn backend(&self, d: DeviceRef) -> Option<&Arc<dyn Vfs>> {
        self.devices[d].backend.as_ref()
    }

    /// Number of devices.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// True when no devices are registered.
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Distinct tier ranks, ascending (fastest first).
    pub fn tiers(&self) -> Vec<u8> {
        let mut t: Vec<u8> = self.devices.iter().map(|d| d.tier).collect();
        t.sort_unstable();
        t.dedup();
        t
    }

    /// Devices of a given tier, in insertion order.
    pub fn tier_devices(&self, tier: u8) -> Vec<DeviceRef> {
        self.devices
            .iter()
            .enumerate()
            .filter(|(_, d)| d.tier == tier)
            .map(|(i, _)| i)
            .collect()
    }

    /// Iterate (ref, info) pairs.
    pub fn iter(&self) -> impl Iterator<Item = (DeviceRef, &DeviceInfo)> {
        self.devices.iter().enumerate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::GIB;
    use crate::vfs::RealFs;
    use crate::vfs::testutil::scratch;

    #[test]
    fn tiers_sorted_and_deduped() {
        let mut h = Hierarchy::new();
        h.add(1, GIB, "ssd0");
        h.add(0, GIB, "tmpfs");
        h.add(1, GIB, "ssd1");
        assert_eq!(h.tiers(), vec![0, 1]);
        assert_eq!(h.tier_devices(1).len(), 2);
        assert_eq!(h.info(1).name, "tmpfs");
        assert_eq!(h.len(), 3);
        assert!(h.backend(0).is_none(), "abstract devices carry no backend");
    }

    #[test]
    fn backed_devices_expose_their_vfs() {
        let dir = scratch("hier_backed");
        let mut h = Hierarchy::new();
        let fs: Arc<dyn Vfs> = Arc::new(RealFs::new(&dir).unwrap());
        let d = h.add_backed(0, GIB, "tmpfs", fs);
        assert!(h.backend(d).is_some());
        // the handle is usable as a plain Vfs
        h.backend(d)
            .unwrap()
            .write(std::path::Path::new("probe"), b"x")
            .unwrap();
        assert!(h.backend(d).unwrap().exists(std::path::Path::new("probe")));
        // Debug doesn't choke on the non-Debug trait object
        assert!(format!("{h:?}").contains("tmpfs"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
