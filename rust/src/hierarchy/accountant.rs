//! Thread-safe free-space accounting for hierarchy devices.
//!
//! Real-mode worker threads and the (single-threaded) simulator share
//! this type; a plain mutex keeps the arithmetic exact — contention is
//! negligible next to actual I/O.
//!
//! Since the backend-stack refactor the accountant keeps a full
//! [`LedgerLine`] per device (free, used, cumulative debits/credits)
//! rather than a bare free counter, so every credit and debit is
//! attributable to the backend it targeted (`SeaFs::ledger` surfaces
//! the lines next to each device's name and backend).

use std::sync::Mutex;

use crate::hierarchy::{DeviceRef, Hierarchy};

/// One device's ledger state.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LedgerLine {
    /// Bytes currently free.
    pub free: u64,
    /// Bytes currently debited (placed files, reservations).
    pub used: u64,
    /// Cumulative bytes ever debited (placement traffic).
    pub debits: u64,
    /// Cumulative bytes ever credited back (evictions, shrinks, spills).
    pub credits: u64,
}

/// Per-device space ledger over a [`Hierarchy`]'s devices.
#[derive(Debug)]
pub struct SpaceAccountant {
    lines: Mutex<Vec<LedgerLine>>,
}

impl SpaceAccountant {
    /// All devices start with their full capacity free.
    pub fn new(h: &Hierarchy) -> SpaceAccountant {
        SpaceAccountant {
            lines: Mutex::new(
                h.iter()
                    .map(|(_, d)| LedgerLine { free: d.capacity, ..LedgerLine::default() })
                    .collect(),
            ),
        }
    }

    /// Current free bytes of `d`.
    pub fn free(&self, d: DeviceRef) -> u64 {
        self.lines.lock().expect("accountant poisoned")[d].free
    }

    /// Bytes currently debited from `d`.
    pub fn used(&self, d: DeviceRef) -> u64 {
        self.lines.lock().expect("accountant poisoned")[d].used
    }

    /// Attempt to debit `bytes` from `d` **iff** at least `floor` bytes
    /// are free (the `p·F` eligibility rule). Returns success.
    pub fn try_debit(&self, d: DeviceRef, bytes: u64, floor: u64) -> bool {
        let mut lines = self.lines.lock().expect("accountant poisoned");
        let l = &mut lines[d];
        if l.free >= floor && l.free >= bytes {
            l.free -= bytes;
            l.used += bytes;
            l.debits += bytes;
            true
        } else {
            false
        }
    }

    /// Credit `bytes` back to `d` (eviction / deletion / spill),
    /// saturating at the ledger's running totals (over-credit is a
    /// caller bug, but we saturate rather than wrap).
    pub fn credit(&self, d: DeviceRef, bytes: u64) {
        let mut lines = self.lines.lock().expect("accountant poisoned");
        let l = &mut lines[d];
        l.free = l.free.saturating_add(bytes);
        l.used = l.used.saturating_sub(bytes);
        l.credits += bytes;
    }

    /// Largest free block across devices (diagnostics for NoSpace errors).
    pub fn largest_free(&self) -> u64 {
        self.lines
            .lock()
            .expect("accountant poisoned")
            .iter()
            .map(|l| l.free)
            .max()
            .unwrap_or(0)
    }

    /// Total free bytes.
    pub fn total_free(&self) -> u64 {
        self.lines
            .lock()
            .expect("accountant poisoned")
            .iter()
            .map(|l| l.free)
            .sum()
    }

    /// Snapshot of every device's ledger line, indexed by [`DeviceRef`].
    pub fn lines(&self) -> Vec<LedgerLine> {
        self.lines.lock().expect("accountant poisoned").clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    fn h2() -> Hierarchy {
        let mut h = Hierarchy::new();
        h.add(0, 10 * MIB, "a");
        h.add(1, 100 * MIB, "b");
        h
    }

    #[test]
    fn debit_respects_floor() {
        let h = h2();
        let acc = SpaceAccountant::new(&h);
        // floor 8 MiB: first debit of 4 MiB ok (10 free >= 8)
        assert!(acc.try_debit(0, 4 * MIB, 8 * MIB));
        // now 6 MiB free < 8 MiB floor: rejected even though 4 fits
        assert!(!acc.try_debit(0, 4 * MIB, 8 * MIB));
        assert_eq!(acc.free(0), 6 * MIB);
        assert_eq!(acc.used(0), 4 * MIB);
    }

    #[test]
    fn credit_restores() {
        let h = h2();
        let acc = SpaceAccountant::new(&h);
        assert!(acc.try_debit(1, 50 * MIB, 0));
        acc.credit(1, 50 * MIB);
        assert_eq!(acc.free(1), 100 * MIB);
        assert_eq!(acc.used(1), 0);
    }

    #[test]
    fn totals() {
        let h = h2();
        let acc = SpaceAccountant::new(&h);
        assert_eq!(acc.total_free(), 110 * MIB);
        assert_eq!(acc.largest_free(), 100 * MIB);
    }

    #[test]
    fn ledger_lines_record_cumulative_traffic() {
        let h = h2();
        let acc = SpaceAccountant::new(&h);
        assert!(acc.try_debit(0, 3 * MIB, 0));
        assert!(acc.try_debit(0, 2 * MIB, 0));
        acc.credit(0, 4 * MIB);
        let lines = acc.lines();
        assert_eq!(lines[0].free, 9 * MIB);
        assert_eq!(lines[0].used, MIB);
        assert_eq!(lines[0].debits, 5 * MIB);
        assert_eq!(lines[0].credits, 4 * MIB);
        // device 1 untouched
        assert_eq!(lines[1], LedgerLine { free: 100 * MIB, ..LedgerLine::default() });
    }

    #[test]
    fn concurrent_debits_never_oversubscribe() {
        use std::sync::Arc;
        let mut h = Hierarchy::new();
        h.add(0, 1000, "d");
        let acc = Arc::new(SpaceAccountant::new(&h));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let a = acc.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = 0u64;
                for _ in 0..1000 {
                    if a.try_debit(0, 1, 0) {
                        got += 1;
                    }
                }
                got
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 1000, "exactly capacity granted");
        assert_eq!(acc.free(0), 0);
        assert_eq!(acc.used(0), 1000);
    }
}
