//! Thread-safe free-space accounting for hierarchy devices.
//!
//! Real-mode worker threads and the (single-threaded) simulator share
//! this type; a plain mutex keeps the arithmetic exact — contention is
//! negligible next to actual I/O.
//!
//! Since the backend-stack refactor the accountant keeps a full
//! [`LedgerLine`] per device (free, used, cumulative debits/credits)
//! rather than a bare free counter, so every credit and debit is
//! attributable to the backend it targeted (`SeaFs::ledger` surfaces
//! the lines next to each device's name and backend).
//!
//! # Logical vs physical bytes
//!
//! With transparent cold-tier compression (`crate::vfs::compress`) a
//! file has two sizes: the **logical** bytes applications wrote and
//! read back, and the **physical** bytes the device actually stores
//! after the codec ran. The ledger's space arithmetic — `free`, `used`,
//! `debits`, `credits`, the `try_debit` floor rule — is always
//! **physical**: capacity is a physical resource, and a compressed
//! replica only consumes what it stores. The [`LedgerLine::logical`]
//! column tracks the logical bytes those physical debits represent, so
//! `sea stat` can show `logical / physical` per device and the
//! placement engine can weigh how "cheap to keep" a device's residents
//! are. On devices that never see the codec (fast tiers, raw spills)
//! the two columns move in lock-step via the plain
//! [`SpaceAccountant::try_debit`] / [`SpaceAccountant::credit`], which
//! debit the same amount from both.

use std::sync::Mutex;

use crate::hierarchy::{DeviceRef, Hierarchy};

/// One device's ledger state.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LedgerLine {
    /// Bytes currently free.
    pub free: u64,
    /// Bytes currently debited (placed files, reservations).
    pub used: u64,
    /// Cumulative bytes ever debited (placement traffic).
    pub debits: u64,
    /// Cumulative bytes ever credited back (evictions, shrinks, spills).
    pub credits: u64,
    /// Logical bytes the current physical `used` represents (equal to
    /// `used` unless the device stores compressed replicas).
    pub logical: u64,
}

/// Per-device space ledger over a [`Hierarchy`]'s devices.
#[derive(Debug)]
pub struct SpaceAccountant {
    lines: Mutex<Vec<LedgerLine>>,
}

impl SpaceAccountant {
    /// All devices start with their full capacity free.
    pub fn new(h: &Hierarchy) -> SpaceAccountant {
        SpaceAccountant {
            lines: Mutex::new(
                h.iter()
                    .map(|(_, d)| LedgerLine { free: d.capacity, ..LedgerLine::default() })
                    .collect(),
            ),
        }
    }

    /// Current free bytes of `d`.
    pub fn free(&self, d: DeviceRef) -> u64 {
        self.lines.lock().expect("accountant poisoned")[d].free
    }

    /// Bytes currently debited from `d`.
    pub fn used(&self, d: DeviceRef) -> u64 {
        self.lines.lock().expect("accountant poisoned")[d].used
    }

    /// Attempt to debit `bytes` from `d` **iff** at least `floor` bytes
    /// are free (the `p·F` eligibility rule). Returns success. Logical
    /// and physical move in lock-step — uncompressed placement.
    pub fn try_debit(&self, d: DeviceRef, bytes: u64, floor: u64) -> bool {
        self.try_debit_split(d, bytes, bytes, floor)
    }

    /// [`SpaceAccountant::try_debit`] for a compressed placement:
    /// space arithmetic (free/used/debits and the floor rule) uses
    /// `physical` bytes, while the [`LedgerLine::logical`] column
    /// grows by `logical`.
    pub fn try_debit_split(
        &self,
        d: DeviceRef,
        logical: u64,
        physical: u64,
        floor: u64,
    ) -> bool {
        let mut lines = self.lines.lock().expect("accountant poisoned");
        let l = &mut lines[d];
        if l.free >= floor && l.free >= physical {
            l.free -= physical;
            l.used += physical;
            l.debits += physical;
            l.logical += logical;
            true
        } else {
            false
        }
    }

    /// Credit `bytes` back to `d` (eviction / deletion / spill),
    /// saturating at the ledger's running totals (over-credit is a
    /// caller bug, but we saturate rather than wrap). Logical and
    /// physical move in lock-step — uncompressed placement.
    pub fn credit(&self, d: DeviceRef, bytes: u64) {
        self.credit_split(d, bytes, bytes)
    }

    /// [`SpaceAccountant::credit`] for a compressed placement: frees
    /// `physical` bytes of space, retires `logical` bytes from the
    /// logical column.
    pub fn credit_split(&self, d: DeviceRef, logical: u64, physical: u64) {
        let mut lines = self.lines.lock().expect("accountant poisoned");
        let l = &mut lines[d];
        l.free = l.free.saturating_add(physical);
        l.used = l.used.saturating_sub(physical);
        l.credits += physical;
        l.logical = l.logical.saturating_sub(logical);
    }

    /// Largest free block across devices (diagnostics for NoSpace errors).
    pub fn largest_free(&self) -> u64 {
        self.lines
            .lock()
            .expect("accountant poisoned")
            .iter()
            .map(|l| l.free)
            .max()
            .unwrap_or(0)
    }

    /// Total free bytes.
    pub fn total_free(&self) -> u64 {
        self.lines
            .lock()
            .expect("accountant poisoned")
            .iter()
            .map(|l| l.free)
            .sum()
    }

    /// Snapshot of every device's ledger line, indexed by [`DeviceRef`].
    pub fn lines(&self) -> Vec<LedgerLine> {
        self.lines.lock().expect("accountant poisoned").clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    fn h2() -> Hierarchy {
        let mut h = Hierarchy::new();
        h.add(0, 10 * MIB, "a");
        h.add(1, 100 * MIB, "b");
        h
    }

    #[test]
    fn debit_respects_floor() {
        let h = h2();
        let acc = SpaceAccountant::new(&h);
        // floor 8 MiB: first debit of 4 MiB ok (10 free >= 8)
        assert!(acc.try_debit(0, 4 * MIB, 8 * MIB));
        // now 6 MiB free < 8 MiB floor: rejected even though 4 fits
        assert!(!acc.try_debit(0, 4 * MIB, 8 * MIB));
        assert_eq!(acc.free(0), 6 * MIB);
        assert_eq!(acc.used(0), 4 * MIB);
    }

    #[test]
    fn credit_restores() {
        let h = h2();
        let acc = SpaceAccountant::new(&h);
        assert!(acc.try_debit(1, 50 * MIB, 0));
        acc.credit(1, 50 * MIB);
        assert_eq!(acc.free(1), 100 * MIB);
        assert_eq!(acc.used(1), 0);
    }

    #[test]
    fn totals() {
        let h = h2();
        let acc = SpaceAccountant::new(&h);
        assert_eq!(acc.total_free(), 110 * MIB);
        assert_eq!(acc.largest_free(), 100 * MIB);
    }

    #[test]
    fn ledger_lines_record_cumulative_traffic() {
        let h = h2();
        let acc = SpaceAccountant::new(&h);
        assert!(acc.try_debit(0, 3 * MIB, 0));
        assert!(acc.try_debit(0, 2 * MIB, 0));
        acc.credit(0, 4 * MIB);
        let lines = acc.lines();
        assert_eq!(lines[0].free, 9 * MIB);
        assert_eq!(lines[0].used, MIB);
        assert_eq!(lines[0].debits, 5 * MIB);
        assert_eq!(lines[0].credits, 4 * MIB);
        // uncompressed traffic: logical tracks used exactly
        assert_eq!(lines[0].logical, lines[0].used);
        // device 1 untouched
        assert_eq!(lines[1], LedgerLine { free: 100 * MIB, ..LedgerLine::default() });
    }

    #[test]
    fn split_debits_account_logical_and_physical_separately() {
        let h = h2();
        let acc = SpaceAccountant::new(&h);
        // a 10 MiB file compressed to 4 MiB: space moves by 4,
        // logical by 10
        assert!(acc.try_debit_split(1, 10 * MIB, 4 * MIB, 0));
        let l = acc.lines()[1];
        assert_eq!(l.free, 96 * MIB);
        assert_eq!(l.used, 4 * MIB);
        assert_eq!(l.debits, 4 * MIB);
        assert_eq!(l.logical, 10 * MIB);
        // the floor rule is physical: 94 MiB floor still admits 4 MiB
        assert!(acc.try_debit_split(1, 8 * MIB, 2 * MIB, 94 * MIB));
        // retiring the replica restores both columns
        acc.credit_split(1, 10 * MIB, 4 * MIB);
        acc.credit_split(1, 8 * MIB, 2 * MIB);
        let l = acc.lines()[1];
        assert_eq!(l.free, 100 * MIB);
        assert_eq!(l.used, 0);
        assert_eq!(l.logical, 0);
        assert_eq!(l.credits, 6 * MIB);
    }

    #[test]
    fn concurrent_debits_never_oversubscribe() {
        use std::sync::Arc;
        let mut h = Hierarchy::new();
        h.add(0, 1000, "d");
        let acc = Arc::new(SpaceAccountant::new(&h));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let a = acc.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = 0u64;
                for _ in 0..1000 {
                    if a.try_debit(0, 1, 0) {
                        got += 1;
                    }
                }
                got
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 1000, "exactly capacity granted");
        assert_eq!(acc.free(0), 0);
        assert_eq!(acc.used(0), 1000);
    }
}
