//! Thread-safe free-space accounting for hierarchy devices.
//!
//! Real-mode worker threads and the (single-threaded) simulator share
//! this type; a plain mutex keeps the arithmetic exact — contention is
//! negligible next to actual I/O.

use std::sync::Mutex;

use crate::hierarchy::{DeviceRef, Hierarchy};

/// Free-space ledger over a [`Hierarchy`]'s devices.
#[derive(Debug)]
pub struct SpaceAccountant {
    free: Mutex<Vec<u64>>,
}

impl SpaceAccountant {
    /// All devices start with their full capacity free.
    pub fn new(h: &Hierarchy) -> SpaceAccountant {
        SpaceAccountant {
            free: Mutex::new(h.iter().map(|(_, d)| d.capacity).collect()),
        }
    }

    /// Current free bytes of `d`.
    pub fn free(&self, d: DeviceRef) -> u64 {
        self.free.lock().expect("accountant poisoned")[d]
    }

    /// Attempt to debit `bytes` from `d` **iff** at least `floor` bytes
    /// are free (the `p·F` eligibility rule). Returns success.
    pub fn try_debit(&self, d: DeviceRef, bytes: u64, floor: u64) -> bool {
        let mut f = self.free.lock().expect("accountant poisoned");
        if f[d] >= floor && f[d] >= bytes {
            f[d] -= bytes;
            true
        } else {
            false
        }
    }

    /// Credit `bytes` back to `d` (eviction / deletion), saturating at
    /// the ledger's running total (over-credit is a caller bug, but we
    /// saturate rather than wrap).
    pub fn credit(&self, d: DeviceRef, bytes: u64) {
        let mut f = self.free.lock().expect("accountant poisoned");
        f[d] = f[d].saturating_add(bytes);
    }

    /// Largest free block across devices (diagnostics for NoSpace errors).
    pub fn largest_free(&self) -> u64 {
        self.free
            .lock()
            .expect("accountant poisoned")
            .iter()
            .copied()
            .max()
            .unwrap_or(0)
    }

    /// Total free bytes.
    pub fn total_free(&self) -> u64 {
        self.free.lock().expect("accountant poisoned").iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    fn h2() -> Hierarchy {
        let mut h = Hierarchy::new();
        h.add(0, 10 * MIB, "a");
        h.add(1, 100 * MIB, "b");
        h
    }

    #[test]
    fn debit_respects_floor() {
        let h = h2();
        let acc = SpaceAccountant::new(&h);
        // floor 8 MiB: first debit of 4 MiB ok (10 free >= 8)
        assert!(acc.try_debit(0, 4 * MIB, 8 * MIB));
        // now 6 MiB free < 8 MiB floor: rejected even though 4 fits
        assert!(!acc.try_debit(0, 4 * MIB, 8 * MIB));
        assert_eq!(acc.free(0), 6 * MIB);
    }

    #[test]
    fn credit_restores() {
        let h = h2();
        let acc = SpaceAccountant::new(&h);
        assert!(acc.try_debit(1, 50 * MIB, 0));
        acc.credit(1, 50 * MIB);
        assert_eq!(acc.free(1), 100 * MIB);
    }

    #[test]
    fn totals() {
        let h = h2();
        let acc = SpaceAccountant::new(&h);
        assert_eq!(acc.total_free(), 110 * MIB);
        assert_eq!(acc.largest_free(), 100 * MIB);
    }

    #[test]
    fn concurrent_debits_never_oversubscribe() {
        use std::sync::Arc;
        let mut h = Hierarchy::new();
        h.add(0, 1000, "d");
        let acc = Arc::new(SpaceAccountant::new(&h));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let a = acc.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = 0u64;
                for _ in 0..1000 {
                    if a.try_debit(0, 1, 0) {
                        got += 1;
                    }
                }
                got
            }));
        }
        let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 1000, "exactly capacity granted");
        assert_eq!(acc.free(0), 0);
    }
}
