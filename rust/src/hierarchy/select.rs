//! The paper's device-selection rule (§3.1.2).

use crate::hierarchy::{DeviceRef, Hierarchy, SpaceAccountant};
use crate::util::Rng;

/// Selection parameters.
#[derive(Debug, Clone, Copy)]
pub struct SelectCfg {
    /// Max file size produced by the workflow (`F`, user-declared).
    pub max_file_size: u64,
    /// Parallel application processes on this node (`p`, user-declared).
    pub parallel_procs: u64,
}

impl SelectCfg {
    /// The eligibility floor `p · F`.
    pub fn floor(&self) -> u64 {
        self.max_file_size.saturating_mul(self.parallel_procs)
    }
}

/// Pick the fastest eligible device for a `size`-byte file and debit it.
///
/// Tiers are walked fastest-first; peers within a tier are visited in
/// randomly shuffled order (load spreading across same-speed disks).
/// Returns `None` when no device qualifies — the caller falls back to the
/// PFS (which Sea always treats as the unbounded last resort).
pub fn select_device(
    h: &Hierarchy,
    acc: &SpaceAccountant,
    cfg: &SelectCfg,
    size: u64,
    rng: &mut Rng,
) -> Option<DeviceRef> {
    let floor = cfg.floor().max(size);
    for tier in h.tiers() {
        let mut peers = h.tier_devices(tier);
        rng.shuffle(&mut peers);
        for d in peers {
            if acc.try_debit(d, size, floor) {
                return Some(d);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    fn setup() -> (Hierarchy, SpaceAccountant) {
        let mut h = Hierarchy::new();
        h.add(0, 10 * MIB, "tmpfs");
        h.add(1, 100 * MIB, "ssd0");
        h.add(1, 100 * MIB, "ssd1");
        let acc = SpaceAccountant::new(&h);
        (h, acc)
    }

    fn cfg(f: u64, p: u64) -> SelectCfg {
        SelectCfg { max_file_size: f, parallel_procs: p }
    }

    #[test]
    fn prefers_fastest_tier() {
        let (h, acc) = setup();
        let mut rng = Rng::new(1);
        let d = select_device(&h, &acc, &cfg(MIB, 2), MIB, &mut rng).unwrap();
        assert_eq!(h.info(d).name, "tmpfs");
    }

    #[test]
    fn falls_to_next_tier_when_floor_unmet() {
        let (h, acc) = setup();
        let mut rng = Rng::new(1);
        // floor 4*5 = 20 MiB > tmpfs capacity: tmpfs never eligible
        let d = select_device(&h, &acc, &cfg(4 * MIB, 5), MIB, &mut rng).unwrap();
        assert!(h.info(d).name.starts_with("ssd"));
    }

    #[test]
    fn shuffling_spreads_across_peers() {
        let (h, acc) = setup();
        let mut rng = Rng::new(7);
        let c = cfg(20 * MIB, 1); // skip tmpfs (floor 20 MiB)
        let mut seen = std::collections::HashSet::new();
        for _ in 0..20 {
            let d = select_device(&h, &acc, &c, MIB, &mut rng).unwrap();
            seen.insert(h.info(d).name.clone());
            acc.credit(d, MIB); // keep space constant
        }
        assert_eq!(seen.len(), 2, "both ssds should be picked over 20 draws");
    }

    #[test]
    fn exhaustion_returns_none() {
        let (h, acc) = setup();
        let mut rng = Rng::new(3);
        let c = cfg(MIB, 1);
        let mut picks = 0;
        while select_device(&h, &acc, &c, 10 * MIB, &mut rng).is_some() {
            picks += 1;
            assert!(picks < 1000, "must exhaust");
        }
        // 10 MiB files: 1 fits tmpfs, 10 per ssd => 21 total
        assert_eq!(picks, 21);
        assert!(select_device(&h, &acc, &c, 10 * MIB, &mut rng).is_none());
    }

    #[test]
    fn floor_is_at_least_file_size() {
        let (h, acc) = setup();
        let mut rng = Rng::new(3);
        // tiny declared F but huge file: floor must still cover the file
        let d = select_device(&h, &acc, &cfg(1, 1), 50 * MIB, &mut rng).unwrap();
        assert!(h.info(d).name.starts_with("ssd"));
    }
}
