//! Crate-wide error type.

use std::path::PathBuf;

/// Unified error for all sea subsystems.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Underlying I/O failure from the real file system.
    #[error("io error on {path:?}: {source}")]
    Io {
        path: PathBuf,
        #[source]
        source: std::io::Error,
    },

    /// A path was expected to live under the Sea mountpoint.
    #[error("path {0:?} is outside the sea mountpoint")]
    OutsideMount(PathBuf),

    /// File not found in any tier / backend.
    #[error("no such file: {0:?}")]
    NotFound(PathBuf),

    /// No storage device has room for the requested reservation.
    #[error("no space: need {needed} B for {path:?} (largest free {largest_free} B)")]
    NoSpace {
        path: PathBuf,
        needed: u64,
        largest_free: u64,
    },

    /// Configuration file / value errors.
    #[error("config error: {0}")]
    Config(String),

    /// Simulator protocol violations (these are bugs, not user errors).
    #[error("simulator invariant violated: {0}")]
    Sim(String),

    /// PJRT / XLA runtime failures.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Workload-level integrity failure (checksum mismatch etc.).
    #[error("integrity error: {0}")]
    Integrity(String),

    /// Invalid argument to a public API.
    #[error("invalid argument: {0}")]
    InvalidArg(String),
}

impl Error {
    /// Convenience constructor tagging an `io::Error` with its path.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
