//! Crate-wide error type.
//!
//! `Display` / `Error` are hand-implemented (offline substitute for the
//! `thiserror` derive, in the same spirit as `util`'s rand/serde
//! substitutes).

use std::fmt;
use std::path::PathBuf;

/// Unified error for all sea subsystems.
#[derive(Debug)]
pub enum Error {
    /// Underlying I/O failure from the real file system.
    Io {
        /// Path the operation touched.
        path: PathBuf,
        /// The originating I/O error.
        source: std::io::Error,
    },

    /// A path was expected to live under the Sea mountpoint.
    OutsideMount(PathBuf),

    /// File not found in any tier / backend.
    NotFound(PathBuf),

    /// No storage device has room for the requested reservation.
    NoSpace {
        /// File being placed.
        path: PathBuf,
        /// Bytes requested.
        needed: u64,
        /// Largest free block across devices.
        largest_free: u64,
    },

    /// Configuration file / value errors.
    Config(String),

    /// Simulator protocol violations (these are bugs, not user errors).
    Sim(String),

    /// PJRT / XLA runtime failures.
    Runtime(String),

    /// Workload-level integrity failure (checksum mismatch etc.).
    Integrity(String),

    /// Invalid argument to a public API.
    InvalidArg(String),

    /// The `sea serve` daemon died or became unreachable mid-operation
    /// (connection refused after retries, or EOF on a non-retryable
    /// request). Distinct from [`Error::Daemon`] so callers can tell
    /// "the daemon is gone" from "the daemon said no".
    DaemonGone(String),

    /// Daemon/protocol-level failure on a live connection (malformed
    /// frame, version mismatch, stale handle, server-side fault that
    /// does not map onto a more specific variant).
    Daemon(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io { path, source } => write!(f, "io error on {path:?}: {source}"),
            Error::OutsideMount(p) => {
                write!(f, "path {p:?} is outside the sea mountpoint")
            }
            Error::NotFound(p) => write!(f, "no such file: {p:?}"),
            Error::NoSpace { path, needed, largest_free } => write!(
                f,
                "no space: need {needed} B for {path:?} (largest free {largest_free} B)"
            ),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Sim(m) => write!(f, "simulator invariant violated: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Integrity(m) => write!(f, "integrity error: {m}"),
            Error::InvalidArg(m) => write!(f, "invalid argument: {m}"),
            Error::DaemonGone(m) => write!(f, "sea daemon unreachable: {m}"),
            Error::Daemon(m) => write!(f, "sea daemon error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Convenience constructor tagging an `io::Error` with its path.
    pub fn io(path: impl Into<PathBuf>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
