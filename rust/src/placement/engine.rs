//! The placement decision surface: [`PlacementEngine`].
//!
//! Historically the repo carried two disjoint implementations of the
//! paper's placement policy: the simulator's `SimPlacer` flavour and the
//! ad-hoc `select_device` + `RuleSet::mode_for` calls hardwired into the
//! real-bytes `SeaFs`. This module turns the decision surface into one
//! first-class trait with typed lifecycle hooks:
//!
//! * [`PlacementEngine::place`] — device pick for a new file
//!   ([`PlaceCtx`] → [`Placement`]), debiting the ledger on success;
//! * [`PlacementEngine::on_access`] / [`PlacementEngine::on_close`] —
//!   access-history bookkeeping and Table 1 management at last close;
//! * [`PlacementEngine::on_pressure`] — what to do when a streaming
//!   writer exhausts its device ([`PressureCtx`] → spill the writer
//!   itself, or spill colder *victim* residents instead);
//! * [`PlacementEngine::on_freed`] — react to reclaimed space (e.g.
//!   promote hot spilled files back onto fast tiers).
//!
//! Hooks return typed [`Decision`]s instead of bare `Option<DeviceRef>`
//! / `MgmtMode`, so both the simulator adapters
//! ([`crate::placement::policy`]) and the VFS ([`crate::vfs::SeaFs`])
//! execute the *same* policy code path.
//!
//! Shipped engines:
//!
//! * [`PaperEngine`] — bit-for-bit reproduction of the paper's §3.1.2
//!   `p·F` selection and Table 1 modes (spill-self under pressure, no
//!   promotion);
//! * [`TemperatureEngine`] — tracks per-file recency/size heat, spills
//!   the **coldest resident file** instead of the active writer, and
//!   promotes hot spilled files back when space frees (the HSM
//!   follow-up direction, arXiv:2404.11556);
//! * [`PfsOnlyEngine`] — the plain-PFS (Lustre) baseline.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::hierarchy::{select_device, DeviceRef, Hierarchy, SelectCfg, SpaceAccountant};
use crate::placement::rules::{MgmtMode, RuleSet};
use crate::util::Rng;

/// Which shipped engine a mount should build (`[sea] engine = "..."`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// [`PaperEngine`]: the paper's policy, verbatim.
    #[default]
    Paper,
    /// [`TemperatureEngine`]: heat-driven victims and promotion.
    Temperature,
}

impl EngineKind {
    /// Parse a config/CLI token.
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "paper" => Some(EngineKind::Paper),
            "temperature" | "temp" => Some(EngineKind::Temperature),
            _ => None,
        }
    }

    /// Canonical token.
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Paper => "paper",
            EngineKind::Temperature => "temperature",
        }
    }
}

/// Tuning of the [`TemperatureEngine`]'s heat model (`[sea]`
/// `heat_decay` / `heat_freq_weight` / `promote_headroom_bytes`, and
/// the matching `sea run` flags — the PR 4 ROADMAP item).
///
/// A file's heat is an exponentially-decayed touch count: touching at
/// logical tick `T` sets `score = score · decay^(T - last_tick) +
/// freq_weight`, and comparisons decay both sides to the present tick.
/// With equal touch counts this reduces to pure recency (the historic
/// behaviour); `freq_weight` raises how much a *history* of touches
/// outweighs one recent touch, and `heat_decay → 0` forgets history
/// faster.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TempTuning {
    /// Per-tick decay of accumulated heat, in `[0, 1]`.
    pub heat_decay: f64,
    /// Heat added per touch (frequency weighting).
    pub freq_weight: f64,
    /// Extra free bytes a tier must have beyond the candidate's size
    /// before a promotion is emitted — headroom against promote/spill
    /// thrash on a nearly-full device.
    pub promote_headroom: u64,
}

impl Default for TempTuning {
    fn default() -> TempTuning {
        TempTuning { heat_decay: 0.5, freq_weight: 1.0, promote_headroom: 0 }
    }
}

/// What the engine sees of the device hierarchy when deciding.
pub struct EngineCtx<'a> {
    /// Device tiers.
    pub hierarchy: &'a Hierarchy,
    /// Per-device ledger (placement debits go through here).
    pub accountant: &'a SpaceAccountant,
}

/// Where a new file should live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// On a fast device (already debited for `PlaceCtx::size` bytes).
    Device(DeviceRef),
    /// Fall through to the PFS (unbounded last resort).
    Pfs,
}

/// Context for one placement decision.
pub struct PlaceCtx<'a> {
    /// Mount-relative path.
    pub rel: &'a str,
    /// Bytes known up front; 0 for streaming opens (space is then
    /// debited incrementally as the handle grows the file).
    pub size: u64,
    /// Mount-time prefetch pass: the bytes already live on the PFS, the
    /// placement is a pure cache fill.
    pub prefetch: bool,
}

/// How a file was touched (heat bookkeeping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    /// Opened or read for reading.
    Read,
    /// Opened for writing (join of an existing entry).
    Write,
}

/// Context at (last) close of a file.
pub struct CloseCtx<'a> {
    /// Mount-relative path.
    pub rel: &'a str,
    /// Device holding the local copy; `None` when the file spilled to
    /// (or always lived on) the PFS.
    pub dev: Option<DeviceRef>,
    /// Final size in bytes (0 when unknown).
    pub size: u64,
}

/// One closed, device-resident file: a spill-victim candidate.
#[derive(Debug, Clone)]
pub struct Resident {
    /// Mount-relative path.
    pub rel: String,
    /// Device holding it.
    pub dev: DeviceRef,
    /// Bytes it occupies (= ledger debit).
    pub size: u64,
    /// Physical bytes its *cold* (PFS) replica occupies, when one is
    /// already known to exist — `size` otherwise. A compressed replica
    /// makes a resident "cheap to keep cold": spilling it frees `size`
    /// device bytes while consuming only `physical` PFS bytes, so
    /// victim election may prefer it on a heat tie.
    pub physical: u64,
}

/// Context when a streaming writer exhausts its device.
pub struct PressureCtx<'a> {
    /// The writer that ran out of space.
    pub rel: &'a str,
    /// Its device.
    pub dev: DeviceRef,
    /// Additional bytes its pending write needs.
    pub need: u64,
    /// Closed resident files (no open writers) across fast devices.
    pub residents: &'a [Resident],
}

/// A typed policy decision.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Decision {
    /// Replicate `rel` to the PFS, keep the local copy (Table 1 Copy;
    /// Copy + Evict together are Move).
    Flush {
        /// Mount-relative path.
        rel: String,
    },
    /// Drop `rel`'s local copy (Table 1 Remove; after a Flush, Move).
    Evict {
        /// Mount-relative path.
        rel: String,
    },
    /// Under pressure: migrate the asking writer itself to the PFS.
    SpillSelf,
    /// Under pressure: persist-and-drop a colder resident file instead,
    /// so the active writer stays on its device.
    SpillVictim {
        /// Mount-relative path of the victim.
        rel: String,
    },
    /// Pull a PFS-resident file back onto a fast tier.
    Promote {
        /// Mount-relative path.
        rel: String,
        /// Target tier rank (0 = fastest).
        tier: u8,
    },
}

/// One placement brain shared by the simulator and the real-bytes VFS.
///
/// Implementations must be internally synchronised (`SeaFs` calls hooks
/// from writer threads and flush-pool workers concurrently).
pub trait PlacementEngine: Send + Sync {
    /// Pick where a new file goes. A `Device` pick has already debited
    /// `p.size` bytes from the ledger.
    fn place(&self, ctx: EngineCtx<'_>, p: PlaceCtx<'_>) -> Placement;

    /// A file was read or re-opened for writing (heat bookkeeping).
    fn on_access(&self, rel: &str, access: Access) {
        let _ = (rel, access);
    }

    /// `rel` was unlinked: forget any heat / promotion state. Without
    /// this, dead paths hold heat-map slots forever and can win stale
    /// promotion decisions.
    fn on_removed(&self, rel: &str) {
        let _ = rel;
    }

    /// `from` was renamed to `to`: carry heat / promotion state across
    /// so the file keeps its temperature under its new name.
    fn on_renamed(&self, from: &str, to: &str) {
        let _ = (from, to);
    }

    /// The last writer handle closed: return the management decisions
    /// (Table 1) for the file.
    fn on_close(&self, c: CloseCtx<'_>) -> Vec<Decision>;

    /// A writer exhausted its device: decide who spills.
    fn on_pressure(&self, ctx: EngineCtx<'_>, p: PressureCtx<'_>) -> Vec<Decision>;

    /// `size` bytes were credited back to `dev` (evict / unlink / spill
    /// / shrink): optionally react, e.g. with `Promote` decisions.
    fn on_freed(&self, ctx: EngineCtx<'_>, dev: DeviceRef, size: u64) -> Vec<Decision>;

    /// Does `on_pressure` consult [`PressureCtx::residents`]? When
    /// `false` the executor skips the full-registry snapshot on the
    /// write hot path.
    fn wants_residents(&self) -> bool {
        false
    }

    /// Called by the executor right before a queued `Promote` decision
    /// runs; returning `false` vetoes it. Engines that emit promotions
    /// should consume the candidate *here* rather than at emission
    /// time, so an intervening write-open or re-placement cancels a
    /// queued promote instead of installing a stale device copy over a
    /// live PFS file.
    fn approve_promote(&self, rel: &str) -> bool {
        let _ = rel;
        true
    }

    /// Should `rel` be pulled off the PFS at mount time?
    fn wants_prefetch(&self, rel: &str) -> bool {
        let _ = rel;
        false
    }

    /// Display name (diagnostics / benches).
    fn name(&self) -> &'static str;
}

/// Build a shipped engine by kind. `temp` only affects the
/// temperature engine; the paper engine has no tunables beyond `p·F`.
pub fn build_engine(
    kind: EngineKind,
    select: SelectCfg,
    rules: RuleSet,
    seed: u64,
    temp: TempTuning,
) -> Arc<dyn PlacementEngine> {
    match kind {
        EngineKind::Paper => Arc::new(PaperEngine::new(select, rules, seed)),
        EngineKind::Temperature => {
            Arc::new(TemperatureEngine::with_tuning(select, rules, seed, temp))
        }
    }
}

/// Which of a decision list's `Flush`/`Evict` decisions target `rel`
/// itself: the `(flush, evict)` pair both executors (the VFS flush
/// pool and the simulator adapter) dispatch on.
pub fn flush_evict_flags(rel: &str, decisions: &[Decision]) -> (bool, bool) {
    let mut flush = false;
    let mut evict = false;
    for d in decisions {
        match d {
            Decision::Flush { rel: r } if r == rel => flush = true,
            Decision::Evict { rel: r } if r == rel => evict = true,
            _ => {}
        }
    }
    (flush, evict)
}

/// Table 1, expressed as typed decisions.
fn table1_decisions(rules: &RuleSet, rel: &str) -> Vec<Decision> {
    match rules.mode_for(rel) {
        MgmtMode::Copy => vec![Decision::Flush { rel: rel.to_string() }],
        MgmtMode::Remove => vec![Decision::Evict { rel: rel.to_string() }],
        MgmtMode::Move => vec![
            Decision::Flush { rel: rel.to_string() },
            Decision::Evict { rel: rel.to_string() },
        ],
        MgmtMode::Keep => Vec::new(),
    }
}

/// The paper's policy, verbatim: `p·F` fastest-eligible selection,
/// Table 1 management at close, spill-self under pressure, no reaction
/// to freed space.
pub struct PaperEngine {
    select: SelectCfg,
    rules: RuleSet,
    rng: Mutex<Rng>,
}

impl PaperEngine {
    /// Engine over the declared `p·F` config and rule lists.
    pub fn new(select: SelectCfg, rules: RuleSet, seed: u64) -> PaperEngine {
        PaperEngine { select, rules, rng: Mutex::new(Rng::new(seed)) }
    }
}

impl PlacementEngine for PaperEngine {
    fn place(&self, ctx: EngineCtx<'_>, p: PlaceCtx<'_>) -> Placement {
        let mut rng = self.rng.lock().expect("engine rng poisoned");
        match select_device(ctx.hierarchy, ctx.accountant, &self.select, p.size, &mut rng) {
            Some(d) => Placement::Device(d),
            None => Placement::Pfs,
        }
    }

    fn on_close(&self, c: CloseCtx<'_>) -> Vec<Decision> {
        table1_decisions(&self.rules, c.rel)
    }

    fn on_pressure(&self, _ctx: EngineCtx<'_>, _p: PressureCtx<'_>) -> Vec<Decision> {
        vec![Decision::SpillSelf]
    }

    fn on_freed(&self, _ctx: EngineCtx<'_>, _dev: DeviceRef, _size: u64) -> Vec<Decision> {
        Vec::new()
    }

    fn wants_prefetch(&self, rel: &str) -> bool {
        self.rules.prefetch.matches(rel)
    }

    fn name(&self) -> &'static str {
        "paper"
    }
}

/// The plain-PFS baseline: everything goes to long-term storage, no
/// management ever runs.
#[derive(Debug, Default)]
pub struct PfsOnlyEngine;

impl PlacementEngine for PfsOnlyEngine {
    fn place(&self, _ctx: EngineCtx<'_>, _p: PlaceCtx<'_>) -> Placement {
        Placement::Pfs
    }

    fn on_close(&self, _c: CloseCtx<'_>) -> Vec<Decision> {
        Vec::new()
    }

    fn on_pressure(&self, _ctx: EngineCtx<'_>, _p: PressureCtx<'_>) -> Vec<Decision> {
        vec![Decision::SpillSelf]
    }

    fn on_freed(&self, _ctx: EngineCtx<'_>, _dev: DeviceRef, _size: u64) -> Vec<Decision> {
        Vec::new()
    }

    fn name(&self) -> &'static str {
        "pfs-only"
    }
}

/// A spilled / PFS-resident file remembered for possible promotion.
#[derive(Debug, Clone, Copy)]
struct Spilled {
    /// Last known size (0 = unknown, writer still open).
    size: u64,
    /// Logical tick at which it was spilled. A file only becomes a
    /// promotion candidate once it is accessed *after* this tick —
    /// otherwise the `on_freed` fired by the spill's own ledger credit
    /// would immediately promote the victim back, stealing the space
    /// the spill just freed.
    tick: u64,
}

/// Heat shard count: like the VFS registry's sharded entry map,
/// per-shard mutexes keep concurrent read/open heat updates on
/// different files from serialising on one lock (the read-path
/// bottleneck the single `Mutex<TempState>` used to be).
const HEAT_SHARDS: usize = 16;

/// Heat-map size bound **per shard** (global bound: `HEAT_SHARDS ×`
/// this): when exceeded, the coldest half of the shard is pruned so a
/// churning workload (millions of lifetime-unique paths) cannot grow
/// the map without bound.
const MAX_HEAT_ENTRIES: usize = 65_536 / HEAT_SHARDS;

/// One file's heat: an exponentially-decayed touch count plus the tick
/// of the latest touch (see [`TempTuning`]).
#[derive(Debug, Clone, Copy)]
struct Heat {
    /// Accumulated, decayed touch weight as of `tick`.
    score: f64,
    /// Logical tick of the most recent touch.
    tick: u64,
}

impl Heat {
    /// The score decayed forward to tick `now`.
    fn decayed(&self, now: u64, decay: f64) -> f64 {
        self.score * decay.powf(now.saturating_sub(self.tick) as f64)
    }
}

/// One shard of the temperature state: the heat and spill candidates
/// of every rel that hashes here. A rel's heat and its `spilled` entry
/// always share a shard, so candidate scans need one lock at a time.
#[derive(Default)]
struct HeatShard {
    /// rel → heat (absent = never touched = coldest).
    heat: HashMap<String, Heat>,
    /// Spilled / PFS-resident files eligible for promotion.
    spilled: HashMap<String, Spilled>,
}

impl HeatShard {
    fn touch(&mut self, rel: &str, tick: u64, tuning: &TempTuning) {
        let h = self
            .heat
            .entry(rel.to_string())
            .or_insert(Heat { score: 0.0, tick });
        h.score = h.decayed(tick, tuning.heat_decay) + tuning.freq_weight;
        h.tick = tick;
        if self.heat.len() > MAX_HEAT_ENTRIES {
            // amortized O(1) per touch: each prune halves the shard.
            // Spilled promotion candidates keep their heat so their
            // ordering stays meaningful; pruned files simply read as
            // cold (score 0, tick 0) again.
            let mut ticks: Vec<u64> = self.heat.values().map(|h| h.tick).collect();
            ticks.sort_unstable();
            let cutoff = ticks[ticks.len() / 2];
            let spilled = &self.spilled;
            self.heat
                .retain(|rel, h| h.tick > cutoff || spilled.contains_key(rel));
        }
    }

    fn heat_tick(&self, rel: &str) -> u64 {
        self.heat.get(rel).map(|h| h.tick).unwrap_or(0)
    }

    fn heat_score(&self, rel: &str, now: u64, decay: f64) -> f64 {
        self.heat.get(rel).map(|h| h.decayed(now, decay)).unwrap_or(0.0)
    }
}

/// Max `Promote` decisions emitted per `on_freed` call (keeps one large
/// free from flooding the flush pool with promote jobs).
const MAX_PROMOTES_PER_FREE: usize = 8;

/// Heat-driven placement: the paper's selection rule for placement, but
/// under pressure the **coldest resident file** is persisted and
/// dropped (the active writer keeps streaming to its fast device), and
/// when space frees the hottest spilled files are promoted back. Heat
/// lives in [`HEAT_SHARDS`] independently-locked shards, so the
/// read/open hot path never serialises on one mutex.
pub struct TemperatureEngine {
    select: SelectCfg,
    rules: RuleSet,
    tuning: TempTuning,
    rng: Mutex<Rng>,
    clock: AtomicU64,
    shards: Vec<Mutex<HeatShard>>,
}

impl TemperatureEngine {
    /// Engine over the declared `p·F` config and rule lists, with the
    /// default heat tuning.
    pub fn new(select: SelectCfg, rules: RuleSet, seed: u64) -> TemperatureEngine {
        TemperatureEngine::with_tuning(select, rules, seed, TempTuning::default())
    }

    /// Engine with explicit [`TempTuning`] (decay / frequency
    /// weighting / promotion headroom).
    pub fn with_tuning(
        select: SelectCfg,
        rules: RuleSet,
        seed: u64,
        tuning: TempTuning,
    ) -> TemperatureEngine {
        TemperatureEngine {
            select,
            rules,
            tuning: TempTuning {
                heat_decay: tuning.heat_decay.clamp(0.0, 1.0),
                freq_weight: tuning.freq_weight.max(0.0),
                promote_headroom: tuning.promote_headroom,
            },
            rng: Mutex::new(Rng::new(seed)),
            clock: AtomicU64::new(0),
            shards: (0..HEAT_SHARDS).map(|_| Mutex::new(HeatShard::default())).collect(),
        }
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn shard(&self, rel: &str) -> &Mutex<HeatShard> {
        let mut h = DefaultHasher::new();
        rel.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    fn touch(&self, rel: &str, tick: u64) {
        self.shard(rel)
            .lock()
            .expect("temp state poisoned")
            .touch(rel, tick, &self.tuning);
    }

    /// Logical tick of `rel`'s most recent touch (0 = never touched).
    /// Diagnostics / tests; victim ordering uses the decayed score.
    pub fn heat_tick(&self, rel: &str) -> u64 {
        self.shard(rel).lock().expect("temp state poisoned").heat_tick(rel)
    }

    fn heat_score(&self, rel: &str, now: u64) -> f64 {
        self.shard(rel)
            .lock()
            .expect("temp state poisoned")
            .heat_score(rel, now, self.tuning.heat_decay)
    }

    fn spill_insert(&self, rel: &str, s: Spilled) {
        self.shard(rel)
            .lock()
            .expect("temp state poisoned")
            .spilled
            .insert(rel.to_string(), s);
    }

    /// Fastest tier with a device that can hold `size` bytes — plus the
    /// configured promotion headroom — right now.
    fn tier_with_room(&self, ctx: &EngineCtx<'_>, size: u64) -> Option<u8> {
        let need = size.saturating_add(self.tuning.promote_headroom);
        for tier in ctx.hierarchy.tiers() {
            for d in ctx.hierarchy.tier_devices(tier) {
                if ctx.accountant.free(d) >= need {
                    return Some(tier);
                }
            }
        }
        None
    }
}

impl PlacementEngine for TemperatureEngine {
    fn place(&self, ctx: EngineCtx<'_>, p: PlaceCtx<'_>) -> Placement {
        let tick = self.tick();
        {
            let mut st = self.shard(p.rel).lock().expect("temp state poisoned");
            st.touch(p.rel, tick, &self.tuning);
            // a (re)placement supersedes any pending promotion
            st.spilled.remove(p.rel);
        }
        let mut rng = self.rng.lock().expect("engine rng poisoned");
        match select_device(ctx.hierarchy, ctx.accountant, &self.select, p.size, &mut rng) {
            Some(d) => Placement::Device(d),
            None => Placement::Pfs,
        }
    }

    fn on_access(&self, rel: &str, access: Access) {
        let tick = self.tick();
        let mut st = self.shard(rel).lock().expect("temp state poisoned");
        st.touch(rel, tick, &self.tuning);
        if access == Access::Write {
            // a write-open (possibly through a raw PFS handle the VFS
            // does not track) supersedes any pending promotion:
            // promoting now would install a stale shadow copy
            st.spilled.remove(rel);
        }
    }

    fn on_close(&self, c: CloseCtx<'_>) -> Vec<Decision> {
        let tick = self.tick();
        {
            let mut st = self.shard(c.rel).lock().expect("temp state poisoned");
            st.touch(c.rel, tick, &self.tuning);
            if c.dev.is_none() {
                // spilled mid-stream: now a promotion candidate with a
                // known final size (but only once re-accessed)
                st.spilled
                    .insert(c.rel.to_string(), Spilled { size: c.size, tick });
            }
        }
        table1_decisions(&self.rules, c.rel)
    }

    fn on_removed(&self, rel: &str) {
        let mut st = self.shard(rel).lock().expect("temp state poisoned");
        st.heat.remove(rel);
        st.spilled.remove(rel);
    }

    fn on_renamed(&self, from: &str, to: &str) {
        // take `from`'s state out first, then install under `to` —
        // never two shard locks at once
        let (heat, spilled) = {
            let mut st = self.shard(from).lock().expect("temp state poisoned");
            (st.heat.remove(from), st.spilled.remove(from))
        };
        let mut st = self.shard(to).lock().expect("temp state poisoned");
        // the destination's own state died with the replaced file
        st.heat.remove(to);
        st.spilled.remove(to);
        if let Some(h) = heat {
            st.heat.insert(to.to_string(), h);
        }
        if let Some(s) = spilled {
            st.spilled.insert(to.to_string(), s);
        }
    }

    fn on_pressure(&self, ctx: EngineCtx<'_>, p: PressureCtx<'_>) -> Vec<Decision> {
        let tick = self.tick();
        // the active writer is hot by definition
        self.touch(p.rel, tick);
        let mut cands: Vec<(f64, &Resident)> = p
            .residents
            .iter()
            .filter(|r| r.dev == p.dev && r.rel != p.rel)
            .map(|r| {
                // weigh heat by how expensive the resident is to keep
                // cold: a compressed PFS replica (physical < size)
                // scales its effective heat down, so between two files
                // of similar warmth the cheap-to-keep one is spilled
                // first — it costs the cold tier less and frees the
                // same device bytes.
                let keep_cost = if r.size > 0 {
                    (r.physical as f64 / r.size as f64).clamp(0.05, 1.0)
                } else {
                    1.0
                };
                (self.heat_score(&r.rel, tick) * keep_cost, r)
            })
            .collect();
        // coldest (cost-weighted) first; ties broken towards the
        // larger file (more space reclaimed per migration)
        cands.sort_by(|a, b| a.0.total_cmp(&b.0).then_with(|| b.1.size.cmp(&a.1.size)));
        let free = ctx.accountant.free(p.dev);
        let mut freed = 0u64;
        let mut out = Vec::new();
        for (_, r) in &cands {
            if free + freed >= p.need {
                break;
            }
            out.push(Decision::SpillVictim { rel: r.rel.clone() });
            freed += r.size;
        }
        if free + freed < p.need {
            // victims alone cannot satisfy the write: spill the writer
            // itself (its size is recorded at close)
            self.spill_insert(p.rel, Spilled { size: 0, tick });
            return vec![Decision::SpillSelf];
        }
        for d in &out {
            if let Decision::SpillVictim { rel } = d {
                let size = p
                    .residents
                    .iter()
                    .find(|r| &r.rel == rel)
                    .map_or(0, |r| r.size);
                self.spill_insert(rel, Spilled { size, tick });
            }
        }
        out
    }

    fn on_freed(&self, ctx: EngineCtx<'_>, _dev: DeviceRef, _size: u64) -> Vec<Decision> {
        // candidates: spilled files with a known size that have been
        // accessed since their spill (hot again), hottest first by
        // decayed score. A rel's heat and spill entry share a shard, so
        // this scan takes one shard lock at a time.
        let now = self.clock.load(Ordering::Relaxed);
        let mut cands: Vec<(String, u64, f64)> = Vec::new();
        for shard in &self.shards {
            let st = shard.lock().expect("temp state poisoned");
            if st.spilled.is_empty() {
                continue;
            }
            for (rel, s) in st.spilled.iter() {
                if s.size > 0 && st.heat_tick(rel) > s.tick {
                    cands.push((rel.clone(), s.size, st.heat_score(rel, now, self.tuning.heat_decay)));
                }
            }
        }
        cands.sort_by(|a, b| b.2.total_cmp(&a.2));
        let mut out = Vec::new();
        for (rel, size, _) in cands {
            if out.len() >= MAX_PROMOTES_PER_FREE {
                break;
            }
            if let Some(tier) = self.tier_with_room(&ctx, size) {
                // the candidate stays in `spilled` until the executor
                // calls `approve_promote` — an intervening write-open
                // or re-placement cancels the queued decision
                out.push(Decision::Promote { rel, tier });
            }
        }
        out
    }

    fn wants_residents(&self) -> bool {
        true
    }

    fn approve_promote(&self, rel: &str) -> bool {
        // one-shot: consuming the candidate here means a second queued
        // promote for the same file, or one queued before the file was
        // written again, is vetoed
        self.shard(rel)
            .lock()
            .expect("temp state poisoned")
            .spilled
            .remove(rel)
            .is_some()
    }

    fn wants_prefetch(&self, rel: &str) -> bool {
        self.rules.prefetch.matches(rel)
    }

    fn name(&self) -> &'static str {
        "temperature"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    fn hierarchy() -> (Hierarchy, SpaceAccountant) {
        let mut h = Hierarchy::new();
        h.add(0, 4 * MIB, "tmpfs");
        h.add(1, 100 * MIB, "disk");
        let acc = SpaceAccountant::new(&h);
        (h, acc)
    }

    fn select() -> SelectCfg {
        SelectCfg { max_file_size: MIB, parallel_procs: 1 }
    }

    #[test]
    fn engine_kind_parses_and_round_trips() {
        assert_eq!(EngineKind::parse("paper"), Some(EngineKind::Paper));
        assert_eq!(EngineKind::parse("temperature"), Some(EngineKind::Temperature));
        assert_eq!(EngineKind::parse("temp"), Some(EngineKind::Temperature));
        assert_eq!(EngineKind::parse("nope"), None);
        for k in [EngineKind::Paper, EngineKind::Temperature] {
            assert_eq!(EngineKind::parse(k.name()), Some(k));
        }
        assert_eq!(EngineKind::default(), EngineKind::Paper);
    }

    #[test]
    fn paper_engine_places_like_select_device_and_spills_self() {
        let (h, acc) = hierarchy();
        let eng = PaperEngine::new(select(), RuleSet::from_texts("**", "**", ""), 9);
        let ctx = EngineCtx { hierarchy: &h, accountant: &acc };
        match eng.place(ctx, PlaceCtx { rel: "a", size: MIB, prefetch: false }) {
            Placement::Device(d) => assert_eq!(h.info(d).name, "tmpfs"),
            Placement::Pfs => panic!("tmpfs has room"),
        }
        let ds = eng.on_close(CloseCtx { rel: "a", dev: Some(0), size: MIB });
        assert_eq!(
            ds,
            vec![
                Decision::Flush { rel: "a".into() },
                Decision::Evict { rel: "a".into() }
            ]
        );
        let ctx = EngineCtx { hierarchy: &h, accountant: &acc };
        let ds = eng.on_pressure(
            ctx,
            PressureCtx { rel: "a", dev: 0, need: MIB, residents: &[] },
        );
        assert_eq!(ds, vec![Decision::SpillSelf]);
        let ctx = EngineCtx { hierarchy: &h, accountant: &acc };
        assert!(eng.on_freed(ctx, 0, MIB).is_empty());
        assert!(!eng.wants_residents(), "paper never inspects residents");
        assert!(eng.approve_promote("anything"), "default approval is a no-op");
    }

    #[test]
    fn temperature_engine_picks_coldest_victim() {
        let (h, acc) = hierarchy();
        let eng = TemperatureEngine::new(select(), RuleSet::default(), 9);
        // heat order: cold (never touched) < warm < hot (the writer)
        eng.on_access("warm.dat", Access::Read);
        let residents = vec![
            Resident { rel: "cold.dat".into(), dev: 0, size: MIB, physical: MIB },
            Resident { rel: "warm.dat".into(), dev: 0, size: MIB, physical: MIB },
        ];
        // fill the device so free == 0
        assert!(acc.try_debit(0, 4 * MIB, 0));
        let ds = eng.on_pressure(
            EngineCtx { hierarchy: &h, accountant: &acc },
            PressureCtx { rel: "hot.dat", dev: 0, need: MIB, residents: &residents },
        );
        assert_eq!(ds, vec![Decision::SpillVictim { rel: "cold.dat".into() }]);
        // victims cannot satisfy a huge need: the writer spills itself
        let ds = eng.on_pressure(
            EngineCtx { hierarchy: &h, accountant: &acc },
            PressureCtx { rel: "hot.dat", dev: 0, need: 100 * MIB, residents: &residents },
        );
        assert_eq!(ds, vec![Decision::SpillSelf]);
    }

    #[test]
    fn compressed_residents_are_cheaper_to_keep_and_spill_first() {
        // two equally-warm residents; "packed.dat" already has a
        // well-compressed PFS replica (physical << size), so its
        // cost-weighted heat is lower and it wins the victim election
        let (h, acc) = hierarchy();
        let eng = TemperatureEngine::new(select(), RuleSet::default(), 9);
        eng.on_access("plain.dat", Access::Read);
        eng.on_access("packed.dat", Access::Read);
        let residents = vec![
            Resident { rel: "plain.dat".into(), dev: 0, size: MIB, physical: MIB },
            Resident { rel: "packed.dat".into(), dev: 0, size: MIB, physical: MIB / 4 },
        ];
        assert!(acc.try_debit(0, 4 * MIB, 0));
        let ds = eng.on_pressure(
            EngineCtx { hierarchy: &h, accountant: &acc },
            PressureCtx { rel: "hot.dat", dev: 0, need: MIB, residents: &residents },
        );
        assert_eq!(
            ds,
            vec![Decision::SpillVictim { rel: "packed.dat".into() }],
            "the cheap-to-keep compressed resident spills first"
        );
    }

    #[test]
    fn temperature_engine_promotes_hot_spilled_files_on_free() {
        let (h, acc) = hierarchy();
        let eng = TemperatureEngine::new(select(), RuleSet::default(), 9);
        // two spilled files with known sizes; only "b" is re-accessed
        eng.on_close(CloseCtx { rel: "a.dat", dev: None, size: MIB });
        eng.on_close(CloseCtx { rel: "b.dat", dev: None, size: MIB });
        eng.on_access("b.dat", Access::Read);
        let ds = eng.on_freed(EngineCtx { hierarchy: &h, accountant: &acc }, 0, 2 * MIB);
        assert_eq!(
            ds,
            vec![Decision::Promote { rel: "b.dat".into(), tier: 0 }],
            "only the re-accessed file promotes; a.dat stays cold on the PFS"
        );
        // the executor consumes the candidate at approval time, once
        assert!(eng.approve_promote("b.dat"));
        assert!(!eng.approve_promote("b.dat"), "approval is one-shot");
        let ds = eng.on_freed(EngineCtx { hierarchy: &h, accountant: &acc }, 0, MIB);
        assert!(ds.is_empty(), "approved candidate no longer re-emits");
        // once a.dat heats up again it promotes too
        eng.on_access("a.dat", Access::Read);
        let ds = eng.on_freed(EngineCtx { hierarchy: &h, accountant: &acc }, 0, MIB);
        assert_eq!(ds, vec![Decision::Promote { rel: "a.dat".into(), tier: 0 }]);
        // a write-open between emission and execution vetoes the promote
        eng.on_access("a.dat", Access::Write);
        assert!(!eng.approve_promote("a.dat"), "write-open cancels the queued promote");
    }

    #[test]
    fn temperature_engine_forgets_removed_files_and_follows_renames() {
        let (h, acc) = hierarchy();
        let eng = TemperatureEngine::new(select(), RuleSet::default(), 9);
        // a spilled, re-heated file is a promotion candidate — until
        // it is unlinked (ISSUE 4 satellite: dead paths must not win
        // stale promotions or hold heat slots)
        eng.on_close(CloseCtx { rel: "gone.dat", dev: None, size: MIB });
        eng.on_access("gone.dat", Access::Read);
        eng.on_removed("gone.dat");
        let ds = eng.on_freed(EngineCtx { hierarchy: &h, accountant: &acc }, 0, MIB);
        assert!(ds.is_empty(), "unlinked file must not promote: {ds:?}");
        assert_eq!(eng.heat_tick("gone.dat"), 0, "heat slot released");
        // a rename carries both heat and the promotion candidacy
        eng.on_close(CloseCtx { rel: "old.dat", dev: None, size: MIB });
        eng.on_access("old.dat", Access::Read);
        eng.on_renamed("old.dat", "new.dat");
        assert_eq!(eng.heat_tick("old.dat"), 0, "old name forgotten");
        assert!(eng.heat_tick("new.dat") > 0, "heat follows the rename");
        let ds = eng.on_freed(EngineCtx { hierarchy: &h, accountant: &acc }, 0, MIB);
        assert_eq!(
            ds,
            vec![Decision::Promote { rel: "new.dat".into(), tier: 0 }],
            "candidacy follows the rename"
        );
        assert!(!eng.approve_promote("old.dat"));
        assert!(eng.approve_promote("new.dat"));
    }

    #[test]
    fn frequency_weighting_lets_touch_history_beat_one_recent_touch() {
        // ISSUE 5 satellite (open PR 4 ROADMAP item): with a slow decay
        // a file touched many times stays hotter than a file touched
        // once more recently — pure recency would pick the opposite
        // victim
        let (h, acc) = hierarchy();
        let eng = TemperatureEngine::with_tuning(
            select(),
            RuleSet::default(),
            9,
            TempTuning { heat_decay: 0.99, freq_weight: 1.0, promote_headroom: 0 },
        );
        for _ in 0..5 {
            eng.on_access("often.dat", Access::Read);
        }
        eng.on_access("once.dat", Access::Read); // most recent single touch
        let residents = vec![
            Resident { rel: "often.dat".into(), dev: 0, size: MIB, physical: MIB },
            Resident { rel: "once.dat".into(), dev: 0, size: MIB, physical: MIB },
        ];
        assert!(acc.try_debit(0, 4 * MIB, 0));
        let ds = eng.on_pressure(
            EngineCtx { hierarchy: &h, accountant: &acc },
            PressureCtx { rel: "hot.dat", dev: 0, need: MIB, residents: &residents },
        );
        assert_eq!(
            ds,
            vec![Decision::SpillVictim { rel: "once.dat".into() }],
            "the frequently-touched file outranks the single recent touch"
        );
    }

    #[test]
    fn fast_decay_reduces_to_recency_ordering() {
        // heat_decay near 0 forgets history: the most recently touched
        // file is always the hottest, whatever the touch counts
        let (h, acc) = hierarchy();
        let eng = TemperatureEngine::with_tuning(
            select(),
            RuleSet::default(),
            9,
            TempTuning { heat_decay: 0.01, freq_weight: 1.0, promote_headroom: 0 },
        );
        for _ in 0..10 {
            eng.on_access("often.dat", Access::Read);
        }
        eng.on_access("recent.dat", Access::Read);
        // burn a few ticks so both decay from their last touch
        for _ in 0..3 {
            eng.on_access("other.dat", Access::Read);
        }
        let residents = vec![
            Resident { rel: "often.dat".into(), dev: 0, size: MIB, physical: MIB },
            Resident { rel: "recent.dat".into(), dev: 0, size: MIB, physical: MIB },
        ];
        assert!(acc.try_debit(0, 4 * MIB, 0));
        let ds = eng.on_pressure(
            EngineCtx { hierarchy: &h, accountant: &acc },
            PressureCtx { rel: "hot.dat", dev: 0, need: MIB, residents: &residents },
        );
        assert_eq!(
            ds,
            vec![Decision::SpillVictim { rel: "often.dat".into() }],
            "with fast decay only recency matters"
        );
    }

    #[test]
    fn promote_headroom_gates_promotions() {
        // a candidate that fits exactly must NOT promote when headroom
        // is configured: the tier needs size + headroom free
        let (h, acc) = hierarchy();
        let eng = TemperatureEngine::with_tuning(
            select(),
            RuleSet::default(),
            9,
            TempTuning { heat_decay: 0.5, freq_weight: 1.0, promote_headroom: 200 * MIB },
        );
        eng.on_close(CloseCtx { rel: "s.dat", dev: None, size: MIB });
        eng.on_access("s.dat", Access::Read);
        let ds = eng.on_freed(EngineCtx { hierarchy: &h, accountant: &acc }, 0, MIB);
        assert!(ds.is_empty(), "no tier has size + headroom free: {ds:?}");
        // the same state without headroom promotes
        let eng = TemperatureEngine::new(select(), RuleSet::default(), 9);
        eng.on_close(CloseCtx { rel: "s.dat", dev: None, size: MIB });
        eng.on_access("s.dat", Access::Read);
        let ds = eng.on_freed(EngineCtx { hierarchy: &h, accountant: &acc }, 0, MIB);
        assert_eq!(ds, vec![Decision::Promote { rel: "s.dat".into(), tier: 0 }]);
    }

    #[test]
    fn pfs_only_engine_never_uses_devices() {
        let (h, acc) = hierarchy();
        let eng = PfsOnlyEngine;
        let p = eng.place(
            EngineCtx { hierarchy: &h, accountant: &acc },
            PlaceCtx { rel: "x", size: MIB, prefetch: false },
        );
        assert_eq!(p, Placement::Pfs);
        assert!(eng.on_close(CloseCtx { rel: "x", dev: None, size: MIB }).is_empty());
        assert_eq!(acc.free(0), 4 * MIB, "nothing debited");
    }
}
