//! Minimal glob matching for Sea rule lists (offline `glob` substitute).
//!
//! Supported syntax, matched against `/`-separated paths:
//! * `?`  — any single character except `/`
//! * `*`  — any run of characters except `/`
//! * `**` — any run of characters *including* `/`
//! * everything else matches literally.

/// Does `pat` match `path` in full?
pub fn glob_match(pat: &str, path: &str) -> bool {
    let p: Vec<char> = pat.chars().collect();
    let s: Vec<char> = path.chars().collect();
    matches_at(&p, 0, &s, 0)
}

fn matches_at(p: &[char], mut pi: usize, s: &[char], mut si: usize) -> bool {
    // iterative with backtracking stack for * / ** (classic two-pointer
    // doesn't cover the two star kinds cleanly, so do explicit recursion
    // on stars only — patterns are short).
    loop {
        if pi == p.len() {
            return si == s.len();
        }
        match p[pi] {
            '*' => {
                let double = pi + 1 < p.len() && p[pi + 1] == '*';
                let (skip, cross_sep) = if double { (2, true) } else { (1, false) };
                // try every possible extent, shortest first
                let mut k = si;
                loop {
                    if matches_at(p, pi + skip, s, k) {
                        return true;
                    }
                    if k == s.len() || (!cross_sep && s[k] == '/') {
                        return false;
                    }
                    k += 1;
                }
            }
            '?' => {
                if si == s.len() || s[si] == '/' {
                    return false;
                }
                pi += 1;
                si += 1;
            }
            c => {
                if si == s.len() || s[si] != c {
                    return false;
                }
                pi += 1;
                si += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals() {
        assert!(glob_match("a/b.txt", "a/b.txt"));
        assert!(!glob_match("a/b.txt", "a/b.txd"));
        assert!(!glob_match("a/b", "a/b/c"));
    }

    #[test]
    fn single_star_stops_at_separator() {
        assert!(glob_match("out/*.nii", "out/block_001.nii"));
        assert!(!glob_match("out/*.nii", "out/sub/block.nii"));
        assert!(glob_match("*.log", "app.log"));
        assert!(!glob_match("*.log", "dir/app.log"));
    }

    #[test]
    fn double_star_crosses_separators() {
        assert!(glob_match("**/*.nii", "a/b/c/block.nii"));
        assert!(glob_match("out/**", "out/x/y/z"));
        assert!(glob_match("**", "anything/at/all"));
        assert!(!glob_match("**/*.nii", "a/b/c/block.txt"));
    }

    #[test]
    fn question_mark() {
        assert!(glob_match("iter_?.dat", "iter_3.dat"));
        assert!(!glob_match("iter_?.dat", "iter_10.dat"));
        assert!(!glob_match("a?c", "a/c"));
    }

    #[test]
    fn tricky_backtracking() {
        assert!(glob_match("*_final_*", "block_final_0001"));
        assert!(glob_match("a*b*c", "axxbyyc"));
        assert!(!glob_match("a*b*c", "axxbyy"));
        assert!(glob_match("**final**", "x/y/final/z"));
    }

    #[test]
    fn empty_cases() {
        assert!(glob_match("", ""));
        assert!(!glob_match("", "a"));
        assert!(glob_match("*", ""));
        assert!(glob_match("**", ""));
    }
}
