//! Sea's rule lists and the Table 1 memory-management modes.
//!
//! | Mode   | `.sea_flushlist` | `.sea_evictlist` |
//! |--------|------------------|------------------|
//! | Copy   | yes              | no               |
//! | Remove | no               | yes              |
//! | Move   | yes              | yes              |
//! | Keep   | no               | no               |
//!
//! A third list, `.sea_prefetchlist`, names input files to pull into the
//! fast tiers at startup.

use std::fs;
use std::path::Path;

use crate::error::{Error, Result};
use crate::placement::glob::glob_match;

/// The four per-file modes of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MgmtMode {
    /// Flush to PFS, keep in cache (reused + shared).
    Copy,
    /// Drop without persisting (scratch/log files).
    Remove,
    /// Flush to PFS then drop from cache (copy-and-remove).
    Move,
    /// Stay in cache, never persisted.
    Keep,
}

/// One parsed pattern list.
#[derive(Debug, Clone, Default)]
pub struct PatternList {
    patterns: Vec<String>,
}

impl PatternList {
    /// Parse a list body: one glob per line, `#` comments, blank lines ok.
    pub fn parse(text: &str) -> PatternList {
        PatternList {
            patterns: text
                .lines()
                .map(str::trim)
                .filter(|l| !l.is_empty() && !l.starts_with('#'))
                .map(String::from)
                .collect(),
        }
    }

    /// Load from a file; a missing file is an empty list (Sea's default).
    pub fn load(path: &Path) -> Result<PatternList> {
        match fs::read_to_string(path) {
            Ok(text) => Ok(Self::parse(&text)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(PatternList::default()),
            Err(e) => Err(Error::io(path, e)),
        }
    }

    /// Does any pattern match `path` (mount-relative)?
    pub fn matches(&self, path: &str) -> bool {
        self.patterns.iter().any(|p| glob_match(p, path))
    }

    /// Number of patterns.
    pub fn len(&self) -> usize {
        self.patterns.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.patterns.is_empty()
    }
}

/// The complete rule configuration of a Sea mount.
#[derive(Debug, Clone, Default)]
pub struct RuleSet {
    /// `.sea_flushlist` patterns.
    pub flush: PatternList,
    /// `.sea_evictlist` patterns.
    pub evict: PatternList,
    /// `.sea_prefetchlist` patterns.
    pub prefetch: PatternList,
}

impl RuleSet {
    /// Build from in-memory pattern bodies.
    pub fn from_texts(flush: &str, evict: &str, prefetch: &str) -> RuleSet {
        RuleSet {
            flush: PatternList::parse(flush),
            evict: PatternList::parse(evict),
            prefetch: PatternList::parse(prefetch),
        }
    }

    /// Load the three dot-files from a directory (each optional).
    pub fn load_dir(dir: &Path) -> Result<RuleSet> {
        Ok(RuleSet {
            flush: PatternList::load(&dir.join(".sea_flushlist"))?,
            evict: PatternList::load(&dir.join(".sea_evictlist"))?,
            prefetch: PatternList::load(&dir.join(".sea_prefetchlist"))?,
        })
    }

    /// Table 1: the mode of a (mount-relative) path.
    pub fn mode_for(&self, rel_path: &str) -> MgmtMode {
        match (self.flush.matches(rel_path), self.evict.matches(rel_path)) {
            (true, false) => MgmtMode::Copy,
            (false, true) => MgmtMode::Remove,
            (true, true) => MgmtMode::Move,
            (false, false) => MgmtMode::Keep,
        }
    }

    /// Convenience: "flush everything, evict nothing" (Sea copy-all).
    pub fn copy_all() -> RuleSet {
        Self::from_texts("**", "", "")
    }

    /// Convenience: flush+evict only paths matching `final_pat`
    /// (the paper's in-memory configuration: only the last iteration of
    /// files is flushed and evicted).
    pub fn in_memory(final_pat: &str) -> RuleSet {
        Self::from_texts(final_pat, final_pat, "")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_modes() {
        let r = RuleSet::from_texts("keepme/**\nshared_*", "scratch/**\nshared_*", "");
        assert_eq!(r.mode_for("keepme/x"), MgmtMode::Copy);
        assert_eq!(r.mode_for("scratch/tmp.log"), MgmtMode::Remove);
        assert_eq!(r.mode_for("shared_01.nii"), MgmtMode::Move);
        assert_eq!(r.mode_for("other.dat"), MgmtMode::Keep);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let l = PatternList::parse("# header\n\n  *.log  \n# trailing\n");
        assert_eq!(l.len(), 1);
        assert!(l.matches("x.log"));
        assert!(!l.matches("x.dat"));
    }

    #[test]
    fn missing_files_mean_empty_lists() {
        let dir = std::env::temp_dir().join("sea_rules_none");
        std::fs::create_dir_all(&dir).unwrap();
        let r = RuleSet::load_dir(&dir).unwrap();
        assert!(r.flush.is_empty() && r.evict.is_empty() && r.prefetch.is_empty());
        assert_eq!(r.mode_for("anything"), MgmtMode::Keep);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_dir_reads_dotfiles() {
        let dir = std::env::temp_dir().join("sea_rules_load");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(".sea_flushlist"), "out/**\n").unwrap();
        std::fs::write(dir.join(".sea_evictlist"), "out/iter9_*\n").unwrap();
        std::fs::write(dir.join(".sea_prefetchlist"), "input/**\n").unwrap();
        let r = RuleSet::load_dir(&dir).unwrap();
        assert_eq!(r.mode_for("out/iter1_b.dat"), MgmtMode::Copy);
        assert_eq!(r.mode_for("out/iter9_b.dat"), MgmtMode::Move);
        assert!(r.prefetch.matches("input/block1"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn presets() {
        let ca = RuleSet::copy_all();
        assert_eq!(ca.mode_for("x/y/z"), MgmtMode::Copy);
        let im = RuleSet::in_memory("**/final_*");
        assert_eq!(im.mode_for("b/final_3"), MgmtMode::Move);
        assert_eq!(im.mode_for("b/iter_2"), MgmtMode::Keep);
    }
}
