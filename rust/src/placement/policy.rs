//! Simulator placement policies, as thin **adapters** over the shared
//! [`PlacementEngine`] API.
//!
//! Since the engine refactor the simulator no longer carries its own
//! copy of the paper's policy: [`SeaPolicy`] drives a
//! [`crate::placement::engine::PaperEngine`] (one engine instance — and
//! one shuffle RNG stream — shared across nodes, exactly like the
//! pre-refactor implementation) and [`LustrePolicy`] drives the
//! [`PfsOnlyEngine`] baseline. The real-bytes VFS (`vfs::sea`) drives
//! the *same* engines, so simulation and real bytes share one policy
//! code path. The adapters map [`Placement`] picks onto simulator
//! [`Location`]s and typed [`Decision`]s onto [`MgmtAction`]s; decisions
//! the simulator cannot execute (`Promote`, spill variants) are dropped
//! here — the simulator has no pressure or promotion machinery.

use std::collections::HashMap;
use std::sync::Arc;

use crate::hierarchy::{DeviceRef, Hierarchy, SelectCfg, SpaceAccountant};
use crate::placement::engine::{
    flush_evict_flags, CloseCtx, Decision, EngineCtx, PaperEngine, PfsOnlyEngine, PlaceCtx,
    Placement, PlacementEngine,
};
use crate::placement::rules::RuleSet;
use crate::placement::table::FileTable;
use crate::sim::app::{MgmtAction, SimPlacer};
use crate::sim::spec::ClusterSpec;
use crate::sim::stack::{FileId, StackState};
use crate::sim::topology::Location;

/// Map close decisions onto the simulator's management actions.
fn actions_from_decisions(file: FileId, rel: &str, decisions: &[Decision]) -> Vec<MgmtAction> {
    match flush_evict_flags(rel, decisions) {
        (true, true) => vec![MgmtAction::FlushEvict(file)],
        (true, false) => vec![MgmtAction::Flush(file)],
        (false, true) => vec![MgmtAction::Evict(file)],
        (false, false) => Vec::new(),
    }
}

/// Baseline: every file goes straight to Lustre; no management actions.
pub struct LustrePolicy {
    /// Empty hierarchy (the baseline declares no fast devices).
    hierarchy: Hierarchy,
    accountant: SpaceAccountant,
    engine: PfsOnlyEngine,
}

impl Default for LustrePolicy {
    fn default() -> LustrePolicy {
        let hierarchy = Hierarchy::new();
        let accountant = SpaceAccountant::new(&hierarchy);
        LustrePolicy { hierarchy, accountant, engine: PfsOnlyEngine }
    }
}

impl LustrePolicy {
    /// The baseline adapter.
    pub fn new() -> LustrePolicy {
        LustrePolicy::default()
    }
}

impl SimPlacer for LustrePolicy {
    fn place(&mut self, _st: &mut StackState, _node: usize, _f: FileId, size: u64) -> Location {
        let ctx = EngineCtx { hierarchy: &self.hierarchy, accountant: &self.accountant };
        match self.engine.place(ctx, PlaceCtx { rel: "", size, prefetch: false }) {
            Placement::Pfs => Location::Lustre,
            Placement::Device(_) => unreachable!("pfs-only engine never picks a device"),
        }
    }
    fn on_write_complete(&mut self, _file: FileId) -> Vec<MgmtAction> {
        Vec::new()
    }
    fn on_freed(&mut self, _loc: Location, _size: u64) {}
}

/// One node's view of the Sea hierarchy (simulation flavour).
struct NodeDevices {
    hierarchy: Hierarchy,
    accountant: SpaceAccountant,
    /// DeviceRef → simulator location.
    loc_of: Vec<Location>,
    /// Reverse map for space credits.
    dev_of: HashMap<Location, DeviceRef>,
}

/// Sea's placement policy over the simulated cluster.
///
/// Owns per-node hierarchies (tmpfs tier 0, local disks tier 1) and one
/// shared [`PaperEngine`] carrying the `p·F` reservation config and the
/// rule lists that decide Table 1 actions after each write.
pub struct SeaPolicy {
    nodes: Vec<NodeDevices>,
    engine: Arc<dyn PlacementEngine>,
    table: Arc<FileTable>,
    /// Last placement per file (location + size), for close contexts.
    last_placed: HashMap<FileId, (Location, u64)>,
    /// Statistics: placements per tier name.
    pub placed: HashMap<&'static str, u64>,
    /// Statistics: placements that fell back to Lustre.
    pub fallbacks: u64,
}

impl SeaPolicy {
    /// Build the per-node hierarchies from a cluster spec, over a
    /// [`PaperEngine`] (the paper's policy).
    pub fn new(
        spec: &ClusterSpec,
        cfg: SelectCfg,
        rules: RuleSet,
        table: Arc<FileTable>,
        seed: u64,
    ) -> SeaPolicy {
        let engine: Arc<dyn PlacementEngine> = Arc::new(PaperEngine::new(cfg, rules, seed));
        SeaPolicy::with_engine(spec, engine, table)
    }

    /// Build the adapter over any [`PlacementEngine`].
    pub fn with_engine(
        spec: &ClusterSpec,
        engine: Arc<dyn PlacementEngine>,
        table: Arc<FileTable>,
    ) -> SeaPolicy {
        let mut nodes = Vec::with_capacity(spec.nodes);
        for n in 0..spec.nodes {
            let mut h = Hierarchy::new();
            let mut loc_of = Vec::new();
            let mut dev_of = HashMap::new();
            let d = h.add(0, spec.tmpfs_bytes, format!("n{n}.tmpfs"));
            loc_of.push(Location::Tmpfs { node: n });
            dev_of.insert(Location::Tmpfs { node: n }, d);
            for disk in 0..spec.disks_per_node {
                let d = h.add(1, spec.disk_bytes, format!("n{n}.disk{disk}"));
                loc_of.push(Location::Disk { node: n, disk });
                dev_of.insert(Location::Disk { node: n, disk }, d);
            }
            let accountant = SpaceAccountant::new(&h);
            nodes.push(NodeDevices { hierarchy: h, accountant, loc_of, dev_of });
        }
        SeaPolicy {
            nodes,
            engine,
            table,
            last_placed: HashMap::new(),
            placed: HashMap::new(),
            fallbacks: 0,
        }
    }

    /// Free bytes on a node's fastest tier (diagnostics).
    pub fn tmpfs_free(&self, node: usize) -> u64 {
        self.nodes[node].accountant.free(0)
    }

    /// Per-device `(name, used, free)` on one node, from the ledger —
    /// lets experiments report tier occupancy without poking the
    /// accountant directly.
    pub fn device_usage(&self, node: usize) -> Vec<(String, u64, u64)> {
        let nd = &self.nodes[node];
        nd.hierarchy
            .iter()
            .zip(nd.accountant.lines())
            .map(|((_, info), l)| (info.name.clone(), l.used, l.free))
            .collect()
    }
}

impl SimPlacer for SeaPolicy {
    fn place(&mut self, _st: &mut StackState, node: usize, file: FileId, size: u64) -> Location {
        let path = self.table.path(file);
        let loc = {
            let nd = &self.nodes[node];
            let ctx = EngineCtx { hierarchy: &nd.hierarchy, accountant: &nd.accountant };
            match self
                .engine
                .place(ctx, PlaceCtx { rel: &path, size, prefetch: false })
            {
                Placement::Device(d) => Some(nd.loc_of[d]),
                Placement::Pfs => None,
            }
        };
        let loc = match loc {
            Some(l) => {
                *self.placed.entry(l.tier_name()).or_default() += 1;
                l
            }
            None => {
                self.fallbacks += 1;
                *self.placed.entry("lustre").or_default() += 1;
                Location::Lustre
            }
        };
        self.last_placed.insert(file, (loc, size));
        loc
    }

    fn on_write_complete(&mut self, file: FileId) -> Vec<MgmtAction> {
        let path = self.table.path(file);
        // drain the record: each completion is its last consumer (a
        // re-written file re-inserts at its next place()), so the map
        // never grows with the run
        let (loc, size) = self
            .last_placed
            .remove(&file)
            .unwrap_or((Location::Lustre, 0));
        let dev = match loc {
            Location::Tmpfs { node } | Location::Disk { node, .. } => {
                self.nodes[node].dev_of.get(&loc).copied()
            }
            Location::Lustre => None,
        };
        let decisions = self.engine.on_close(CloseCtx { rel: &path, dev, size });
        actions_from_decisions(file, &path, &decisions)
    }

    fn on_freed(&mut self, loc: Location, size: u64) {
        let node = match loc {
            Location::Tmpfs { node } | Location::Disk { node, .. } => node,
            Location::Lustre => return,
        };
        let nd = &self.nodes[node];
        if let Some(&d) = nd.dev_of.get(&loc) {
            nd.accountant.credit(d, size);
            // the simulator has no promotion machinery: the engine is
            // informed (heat bookkeeping) but its decisions are dropped
            let ctx = EngineCtx { hierarchy: &nd.hierarchy, accountant: &nd.accountant };
            let _ = self.engine.on_freed(ctx, d, size);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::Sim;
    use crate::sim::stack::Stack;
    use crate::util::{GIB, MIB};

    fn spec() -> ClusterSpec {
        ClusterSpec {
            nodes: 2,
            disks_per_node: 2,
            tmpfs_bytes: 10 * MIB,
            disk_bytes: 100 * MIB,
            ..ClusterSpec::default()
        }
    }

    fn policy(rules: RuleSet) -> (SeaPolicy, Arc<FileTable>) {
        let table = Arc::new(FileTable::new());
        let cfg = SelectCfg { max_file_size: MIB, parallel_procs: 2 };
        (SeaPolicy::new(&spec(), cfg, rules, table.clone(), 42), table)
    }

    fn stack_state() -> (Sim, Stack) {
        let mut sim = Sim::new();
        let stack = Stack::new(&mut sim, &spec());
        (sim, stack)
    }

    #[test]
    fn fills_tmpfs_then_disks_then_lustre() {
        let (mut p, table) = policy(RuleSet::default());
        let (_sim, stack) = stack_state();
        let mut st = stack.state.borrow_mut();
        let mut tiers = Vec::new();
        for i in 0..230 {
            let f = table.intern(&format!("f{i}"));
            tiers.push(p.place(&mut st, 0, f, MIB).tier_name());
        }
        // 10 MiB tmpfs with floor 2 MiB -> ~8 placements; 2x100 MiB disks
        // with floor 2 -> ~198; rest lustre
        let tmpfs = tiers.iter().filter(|t| **t == "tmpfs").count();
        let disk = tiers.iter().filter(|t| **t == "local disk").count();
        let lustre = tiers.iter().filter(|t| **t == "lustre").count();
        assert!(tmpfs >= 8 && tmpfs <= 10, "tmpfs {tmpfs}");
        assert!(disk >= 196 && disk <= 200, "disk {disk}");
        assert!(lustre >= 20, "lustre {lustre}");
        assert!(p.fallbacks > 0);
        // fastest-first: first placement must be tmpfs
        assert_eq!(tiers[0], "tmpfs");
    }

    #[test]
    fn nodes_have_independent_space() {
        let (mut p, table) = policy(RuleSet::default());
        let (_sim, stack) = stack_state();
        let mut st = stack.state.borrow_mut();
        for i in 0..8 {
            let f = table.intern(&format!("a{i}"));
            p.place(&mut st, 0, f, MIB);
        }
        // node 1 untouched: still places on its tmpfs
        let f = table.intern("b0");
        let loc = p.place(&mut st, 1, f, MIB);
        assert_eq!(loc, Location::Tmpfs { node: 1 });
    }

    #[test]
    fn rules_translate_to_actions() {
        let rules = RuleSet::from_texts("out/final_*", "out/final_*\nscratch/*", "");
        let (mut p, table) = policy(rules);
        let fin = table.intern("out/final_3");
        let scr = table.intern("scratch/tmp");
        let keep = table.intern("out/iter_1");
        assert_eq!(p.on_write_complete(fin), vec![MgmtAction::FlushEvict(fin)]);
        assert_eq!(p.on_write_complete(scr), vec![MgmtAction::Evict(scr)]);
        assert_eq!(p.on_write_complete(keep), vec![]);
    }

    #[test]
    fn freed_space_is_reusable() {
        let (mut p, table) = policy(RuleSet::default());
        let (_sim, stack) = stack_state();
        let mut st = stack.state.borrow_mut();
        // fill tmpfs: 9 placements leave 1 MiB free (< 2 MiB floor)
        let mut placed = Vec::new();
        for i in 0..9 {
            let f = table.intern(&format!("x{i}"));
            placed.push(p.place(&mut st, 0, f, MIB));
        }
        assert!(placed.iter().all(|l| *l == Location::Tmpfs { node: 0 }));
        // exhausted -> next goes to disk
        let f = table.intern("spill");
        assert_eq!(p.place(&mut st, 0, f, MIB).tier_name(), "local disk");
        // credit back 4 MiB -> tmpfs eligible again (floor 2 MiB)
        p.on_freed(Location::Tmpfs { node: 0 }, 4 * MIB);
        let f2 = table.intern("again");
        assert_eq!(p.place(&mut st, 0, f2, MIB), Location::Tmpfs { node: 0 });
    }

    #[test]
    fn device_usage_tracks_the_ledger() {
        let (mut p, table) = policy(RuleSet::default());
        let (_sim, stack) = stack_state();
        let mut st = stack.state.borrow_mut();
        let f = table.intern("u0");
        let loc = p.place(&mut st, 0, f, MIB);
        assert_eq!(loc, Location::Tmpfs { node: 0 });
        let usage = p.device_usage(0);
        assert_eq!(usage.len(), 3, "tmpfs + 2 disks");
        assert_eq!(usage[0], ("n0.tmpfs".to_string(), MIB, 9 * MIB));
        p.on_freed(loc, MIB);
        assert_eq!(p.device_usage(0)[0].1, 0, "freed space leaves the ledger");
    }

    #[test]
    fn lustre_policy_places_everything_on_lustre() {
        let mut p = LustrePolicy::new();
        let (_sim, stack) = stack_state();
        let mut st = stack.state.borrow_mut();
        assert_eq!(p.place(&mut st, 0, 1, GIB), Location::Lustre);
        assert!(p.on_write_complete(1).is_empty());
    }

    #[test]
    fn close_context_carries_the_placed_device() {
        // the adapter feeds the engine truthful close contexts: a file
        // placed on tmpfs closes with its device, a fallback with None —
        // observable through an engine that records them
        use std::sync::Mutex;
        struct Recording(Mutex<Vec<(String, Option<DeviceRef>, u64)>>);
        impl PlacementEngine for Recording {
            fn place(&self, _c: EngineCtx<'_>, _p: PlaceCtx<'_>) -> Placement {
                Placement::Device(0)
            }
            fn on_close(&self, c: CloseCtx<'_>) -> Vec<Decision> {
                self.0
                    .lock()
                    .unwrap()
                    .push((c.rel.to_string(), c.dev, c.size));
                Vec::new()
            }
            fn on_pressure(&self, _c: EngineCtx<'_>, _p: PressureCtx<'_>) -> Vec<Decision> {
                vec![Decision::SpillSelf]
            }
            fn on_freed(&self, _c: EngineCtx<'_>, _d: DeviceRef, _s: u64) -> Vec<Decision> {
                Vec::new()
            }
            fn name(&self) -> &'static str {
                "recording"
            }
        }
        use crate::placement::engine::PressureCtx;
        let table = Arc::new(FileTable::new());
        let rec = Arc::new(Recording(Mutex::new(Vec::new())));
        let mut p = SeaPolicy::with_engine(&spec(), rec.clone(), table.clone());
        let (_sim, stack) = stack_state();
        let mut st = stack.state.borrow_mut();
        let f = table.intern("ctx/file.dat");
        let loc = p.place(&mut st, 1, f, MIB);
        assert_eq!(loc, Location::Tmpfs { node: 1 });
        p.on_write_complete(f);
        let seen = rec.0.lock().unwrap().clone();
        assert_eq!(seen, vec![("ctx/file.dat".to_string(), Some(0), MIB)]);
    }
}
