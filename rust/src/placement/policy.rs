//! Placement policies: Sea's hierarchy policy and the plain-Lustre
//! baseline, as [`SimPlacer`]s for the simulator.
//!
//! The real-bytes VFS uses the same [`Hierarchy`]/[`SpaceAccountant`]/
//! [`RuleSet`] machinery (module `vfs::sea`); only the device mapping
//! differs (the simulator binds devices to [`Location`]s, the VFS binds
//! them to `Vfs` backends via `Hierarchy::add_backed`). Both flavours
//! account through the same per-device ledger, so occupancy diagnostics
//! ([`SeaPolicy::device_usage`]) read identically on either side.

use std::collections::HashMap;
use std::sync::Arc;

use crate::hierarchy::{select_device, DeviceRef, Hierarchy, SelectCfg, SpaceAccountant};
use crate::placement::rules::{MgmtMode, RuleSet};
use crate::placement::table::FileTable;
use crate::sim::app::{MgmtAction, SimPlacer};
use crate::sim::spec::ClusterSpec;
use crate::sim::stack::{FileId, StackState};
use crate::sim::topology::Location;
use crate::util::Rng;

/// Baseline: every file goes straight to Lustre; no management actions.
#[derive(Debug, Default)]
pub struct LustrePolicy;

impl SimPlacer for LustrePolicy {
    fn place(&mut self, _st: &mut StackState, _node: usize, _f: FileId, _s: u64) -> Location {
        Location::Lustre
    }
    fn on_write_complete(&mut self, _file: FileId) -> Vec<MgmtAction> {
        Vec::new()
    }
    fn on_freed(&mut self, _loc: Location, _size: u64) {}
}

/// One node's view of the Sea hierarchy (simulation flavour).
struct NodeDevices {
    hierarchy: Hierarchy,
    accountant: SpaceAccountant,
    /// DeviceRef → simulator location.
    loc_of: Vec<Location>,
    /// Reverse map for space credits.
    dev_of: HashMap<Location, DeviceRef>,
}

/// Sea's placement policy over the simulated cluster.
///
/// Owns per-node hierarchies (tmpfs tier 0, local disks tier 1), the
/// `p·F` reservation config, and the rule lists that decide Table 1
/// actions after each write.
pub struct SeaPolicy {
    nodes: Vec<NodeDevices>,
    cfg: SelectCfg,
    rules: RuleSet,
    table: Arc<FileTable>,
    rng: Rng,
    /// Statistics: placements per tier name.
    pub placed: HashMap<&'static str, u64>,
    /// Statistics: placements that fell back to Lustre.
    pub fallbacks: u64,
}

impl SeaPolicy {
    /// Build the per-node hierarchies from a cluster spec.
    pub fn new(
        spec: &ClusterSpec,
        cfg: SelectCfg,
        rules: RuleSet,
        table: Arc<FileTable>,
        seed: u64,
    ) -> SeaPolicy {
        let mut nodes = Vec::with_capacity(spec.nodes);
        for n in 0..spec.nodes {
            let mut h = Hierarchy::new();
            let mut loc_of = Vec::new();
            let mut dev_of = HashMap::new();
            let d = h.add(0, spec.tmpfs_bytes, format!("n{n}.tmpfs"));
            loc_of.push(Location::Tmpfs { node: n });
            dev_of.insert(Location::Tmpfs { node: n }, d);
            for disk in 0..spec.disks_per_node {
                let d = h.add(1, spec.disk_bytes, format!("n{n}.disk{disk}"));
                loc_of.push(Location::Disk { node: n, disk });
                dev_of.insert(Location::Disk { node: n, disk }, d);
            }
            let accountant = SpaceAccountant::new(&h);
            nodes.push(NodeDevices { hierarchy: h, accountant, loc_of, dev_of });
        }
        SeaPolicy {
            nodes,
            cfg,
            rules,
            table,
            rng: Rng::new(seed),
            placed: HashMap::new(),
            fallbacks: 0,
        }
    }

    /// Free bytes on a node's fastest tier (diagnostics).
    pub fn tmpfs_free(&self, node: usize) -> u64 {
        self.nodes[node].accountant.free(0)
    }

    /// Per-device `(name, used, free)` on one node, from the ledger —
    /// lets experiments report tier occupancy without poking the
    /// accountant directly.
    pub fn device_usage(&self, node: usize) -> Vec<(String, u64, u64)> {
        let nd = &self.nodes[node];
        nd.hierarchy
            .iter()
            .zip(nd.accountant.lines())
            .map(|((_, info), l)| (info.name.clone(), l.used, l.free))
            .collect()
    }
}

impl SimPlacer for SeaPolicy {
    fn place(&mut self, _st: &mut StackState, node: usize, _file: FileId, size: u64) -> Location {
        let nd = &self.nodes[node];
        match select_device(&nd.hierarchy, &nd.accountant, &self.cfg, size, &mut self.rng) {
            Some(d) => {
                let loc = nd.loc_of[d];
                *self.placed.entry(loc.tier_name()).or_default() += 1;
                loc
            }
            None => {
                self.fallbacks += 1;
                *self.placed.entry("lustre").or_default() += 1;
                Location::Lustre
            }
        }
    }

    fn on_write_complete(&mut self, file: FileId) -> Vec<MgmtAction> {
        let path = self.table.path(file);
        match self.rules.mode_for(&path) {
            MgmtMode::Copy => vec![MgmtAction::Flush(file)],
            MgmtMode::Move => vec![MgmtAction::FlushEvict(file)],
            MgmtMode::Remove => vec![MgmtAction::Evict(file)],
            MgmtMode::Keep => Vec::new(),
        }
    }

    fn on_freed(&mut self, loc: Location, size: u64) {
        let node = match loc {
            Location::Tmpfs { node } | Location::Disk { node, .. } => node,
            Location::Lustre => return,
        };
        let nd = &self.nodes[node];
        if let Some(&d) = nd.dev_of.get(&loc) {
            nd.accountant.credit(d, size);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::Sim;
    use crate::sim::stack::Stack;
    use crate::util::{GIB, MIB};

    fn spec() -> ClusterSpec {
        ClusterSpec {
            nodes: 2,
            disks_per_node: 2,
            tmpfs_bytes: 10 * MIB,
            disk_bytes: 100 * MIB,
            ..ClusterSpec::default()
        }
    }

    fn policy(rules: RuleSet) -> (SeaPolicy, Arc<FileTable>) {
        let table = Arc::new(FileTable::new());
        let cfg = SelectCfg { max_file_size: MIB, parallel_procs: 2 };
        (SeaPolicy::new(&spec(), cfg, rules, table.clone(), 42), table)
    }

    fn stack_state() -> (Sim, Stack) {
        let mut sim = Sim::new();
        let stack = Stack::new(&mut sim, &spec());
        (sim, stack)
    }

    #[test]
    fn fills_tmpfs_then_disks_then_lustre() {
        let (mut p, table) = policy(RuleSet::default());
        let (_sim, stack) = stack_state();
        let mut st = stack.state.borrow_mut();
        let mut tiers = Vec::new();
        for i in 0..230 {
            let f = table.intern(&format!("f{i}"));
            tiers.push(p.place(&mut st, 0, f, MIB).tier_name());
        }
        // 10 MiB tmpfs with floor 2 MiB -> ~8 placements; 2x100 MiB disks
        // with floor 2 -> ~198; rest lustre
        let tmpfs = tiers.iter().filter(|t| **t == "tmpfs").count();
        let disk = tiers.iter().filter(|t| **t == "local disk").count();
        let lustre = tiers.iter().filter(|t| **t == "lustre").count();
        assert!(tmpfs >= 8 && tmpfs <= 10, "tmpfs {tmpfs}");
        assert!(disk >= 196 && disk <= 200, "disk {disk}");
        assert!(lustre >= 20, "lustre {lustre}");
        assert!(p.fallbacks > 0);
        // fastest-first: first placement must be tmpfs
        assert_eq!(tiers[0], "tmpfs");
    }

    #[test]
    fn nodes_have_independent_space() {
        let (mut p, table) = policy(RuleSet::default());
        let (_sim, stack) = stack_state();
        let mut st = stack.state.borrow_mut();
        for i in 0..8 {
            let f = table.intern(&format!("a{i}"));
            p.place(&mut st, 0, f, MIB);
        }
        // node 1 untouched: still places on its tmpfs
        let f = table.intern("b0");
        let loc = p.place(&mut st, 1, f, MIB);
        assert_eq!(loc, Location::Tmpfs { node: 1 });
    }

    #[test]
    fn rules_translate_to_actions() {
        let rules = RuleSet::from_texts("out/final_*", "out/final_*\nscratch/*", "");
        let (mut p, table) = policy(rules);
        let fin = table.intern("out/final_3");
        let scr = table.intern("scratch/tmp");
        let keep = table.intern("out/iter_1");
        assert_eq!(p.on_write_complete(fin), vec![MgmtAction::FlushEvict(fin)]);
        assert_eq!(p.on_write_complete(scr), vec![MgmtAction::Evict(scr)]);
        assert_eq!(p.on_write_complete(keep), vec![]);
    }

    #[test]
    fn freed_space_is_reusable() {
        let (mut p, table) = policy(RuleSet::default());
        let (_sim, stack) = stack_state();
        let mut st = stack.state.borrow_mut();
        // fill tmpfs: 9 placements leave 1 MiB free (< 2 MiB floor)
        let mut placed = Vec::new();
        for i in 0..9 {
            let f = table.intern(&format!("x{i}"));
            placed.push(p.place(&mut st, 0, f, MIB));
        }
        assert!(placed.iter().all(|l| *l == Location::Tmpfs { node: 0 }));
        // exhausted -> next goes to disk
        let f = table.intern("spill");
        assert_eq!(p.place(&mut st, 0, f, MIB).tier_name(), "local disk");
        // credit back 4 MiB -> tmpfs eligible again (floor 2 MiB)
        p.on_freed(Location::Tmpfs { node: 0 }, 4 * MIB);
        let f2 = table.intern("again");
        assert_eq!(p.place(&mut st, 0, f2, MIB), Location::Tmpfs { node: 0 });
    }

    #[test]
    fn device_usage_tracks_the_ledger() {
        let (mut p, table) = policy(RuleSet::default());
        let (_sim, stack) = stack_state();
        let mut st = stack.state.borrow_mut();
        let f = table.intern("u0");
        let loc = p.place(&mut st, 0, f, MIB);
        assert_eq!(loc, Location::Tmpfs { node: 0 });
        let usage = p.device_usage(0);
        assert_eq!(usage.len(), 3, "tmpfs + 2 disks");
        assert_eq!(usage[0], ("n0.tmpfs".to_string(), MIB, 9 * MIB));
        p.on_freed(loc, MIB);
        assert_eq!(p.device_usage(0)[0].1, 0, "freed space leaves the ledger");
    }

    #[test]
    fn lustre_policy_places_everything_on_lustre() {
        let mut p = LustrePolicy;
        let (_sim, stack) = stack_state();
        let mut st = stack.state.borrow_mut();
        assert_eq!(p.place(&mut st, 0, 1, GIB), Location::Lustre);
        assert!(p.on_write_complete(1).is_empty());
    }
}
