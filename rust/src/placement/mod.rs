//! Sea memory management: rule lists, Table 1 modes, and placement
//! policies (DESIGN.md S8/S10).
//!
//! * [`glob`] — pattern matching for the rule lists;
//! * [`rules`] — `.sea_flushlist` / `.sea_evictlist` / `.sea_prefetchlist`
//!   parsing and the Copy/Remove/Move/Keep mode table;
//! * [`table`] — path ⇄ id interning shared by policies and workloads;
//! * [`policy`] — [`SeaPolicy`] (hierarchy placement + rule actions) and
//!   the [`LustrePolicy`] baseline, as simulator placers. The real-bytes
//!   counterpart lives in `vfs::sea` and shares everything but the device
//!   mapping.

pub mod glob;
pub mod policy;
pub mod rules;
pub mod table;

pub use glob::glob_match;
pub use policy::{LustrePolicy, SeaPolicy};
pub use rules::{MgmtMode, PatternList, RuleSet};
pub use table::FileTable;
