//! Sea memory management: rule lists, Table 1 modes, and placement
//! policies (DESIGN.md S8/S10).
//!
//! * [`glob`] — pattern matching for the rule lists;
//! * [`rules`] — `.sea_flushlist` / `.sea_evictlist` / `.sea_prefetchlist`
//!   parsing and the Copy/Remove/Move/Keep mode table;
//! * [`table`] — path ⇄ id interning shared by policies and workloads;
//! * [`engine`] — **the placement decision surface**: the
//!   [`PlacementEngine`] trait (typed `place` / `on_close` /
//!   `on_pressure` / `on_freed` lifecycle hooks returning [`Decision`]s)
//!   and the shipped engines — [`PaperEngine`] (the paper's `p·F` +
//!   Table 1 policy, verbatim) and [`TemperatureEngine`]
//!   (recency/size-heat victims and promotion);
//! * [`policy`] — [`SeaPolicy`] / [`LustrePolicy`], the simulator-side
//!   adapters over the same engines. The real-bytes counterpart lives
//!   in `vfs::sea` and drives an `Arc<dyn PlacementEngine>` end to end.

pub mod engine;
pub mod glob;
pub mod policy;
pub mod rules;
pub mod table;

pub use engine::{
    build_engine, Access, CloseCtx, Decision, EngineCtx, EngineKind, PaperEngine, PfsOnlyEngine,
    PlaceCtx, Placement, PlacementEngine, PressureCtx, Resident, TempTuning, TemperatureEngine,
};
pub use glob::glob_match;
pub use policy::{LustrePolicy, SeaPolicy};
pub use rules::{MgmtMode, PatternList, RuleSet};
pub use table::FileTable;
