//! Interning table mapping logical file paths to numeric [`FileId`]s.
//!
//! The simulator and the Sea policies work with `u64` ids; paths are the
//! user-facing identity (and what the rule globs match). One table per
//! run, shared via `Rc`/`Arc` as needed.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::sim::stack::FileId;

/// Bidirectional path ⇄ id map (thread-safe).
#[derive(Debug, Default)]
pub struct FileTable {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    by_path: HashMap<String, FileId>,
    by_id: Vec<String>,
}

impl FileTable {
    /// Empty table.
    pub fn new() -> FileTable {
        FileTable::default()
    }

    /// Get or assign the id for `path`.
    pub fn intern(&self, path: &str) -> FileId {
        let mut g = self.inner.lock().expect("filetable poisoned");
        if let Some(&id) = g.by_path.get(path) {
            return id;
        }
        let id = g.by_id.len() as FileId;
        g.by_id.push(path.to_string());
        g.by_path.insert(path.to_string(), id);
        id
    }

    /// Look up an existing id (no interning).
    pub fn get(&self, path: &str) -> Option<FileId> {
        self.inner.lock().expect("filetable poisoned").by_path.get(path).copied()
    }

    /// Path of an id (panics on unknown id — ids only come from intern).
    pub fn path(&self, id: FileId) -> String {
        self.inner.lock().expect("filetable poisoned").by_id[id as usize].clone()
    }

    /// Number of interned paths.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("filetable poisoned").by_id.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_idempotent() {
        let t = FileTable::new();
        let a = t.intern("x/y");
        let b = t.intern("x/y");
        assert_eq!(a, b);
        assert_eq!(t.path(a), "x/y");
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn distinct_paths_distinct_ids() {
        let t = FileTable::new();
        let a = t.intern("a");
        let b = t.intern("b");
        assert_ne!(a, b);
        assert_eq!(t.get("a"), Some(a));
        assert_eq!(t.get("c"), None);
    }
}
