//! The TOML-subset parser.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::bytes::parse_bytes;

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Number (all numerics parse as f64).
    Num(f64),
    /// Boolean.
    Bool(bool),
    /// Array of scalars.
    Array(Vec<Value>),
}

impl Value {
    /// As string, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64, if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As u64: a number, or a size string like `"617MiB"`.
    pub fn as_bytes(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 => Some(*n as u64),
            Value::Str(s) => parse_bytes(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: dotted-key → value.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    values: BTreeMap<String, Value>,
}

fn parse_scalar(tok: &str, lineno: usize) -> Result<Value> {
    let t = tok.trim();
    if let Some(stripped) = t.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| Error::Config(format!("line {lineno}: unterminated string")))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    t.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| Error::Config(format!("line {lineno}: bad value {t:?}")))
}

impl Doc {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<Doc> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let lineno = i + 1;
            let line = match raw.find('#') {
                // keep '#' inside quotes
                Some(pos) if !raw[..pos].contains('"') || raw[..pos].matches('"').count() % 2 == 0 => {
                    &raw[..pos]
                }
                _ => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(h) = line.strip_prefix('[') {
                let name = h
                    .strip_suffix(']')
                    .ok_or_else(|| Error::Config(format!("line {lineno}: bad section")))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| Error::Config(format!("line {lineno}: expected key = value")))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{}.{}", section, k.trim())
            };
            let vt = v.trim();
            let value = if let Some(body) = vt.strip_prefix('[') {
                let body = body
                    .strip_suffix(']')
                    .ok_or_else(|| Error::Config(format!("line {lineno}: unterminated array")))?;
                let items: Result<Vec<Value>> = body
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(|s| parse_scalar(s, lineno))
                    .collect();
                Value::Array(items?)
            } else {
                parse_scalar(vt, lineno)?
            };
            values.insert(key, value);
        }
        Ok(Doc { values })
    }

    /// Load and parse a file.
    pub fn load(path: &Path) -> Result<Doc> {
        let text =
            std::fs::read_to_string(path).map_err(|e| Error::io(path, e))?;
        Self::parse(&text)
    }

    /// Raw lookup by dotted key.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    /// Typed getters with defaults.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    /// usize with default.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_f64).map(|v| v as usize).unwrap_or(default)
    }

    /// Byte size with default (numbers or `"617MiB"` strings).
    pub fn bytes_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Value::as_bytes).unwrap_or(default)
    }

    /// Bool with default.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// String with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    /// All keys (diagnostics).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    const SAMPLE: &str = r#"
# cluster description
title = "paper"

[cluster]
nodes = 5
procs_per_node = 6
tmpfs = "126GiB"
dirty_ratio = 0.2
swap = false

[cluster.lustre]
oss = 4
sweep = [1, 2, 4, 8]
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = Doc::parse(SAMPLE).unwrap();
        assert_eq!(d.str_or("title", ""), "paper");
        assert_eq!(d.usize_or("cluster.nodes", 0), 5);
        assert_eq!(d.bytes_or("cluster.tmpfs", 0), 126 * 1024 * MIB);
        assert_eq!(d.f64_or("cluster.dirty_ratio", 0.0), 0.2);
        assert_eq!(d.get("cluster.swap").unwrap().as_bool(), Some(false));
        assert_eq!(d.usize_or("cluster.lustre.oss", 0), 4);
        match d.get("cluster.lustre.sweep").unwrap() {
            Value::Array(a) => assert_eq!(a.len(), 4),
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let d = Doc::parse("").unwrap();
        assert_eq!(d.usize_or("none", 7), 7);
        assert_eq!(d.str_or("none", "dflt"), "dflt");
        assert!(d.bool_or("none", true));
        let d = Doc::parse("flag = false\n").unwrap();
        assert!(!d.bool_or("flag", true));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Doc::parse("[unclosed").is_err());
        assert!(Doc::parse("novalue").is_err());
        assert!(Doc::parse("x = \"unterminated").is_err());
        assert!(Doc::parse("x = [1, 2").is_err());
        assert!(Doc::parse("x = nonsense").is_err());
    }

    #[test]
    fn comments_stripped() {
        let d = Doc::parse("a = 1 # trailing\n# whole line\nb = 2\n").unwrap();
        assert_eq!(d.f64_or("a", 0.0), 1.0);
        assert_eq!(d.f64_or("b", 0.0), 2.0);
    }
}
