//! Configuration: a TOML-subset parser (offline serde/toml substitute)
//! plus typed loaders for cluster specs ([`load_cluster_spec`]) and
//! Sea-mount tuning (`[sea]` → [`tuning_from_doc`]).
//!
//! Supported syntax: `[section]` and `[section.sub]` headers, `key =
//! value` with string/float/integer/bool/size values (`"x"`, `1.5`, `42`,
//! `true`, `"617MiB"` via the size-typed getters), `#` comments. Arrays
//! of scalars: `[1, 2, 3]`. That covers every config this repo ships
//! (`configs/paper_cluster.toml` etc.) without pulling in serde.

mod cluster;
mod parse;
mod sea;

pub use cluster::{load_cluster_spec, spec_from_doc};
pub use parse::{Doc, Value};
pub use sea::{serve_from_doc, tuning_from_doc, ServeOpts};
