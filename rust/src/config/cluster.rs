//! Typed cluster-spec loading from a config document.

use std::path::Path;

use crate::config::parse::Doc;
use crate::error::Result;
use crate::sim::spec::{ClusterSpec, LustreSpec};
use crate::util::{GIB, MIB};

/// Build a [`ClusterSpec`] from a parsed document; missing keys keep the
/// paper defaults, so an empty file IS the paper cluster.
pub fn spec_from_doc(d: &Doc) -> Result<ClusterSpec> {
    let dflt = ClusterSpec::paper_default();
    let ldflt = LustreSpec::default();
    let mib = MIB as f64;
    let spec = ClusterSpec {
        nodes: d.usize_or("cluster.nodes", dflt.nodes),
        procs_per_node: d.usize_or("cluster.procs_per_node", dflt.procs_per_node),
        cores_per_node: d.usize_or("cluster.cores_per_node", dflt.cores_per_node),
        mem_bytes: d.bytes_or("cluster.mem", dflt.mem_bytes),
        tmpfs_bytes: d.bytes_or("cluster.tmpfs", dflt.tmpfs_bytes),
        mem_read_bw: d.f64_or("cluster.mem_read_mibs", dflt.mem_read_bw / mib) * mib,
        mem_write_bw: d.f64_or("cluster.mem_write_mibs", dflt.mem_write_bw / mib) * mib,
        disks_per_node: d.usize_or("cluster.disks_per_node", dflt.disks_per_node),
        disk_bytes: d.bytes_or("cluster.disk", dflt.disk_bytes),
        disk_read_bw: d.f64_or("cluster.disk_read_mibs", dflt.disk_read_bw / mib) * mib,
        disk_write_bw: d.f64_or("cluster.disk_write_mibs", dflt.disk_write_bw / mib) * mib,
        nic_bw: d.f64_or("cluster.nic_gbps", dflt.nic_bw * 8.0 / 1e9) * 1e9 / 8.0,
        dirty_ratio: d.f64_or("cluster.dirty_ratio", dflt.dirty_ratio),
        cacheable_ratio: d.f64_or("cluster.cacheable_ratio", dflt.cacheable_ratio),
        flush_parallelism: d.usize_or("cluster.flush_parallelism", dflt.flush_parallelism),
        lustre: LustreSpec {
            oss_count: d.usize_or("lustre.oss", ldflt.oss_count),
            osts_per_oss: d.usize_or("lustre.osts_per_oss", ldflt.osts_per_oss),
            ost_bytes: d.bytes_or("lustre.ost", ldflt.ost_bytes),
            ost_read_bw: d.f64_or("lustre.ost_read_mibs", ldflt.ost_read_bw / mib) * mib,
            ost_write_bw: d.f64_or("lustre.ost_write_mibs", ldflt.ost_write_bw / mib) * mib,
            server_nic_bw: d.f64_or("lustre.nic_gbps", ldflt.server_nic_bw * 8.0 / 1e9)
                * 1e9
                / 8.0,
            mds_ops_per_sec: d.f64_or("lustre.mds_ops_per_sec", ldflt.mds_ops_per_sec),
            mds_op_latency: d.f64_or("lustre.mds_op_latency", ldflt.mds_op_latency),
            mds_ops_per_open: d.f64_or("lustre.mds_ops_per_open", ldflt.mds_ops_per_open),
            mds_ops_per_mib_written: d.f64_or(
                "lustre.mds_ops_per_mib_written",
                ldflt.mds_ops_per_mib_written,
            ),
            client_dirty_per_ost: d.bytes_or("lustre.client_dirty_per_ost", GIB),
            mds_contention_alpha: d.f64_or(
                "lustre.mds_contention_alpha",
                ldflt.mds_contention_alpha,
            ),
        },
    };
    spec.validate()?;
    Ok(spec)
}

/// Load a cluster spec from a TOML-subset file.
pub fn load_cluster_spec(path: &Path) -> Result<ClusterSpec> {
    spec_from_doc(&Doc::load(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_doc_is_the_paper_cluster() {
        let d = Doc::parse("").unwrap();
        let s = spec_from_doc(&d).unwrap();
        assert_eq!(s.nodes, 5);
        assert_eq!(s.lustre.ost_count(), 44);
        assert!((s.lustre.ost_write_bw / MIB as f64 - 121.0).abs() < 1e-9);
    }

    #[test]
    fn overrides_apply() {
        let d = Doc::parse(
            "[cluster]\nnodes = 8\ntmpfs = \"64GiB\"\ndisk_write_mibs = 200\n\
             [lustre]\noss = 2\nost_write_mibs = 50\n",
        )
        .unwrap();
        let s = spec_from_doc(&d).unwrap();
        assert_eq!(s.nodes, 8);
        assert_eq!(s.tmpfs_bytes, 64 * GIB);
        assert!((s.disk_write_bw / MIB as f64 - 200.0).abs() < 1e-9);
        assert_eq!(s.lustre.oss_count, 2);
        assert!((s.lustre.ost_write_bw / MIB as f64 - 50.0).abs() < 1e-9);
    }

    #[test]
    fn invalid_specs_rejected() {
        let d = Doc::parse("[cluster]\nnodes = 0\n").unwrap();
        assert!(spec_from_doc(&d).is_err());
    }
}
