//! Typed Sea-mount tuning from a config document.
//!
//! The `[sea]` section carries the knobs that used to be compile-time
//! constants (`FLUSH_WORKERS`, `REGISTRY_SHARDS`) plus the striped-PFS
//! scheduling cap, the streamed-transfer shape (`chunk_bytes` — number
//! or a `"4MiB"` size string — and `copy_window`, bounding every
//! management copy at `chunk_bytes × copy_window` memory), the
//! page-cache shape for mapped I/O (`page_bytes` / `page_budget` —
//! mapped views never hold more than `page_budget` resident bytes),
//! the placement-engine selector (`engine = "paper" | "temperature"`),
//! the temperature-engine heat knobs (`heat_decay`,
//! `heat_freq_weight`, `promote_headroom_bytes`), and the cold-tier
//! codec stage (`compress`, `compress_level`, `compress_min_ratio` —
//! see [`crate::vfs::compress`]); missing keys keep
//! the defaults, so an empty file IS the default mount. An
//! *unrecognized* engine token is a hard error, matching the
//! `--engine` CLI flag — silently benchmarking the wrong policy is
//! worse than failing.

use crate::config::parse::Doc;
use crate::error::{Error, Result};
use crate::placement::EngineKind;
use crate::vfs::SeaTuning;

/// Build a [`SeaTuning`] from a parsed document.
pub fn tuning_from_doc(d: &Doc) -> Result<SeaTuning> {
    let dflt = SeaTuning::default();
    let engine_tok = d.str_or("sea.engine", dflt.engine.name());
    let engine = EngineKind::parse(&engine_tok).ok_or_else(|| {
        Error::Config(format!(
            "[sea] engine = {engine_tok:?}: expected \"paper\" | \"temperature\""
        ))
    })?;
    Ok(SeaTuning {
        flush_workers: d.usize_or("sea.flush_workers", dflt.flush_workers),
        registry_shards: d.usize_or("sea.registry_shards", dflt.registry_shards),
        per_member_concurrency: d.usize_or(
            "sea.per_member_concurrency",
            dflt.per_member_concurrency,
        ),
        chunk_bytes: d.bytes_or("sea.chunk_bytes", dflt.chunk_bytes as u64) as usize,
        copy_window: d.usize_or("sea.copy_window", dflt.copy_window),
        page_bytes: d.bytes_or("sea.page_bytes", dflt.page_bytes as u64) as usize,
        page_budget: d.bytes_or("sea.page_budget", dflt.page_budget),
        engine,
        heat_decay: d.f64_or("sea.heat_decay", dflt.heat_decay),
        heat_freq_weight: d.f64_or("sea.heat_freq_weight", dflt.heat_freq_weight),
        promote_headroom_bytes: d.bytes_or(
            "sea.promote_headroom_bytes",
            dflt.promote_headroom_bytes,
        ),
        compress: d.bool_or("sea.compress", dflt.compress),
        compress_level: d.usize_or("sea.compress_level", dflt.compress_level as usize)
            as u8,
        compress_min_ratio: d.f64_or("sea.compress_min_ratio", dflt.compress_min_ratio),
    })
}

/// The `[serve]` section: `sea serve` daemon knobs. Missing keys keep
/// the defaults; the socket path from `--socket` wins over the file.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeOpts {
    /// Socket path from `[serve] socket = "..."`, when present.
    pub socket: Option<String>,
    /// Reap clients silent for this many seconds between frames.
    pub idle_timeout_secs: u64,
    /// Lease dup'd read fds to clients over `SCM_RIGHTS`
    /// (`[serve] lease_fds = false` or `--no-leases` disables the
    /// zero-copy read path).
    pub lease_fds: bool,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts { socket: None, idle_timeout_secs: 300, lease_fds: true }
    }
}

/// Build [`ServeOpts`] from a parsed document.
pub fn serve_from_doc(d: &Doc) -> Result<ServeOpts> {
    let dflt = ServeOpts::default();
    let socket = {
        let s = d.str_or("serve.socket", "");
        if s.is_empty() {
            None
        } else {
            Some(s)
        }
    };
    Ok(ServeOpts {
        socket,
        idle_timeout_secs: d
            .usize_or("serve.idle_timeout_secs", dflt.idle_timeout_secs as usize)
            as u64,
        lease_fds: d.bool_or("serve.lease_fds", dflt.lease_fds),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_doc_is_the_default_tuning() {
        let d = Doc::parse("").unwrap();
        assert_eq!(tuning_from_doc(&d).unwrap(), SeaTuning::default());
    }

    #[test]
    fn overrides_apply() {
        let d = Doc::parse(
            "[sea]\nflush_workers = 8\nregistry_shards = 32\nper_member_concurrency = 1\n\
             chunk_bytes = \"4MiB\"\ncopy_window = 3\nengine = \"temperature\"\n\
             page_bytes = \"16KiB\"\npage_budget = \"8MiB\"\n\
             heat_decay = 0.9\nheat_freq_weight = 2.5\npromote_headroom_bytes = \"1MiB\"\n\
             compress = true\ncompress_level = 6\ncompress_min_ratio = 0.8\n",
        )
        .unwrap();
        let t = tuning_from_doc(&d).unwrap();
        assert_eq!(t.flush_workers, 8);
        assert_eq!(t.registry_shards, 32);
        assert_eq!(t.per_member_concurrency, 1);
        assert_eq!(t.chunk_bytes, 4 * 1024 * 1024, "size strings parse");
        assert_eq!(t.copy_window, 3);
        assert_eq!(t.engine, EngineKind::Temperature);
        assert_eq!(t.page_bytes, 16 * 1024, "page-cache knobs parse");
        assert_eq!(t.page_budget, 8 * 1024 * 1024);
        assert_eq!(t.heat_decay, 0.9, "temperature knobs parse");
        assert_eq!(t.heat_freq_weight, 2.5);
        assert_eq!(t.promote_headroom_bytes, 1024 * 1024);
        assert!(t.compress, "codec knobs parse");
        assert_eq!(t.compress_level, 6);
        assert_eq!(t.compress_min_ratio, 0.8);
    }

    #[test]
    fn chunk_bytes_accepts_plain_numbers() {
        let d = Doc::parse("[sea]\nchunk_bytes = 65536\n").unwrap();
        assert_eq!(tuning_from_doc(&d).unwrap().chunk_bytes, 65536);
    }

    #[test]
    fn unknown_engine_token_is_rejected() {
        let d = Doc::parse("[sea]\nengine = \"bogus\"\n").unwrap();
        assert!(matches!(tuning_from_doc(&d), Err(Error::Config(_))));
    }

    #[test]
    fn serve_section_defaults_and_overrides() {
        let d = Doc::parse("").unwrap();
        assert_eq!(serve_from_doc(&d).unwrap(), ServeOpts::default());
        let d = Doc::parse(
            "[serve]\nsocket = \"/tmp/sea.sock\"\nidle_timeout_secs = 30\n\
             lease_fds = false\n",
        )
        .unwrap();
        let s = serve_from_doc(&d).unwrap();
        assert_eq!(s.socket.as_deref(), Some("/tmp/sea.sock"));
        assert_eq!(s.idle_timeout_secs, 30);
        assert!(!s.lease_fds, "[serve] lease_fds = false must parse");
    }
}
