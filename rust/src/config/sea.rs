//! Typed Sea-mount tuning from a config document.
//!
//! The `[sea]` section carries the knobs that used to be compile-time
//! constants (`FLUSH_WORKERS`, `REGISTRY_SHARDS`) plus the striped-PFS
//! scheduling cap; missing keys keep the defaults, so an empty file IS
//! the default mount.

use crate::config::parse::Doc;
use crate::vfs::SeaTuning;

/// Build a [`SeaTuning`] from a parsed document.
pub fn tuning_from_doc(d: &Doc) -> SeaTuning {
    let dflt = SeaTuning::default();
    SeaTuning {
        flush_workers: d.usize_or("sea.flush_workers", dflt.flush_workers),
        registry_shards: d.usize_or("sea.registry_shards", dflt.registry_shards),
        per_member_concurrency: d.usize_or(
            "sea.per_member_concurrency",
            dflt.per_member_concurrency,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_doc_is_the_default_tuning() {
        let d = Doc::parse("").unwrap();
        assert_eq!(tuning_from_doc(&d), SeaTuning::default());
    }

    #[test]
    fn overrides_apply() {
        let d = Doc::parse(
            "[sea]\nflush_workers = 8\nregistry_shards = 32\nper_member_concurrency = 1\n",
        )
        .unwrap();
        let t = tuning_from_doc(&d);
        assert_eq!(t.flush_workers, 8);
        assert_eq!(t.registry_shards, 32);
        assert_eq!(t.per_member_concurrency, 1);
    }
}
