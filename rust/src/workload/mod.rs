//! Workload definitions: the paper's incrementation application
//! (Algorithm 1), a multi-stage variant, and dataset generators.
//!
//! * [`incrementation`] — program builder shared by the simulator and the
//!   real-bytes runner: per-process instruction lists with the canonical
//!   file naming that the Sea rule lists match against.
//! * [`dataset`] — real-bytes chunk files (f32, canonical `(rows, 256)`
//!   geometry) for the end-to-end examples, plus the BigBrain-scale
//!   descriptor used by the simulator.

pub mod dataset;
pub mod incrementation;

pub use incrementation::{stream_block, IncrementationSpec, SimPrograms, StridePlan};
