//! Dataset generation: real-bytes chunk files for the end-to-end runs
//! and the BigBrain-scale descriptor used by the simulator.
//!
//! Real chunks are raw little-endian f32 arrays in the canonical
//! `(rows, 256)` geometry the AOT artifacts were lowered for; values are
//! integral (0..=1000) so `n` increments stay exactly representable and
//! the PJRT `block_stats` integrity check is bit-exact.

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::Rng;

/// Description of a generated dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Directory the blocks live in.
    pub dir: PathBuf,
    /// Block file paths in index order.
    pub blocks: Vec<PathBuf>,
    /// Elements per block.
    pub elems: usize,
    /// Constant base value of block `i` is `base_of(i)`.
    pub seed: u64,
}

impl Dataset {
    /// The base value every element of block `i` is initialized to.
    /// Kept uniform per block so integrity after `n` increments is a
    /// three-number check (min == max == base + n) on device.
    pub fn base_of(&self, i: usize) -> f32 {
        let mut s = self.seed.wrapping_add(i as u64);
        (crate::util::rng::splitmix64(&mut s) % 1000) as f32
    }

    /// Bytes per block.
    pub fn block_bytes(&self) -> u64 {
        (self.elems * 4) as u64
    }
}

/// Generate `blocks` files of `elems` f32 elements each under `dir`.
///
/// Returns the dataset descriptor. Existing files of the right size are
/// reused (idempotent, like a cached download of BigBrain tiles).
pub fn generate(dir: &Path, blocks: usize, elems: usize, seed: u64) -> Result<Dataset> {
    fs::create_dir_all(dir).map_err(|e| Error::io(dir, e))?;
    let ds = Dataset {
        dir: dir.to_path_buf(),
        blocks: (0..blocks).map(|i| dir.join(format!("block_{i:04}.dat"))).collect(),
        elems,
        seed,
    };
    let mut buf: Vec<u8> = Vec::new();
    for (i, path) in ds.blocks.iter().enumerate() {
        let want = ds.block_bytes();
        if let Ok(md) = fs::metadata(path) {
            if md.len() == want {
                continue; // already generated
            }
        }
        let base = ds.base_of(i);
        buf.clear();
        buf.reserve(want as usize);
        for _ in 0..elems {
            buf.extend_from_slice(&base.to_le_bytes());
        }
        fs::write(path, &buf).map_err(|e| Error::io(path, e))?;
    }
    Ok(ds)
}

/// Generate a *varied* block (non-uniform values) — used by tests that
/// need realistic content rather than integrity-checkable uniformity.
pub fn generate_varied_block(path: &Path, elems: usize, seed: u64) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir).map_err(|e| Error::io(dir, e))?;
    }
    let mut rng = Rng::new(seed);
    let mut buf = Vec::with_capacity(elems * 4);
    for _ in 0..elems {
        let v = (rng.below(2048) as f32) - 1024.0;
        buf.extend_from_slice(&v.to_le_bytes());
    }
    fs::write(path, &buf).map_err(|e| Error::io(path, e))
}

/// Decode little-endian f32 bytes into `out` (length-checked: `raw`
/// must be exactly `4 * out.len()` bytes). Shared by the streaming
/// pipeline paths so stride buffers are reused instead of reallocated.
pub fn bytes_to_f32_into(raw: &[u8], out: &mut [f32]) -> Result<()> {
    if raw.len() != out.len() * 4 {
        return Err(Error::Integrity(format!(
            "stride has {} bytes, expected {}",
            raw.len(),
            out.len() * 4
        )));
    }
    for (c, v) in raw.chunks_exact(4).zip(out.iter_mut()) {
        *v = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(())
}

/// Encode f32s little-endian into `out` (`out` must be `4 * data.len()`
/// bytes). Panics on length mismatch — callers own both buffers.
pub fn f32_to_bytes_into(data: &[f32], out: &mut [u8]) {
    assert_eq!(out.len(), data.len() * 4, "encode buffer length mismatch");
    for (v, c) in data.iter().zip(out.chunks_exact_mut(4)) {
        c.copy_from_slice(&v.to_le_bytes());
    }
}

/// Read a block file as f32s (length-checked against `elems`).
pub fn read_block(path: &Path, elems: usize) -> Result<Vec<f32>> {
    let bytes = fs::read(path).map_err(|e| Error::io(path, e))?;
    if bytes.len() != elems * 4 {
        return Err(Error::Integrity(format!(
            "block {path:?}: {} bytes, expected {}",
            bytes.len(),
            elems * 4
        )));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a block of f32s.
pub fn write_block(path: &Path, data: &[f32]) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir).map_err(|e| Error::io(dir, e))?;
    }
    let mut buf = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    fs::write(path, &buf).map_err(|e| Error::io(path, e))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("sea_dataset_{name}"))
    }

    #[test]
    fn generate_and_read_round_trip() {
        let dir = tmp("rt");
        let ds = generate(&dir, 3, 1024, 7).unwrap();
        assert_eq!(ds.blocks.len(), 3);
        for (i, b) in ds.blocks.iter().enumerate() {
            let data = read_block(b, 1024).unwrap();
            assert_eq!(data.len(), 1024);
            assert!(data.iter().all(|&x| x == ds.base_of(i)));
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn generation_is_idempotent() {
        let dir = tmp("idem");
        let ds1 = generate(&dir, 2, 256, 1).unwrap();
        let mtime = fs::metadata(&ds1.blocks[0]).unwrap().modified().unwrap();
        let _ds2 = generate(&dir, 2, 256, 1).unwrap();
        let mtime2 = fs::metadata(&ds1.blocks[0]).unwrap().modified().unwrap();
        assert_eq!(mtime, mtime2, "existing blocks untouched");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn base_values_are_integral_and_bounded() {
        let dir = tmp("base");
        let ds = generate(&dir, 1, 16, 99).unwrap();
        for i in 0..100 {
            let b = ds.base_of(i);
            assert!(b >= 0.0 && b < 1000.0);
            assert_eq!(b.fract(), 0.0);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_block_rejects_bad_length() {
        let dir = tmp("bad");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.dat");
        fs::write(&p, [0u8; 10]).unwrap();
        assert!(read_block(&p, 4).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn f32_byte_conversions_round_trip() {
        let vals: Vec<f32> = (0..64).map(|i| i as f32 - 32.0).collect();
        let mut raw = vec![0u8; 64 * 4];
        f32_to_bytes_into(&vals, &mut raw);
        let mut back = vec![0f32; 64];
        bytes_to_f32_into(&raw, &mut back).unwrap();
        assert_eq!(vals, back);
        // length mismatch is an integrity error
        assert!(bytes_to_f32_into(&raw[..8], &mut back).is_err());
    }

    #[test]
    fn write_read_varied() {
        let dir = tmp("varied");
        let p = dir.join("v.dat");
        generate_varied_block(&p, 512, 3).unwrap();
        let d = read_block(&p, 512).unwrap();
        assert_eq!(d.len(), 512);
        let distinct: std::collections::HashSet<i64> =
            d.iter().map(|&x| x as i64).collect();
        assert!(distinct.len() > 10, "values vary");
        let _ = fs::remove_dir_all(&dir);
    }
}
