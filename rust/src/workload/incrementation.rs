//! Algorithm 1 — the incrementation application.
//!
//! ```text
//! foreach chunk ∈ C:
//!     read chunk from Lustre
//!     for i ∈ [1, n]:
//!         chunk ← chunk + 1
//!         save chunk to fs
//! ```
//!
//! Each iteration's output is written to the evaluated file system and
//! the next iteration re-reads it (task-per-iteration structure, which is
//! what gives the paper its intermediate-data volume `D_m`: condition 4
//! varies `n` precisely to scale `D_m`). Final-iteration files are named
//! `*_final.dat` so the Sea in-memory rule `**_final.dat` (flush + evict
//! last iteration only, §3.5.1) can match them.

use std::sync::Arc;

use crate::placement::FileTable;
use crate::sim::app::Instr;
use crate::sim::stack::FileId;

/// Parameters of one incrementation run.
#[derive(Debug, Clone)]
pub struct IncrementationSpec {
    /// Number of image chunks (paper: 1000).
    pub blocks: usize,
    /// Bytes per chunk (paper: 617 MiB).
    pub file_size: u64,
    /// Increment rounds `n` (paper default: 10).
    pub iterations: usize,
    /// CPU-seconds charged per chunk-iteration (calibrated from the PJRT
    /// hot path; ≈0 reproduces the paper's pure data-intensive regime).
    pub compute_per_iter: f64,
    /// Re-read the previous iteration's file (task-per-iteration, the
    /// paper's structure). `false` models a single task holding the chunk
    /// in memory (no `D_m` reads).
    pub read_back: bool,
}

impl IncrementationSpec {
    /// The paper's fixed conditions: 1000 × 617 MiB, 10 iterations.
    pub fn paper_default() -> IncrementationSpec {
        IncrementationSpec {
            blocks: 1000,
            file_size: 617 * crate::util::MIB,
            iterations: 10,
            compute_per_iter: 0.0,
            read_back: true,
        }
    }

    /// Canonical input path of block `b`.
    pub fn input_path(b: usize) -> String {
        format!("bigbrain/block_{b:04}.dat")
    }

    /// Canonical output path of block `b` after iteration `i` (1-based);
    /// the last iteration gets the `_final` suffix the rules match.
    pub fn iter_path(&self, b: usize, i: usize) -> String {
        if i == self.iterations {
            format!("derived/block_{b:04}_final.dat")
        } else {
            format!("derived/block_{b:04}_iter{i:02}.dat")
        }
    }

    /// The glob matching final-iteration files (for in-memory rules).
    pub fn final_glob() -> &'static str {
        "**_final.dat"
    }

    /// Total volumes for the analytic model.
    pub fn volume(&self) -> crate::model::WorkloadVolume {
        crate::model::WorkloadVolume::incrementation(
            self.blocks,
            self.file_size,
            self.iterations,
        )
    }
}

/// Simulation programs: per-process instruction lists plus the input
/// files to pre-register on Lustre.
#[derive(Debug)]
pub struct SimPrograms {
    /// `programs[k]` runs on node `k % nodes`.
    pub programs: Vec<Vec<Instr>>,
    /// `(file, size)` of every input block, to register on Lustre.
    pub inputs: Vec<(FileId, u64)>,
}

impl IncrementationSpec {
    /// Build per-process programs for `nodes × procs_per_node` workers.
    ///
    /// Blocks are dealt round-robin over all processes (the paper fixes
    /// equal work per process by construction). File ids are interned in
    /// `table` so placement rules can see the paths.
    pub fn build_programs(
        &self,
        nodes: usize,
        procs_per_node: usize,
        table: &Arc<FileTable>,
    ) -> SimPrograms {
        let nprocs = nodes * procs_per_node;
        assert!(nprocs > 0, "need at least one process");
        let mut programs: Vec<Vec<Instr>> = vec![Vec::new(); nprocs];
        let mut inputs = Vec::with_capacity(self.blocks);
        for b in 0..self.blocks {
            let input = table.intern(&Self::input_path(b));
            inputs.push((input, self.file_size));
            let prog = &mut programs[b % nprocs];
            // read chunk from Lustre
            prog.push(Instr::Read(input));
            let mut prev: Option<FileId> = None;
            for i in 1..=self.iterations {
                if let Some(p) = prev {
                    if self.read_back {
                        prog.push(Instr::Read(p));
                    }
                }
                if self.compute_per_iter > 0.0 {
                    prog.push(Instr::Compute { seconds: self.compute_per_iter });
                }
                let out = table.intern(&self.iter_path(b, i));
                prog.push(Instr::Write { file: out, size: self.file_size });
                prev = Some(out);
            }
        }
        SimPrograms { programs, inputs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    fn spec() -> IncrementationSpec {
        IncrementationSpec {
            blocks: 6,
            file_size: MIB,
            iterations: 3,
            compute_per_iter: 0.5,
            read_back: true,
        }
    }

    #[test]
    fn paths_and_final_glob() {
        let s = spec();
        assert_eq!(s.iter_path(2, 1), "derived/block_0002_iter01.dat");
        assert_eq!(s.iter_path(2, 3), "derived/block_0002_final.dat");
        assert!(crate::placement::glob_match(
            IncrementationSpec::final_glob(),
            &s.iter_path(0, 3)
        ));
        assert!(!crate::placement::glob_match(
            IncrementationSpec::final_glob(),
            &s.iter_path(0, 2)
        ));
    }

    #[test]
    fn programs_cover_all_blocks_evenly() {
        let s = spec();
        let table = Arc::new(FileTable::new());
        let p = s.build_programs(2, 2, &table); // 4 procs, 6 blocks
        assert_eq!(p.programs.len(), 4);
        assert_eq!(p.inputs.len(), 6);
        let reads: usize = p
            .programs
            .iter()
            .flat_map(|pr| pr.iter())
            .filter(|i| matches!(i, Instr::Read(_)))
            .count();
        let writes: usize = p
            .programs
            .iter()
            .flat_map(|pr| pr.iter())
            .filter(|i| matches!(i, Instr::Write { .. }))
            .count();
        let computes: usize = p
            .programs
            .iter()
            .flat_map(|pr| pr.iter())
            .filter(|i| matches!(i, Instr::Compute { .. }))
            .count();
        // per block: 1 input read + 2 read-backs = 3 reads, 3 writes, 3 computes
        assert_eq!(reads, 6 * 3);
        assert_eq!(writes, 6 * 3);
        assert_eq!(computes, 6 * 3);
        // even split: 6 blocks over 4 procs -> 2,2,1,1
        let mut lens: Vec<usize> = p
            .programs
            .iter()
            .map(|pr| pr.iter().filter(|i| matches!(i, Instr::Write { .. })).count())
            .collect();
        lens.sort_unstable();
        assert_eq!(lens, vec![3, 3, 6, 6]); // writes per proc: blocks*(iters)
    }

    #[test]
    fn no_read_back_skips_intermediate_reads() {
        let mut s = spec();
        s.read_back = false;
        let table = Arc::new(FileTable::new());
        let p = s.build_programs(1, 1, &table);
        let reads: usize = p.programs[0]
            .iter()
            .filter(|i| matches!(i, Instr::Read(_)))
            .count();
        assert_eq!(reads, 6, "only the input reads remain");
    }

    #[test]
    fn volume_matches_model() {
        let s = spec();
        let v = s.volume();
        assert_eq!(v.d_i, 6.0 * MIB as f64);
        assert_eq!(v.d_m, 2.0 * 6.0 * MIB as f64);
        assert_eq!(v.d_f, 6.0 * MIB as f64);
    }
}
