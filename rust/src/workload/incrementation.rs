//! Algorithm 1 — the incrementation application.
//!
//! ```text
//! foreach chunk ∈ C:
//!     read chunk from Lustre
//!     for i ∈ [1, n]:
//!         chunk ← chunk + 1
//!         save chunk to fs
//! ```
//!
//! Each iteration's output is written to the evaluated file system and
//! the next iteration re-reads it (task-per-iteration structure, which is
//! what gives the paper its intermediate-data volume `D_m`: condition 4
//! varies `n` precisely to scale `D_m`). Final-iteration files are named
//! `*_final.dat` so the Sea in-memory rule `**_final.dat` (flush + evict
//! last iteration only, §3.5.1) can match them.

use std::path::Path;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::placement::FileTable;
use crate::sim::app::Instr;
use crate::sim::stack::FileId;
use crate::vfs::{OpenMode, Vfs};

/// Parameters of one incrementation run.
#[derive(Debug, Clone)]
pub struct IncrementationSpec {
    /// Number of image chunks (paper: 1000).
    pub blocks: usize,
    /// Bytes per chunk (paper: 617 MiB).
    pub file_size: u64,
    /// Increment rounds `n` (paper default: 10).
    pub iterations: usize,
    /// CPU-seconds charged per chunk-iteration (calibrated from the PJRT
    /// hot path; ≈0 reproduces the paper's pure data-intensive regime).
    pub compute_per_iter: f64,
    /// Re-read the previous iteration's file (task-per-iteration, the
    /// paper's structure). `false` models a single task holding the chunk
    /// in memory (no `D_m` reads).
    pub read_back: bool,
}

impl IncrementationSpec {
    /// The paper's fixed conditions: 1000 × 617 MiB, 10 iterations.
    pub fn paper_default() -> IncrementationSpec {
        IncrementationSpec {
            blocks: 1000,
            file_size: 617 * crate::util::MIB,
            iterations: 10,
            compute_per_iter: 0.0,
            read_back: true,
        }
    }

    /// Canonical input path of block `b`.
    pub fn input_path(b: usize) -> String {
        format!("bigbrain/block_{b:04}.dat")
    }

    /// Canonical output path of block `b` after iteration `i` (1-based);
    /// the last iteration gets the `_final` suffix the rules match.
    pub fn iter_path(&self, b: usize, i: usize) -> String {
        if i == self.iterations {
            format!("derived/block_{b:04}_final.dat")
        } else {
            format!("derived/block_{b:04}_iter{i:02}.dat")
        }
    }

    /// The glob matching final-iteration files (for in-memory rules).
    pub fn final_glob() -> &'static str {
        "**_final.dat"
    }

    /// Total volumes for the analytic model.
    pub fn volume(&self) -> crate::model::WorkloadVolume {
        crate::model::WorkloadVolume::incrementation(
            self.blocks,
            self.file_size,
            self.iterations,
        )
    }
}

/// Fixed-stride streaming plan over one block file.
///
/// Chunks stream through a buffer of exactly one stride: peak memory is
/// `stride_bytes()`, never the whole block, which is what lets the
/// real-bytes pipeline process blocks far larger than RAM-per-worker
/// (the regime where the paper's Table 2 wins materialize).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StridePlan {
    /// f32 elements per stride (the bounded buffer size).
    pub stride_elems: usize,
    /// f32 elements in the whole block.
    pub block_elems: usize,
}

impl StridePlan {
    /// Plan a block of `block_elems` in strides of `stride_elems`
    /// (which must divide the block evenly).
    pub fn new(block_elems: usize, stride_elems: usize) -> Result<StridePlan> {
        if stride_elems == 0 || block_elems == 0 || block_elems % stride_elems != 0 {
            return Err(Error::InvalidArg(format!(
                "stride {stride_elems} must be nonzero and divide block {block_elems}"
            )));
        }
        Ok(StridePlan { stride_elems, block_elems })
    }

    /// Number of strides in the block.
    pub fn strides(&self) -> usize {
        self.block_elems / self.stride_elems
    }

    /// Bytes per stride (f32).
    pub fn stride_bytes(&self) -> usize {
        self.stride_elems * 4
    }

    /// Bytes in the whole block.
    pub fn block_bytes(&self) -> u64 {
        (self.block_elems * 4) as u64
    }

    /// Byte offset of stride `k`.
    pub fn offset(&self, k: usize) -> u64 {
        (k * self.stride_bytes()) as u64
    }
}

/// Stream `src` through `f` into `dst`, one stride at a time, over any
/// [`Vfs`]: every stride is one `pread` + one `pwrite` at the same
/// offset, so peak buffer memory is a single stride. `f` receives the
/// stride index and its f32s, mutating them in place. Returns total
/// bytes processed.
pub fn stream_block<F>(
    vfs: &dyn Vfs,
    src: &Path,
    dst: &Path,
    plan: &StridePlan,
    mut f: F,
) -> Result<u64>
where
    F: FnMut(usize, &mut [f32]) -> Result<()>,
{
    let mut src_f = vfs.open(src, OpenMode::Read)?;
    let mut dst_f = vfs.open(dst, OpenMode::Write)?;
    let mut raw = vec![0u8; plan.stride_bytes()];
    let mut elems = vec![0f32; plan.stride_elems];
    for k in 0..plan.strides() {
        let off = plan.offset(k);
        src_f.pread_exact(&mut raw, off)?;
        super::dataset::bytes_to_f32_into(&raw, &mut elems)?;
        f(k, &mut elems)?;
        super::dataset::f32_to_bytes_into(&elems, &mut raw);
        dst_f.pwrite_all(&raw, off)?;
    }
    Ok(plan.block_bytes())
}

/// Simulation programs: per-process instruction lists plus the input
/// files to pre-register on Lustre.
#[derive(Debug)]
pub struct SimPrograms {
    /// `programs[k]` runs on node `k % nodes`.
    pub programs: Vec<Vec<Instr>>,
    /// `(file, size)` of every input block, to register on Lustre.
    pub inputs: Vec<(FileId, u64)>,
}

impl IncrementationSpec {
    /// Build per-process programs for `nodes × procs_per_node` workers.
    ///
    /// Blocks are dealt round-robin over all processes (the paper fixes
    /// equal work per process by construction). File ids are interned in
    /// `table` so placement rules can see the paths.
    pub fn build_programs(
        &self,
        nodes: usize,
        procs_per_node: usize,
        table: &Arc<FileTable>,
    ) -> SimPrograms {
        let nprocs = nodes * procs_per_node;
        assert!(nprocs > 0, "need at least one process");
        let mut programs: Vec<Vec<Instr>> = vec![Vec::new(); nprocs];
        let mut inputs = Vec::with_capacity(self.blocks);
        for b in 0..self.blocks {
            let input = table.intern(&Self::input_path(b));
            inputs.push((input, self.file_size));
            let prog = &mut programs[b % nprocs];
            // read chunk from Lustre
            prog.push(Instr::Read(input));
            let mut prev: Option<FileId> = None;
            for i in 1..=self.iterations {
                if let Some(p) = prev {
                    if self.read_back {
                        prog.push(Instr::Read(p));
                    }
                }
                if self.compute_per_iter > 0.0 {
                    prog.push(Instr::Compute { seconds: self.compute_per_iter });
                }
                let out = table.intern(&self.iter_path(b, i));
                prog.push(Instr::Write { file: out, size: self.file_size });
                prev = Some(out);
            }
        }
        SimPrograms { programs, inputs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;

    fn spec() -> IncrementationSpec {
        IncrementationSpec {
            blocks: 6,
            file_size: MIB,
            iterations: 3,
            compute_per_iter: 0.5,
            read_back: true,
        }
    }

    #[test]
    fn paths_and_final_glob() {
        let s = spec();
        assert_eq!(s.iter_path(2, 1), "derived/block_0002_iter01.dat");
        assert_eq!(s.iter_path(2, 3), "derived/block_0002_final.dat");
        assert!(crate::placement::glob_match(
            IncrementationSpec::final_glob(),
            &s.iter_path(0, 3)
        ));
        assert!(!crate::placement::glob_match(
            IncrementationSpec::final_glob(),
            &s.iter_path(0, 2)
        ));
    }

    #[test]
    fn programs_cover_all_blocks_evenly() {
        let s = spec();
        let table = Arc::new(FileTable::new());
        let p = s.build_programs(2, 2, &table); // 4 procs, 6 blocks
        assert_eq!(p.programs.len(), 4);
        assert_eq!(p.inputs.len(), 6);
        let reads: usize = p
            .programs
            .iter()
            .flat_map(|pr| pr.iter())
            .filter(|i| matches!(i, Instr::Read(_)))
            .count();
        let writes: usize = p
            .programs
            .iter()
            .flat_map(|pr| pr.iter())
            .filter(|i| matches!(i, Instr::Write { .. }))
            .count();
        let computes: usize = p
            .programs
            .iter()
            .flat_map(|pr| pr.iter())
            .filter(|i| matches!(i, Instr::Compute { .. }))
            .count();
        // per block: 1 input read + 2 read-backs = 3 reads, 3 writes, 3 computes
        assert_eq!(reads, 6 * 3);
        assert_eq!(writes, 6 * 3);
        assert_eq!(computes, 6 * 3);
        // even split: 6 blocks over 4 procs -> 2,2,1,1
        let mut lens: Vec<usize> = p
            .programs
            .iter()
            .map(|pr| pr.iter().filter(|i| matches!(i, Instr::Write { .. })).count())
            .collect();
        lens.sort_unstable();
        assert_eq!(lens, vec![3, 3, 6, 6]); // writes per proc: blocks*(iters)
    }

    #[test]
    fn no_read_back_skips_intermediate_reads() {
        let mut s = spec();
        s.read_back = false;
        let table = Arc::new(FileTable::new());
        let p = s.build_programs(1, 1, &table);
        let reads: usize = p.programs[0]
            .iter()
            .filter(|i| matches!(i, Instr::Read(_)))
            .count();
        assert_eq!(reads, 6, "only the input reads remain");
    }

    #[test]
    fn stride_plan_validates_and_addresses() {
        assert!(StridePlan::new(0, 4).is_err());
        assert!(StridePlan::new(8, 0).is_err());
        assert!(StridePlan::new(10, 4).is_err(), "must divide evenly");
        let p = StridePlan::new(8192, 1024).unwrap();
        assert_eq!(p.strides(), 8);
        assert_eq!(p.stride_bytes(), 4096);
        assert_eq!(p.block_bytes(), 32768);
        assert_eq!(p.offset(3), 3 * 4096);
    }

    #[test]
    fn stream_block_peak_buffer_is_one_stride() {
        use std::path::Path;
        use std::sync::atomic::{AtomicUsize, Ordering};
        use crate::error::Result;
        use crate::vfs::{OpenMode, RealFs, Vfs, VfsFile};

        /// Vfs decorator recording the largest single I/O request, which
        /// bounds the streaming path's peak buffer memory.
        struct SpyFs {
            inner: RealFs,
            max_req: Arc<AtomicUsize>,
        }
        struct SpyFile {
            inner: Box<dyn VfsFile>,
            max_req: Arc<AtomicUsize>,
        }
        impl VfsFile for SpyFile {
            fn pread(&mut self, buf: &mut [u8], off: u64) -> Result<usize> {
                self.max_req.fetch_max(buf.len(), Ordering::Relaxed);
                self.inner.pread(buf, off)
            }
            fn pwrite(&mut self, data: &[u8], off: u64) -> Result<usize> {
                self.max_req.fetch_max(data.len(), Ordering::Relaxed);
                self.inner.pwrite(data, off)
            }
            fn set_len(&mut self, len: u64) -> Result<()> {
                self.inner.set_len(len)
            }
            fn fsync(&mut self) -> Result<()> {
                self.inner.fsync()
            }
            fn len(&self) -> Result<u64> {
                self.inner.len()
            }
        }
        impl Vfs for SpyFs {
            fn open(&self, path: &Path, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
                Ok(Box::new(SpyFile {
                    inner: self.inner.open(path, mode)?,
                    max_req: self.max_req.clone(),
                }))
            }
            fn unlink(&self, path: &Path) -> Result<()> {
                self.inner.unlink(path)
            }
            fn exists(&self, path: &Path) -> bool {
                self.inner.exists(path)
            }
            fn size(&self, path: &Path) -> Result<u64> {
                self.inner.size(path)
            }
            fn rename(&self, from: &Path, to: &Path) -> Result<()> {
                self.inner.rename(from, to)
            }
            fn readdir(&self, path: &Path) -> Result<Vec<String>> {
                self.inner.readdir(path)
            }
        }

        let dir = std::env::temp_dir().join(format!(
            "sea_stream_{}_{}",
            std::process::id(),
            line!()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let max_req = Arc::new(AtomicUsize::new(0));
        let vfs = SpyFs {
            inner: RealFs::new(&dir).unwrap(),
            max_req: max_req.clone(),
        };
        // an 8-stride block: 8192 elements processed 1024 at a time
        let plan = StridePlan::new(8192, 1024).unwrap();
        let input: Vec<f32> = (0..8192).map(|i| (i % 97) as f32).collect();
        let mut raw = vec![0u8; input.len() * 4];
        crate::workload::dataset::f32_to_bytes_into(&input, &mut raw);
        vfs.write(Path::new("src.dat"), &raw).unwrap();
        max_req.store(0, Ordering::Relaxed); // ignore the setup write

        let mut seen = 0usize;
        let bytes = stream_block(
            &vfs,
            Path::new("src.dat"),
            Path::new("dst.dat"),
            &plan,
            |k, chunk| {
                assert_eq!(chunk.len(), plan.stride_elems);
                seen = seen.max(k + 1);
                for v in chunk.iter_mut() {
                    *v += 1.0;
                }
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(bytes, plan.block_bytes());
        assert_eq!(seen, plan.strides(), "every stride visited");
        // peak request (and therefore peak buffer) is exactly one stride
        assert_eq!(max_req.load(Ordering::Relaxed), plan.stride_bytes());

        let out_raw = vfs.read(Path::new("dst.dat")).unwrap();
        let mut out = vec![0f32; 8192];
        crate::workload::dataset::bytes_to_f32_into(&out_raw, &mut out).unwrap();
        assert!(out.iter().zip(&input).all(|(o, i)| *o == i + 1.0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn volume_matches_model() {
        let s = spec();
        let v = s.volume();
        assert_eq!(v.d_i, 6.0 * MIB as f64);
        assert_eq!(v.d_m, 2.0 * 6.0 * MIB as f64);
        assert_eq!(v.d_f, 6.0 * MIB as f64);
    }
}
