//! Mini property-based testing kit (offline proptest substitute).
//!
//! Deterministic: every case derives from a base seed, failures report
//! the case seed so a run can be reproduced exactly. A failing case is
//! *minimized* by retrying with shrunken generator bounds (halving),
//! which in practice localizes size-dependent failures well enough for
//! the invariants this repo checks (space accounting, routing, batching,
//! conservation).
//!
//! ```text
//! use sea::testkit::Config;
//! sea::testkit::check("reverse twice is identity", Config::default(), |g| {
//!     let xs = g.vec_u64(0..100, 0..1000);
//!     let mut r = xs.clone();
//!     r.reverse();
//!     r.reverse();
//!     assert_eq!(xs, r);
//! });
//! ```

use crate::util::Rng;

/// Property-check configuration.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (case `i` uses `seed + i`).
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0x5EA_5EED }
    }
}

/// Per-case generator handle.
pub struct Gen {
    rng: Rng,
    /// Shrink factor in (0, 1]; generators scale their ranges by it.
    shrink: f64,
    /// Log of generated values (printed on failure).
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64, shrink: f64) -> Gen {
        Gen { rng: Rng::new(seed), shrink, log: Vec::new() }
    }

    fn scale(&self, hi: u64, lo: u64) -> u64 {
        let span = hi.saturating_sub(lo).max(1);
        lo + ((span as f64 * self.shrink).ceil() as u64).max(1)
    }

    /// u64 in [range.start, range.end) (shrunk toward the low end).
    pub fn u64(&mut self, range: std::ops::Range<u64>) -> u64 {
        assert!(range.start < range.end);
        let hi = self.scale(range.end, range.start).min(range.end);
        let v = range.start + self.rng.below(hi - range.start);
        self.log.push(format!("u64={v}"));
        v
    }

    /// usize in range.
    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    /// f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.range_f64(lo, lo + (hi - lo) * self.shrink);
        self.log.push(format!("f64={v:.4}"));
        v
    }

    /// bool with probability `p` of true.
    pub fn bool(&mut self, p: f64) -> bool {
        let v = self.rng.f64() < p;
        self.log.push(format!("bool={v}"));
        v
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.index(xs.len());
        self.log.push(format!("pick#{i}"));
        &xs[i]
    }

    /// Vec of u64s with random length in `len` and values in `vals`.
    pub fn vec_u64(
        &mut self,
        len: std::ops::Range<usize>,
        vals: std::ops::Range<u64>,
    ) -> Vec<u64> {
        let n = self.usize(len.start.max(0)..len.end.max(len.start + 1));
        (0..n).map(|_| self.rng.below(vals.end - vals.start) + vals.start).collect()
    }

    /// Raw RNG access (for domain-specific generation).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cfg.cases` random cases; panics (with seed and a
/// minimized reproduction hint) on the first failure.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cfg: Config, prop: F) {
    for i in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(i as u64);
        let failed = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, 1.0);
            prop(&mut g);
        })
        .is_err();
        if failed {
            // shrink: halve the generator scale until it passes, report
            // the smallest failing scale
            let mut failing_shrink = 1.0;
            let mut s = 0.5;
            while s > 0.01 {
                let fails = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, s);
                    prop(&mut g);
                })
                .is_err();
                if fails {
                    failing_shrink = s;
                    s /= 2.0;
                } else {
                    break;
                }
            }
            // re-run at the minimized scale to produce the panic message
            // and the generator log
            let result = std::panic::catch_unwind(|| {
                let mut g = Gen::new(seed, failing_shrink);
                prop(&mut g);
                g
            });
            panic!(
                "property {name:?} failed: case {i}, seed {seed:#x}, \
                 minimized shrink {failing_shrink}; rerun with \
                 Config {{ cases: 1, seed: {seed:#x} }} ({:?})",
                result.err().map(|e| {
                    e.downcast_ref::<String>()
                        .cloned()
                        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_default()
                })
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", Config { cases: 16, ..Config::default() }, |g| {
            let a = g.u64(0..1000);
            let b = g.u64(0..1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports() {
        check("always fails above 10", Config { cases: 32, ..Config::default() }, |g| {
            let v = g.u64(0..100);
            assert!(v <= 10, "v = {v}");
        });
    }

    #[test]
    fn generators_respect_ranges() {
        check("ranges", Config { cases: 64, ..Config::default() }, |g| {
            let v = g.u64(10..20);
            assert!((10..20).contains(&v));
            let x = g.f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&x));
            let n = g.usize(1..5);
            assert!((1..5).contains(&n));
            let xs = g.vec_u64(0..8, 0..100);
            assert!(xs.len() < 8);
            assert!(xs.iter().all(|&x| x < 100));
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Gen::new(9, 1.0);
        let mut b = Gen::new(9, 1.0);
        for _ in 0..32 {
            assert_eq!(a.u64(0..1_000_000), b.u64(0..1_000_000));
        }
    }
}
