//! Small shared utilities: deterministic PRNG, byte-size formatting,
//! descriptive statistics, ASCII plotting and CSV emission.
//!
//! These exist because the offline crate cache has no `rand`, `serde` or
//! plotting crates (DESIGN.md §2, offline substitutions).

pub mod ascii_plot;
pub mod bytes;
pub mod csv;
pub mod rng;
pub mod stats;

pub use bytes::{fmt_bytes, GIB, KIB, MIB};
pub use rng::Rng;
pub use stats::Summary;
