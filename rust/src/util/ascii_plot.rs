//! Minimal ASCII line-plotting for figure reproduction in a terminal.
//!
//! Each paper figure is emitted both as CSV (exact numbers) and as an
//! ASCII chart (shape at a glance). Series are drawn with distinct glyphs;
//! shaded model-bound regions are rendered with `:` fill between the bound
//! lines of the matching series.

/// A named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    /// Legend label.
    pub label: String,
    /// Plot glyph.
    pub glyph: char,
    /// Data points (x ascending not required but typical).
    pub points: Vec<(f64, f64)>,
}

/// A shaded vertical band between two y-series sharing x coordinates.
#[derive(Debug, Clone)]
pub struct Band {
    /// Legend label.
    pub label: String,
    /// Fill glyph.
    pub glyph: char,
    /// (x, y_low, y_high) triples.
    pub points: Vec<(f64, f64, f64)>,
}

/// Plot geometry and labels.
#[derive(Debug, Clone)]
pub struct Plot {
    /// Chart title.
    pub title: String,
    /// X-axis label.
    pub xlabel: String,
    /// Y-axis label.
    pub ylabel: String,
    /// Character columns of the plotting area.
    pub width: usize,
    /// Character rows of the plotting area.
    pub height: usize,
    series: Vec<Series>,
    bands: Vec<Band>,
}

impl Plot {
    /// New empty plot with default 72x20 plotting area.
    pub fn new(title: impl Into<String>, xlabel: impl Into<String>, ylabel: impl Into<String>) -> Self {
        Plot {
            title: title.into(),
            xlabel: xlabel.into(),
            ylabel: ylabel.into(),
            width: 72,
            height: 20,
            series: Vec::new(),
            bands: Vec::new(),
        }
    }

    /// Add a line series.
    pub fn series(mut self, label: impl Into<String>, glyph: char, points: Vec<(f64, f64)>) -> Self {
        self.series.push(Series { label: label.into(), glyph, points });
        self
    }

    /// Add a shaded bound band.
    pub fn band(mut self, label: impl Into<String>, glyph: char, points: Vec<(f64, f64, f64)>) -> Self {
        self.bands.push(Band { label: label.into(), glyph, points });
        self
    }

    fn extents(&self) -> (f64, f64, f64, f64) {
        let mut xmin = f64::INFINITY;
        let mut xmax = f64::NEG_INFINITY;
        let mut ymin: f64 = 0.0; // makespans start at 0
        let mut ymax = f64::NEG_INFINITY;
        for s in &self.series {
            for &(x, y) in &s.points {
                xmin = xmin.min(x);
                xmax = xmax.max(x);
                ymin = ymin.min(y);
                ymax = ymax.max(y);
            }
        }
        for b in &self.bands {
            for &(x, lo, hi) in &b.points {
                xmin = xmin.min(x);
                xmax = xmax.max(x);
                ymin = ymin.min(lo);
                ymax = ymax.max(hi);
            }
        }
        if !xmin.is_finite() {
            (0.0, 1.0, 0.0, 1.0)
        } else {
            let xpad = if xmax > xmin { 0.0 } else { 0.5 };
            let ypad = if ymax > ymin { (ymax - ymin) * 0.05 } else { 0.5 };
            (xmin - xpad, xmax + xpad, ymin, ymax + ypad)
        }
    }

    /// Render to a multi-line string.
    pub fn render(&self) -> String {
        let (xmin, xmax, ymin, ymax) = self.extents();
        let (w, h) = (self.width, self.height);
        let mut grid = vec![vec![' '; w]; h];
        let to_col = |x: f64| -> usize {
            let t = if xmax > xmin { (x - xmin) / (xmax - xmin) } else { 0.5 };
            ((t * (w - 1) as f64).round() as isize).clamp(0, w as isize - 1) as usize
        };
        let to_row = |y: f64| -> usize {
            let t = if ymax > ymin { (y - ymin) / (ymax - ymin) } else { 0.5 };
            let r = ((1.0 - t) * (h - 1) as f64).round() as isize;
            r.clamp(0, h as isize - 1) as usize
        };

        // bands first (underneath)
        for b in &self.bands {
            for &(x, lo, hi) in &b.points {
                if !(lo.is_finite() && hi.is_finite()) {
                    continue;
                }
                let c = to_col(x);
                let (r_hi, r_lo) = (to_row(hi), to_row(lo));
                for r in r_hi..=r_lo {
                    grid[r][c] = b.glyph;
                }
            }
        }
        // line series with linear interpolation between points
        for s in &self.series {
            let mut pts = s.points.clone();
            pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for pair in pts.windows(2) {
                let (x0, y0) = pair[0];
                let (x1, y1) = pair[1];
                let (c0, c1) = (to_col(x0), to_col(x1));
                for c in c0..=c1 {
                    let t = if c1 > c0 { (c - c0) as f64 / (c1 - c0) as f64 } else { 0.0 };
                    let y = y0 + t * (y1 - y0);
                    grid[to_row(y)][c] = s.glyph;
                }
            }
            if pts.len() == 1 {
                grid[to_row(pts[0].1)][to_col(pts[0].0)] = s.glyph;
            }
        }

        let mut out = String::new();
        out.push_str(&format!("  {}\n", self.title));
        out.push_str(&format!("  {} (y)\n", self.ylabel));
        for (i, row) in grid.iter().enumerate() {
            let yval = ymax - (ymax - ymin) * i as f64 / (h - 1) as f64;
            out.push_str(&format!("  {yval:>9.1} |"));
            out.extend(row.iter());
            out.push('\n');
        }
        out.push_str(&format!("  {:>9} +{}\n", "", "-".repeat(w)));
        out.push_str(&format!(
            "  {:>9}  {:<w2$.1}{:>w3$.1}   ({})\n",
            "",
            xmin,
            xmax,
            self.xlabel,
            w2 = w / 2,
            w3 = w - w / 2 - 3,
        ));
        for s in &self.series {
            out.push_str(&format!("    {} {}\n", s.glyph, s.label));
        }
        for b in &self.bands {
            out.push_str(&format!("    {} {}\n", b.glyph, b.label));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_series_and_bands() {
        let p = Plot::new("t", "x", "y")
            .series("lustre", 'L', vec![(1.0, 10.0), (2.0, 20.0), (3.0, 15.0)])
            .band("model", ':', vec![(1.0, 5.0, 12.0), (2.0, 8.0, 25.0)]);
        let s = p.render();
        assert!(s.contains('L'));
        assert!(s.contains(':'));
        assert!(s.contains("lustre"));
    }

    #[test]
    fn empty_plot_is_fine() {
        let p = Plot::new("empty", "x", "y");
        let s = p.render();
        assert!(s.contains("empty"));
    }

    #[test]
    fn single_point_series() {
        let p = Plot::new("one", "x", "y").series("s", '*', vec![(1.0, 1.0)]);
        assert!(p.render().contains('*'));
    }
}
