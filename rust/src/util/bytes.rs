//! Byte-size constants and human-readable formatting.

/// One kibibyte.
pub const KIB: u64 = 1024;
/// One mebibyte.
pub const MIB: u64 = 1024 * KIB;
/// One gibibyte.
pub const GIB: u64 = 1024 * MIB;
/// One tebibyte.
pub const TIB: u64 = 1024 * GIB;

/// Format a byte count with binary units (e.g. `617.0 MiB`).
pub fn fmt_bytes(n: u64) -> String {
    let nf = n as f64;
    if n >= TIB {
        format!("{:.2} TiB", nf / TIB as f64)
    } else if n >= GIB {
        format!("{:.2} GiB", nf / GIB as f64)
    } else if n >= MIB {
        format!("{:.1} MiB", nf / MIB as f64)
    } else if n >= KIB {
        format!("{:.1} KiB", nf / KIB as f64)
    } else {
        format!("{n} B")
    }
}

/// Format a bandwidth (bytes/second) as `MiB/s`.
pub fn fmt_bw(bytes_per_s: f64) -> String {
    format!("{:.1} MiB/s", bytes_per_s / MIB as f64)
}

/// Parse sizes like `617MiB`, `4 GiB`, `128`, `1.5GiB` (used by config).
pub fn parse_bytes(s: &str) -> Option<u64> {
    let t = s.trim();
    let (num, unit) = match t.find(|c: char| c.is_ascii_alphabetic()) {
        Some(i) => (t[..i].trim(), t[i..].trim()),
        None => (t, ""),
    };
    let v: f64 = num.parse().ok()?;
    let mult = match unit.to_ascii_lowercase().as_str() {
        "" | "b" => 1.0,
        "k" | "kib" | "kb" => KIB as f64,
        "m" | "mib" | "mb" => MIB as f64,
        "g" | "gib" | "gb" => GIB as f64,
        "t" | "tib" | "tb" => TIB as f64,
        _ => return None,
    };
    if v < 0.0 {
        return None;
    }
    Some((v * mult).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_units() {
        assert_eq!(parse_bytes("617MiB"), Some(617 * MIB));
        assert_eq!(parse_bytes("1.5 GiB"), Some(3 * GIB / 2));
        assert_eq!(parse_bytes("128"), Some(128));
        assert_eq!(parse_bytes("10 TB"), Some(10 * TIB));
        assert_eq!(parse_bytes("4k"), Some(4 * KIB));
        assert_eq!(parse_bytes("-1"), None);
        assert_eq!(parse_bytes("xMiB"), None);
        assert_eq!(parse_bytes("1 parsec"), None);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(0), "0 B");
        assert_eq!(fmt_bytes(617 * MIB), "617.0 MiB");
        assert_eq!(fmt_bytes(603 * GIB), "603.00 GiB");
        assert_eq!(fmt_bw(2560.0 * MIB as f64), "2560.0 MiB/s");
    }
}
