//! Descriptive statistics over benchmark / experiment samples.

/// Summary statistics of a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub std: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Median (linear interpolation).
    pub median: f64,
    /// 95th percentile (linear interpolation; collapses toward `max`
    /// for small sample counts).
    pub p95: f64,
    /// 99th percentile (linear interpolation).
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty slice.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        })
    }
}

/// Percentile (0–100) of an already-sorted slice, linearly interpolated.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[3.0]).unwrap();
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.median, 2.5);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn tail_percentiles() {
        // 1..=100: p95 interpolates at rank 94.05, p99 at rank 98.01
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs).unwrap();
        assert!((s.p95 - 95.05).abs() < 1e-9, "{}", s.p95);
        assert!((s.p99 - 99.01).abs() < 1e-9, "{}", s.p99);
        // tiny n: tail percentiles collapse toward the max
        let s = Summary::of(&[3.0]).unwrap();
        assert_eq!(s.p95, 3.0);
        assert_eq!(s.p99, 3.0);
    }

    #[test]
    fn percentiles() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 5.0);
        assert_eq!(percentile_sorted(&v, 50.0), 3.0);
        assert_eq!(percentile_sorted(&v, 25.0), 2.0);
    }
}
