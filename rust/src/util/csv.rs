//! Tiny CSV writer (offline substitute for a csv crate).
//!
//! Quotes fields containing separators/quotes/newlines per RFC 4180; all
//! experiment/bench outputs go through this so `results/*.csv` are loadable
//! by pandas/gnuplot downstream.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use crate::error::{Error, Result};

/// In-memory CSV table with a fixed header.
#[derive(Debug, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

fn escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

impl Csv {
    /// Create a table with the given column names.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Csv { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row; panics if the arity doesn't match the header (bug).
    pub fn row<S: Into<String>>(&mut self, fields: Vec<S>) -> &mut Self {
        let fields: Vec<String> = fields.into_iter().map(Into::into).collect();
        assert_eq!(
            fields.len(),
            self.header.len(),
            "csv row arity {} != header {}",
            fields.len(),
            self.header.len()
        );
        self.rows.push(fields);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no data rows have been added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Serialize to a CSV string (header + rows, `\n` line endings).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(|h| escape(h)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|f| escape(f)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn write_to(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir).map_err(|e| Error::io(dir, e))?;
        }
        fs::write(path, self.to_string()).map_err(|e| Error::io(path, e))
    }
}

/// Format an f64 for CSV with enough precision for re-analysis.
pub fn f(x: f64) -> String {
    format!("{x:.6}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_csv() {
        let mut c = Csv::new(vec!["a", "b"]);
        c.row(vec!["1", "2"]).row(vec!["x,y", "he said \"hi\""]);
        let s = c.to_string();
        assert_eq!(s.lines().next(), Some("a,b"));
        assert!(s.contains("\"x,y\""));
        assert!(s.contains("\"he said \"\"hi\"\"\""));
        assert_eq!(c.len(), 2);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut c = Csv::new(vec!["a", "b"]);
        c.row(vec!["only-one"]);
    }

    #[test]
    fn writes_file() {
        let dir = std::env::temp_dir().join("sea_csv_test");
        let path = dir.join("t.csv");
        let mut c = Csv::new(vec!["x"]);
        c.row(vec![f(1.5)]);
        c.write_to(&path).unwrap();
        let got = std::fs::read_to_string(&path).unwrap();
        assert!(got.contains("1.500000"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
