//! Deterministic pseudo-random number generation (offline `rand` substitute).
//!
//! [`Rng`] is xoshiro256++ seeded via SplitMix64 — the standard pairing:
//! SplitMix64 decorrelates small consecutive seeds, xoshiro256++ provides
//! the stream. All simulator and test randomness flows through this so runs
//! are reproducible from a single `u64` seed.

/// SplitMix64 step; used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, n) (Lemire-style, unbiased enough for sim use).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // 128-bit multiply trick
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Fisher–Yates shuffle (used by Sea's same-tier random device pick,
    /// paper §4.1 "selected by Sea via a random shuffling").
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (stable split).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn range_f64_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let x = r.range_f64(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&x));
        }
    }
}
