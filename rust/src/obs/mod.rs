//! Observability: latency histograms and a flight recorder.
//!
//! Two dependency-free halves:
//!
//! - [`hist`] — lock-free log₂-bucketed latency histograms, one per
//!   [`Metric`] (op class × layer), recorded via [`Timer`] at the I/O
//!   call sites in `vfs/sea.rs`, `vfs/pages.rs`, `vfs/mover.rs`,
//!   `vfs/remote.rs`, and `serve/mod.rs`. `sea stat` renders them as
//!   `lat:` p50/p95/p99/max lines, and [`ObsSnapshot`] travels in the
//!   wire `Counters` reply (protocol ≥ 3) so `sea stat --connect`
//!   shows daemon-side latencies.
//! - [`trace`] — a bounded per-thread ring of structured events
//!   (placement decisions, flush/spill/promote lifecycles, page-cache
//!   eviction/write-back, lease grant/revoke), dumpable as Chrome
//!   trace-event JSON via `sea run --trace FILE` or `SEA_TRACE=path`.
//!
//! Histogram recording defaults **on** (set `SEA_OBS=0` to disable; a
//! disabled [`Timer::start`] is one relaxed atomic load and no clock
//! read). The flight recorder defaults **off** and is armed by
//! `--trace`/`SEA_TRACE`. The bench suite asserts the enabled-vs-
//! disabled pread overhead stays ≤ 5%.

pub mod hist;
pub mod trace;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use hist::{Hist, HistSnapshot};

/// I/O operation classes timed per backend tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoOp {
    Pread,
    Pwrite,
    Open,
    Fsync,
}

/// Everything the histogram layer can time: four I/O op classes per
/// backend layer (burst tiers 0/1, deeper tiers folded into `TierN`,
/// and the PFS), plus one metric per cross-cutting path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Metric {
    PreadTier0 = 0,
    PreadTier1 = 1,
    PreadTierN = 2,
    PreadPfs = 3,
    PwriteTier0 = 4,
    PwriteTier1 = 5,
    PwriteTierN = 6,
    PwritePfs = 7,
    OpenTier0 = 8,
    OpenTier1 = 9,
    OpenTierN = 10,
    OpenPfs = 11,
    FsyncTier0 = 12,
    FsyncTier1 = 13,
    FsyncTierN = 14,
    FsyncPfs = 15,
    /// Page-cache miss: filling one page from the backing file.
    PageFaultFill = 16,
    /// One DataMover chunk written to the destination.
    MoverChunk = 17,
    /// Client-observed wire round-trip (send → matching reply).
    WireRtt = 18,
    /// Daemon-side per-request service time (decode → reply queued).
    DaemonRequest = 19,
}

/// Number of metrics ([`Metric::ALL`] length, histogram registry size).
pub const NMETRICS: usize = 20;

impl Metric {
    /// Every metric, index-ordered (`ALL[m.index()] == m`).
    pub const ALL: [Metric; NMETRICS] = [
        Metric::PreadTier0,
        Metric::PreadTier1,
        Metric::PreadTierN,
        Metric::PreadPfs,
        Metric::PwriteTier0,
        Metric::PwriteTier1,
        Metric::PwriteTierN,
        Metric::PwritePfs,
        Metric::OpenTier0,
        Metric::OpenTier1,
        Metric::OpenTierN,
        Metric::OpenPfs,
        Metric::FsyncTier0,
        Metric::FsyncTier1,
        Metric::FsyncTierN,
        Metric::FsyncPfs,
        Metric::PageFaultFill,
        Metric::MoverChunk,
        Metric::WireRtt,
        Metric::DaemonRequest,
    ];

    /// Dense index into the histogram registry.
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Inverse of [`Metric::index`]; `None` for out-of-range (e.g. a
    /// newer peer's metric arriving over the wire).
    pub fn from_index(i: usize) -> Option<Metric> {
        Metric::ALL.get(i).copied()
    }

    /// The metric for `op` against a device of tier `tier` (`None` =
    /// the PFS). Tiers ≥ 2 fold into `TierN`.
    pub fn io(op: IoOp, tier: Option<u8>) -> Metric {
        let t = match tier {
            Some(0) => 0,
            Some(1) => 1,
            Some(_) => 2,
            None => 3,
        };
        Metric::ALL[match op {
            IoOp::Pread => 0,
            IoOp::Pwrite => 4,
            IoOp::Open => 8,
            IoOp::Fsync => 12,
        } + t]
    }

    /// Stable display name (also used as the wire-independent key in
    /// `sea stat` output).
    pub fn name(self) -> &'static str {
        match self {
            Metric::PreadTier0 => "pread.tier0",
            Metric::PreadTier1 => "pread.tier1",
            Metric::PreadTierN => "pread.tierN",
            Metric::PreadPfs => "pread.pfs",
            Metric::PwriteTier0 => "pwrite.tier0",
            Metric::PwriteTier1 => "pwrite.tier1",
            Metric::PwriteTierN => "pwrite.tierN",
            Metric::PwritePfs => "pwrite.pfs",
            Metric::OpenTier0 => "open.tier0",
            Metric::OpenTier1 => "open.tier1",
            Metric::OpenTierN => "open.tierN",
            Metric::OpenPfs => "open.pfs",
            Metric::FsyncTier0 => "fsync.tier0",
            Metric::FsyncTier1 => "fsync.tier1",
            Metric::FsyncTierN => "fsync.tierN",
            Metric::FsyncPfs => "fsync.pfs",
            Metric::PageFaultFill => "page.fill",
            Metric::MoverChunk => "mover.chunk",
            Metric::WireRtt => "wire.rtt",
            Metric::DaemonRequest => "daemon.req",
        }
    }
}

// Histogram gate: 0 = uninitialised, 1 = off, 2 = on. Initialised
// lazily from SEA_OBS (default on; "0"/"off" disable) so the library
// needs no init call; benches flip it with `set_enabled`.
static STATE: AtomicU8 = AtomicU8::new(0);

fn init_state() -> u8 {
    let on = match std::env::var("SEA_OBS") {
        Ok(v) => !matches!(v.trim(), "0" | "off" | "false"),
        Err(_) => true,
    };
    let s = if on { 2 } else { 1 };
    // racing initialisers agree (same env), so a plain store is fine
    STATE.store(s, Ordering::Relaxed);
    s
}

/// Are latency histograms recording? One relaxed load after first use.
#[inline]
pub fn enabled() -> bool {
    let s = STATE.load(Ordering::Relaxed);
    if s == 0 {
        return init_state() == 2;
    }
    s == 2
}

/// Force histogram recording on/off (overrides `SEA_OBS`; used by the
/// bench overhead sweep and tests).
pub fn set_enabled(on: bool) {
    STATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

/// Serializes tests that toggle the process-wide gates (`STATE` here,
/// the trace `ENABLED` flag) or that depend on them staying on for a
/// stretch — without it, a parallel test's brief off-window silently
/// drops another test's samples.
#[cfg(test)]
pub(crate) fn test_gate() -> std::sync::MutexGuard<'static, ()> {
    static GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn hists() -> &'static Vec<Hist> {
    static HISTS: OnceLock<Vec<Hist>> = OnceLock::new();
    HISTS.get_or_init(|| (0..NMETRICS).map(|_| Hist::new()).collect())
}

/// Record one latency sample (ns) against `m`, if enabled.
#[inline]
pub fn record(m: Metric, nanos: u64) {
    if enabled() {
        hists()[m.index()].record(nanos);
    }
}

/// A started latency measurement. [`Timer::start`] reads the clock
/// only when histograms are enabled; [`Timer::stop`] records the
/// elapsed time against a metric chosen at stop time (call sites often
/// only know the tier after the op completes).
#[must_use]
pub struct Timer {
    t0: Option<Instant>,
}

impl Timer {
    /// Start timing (no-op, no clock read, when disabled).
    #[inline]
    pub fn start() -> Timer {
        Timer { t0: enabled().then(Instant::now) }
    }

    /// Is this timer live? Lets call sites skip key bookkeeping that
    /// only matters if `stop` will record.
    #[inline]
    pub fn armed(&self) -> bool {
        self.t0.is_some()
    }

    /// Record the elapsed nanoseconds against `m`.
    #[inline]
    pub fn stop(self, m: Metric) {
        if let Some(t0) = self.t0 {
            hists()[m.index()].record(t0.elapsed().as_nanos() as u64);
        }
    }
}

/// A point-in-time copy of every non-empty histogram, keyed by metric
/// index. Mergeable (client + daemon), wire-encodable, and renderable
/// as the `lat:` block in `sea stat`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ObsSnapshot {
    /// `(metric index, histogram)` pairs, ascending by index. Indices
    /// outside [`Metric::ALL`] (a newer peer) are preserved but
    /// rendered under a numeric key.
    pub metrics: Vec<(u8, HistSnapshot)>,
}

/// Snapshot every non-empty histogram.
pub fn snapshot() -> ObsSnapshot {
    let mut metrics = Vec::new();
    for m in Metric::ALL {
        let s = hists()[m.index()].snapshot();
        if !s.is_empty() {
            metrics.push((m.index() as u8, s));
        }
    }
    ObsSnapshot { metrics }
}

/// Reset every histogram (tests and `--watch` interval deltas are
/// snapshot-diff based; this is for bench isolation).
pub fn reset() {
    for h in hists() {
        h.reset();
    }
}

impl ObsSnapshot {
    /// No samples anywhere?
    pub fn is_empty(&self) -> bool {
        self.metrics.iter().all(|(_, h)| h.is_empty())
    }

    /// Sum of sample counts across all metrics.
    pub fn total_count(&self) -> u64 {
        self.metrics.iter().map(|(_, h)| h.count).sum()
    }

    /// The histogram for `m`, if any samples were recorded.
    pub fn get(&self, m: Metric) -> Option<&HistSnapshot> {
        let idx = m.index() as u8;
        self.metrics.iter().find(|(i, _)| *i == idx).map(|(_, h)| h)
    }

    /// Fold `other`'s samples into `self` (e.g. daemon + local).
    pub fn merge(&mut self, other: &ObsSnapshot) {
        for (idx, h) in &other.metrics {
            match self.metrics.iter_mut().find(|(i, _)| i == idx) {
                Some((_, mine)) => mine.merge(h),
                None => {
                    let at = self
                        .metrics
                        .iter()
                        .position(|(i, _)| i > idx)
                        .unwrap_or(self.metrics.len());
                    self.metrics.insert(at, (*idx, h.clone()));
                }
            }
        }
    }

    /// Per-metric deltas since `prev` (an earlier snapshot of the same
    /// registry — `sea stat --watch` intervals). Metrics absent from
    /// `prev` pass through whole; metrics whose delta is empty are
    /// dropped, so rendering a quiet interval prints nothing.
    pub fn diff(&self, prev: &ObsSnapshot) -> ObsSnapshot {
        let mut metrics = Vec::new();
        for (idx, h) in &self.metrics {
            let d = match prev.metrics.iter().find(|(i, _)| i == idx) {
                Some((_, p)) => h.diff(p),
                None => h.clone(),
            };
            if !d.is_empty() {
                metrics.push((*idx, d));
            }
        }
        ObsSnapshot { metrics }
    }

    /// Render the `lat:` block for `sea stat`: one line per non-empty
    /// metric with count and p50/p95/p99/max. Empty string if no
    /// samples.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (idx, h) in &self.metrics {
            if h.is_empty() {
                continue;
            }
            let name = match Metric::from_index(*idx as usize) {
                Some(m) => m.name().to_string(),
                None => format!("metric#{idx}"),
            };
            out.push_str(&format!(
                "lat    : {:<12} n {:>9}  p50 {:>8}  p95 {:>8}  p99 {:>8}  max {:>8}\n",
                name,
                h.count,
                fmt_ns(h.p50()),
                fmt_ns(h.p95()),
                fmt_ns(h.p99()),
                fmt_ns(h.max),
            ));
        }
        out
    }
}

/// Human-scale duration: `512ns`, `42.0us`, `1.50ms`, `2.10s`.
pub fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}us", ns as f64 / 1_000.0)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1_000_000.0)
    } else {
        format!("{:.2}s", ns as f64 / 1_000_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_indices_are_dense_and_invertible() {
        for (i, m) in Metric::ALL.iter().enumerate() {
            assert_eq!(m.index(), i);
            assert_eq!(Metric::from_index(i), Some(*m));
        }
        assert_eq!(Metric::from_index(NMETRICS), None);
        // names are unique (they key `sea stat` lines)
        let mut names: Vec<_> = Metric::ALL.iter().map(|m| m.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), NMETRICS);
    }

    #[test]
    fn io_metric_maps_op_and_tier() {
        assert_eq!(Metric::io(IoOp::Pread, Some(0)), Metric::PreadTier0);
        assert_eq!(Metric::io(IoOp::Pread, Some(1)), Metric::PreadTier1);
        assert_eq!(Metric::io(IoOp::Pread, Some(2)), Metric::PreadTierN);
        assert_eq!(Metric::io(IoOp::Pread, Some(7)), Metric::PreadTierN);
        assert_eq!(Metric::io(IoOp::Pread, None), Metric::PreadPfs);
        assert_eq!(Metric::io(IoOp::Pwrite, Some(0)), Metric::PwriteTier0);
        assert_eq!(Metric::io(IoOp::Open, None), Metric::OpenPfs);
        assert_eq!(Metric::io(IoOp::Fsync, Some(1)), Metric::FsyncTier1);
    }

    // The histogram registry is process-global, so tests assert via
    // deltas on metrics the I/O paths never touch concurrently, or on
    // snapshot/merge/render structure only.

    #[test]
    fn timer_records_into_the_registry_when_enabled() {
        // Other lib tests exercise instrumented paths concurrently, so
        // counts only ever grow — assert deltas as lower bounds.
        let _gate = test_gate();
        set_enabled(true);
        let before = hists()[Metric::WireRtt.index()].count();
        let t = Timer::start();
        assert!(t.armed());
        t.stop(Metric::WireRtt);
        assert!(hists()[Metric::WireRtt.index()].count() > before);

        set_enabled(false);
        let t = Timer::start();
        assert!(!t.armed(), "disabled timer must not read the clock");
        t.stop(Metric::WireRtt); // records nothing: no start instant
        set_enabled(true);
    }

    fn snap_of(vals: &[u64]) -> HistSnapshot {
        let h = Hist::new();
        for &v in vals {
            h.record(v);
        }
        h.snapshot()
    }

    #[test]
    fn snapshot_merge_and_render_shape() {
        let mut a = ObsSnapshot::default();
        assert!(a.is_empty());
        assert_eq!(a.render(), "");

        let h1 = snap_of(&[100, 200, 400, 100_000]);
        a.metrics.push((Metric::PreadTier0.index() as u8, h1.clone()));

        let mut b = ObsSnapshot::default();
        b.metrics.push((Metric::PreadTier0.index() as u8, h1.clone()));
        b.metrics.push((Metric::DaemonRequest.index() as u8, h1.clone()));

        a.merge(&b);
        assert_eq!(a.get(Metric::PreadTier0).unwrap().count, 8);
        assert_eq!(a.get(Metric::DaemonRequest).unwrap().count, 4);
        assert!(a.get(Metric::MoverChunk).is_none());
        assert_eq!(a.total_count(), 12);
        // merge keeps indices sorted
        assert!(a.metrics.windows(2).all(|w| w[0].0 < w[1].0));

        let r = a.render();
        assert!(r.contains("pread.tier0"), "{r}");
        assert!(r.contains("daemon.req"), "{r}");
        assert!(r.contains("p50"), "{r}");
        assert!(r.contains("p99"), "{r}");
        assert_eq!(r.lines().count(), 2, "{r}");
        assert!(r.lines().all(|l| l.starts_with("lat    : ")), "{r}");
    }

    #[test]
    fn snapshot_diff_keeps_only_changed_metrics() {
        let mut prev = ObsSnapshot::default();
        prev.metrics.push((Metric::PreadTier0.index() as u8, snap_of(&[100, 200])));
        prev.metrics.push((Metric::WireRtt.index() as u8, snap_of(&[500])));

        let mut cur = ObsSnapshot::default();
        cur.metrics
            .push((Metric::PreadTier0.index() as u8, snap_of(&[100, 200, 400, 800])));
        cur.metrics.push((Metric::WireRtt.index() as u8, snap_of(&[500])));
        cur.metrics.push((Metric::MoverChunk.index() as u8, snap_of(&[9000])));

        let d = cur.diff(&prev);
        // quiet WireRtt dropped; grown PreadTier0 keeps the delta;
        // brand-new MoverChunk passes through whole
        assert!(d.get(Metric::WireRtt).is_none());
        assert_eq!(d.get(Metric::PreadTier0).unwrap().count, 2);
        assert_eq!(d.get(Metric::MoverChunk).unwrap().count, 1);
        assert_eq!(d.total_count(), 3);
    }

    #[test]
    fn unknown_metric_indices_render_under_a_numeric_key() {
        let s = ObsSnapshot { metrics: vec![(200, snap_of(&[5000]))] };
        let r = s.render();
        assert!(r.contains("metric#200"), "{r}");
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(512), "512ns");
        assert_eq!(fmt_ns(42_000), "42.0us");
        assert_eq!(fmt_ns(1_500_000), "1.50ms");
        assert_eq!(fmt_ns(2_100_000_000), "2.10s");
    }
}
