//! Lock-free log₂-bucketed latency histograms.
//!
//! A [`Hist`] is 64 atomic `u64` buckets — bucket `i` counts samples in
//! `[2^i, 2^(i+1))` nanoseconds (bucket 0 additionally absorbs 0) —
//! plus running count/sum/max. Recording is four relaxed atomic RMWs
//! and never takes a lock, so call sites on hot I/O paths stay cheap
//! and any number of threads record concurrently.
//!
//! [`Hist::snapshot`] freezes a [`HistSnapshot`]: a plain-value copy
//! that merges with others (client + daemon sides of one op class) and
//! estimates quantiles by cumulative-rank walk with linear
//! interpolation inside the bucket. The estimate is bounded by the
//! bucket that holds the true order statistic: it never leaves
//! `[2^i, 2^(i+1))`, so relative error is at most 2x (tighter near the
//! top, where the observed max clamps the last bucket).

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log₂ buckets (one per bit of a `u64` nanosecond value).
pub const BUCKETS: usize = 64;

/// Log₂ bucket index of `v`: `floor(log2(v))`, with 0 mapping to
/// bucket 0.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    (63 - (v | 1).leading_zeros()) as usize
}

/// Inclusive lower bound of bucket `i` (`0` for bucket 0).
#[inline]
pub fn bucket_lo(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << i
    }
}

/// Inclusive upper bound of bucket `i`.
#[inline]
pub fn bucket_hi(i: usize) -> u64 {
    if i >= 63 {
        u64::MAX
    } else {
        (1u64 << (i + 1)) - 1
    }
}

/// A concurrent log₂ latency histogram (values in nanoseconds).
pub struct Hist {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

impl Hist {
    /// An empty histogram.
    pub fn new() -> Hist {
        Hist {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample. Wait-free: four relaxed atomic RMWs.
    #[inline]
    pub fn record(&self, nanos: u64) {
        self.buckets[bucket_of(nanos)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(nanos, Ordering::Relaxed);
        self.max.fetch_max(nanos, Ordering::Relaxed);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Freeze a point-in-time copy. Concurrent recorders may land
    /// between the field loads, so a snapshot taken mid-burst can be
    /// off by the in-flight samples — fine for reporting, never torn
    /// per field.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut s = HistSnapshot::default();
        for (i, b) in self.buckets.iter().enumerate() {
            s.buckets[i] = b.load(Ordering::Relaxed);
        }
        s.count = self.count.load(Ordering::Relaxed);
        s.sum = self.sum.load(Ordering::Relaxed);
        s.max = self.max.load(Ordering::Relaxed);
        s
    }

    /// Reset every bucket and gauge to zero (bench interval deltas).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

/// Plain-value copy of a [`Hist`]: mergeable, wire-encodable, and the
/// thing quantiles are estimated from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Per-bucket sample counts (`buckets[i]` covers `[2^i, 2^(i+1))`).
    pub buckets: [u64; BUCKETS],
    /// Total samples.
    pub count: u64,
    /// Sum of all samples in nanoseconds.
    pub sum: u64,
    /// Largest sample observed.
    pub max: u64,
}

impl Default for HistSnapshot {
    fn default() -> HistSnapshot {
        HistSnapshot { buckets: [0u64; BUCKETS], count: 0, sum: 0, max: 0 }
    }
}

impl HistSnapshot {
    /// No samples recorded?
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Fold `other` into `self` (e.g. client-side and daemon-side
    /// halves of the same op class).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Samples recorded since `prev` was taken, assuming `prev` is an
    /// earlier snapshot of the same monotonically-growing histogram
    /// (`sea stat --watch` interval deltas). Counts subtract
    /// saturating, so a reset between snapshots degrades to the
    /// current totals instead of wrapping. `max` is all-time, not
    /// per-interval — the bucket counters don't retain enough to
    /// recover an interval max, so the delta keeps the current one.
    pub fn diff(&self, prev: &HistSnapshot) -> HistSnapshot {
        let mut out = HistSnapshot {
            buckets: [0u64; BUCKETS],
            count: self.count.saturating_sub(prev.count),
            sum: self.sum.saturating_sub(prev.sum),
            max: self.max,
        };
        for (i, (a, b)) in self.buckets.iter().zip(prev.buckets.iter()).enumerate() {
            out.buckets[i] = a.saturating_sub(*b);
        }
        out
    }

    /// Mean sample in nanoseconds (0 when empty).
    pub fn mean(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum / self.count
        }
    }

    /// Estimate the `q`-quantile (`0.0..=1.0`) in nanoseconds.
    ///
    /// Walks buckets to the one holding the sample of rank
    /// `ceil(q * count)` and interpolates linearly inside it; the
    /// result is clamped to the bucket's bounds and the observed max,
    /// so the estimate shares a log₂ bucket with the true order
    /// statistic (≤ 2x relative error, exact at the recorded `max`).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= rank {
                let lo = bucket_lo(i);
                let hi = bucket_hi(i).min(self.max.max(lo));
                let frac = (rank - seen) as f64 / c as f64;
                let est = lo as f64 + frac * (hi - lo) as f64;
                return (est as u64).clamp(lo, hi);
            }
            seen += c;
        }
        self.max
    }

    /// p50 estimate in nanoseconds.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// p95 estimate in nanoseconds.
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    /// p99 estimate in nanoseconds.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::percentile_sorted;

    #[test]
    fn bucket_bounds_partition_u64() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(u64::MAX), 63);
        for i in 0..BUCKETS {
            assert!(bucket_of(bucket_lo(i).max(1)) <= i);
            assert_eq!(bucket_of(bucket_hi(i)), i);
            if i > 0 {
                assert_eq!(bucket_of(bucket_lo(i)), i);
                assert_eq!(bucket_lo(i), bucket_hi(i - 1) + 1, "buckets must tile");
            }
        }
    }

    #[test]
    fn record_snapshot_and_stats() {
        let h = Hist::new();
        for v in [0u64, 1, 100, 1000, 1000, 50_000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 6);
        assert_eq!(s.sum, 52_101);
        assert_eq!(s.max, 50_000);
        assert_eq!(s.mean(), 52_101 / 6);
        assert_eq!(s.buckets.iter().sum::<u64>(), 6);
        // two samples of 1000 share floor(log2(1000)) = bucket 9
        assert_eq!(s.buckets[9], 2);
        h.reset();
        assert!(h.snapshot().is_empty());
    }

    #[test]
    fn quantiles_of_uniform_samples_land_in_the_right_bucket() {
        let h = Hist::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        // exact p50 = 500 (bucket 8: 256..511)
        let p50 = s.p50();
        assert_eq!(bucket_of(p50), bucket_of(500), "p50 {p50}");
        let p99 = s.p99();
        assert_eq!(bucket_of(p99), bucket_of(990), "p99 {p99}");
        assert!(s.quantile(1.0) <= s.max);
        assert_eq!(s.quantile(1.0), 1000, "max rank clamps to observed max");
    }

    #[test]
    fn merge_is_additive() {
        let a = Hist::new();
        let b = Hist::new();
        for v in [10u64, 20, 30] {
            a.record(v);
        }
        for v in [1_000u64, 2_000] {
            b.record(v);
        }
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count, 5);
        assert_eq!(m.sum, 3_060);
        assert_eq!(m.max, 2_000);
        assert_eq!(m.buckets.iter().sum::<u64>(), 5);
    }

    #[test]
    fn diff_recovers_the_interval_between_two_snapshots() {
        let h = Hist::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        let before = h.snapshot();
        for v in [1_000u64, 2_000] {
            h.record(v);
        }
        let d = h.snapshot().diff(&before);
        assert_eq!(d.count, 2);
        assert_eq!(d.sum, 3_000);
        assert_eq!(d.max, 2_000, "max stays all-time");
        assert_eq!(d.buckets.iter().sum::<u64>(), 2);
        // a reset between snapshots must not wrap
        h.reset();
        h.record(5);
        let d = h.snapshot().diff(&before);
        assert_eq!(d.count, 1, "saturating diff after reset");
    }

    /// Property: over random sample sets, every quantile estimate
    /// stays inside the log₂ buckets that bracket the exact
    /// `percentile_sorted` interpolation neighbours — the documented
    /// bucket-boundary error bound.
    #[test]
    fn quantile_estimates_track_percentile_sorted_within_bucket_bounds() {
        let mut rng = Rng::new(0xB0CE7);
        for case in 0..200 {
            let n = 1 + (rng.next_u64() % 500) as usize;
            // mix magnitudes: nanoseconds from single digits to seconds
            let mut samples: Vec<u64> = (0..n)
                .map(|_| {
                    let shift = rng.next_u64() % 31;
                    rng.next_u64() % (1u64 << (shift + 1))
                })
                .collect();
            let h = Hist::new();
            for &v in &samples {
                h.record(v);
            }
            let s = h.snapshot();
            samples.sort_unstable();
            let sorted: Vec<f64> = samples.iter().map(|&v| v as f64).collect();
            for &p in &[50.0, 95.0, 99.0] {
                let est = s.quantile(p / 100.0);
                let exact = percentile_sorted(&sorted, p);
                // the exact percentile interpolates between two
                // adjacent order statistics; our rank rounds to one of
                // them (±1) — bound the estimate by the bucket range
                // those neighbours span
                let pos = (n - 1) as f64 * p / 100.0;
                let lo_idx = (pos.floor() as usize).saturating_sub(1);
                let hi_idx = (pos.ceil() as usize + 1).min(n - 1);
                let lo = bucket_lo(bucket_of(samples[lo_idx]));
                let hi = bucket_hi(bucket_of(samples[hi_idx]));
                assert!(
                    est >= lo && est <= hi,
                    "case {case} n {n} p{p}: est {est} outside [{lo}, {hi}] \
                     (exact {exact:.1}, max {})",
                    s.max
                );
            }
        }
    }

    /// Concurrency: hammer one histogram from many threads; totals
    /// must balance exactly (runs under TSan in CI via the `obs::`
    /// filter).
    #[test]
    fn concurrent_recorders_never_lose_samples() {
        let h = std::sync::Arc::new(Hist::new());
        const THREADS: u64 = 8;
        const PER: u64 = 10_000;
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for k in 0..PER {
                    h.record(t * 1_000 + k);
                }
            }));
        }
        for th in handles {
            th.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, THREADS * PER);
        assert_eq!(s.buckets.iter().sum::<u64>(), THREADS * PER);
        let expect_sum: u64 =
            (0..THREADS).map(|t| (0..PER).map(|k| t * 1_000 + k).sum::<u64>()).sum();
        assert_eq!(s.sum, expect_sum);
        assert_eq!(s.max, (THREADS - 1) * 1_000 + PER - 1);
    }
}
