//! Flight recorder: bounded per-thread rings of structured events,
//! dumpable as Chrome trace-event JSON.
//!
//! Recording is off unless armed ([`set_enabled`]) — `sea run --trace
//! FILE` and the `SEA_TRACE=path` environment variable arm it. When
//! off, [`span`] and [`instant`] cost one relaxed atomic load. When
//! on, each event lands in the calling thread's own ring buffer
//! (capacity [`RING_CAP`], overwriting oldest), so a recorder on a hot
//! path never contends with other threads and a runaway workload can
//! never grow memory unboundedly — the recorder keeps the *last*
//! window of activity, like an aircraft flight recorder.
//!
//! Event names, categories and causes are `&'static str` drawn from a
//! small fixed vocabulary (no allocation on the record path; the JSON
//! writer emits them unescaped). Timestamps are monotonic nanoseconds
//! from a process-wide epoch taken at first use.
//!
//! [`dump_to`] collects every ring (including those of exited
//! threads), sorts by timestamp, and writes the Chrome `traceEvents`
//! JSON array — load it in `chrome://tracing` / Perfetto, or parse it
//! with any JSON tool (CI validates with `python3 -m json.tool`).

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Events kept per thread; older ones are overwritten.
pub const RING_CAP: usize = 4096;

/// Chrome trace-event phase of an [`Event`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ph {
    /// A duration (`ph:"X"`): begin timestamp + `dur`.
    Complete,
    /// A point event (`ph:"i"`, thread-scoped).
    Point,
}

/// One recorded event.
#[derive(Debug, Clone)]
pub struct Event {
    /// What happened (`"flush"`, `"spill"`, `"page-evict"`, …).
    pub name: &'static str,
    /// Subsystem (`"mgmt"`, `"pages"`, `"placement"`, `"daemon"`, …).
    pub cat: &'static str,
    /// Why it happened (`"close"`, `"pressure"`, `"heat"`, …; `""`
    /// when not applicable).
    pub cause: &'static str,
    /// Duration vs point event.
    pub ph: Ph,
    /// Start, in nanoseconds since the process trace epoch.
    pub ts_ns: u64,
    /// Duration in nanoseconds (0 for point events).
    pub dur_ns: u64,
    /// Bytes the event moved/covered (0 when not applicable).
    pub bytes: u64,
    /// Recorder thread id (dense, assigned at first record).
    pub tid: u64,
}

struct Ring {
    tid: u64,
    buf: Vec<Event>,
    /// Next overwrite position once `buf` reached capacity.
    head: usize,
    /// Total events ever pushed (dropped = total - buf.len()).
    total: u64,
}

impl Ring {
    fn push(&mut self, e: Event) {
        self.total += 1;
        if self.buf.len() < RING_CAP {
            self.buf.push(e);
        } else {
            self.buf[self.head] = e;
            self.head = (self.head + 1) % RING_CAP;
        }
    }

    /// Events oldest-first.
    fn ordered(&self) -> Vec<Event> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Rings of every thread that ever recorded, including exited ones
/// (their events stay dumpable).
fn rings() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static MY_RING: Arc<Mutex<Ring>> = {
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let ring = Arc::new(Mutex::new(Ring {
            tid,
            buf: Vec::new(),
            head: 0,
            total: 0,
        }));
        rings().lock().expect("trace rings poisoned").push(ring.clone());
        ring
    };
}

/// Arm or disarm the recorder. Events recorded while armed stay in the
/// rings until [`dump_to`] (or process exit).
pub fn set_enabled(on: bool) {
    if on {
        epoch(); // pin the epoch before the first event
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is the recorder armed? One relaxed load — the full cost of a
/// disabled [`span`]/[`instant`].
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

fn push(e: Event) {
    MY_RING.with(|r| {
        let mut ring = r.lock().expect("trace ring poisoned");
        let tid = ring.tid;
        ring.push(Event { tid, ..e });
    });
}

/// Record a point event (eviction, write-back, lease grant/revoke,
/// placement decision). No-op unless armed.
#[inline]
pub fn instant(name: &'static str, cat: &'static str, cause: &'static str, bytes: u64) {
    if !enabled() {
        return;
    }
    push(Event {
        name,
        cat,
        cause,
        ph: Ph::Point,
        ts_ns: now_ns(),
        dur_ns: 0,
        bytes,
        tid: 0,
    });
}

/// RAII span: records a `Complete` event covering its lifetime when
/// dropped. Obtain one with [`span`]; a span built while the recorder
/// is disarmed records nothing.
pub struct Span {
    live: Option<(u64, &'static str, &'static str, &'static str)>,
    bytes: u64,
}

/// Open a span (`flush`/`spill`/`promote` lifecycles). Cost when
/// disarmed: one relaxed load.
#[inline]
pub fn span(name: &'static str, cat: &'static str, cause: &'static str) -> Span {
    if !enabled() {
        return Span { live: None, bytes: 0 };
    }
    Span { live: Some((now_ns(), name, cat, cause)), bytes: 0 }
}

impl Span {
    /// Attach a byte count to the span's `args`.
    pub fn bytes(&mut self, n: u64) {
        self.bytes = n;
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((t0, name, cat, cause)) = self.live.take() {
            let end = now_ns();
            push(Event {
                name,
                cat,
                cause,
                ph: Ph::Complete,
                ts_ns: t0,
                dur_ns: end.saturating_sub(t0),
                bytes: self.bytes,
                tid: 0,
            });
        }
    }
}

/// All recorded events across every thread, oldest-first.
pub fn collect() -> Vec<Event> {
    let rings = rings().lock().expect("trace rings poisoned");
    let mut all = Vec::new();
    for r in rings.iter() {
        all.extend(r.lock().expect("trace ring poisoned").ordered());
    }
    all.sort_by_key(|e| e.ts_ns);
    all
}

/// Drop every recorded event (tests; dumps are otherwise cumulative).
pub fn clear() {
    let rings = rings().lock().expect("trace rings poisoned");
    for r in rings.iter() {
        let mut ring = r.lock().expect("trace ring poisoned");
        ring.buf.clear();
        ring.head = 0;
    }
}

/// Serialize every recorded event as Chrome trace-event JSON
/// (`{"traceEvents":[...]}`; `ts`/`dur` in microseconds).
pub fn to_chrome_json() -> String {
    let events = collect();
    let pid = std::process::id();
    let mut out = String::with_capacity(events.len() * 128 + 32);
    out.push_str("{\"traceEvents\":[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let ts = e.ts_ns as f64 / 1_000.0;
        match e.ph {
            Ph::Complete => {
                let dur = e.dur_ns as f64 / 1_000.0;
                out.push_str(&format!(
                    "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":{pid},\
                     \"tid\":{},\"ts\":{ts:.3},\"dur\":{dur:.3},\
                     \"args\":{{\"cause\":\"{}\",\"bytes\":{}}}}}",
                    e.name, e.cat, e.tid, e.cause, e.bytes
                ));
            }
            Ph::Point => {
                out.push_str(&format!(
                    "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\
                     \"pid\":{pid},\"tid\":{},\"ts\":{ts:.3},\
                     \"args\":{{\"cause\":\"{}\",\"bytes\":{}}}}}",
                    e.name, e.cat, e.tid, e.cause, e.bytes
                ));
            }
        }
    }
    out.push_str("]}");
    out
}

/// Write the Chrome trace JSON to `path`, returning the event count.
pub fn dump_to(path: &Path) -> std::io::Result<u64> {
    let events = collect();
    let n = events.len() as u64;
    let json = to_chrome_json();
    let mut f = std::fs::File::create(path)?;
    f.write_all(json.as_bytes())?;
    f.flush()?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Global recorder state is shared across the test process, so the
    /// assertions here are presence/shape-based, never exact counts.
    #[test]
    fn spans_and_instants_record_when_armed_only() {
        let _gate = crate::obs::test_gate();
        // disarmed: nothing lands
        set_enabled(false);
        let before = collect().len();
        instant("never", "test", "", 0);
        drop(span("never-span", "test", ""));
        assert_eq!(collect().len(), before, "disarmed recorder must be silent");

        set_enabled(true);
        instant("trace-test-point", "test", "unit", 7);
        {
            let mut sp = span("trace-test-span", "test", "unit");
            sp.bytes(1234);
        }
        set_enabled(false);
        let all = collect();
        assert!(all.iter().any(|e| e.name == "trace-test-point" && e.bytes == 7));
        let sp = all
            .iter()
            .find(|e| e.name == "trace-test-span")
            .expect("span must be recorded");
        assert_eq!(sp.ph, Ph::Complete);
        assert_eq!(sp.bytes, 1234);
        assert_eq!(sp.cause, "unit");
        assert!(sp.tid > 0);
    }

    #[test]
    fn ring_overwrites_oldest_beyond_capacity() {
        let mut ring = Ring { tid: 1, buf: Vec::new(), head: 0, total: 0 };
        let ev = |ts| Event {
            name: "e",
            cat: "t",
            cause: "",
            ph: Ph::Point,
            ts_ns: ts,
            dur_ns: 0,
            bytes: 0,
            tid: 1,
        };
        for i in 0..(RING_CAP as u64 + 10) {
            ring.push(ev(i));
        }
        let got = ring.ordered();
        assert_eq!(got.len(), RING_CAP);
        assert_eq!(ring.total, RING_CAP as u64 + 10);
        assert_eq!(got[0].ts_ns, 10, "oldest 10 must have been overwritten");
        assert_eq!(got[RING_CAP - 1].ts_ns, RING_CAP as u64 + 9);
        // oldest-first, no seam at the wrap point
        for w in got.windows(2) {
            assert!(w[0].ts_ns < w[1].ts_ns);
        }
    }

    #[test]
    fn chrome_json_is_structurally_sound() {
        let _gate = crate::obs::test_gate();
        set_enabled(true);
        instant("json-test", "test", "unit", 42);
        {
            let mut sp = span("json-test-span", "test", "unit");
            sp.bytes(9);
        }
        set_enabled(false);
        let json = to_chrome_json();
        assert!(json.starts_with("{\"traceEvents\":["), "{json}");
        assert!(json.ends_with("]}"), "{json}");
        assert!(json.contains("\"name\":\"json-test\""));
        assert!(json.contains("\"ph\":\"X\""), "span must emit a Complete event");
        assert!(json.contains("\"ph\":\"i\""), "instant must emit a Point event");
        assert!(json.contains("\"bytes\":42"));
        // balanced braces/brackets — names come from a fixed static
        // vocabulary, so no escaping can unbalance them
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert_eq!(json.matches('"').count() % 2, 0);
    }
}
