//! Minimal benchmark harness (offline criterion substitute).
//!
//! `cargo bench` runs each `[[bench]]` target with `harness = false`;
//! targets build a [`Harness`], register closures, and call
//! [`Harness::finish`], which prints a criterion-like table and appends
//! CSV rows to `results/bench.csv`.

use std::time::{Duration, Instant};

use crate::util::csv::{f, Csv};
use crate::util::Summary;

/// One benchmark's timing samples.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Bench id (`target/case`).
    pub name: String,
    /// Per-repetition wall times (seconds).
    pub samples: Vec<f64>,
    /// Optional throughput denominator (bytes or items per rep).
    pub throughput: Option<(f64, &'static str)>,
    /// Optional free-form note column (e.g. measured makespan).
    pub note: String,
}

impl BenchResult {
    /// Summary statistics of the samples.
    pub fn summary(&self) -> Summary {
        Summary::of(&self.samples).expect("at least one sample")
    }
}

/// Bench registry + runner.
pub struct Harness {
    target: String,
    warmup: usize,
    reps: usize,
    results: Vec<BenchResult>,
}

impl Harness {
    /// New harness for a bench target. Honours `SEA_BENCH_REPS` /
    /// `SEA_BENCH_WARMUP` env overrides.
    pub fn new(target: impl Into<String>) -> Harness {
        let reps = std::env::var("SEA_BENCH_REPS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(3);
        let warmup = std::env::var("SEA_BENCH_WARMUP")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1);
        Harness { target: target.into(), warmup, reps, results: Vec::new() }
    }

    /// Override repetition counts (tests).
    pub fn with_reps(mut self, warmup: usize, reps: usize) -> Harness {
        self.warmup = warmup;
        self.reps = reps;
        self
    }

    /// Time `body` (called `warmup + reps` times); records the reps.
    pub fn case<F: FnMut() -> R, R>(&mut self, name: &str, mut body: F) -> &mut BenchResult {
        for _ in 0..self.warmup {
            let _ = body();
        }
        let mut samples = Vec::with_capacity(self.reps);
        for _ in 0..self.reps.max(1) {
            let t0 = Instant::now();
            let _ = body();
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.results.push(BenchResult {
            name: format!("{}/{}", self.target, name),
            samples,
            throughput: None,
            note: String::new(),
        });
        self.results.last_mut().expect("just pushed")
    }

    /// Record an externally-measured sample set (e.g. simulated seconds
    /// rather than wall time).
    pub fn record(
        &mut self,
        name: &str,
        samples: Vec<f64>,
        note: impl Into<String>,
    ) -> &mut BenchResult {
        assert!(!samples.is_empty());
        self.results.push(BenchResult {
            name: format!("{}/{}", self.target, name),
            samples,
            throughput: None,
            note: note.into(),
        });
        self.results.last_mut().expect("just pushed")
    }

    /// Print the table and append `results/bench.csv`. Returns the
    /// results for further assertions.
    pub fn finish(self) -> Vec<BenchResult> {
        println!("\n== bench: {} (warmup {}, reps {}) ==", self.target, self.warmup, self.reps);
        println!(
            "{:<52} {:>12} {:>12} {:>12}  {}",
            "case", "mean", "min", "max", "note"
        );
        let mut csv = Csv::new(vec![
            "target", "case", "n", "mean_s", "std_s", "min_s", "max_s", "p95_s",
            "p99_s", "note",
        ]);
        for r in &self.results {
            let s = r.summary();
            let fmt = |x: f64| {
                if x >= 1.0 {
                    format!("{x:.3} s")
                } else if x >= 1e-3 {
                    format!("{:.3} ms", x * 1e3)
                } else {
                    format!("{:.1} µs", x * 1e6)
                }
            };
            println!(
                "{:<52} {:>12} {:>12} {:>12}  {}",
                r.name,
                fmt(s.mean),
                fmt(s.min),
                fmt(s.max),
                r.note
            );
            let case = r.name.split('/').skip(1).collect::<Vec<_>>().join("/");
            csv.row(vec![
                self.target.clone(),
                case,
                s.n.to_string(),
                f(s.mean),
                f(s.std),
                f(s.min),
                f(s.max),
                f(s.p95),
                f(s.p99),
                r.note.clone(),
            ]);
        }
        // append-style: one csv per target to avoid interleaving
        let path = format!("results/bench_{}.csv", self.target.replace('/', "_"));
        if let Err(e) = csv.write_to(&path) {
            eprintln!("bench: could not write {path}: {e}");
        }
        self.results
    }
}

/// Convenience: time one closure once (used inside bench bodies).
pub fn time_once<F: FnOnce() -> R, R>(body: F) -> (Duration, R) {
    let t0 = Instant::now();
    let r = body();
    (t0.elapsed(), r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_collects_samples() {
        let mut h = Harness::new("unit").with_reps(1, 3);
        h.case("noop", || 1 + 1);
        let rs = h.finish();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].samples.len(), 3);
        assert!(rs[0].summary().mean >= 0.0);
        let _ = std::fs::remove_file("results/bench_unit.csv");
    }

    #[test]
    fn record_takes_external_samples() {
        let mut h = Harness::new("unit2").with_reps(0, 1);
        h.record("sim", vec![1.0, 2.0, 3.0], "simulated");
        let rs = h.finish();
        assert_eq!(rs[0].summary().mean, 2.0);
        assert_eq!(rs[0].note, "simulated");
        let _ = std::fs::remove_file("results/bench_unit2.csv");
    }

    #[test]
    fn time_once_returns_value() {
        let (dt, v) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(dt.as_nanos() > 0);
    }
}
