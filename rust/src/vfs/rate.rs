//! Bandwidth-limiting [`Vfs`] decorator.
//!
//! On this single machine there is no Lustre to contend on, so the
//! end-to-end examples emulate a loaded PFS by wrapping its directory in
//! a token-bucket rate limiter: concurrent readers/writers share the
//! configured bandwidth, which is exactly the fair-sharing behaviour the
//! simulator models for a saturated file system.

use std::path::Path;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::vfs::Vfs;

#[derive(Debug)]
struct Bucket {
    rate: f64, // bytes/s
    available: f64,
    last: Instant,
    cap: f64,
}

impl Bucket {
    fn new(rate: f64) -> Bucket {
        // burst budget of 50 ms: big enough to amortize scheduling noise,
        // small enough that workloads beyond a few MiB feel the cap
        Bucket { rate, available: 0.0, last: Instant::now(), cap: rate * 0.05 }
    }

    /// Take `bytes` of budget; returns how long the caller must sleep.
    fn take(&mut self, bytes: f64) -> Duration {
        let now = Instant::now();
        self.available =
            (self.available + now.duration_since(self.last).as_secs_f64() * self.rate)
                .min(self.cap);
        self.last = now;
        self.available -= bytes;
        if self.available >= 0.0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(-self.available / self.rate)
        }
    }
}

/// A [`Vfs`] decorator imposing shared read/write bandwidth caps.
pub struct RateLimitedFs<F> {
    inner: F,
    read_bucket: Mutex<Bucket>,
    write_bucket: Mutex<Bucket>,
}

impl<F: Vfs> RateLimitedFs<F> {
    /// Wrap `inner` with `read_bw` / `write_bw` byte-per-second caps.
    pub fn new(inner: F, read_bw: f64, write_bw: f64) -> RateLimitedFs<F> {
        assert!(read_bw > 0.0 && write_bw > 0.0);
        RateLimitedFs {
            inner,
            read_bucket: Mutex::new(Bucket::new(read_bw)),
            write_bucket: Mutex::new(Bucket::new(write_bw)),
        }
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &F {
        &self.inner
    }

    fn throttle(bucket: &Mutex<Bucket>, bytes: usize) {
        let wait = bucket.lock().expect("bucket poisoned").take(bytes as f64);
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
    }
}

impl<F: Vfs> Vfs for RateLimitedFs<F> {
    fn read(&self, path: &Path) -> Result<Vec<u8>> {
        let data = self.inner.read(path)?;
        Self::throttle(&self.read_bucket, data.len());
        Ok(data)
    }

    fn write(&self, path: &Path, data: &[u8]) -> Result<()> {
        Self::throttle(&self.write_bucket, data.len());
        self.inner.write(path, data)
    }

    fn unlink(&self, path: &Path) -> Result<()> {
        self.inner.unlink(path)
    }

    fn exists(&self, path: &Path) -> bool {
        self.inner.exists(path)
    }

    fn size(&self, path: &Path) -> Result<u64> {
        self.inner.size(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        self.inner.rename(from, to)
    }

    fn readdir(&self, path: &Path) -> Result<Vec<String>> {
        self.inner.readdir(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;
    use crate::vfs::real::RealFs;
    use crate::vfs::testutil::scratch;

    #[test]
    fn writes_are_throttled_to_the_configured_bandwidth() {
        let dir = scratch("rate_w");
        let fs_ = RateLimitedFs::new(
            RealFs::new(&dir).unwrap(),
            1e9,
            20.0 * MIB as f64, // 20 MiB/s writes
        );
        let payload = vec![0u8; 10 * MIB as usize];
        let t0 = Instant::now();
        fs_.write(Path::new("a.dat"), &payload).unwrap();
        fs_.write(Path::new("b.dat"), &payload).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        // 20 MiB at 20 MiB/s ≈ 1s (bucket gives ~0.25s head start)
        assert!(dt > 0.6, "dt = {dt}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reads_are_throttled_too() {
        let dir = scratch("rate_r");
        let fs_ = RateLimitedFs::new(
            RealFs::new(&dir).unwrap(),
            20.0 * MIB as f64,
            1e9,
        );
        fs_.write(Path::new("a.dat"), &vec![0u8; 10 * MIB as usize]).unwrap();
        let t0 = Instant::now();
        let _ = fs_.read(Path::new("a.dat")).unwrap();
        let _ = fs_.read(Path::new("a.dat")).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.6, "dt = {dt}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn metadata_ops_pass_through() {
        let dir = scratch("rate_md");
        let fs_ = RateLimitedFs::new(RealFs::new(&dir).unwrap(), 1e9, 1e9);
        fs_.write(Path::new("x"), b"1").unwrap();
        assert!(fs_.exists(Path::new("x")));
        assert_eq!(fs_.size(Path::new("x")).unwrap(), 1);
        fs_.rename(Path::new("x"), Path::new("y")).unwrap();
        fs_.unlink(Path::new("y")).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
