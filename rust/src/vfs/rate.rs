//! Bandwidth-limiting [`Vfs`] decorator with per-request accounting.
//!
//! On this single machine there is no Lustre to contend on, so the
//! end-to-end examples emulate a loaded PFS by wrapping its directory in
//! a token-bucket rate limiter: concurrent readers/writers share the
//! configured bandwidth, which is exactly the fair-sharing behaviour the
//! simulator models for a saturated file system.
//!
//! Accounting is **per request**: every [`VfsFile::pread`] /
//! [`VfsFile::pwrite`] debits the bucket for exactly the bytes it moved,
//! so a 64 KiB partial read costs 64 KiB — not the whole file — while a
//! whole-file transfer (which is just one big request through the
//! default [`Vfs::read`] / [`Vfs::write`] conveniences) pays the same
//! total as a chunked one.

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::vfs::{OpenMode, Vfs, VfsFile};

#[derive(Debug)]
struct Bucket {
    rate: f64, // bytes/s
    available: f64,
    last: Instant,
    cap: f64,
}

impl Bucket {
    fn new(rate: f64) -> Bucket {
        // burst budget of 50 ms: big enough to amortize scheduling noise,
        // small enough that workloads beyond a few MiB feel the cap
        Bucket { rate, available: 0.0, last: Instant::now(), cap: rate * 0.05 }
    }

    /// Take `bytes` of budget; returns how long the caller must sleep.
    fn take(&mut self, bytes: f64) -> Duration {
        let now = Instant::now();
        self.available =
            (self.available + now.duration_since(self.last).as_secs_f64() * self.rate)
                .min(self.cap);
        self.last = now;
        self.available -= bytes;
        if self.available >= 0.0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(-self.available / self.rate)
        }
    }
}

fn throttle(bucket: &Mutex<Bucket>, bytes: usize) {
    if bytes == 0 {
        return;
    }
    let wait = bucket.lock().expect("bucket poisoned").take(bytes as f64);
    if !wait.is_zero() {
        std::thread::sleep(wait);
    }
}

/// A [`Vfs`] decorator imposing shared read/write bandwidth caps.
pub struct RateLimitedFs<F> {
    inner: F,
    read_bucket: Arc<Mutex<Bucket>>,
    write_bucket: Arc<Mutex<Bucket>>,
}

impl<F: Vfs> RateLimitedFs<F> {
    /// Wrap `inner` with `read_bw` / `write_bw` byte-per-second caps.
    pub fn new(inner: F, read_bw: f64, write_bw: f64) -> RateLimitedFs<F> {
        assert!(read_bw > 0.0 && write_bw > 0.0);
        RateLimitedFs {
            inner,
            read_bucket: Arc::new(Mutex::new(Bucket::new(read_bw))),
            write_bucket: Arc::new(Mutex::new(Bucket::new(write_bw))),
        }
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &F {
        &self.inner
    }
}

/// Handle decorator: each positioned request pays the bucket for the
/// bytes it actually transferred.
struct RateLimitedFile {
    inner: Box<dyn VfsFile>,
    read_bucket: Arc<Mutex<Bucket>>,
    write_bucket: Arc<Mutex<Bucket>>,
}

impl VfsFile for RateLimitedFile {
    fn pread(&mut self, buf: &mut [u8], off: u64) -> Result<usize> {
        let n = self.inner.pread(buf, off)?;
        throttle(&self.read_bucket, n);
        Ok(n)
    }

    fn pwrite(&mut self, data: &[u8], off: u64) -> Result<usize> {
        throttle(&self.write_bucket, data.len());
        self.inner.pwrite(data, off)
    }

    fn set_len(&mut self, len: u64) -> Result<()> {
        self.inner.set_len(len)
    }

    fn fsync(&mut self) -> Result<()> {
        self.inner.fsync()
    }

    fn len(&self) -> Result<u64> {
        self.inner.len()
    }

    // mapped views fault through pread / write back through pwrite, so
    // per-page accounting happens above; the generation, fault and
    // identity hooks must still reach the wrapped handle (e.g. a Sea
    // writer below a rate limiter)
    fn map_sync(&mut self) -> Result<u64> {
        self.inner.map_sync()
    }

    fn note_map_fault(&mut self, off: u64, len: u64) {
        self.inner.note_map_fault(off, len)
    }

    fn map_identity(&self) -> Option<u128> {
        self.inner.map_identity()
    }

    // Deliberately NOT delegated: `lease_fd`. A leased fd would let a
    // remote client pread the inner file directly, bypassing the token
    // buckets this decorator exists to enforce. The trait default
    // (`None`) keeps rate-limited reads on the accounted path.
}

impl<F: Vfs> Vfs for RateLimitedFs<F> {
    fn open(&self, path: &Path, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
        let inner = self.inner.open(path, mode)?;
        Ok(Box::new(RateLimitedFile {
            inner,
            read_bucket: self.read_bucket.clone(),
            write_bucket: self.write_bucket.clone(),
        }))
    }

    // whole-file read/write use the trait defaults, so they route through
    // the same per-request accounting as streamed I/O

    fn unlink(&self, path: &Path) -> Result<()> {
        self.inner.unlink(path)
    }

    fn exists(&self, path: &Path) -> bool {
        self.inner.exists(path)
    }

    fn size(&self, path: &Path) -> Result<u64> {
        self.inner.size(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        self.inner.rename(from, to)
    }

    fn readdir(&self, path: &Path) -> Result<Vec<String>> {
        self.inner.readdir(path)
    }

    fn mkdir(&self, path: &Path) -> Result<()> {
        self.inner.mkdir(path)
    }

    fn sync_mgmt(&self) -> Result<()> {
        self.inner.sync_mgmt()
    }

    // shard topology survives the decorator, so a rate-limited striped
    // PFS still exposes its members to OST-aware flush scheduling
    fn shard_count(&self) -> Option<usize> {
        self.inner.shard_count()
    }

    fn shard_of(&self, path: &Path) -> Option<usize> {
        self.inner.shard_of(path)
    }

    fn stripe_bytes(&self) -> Option<u64> {
        self.inner.stripe_bytes()
    }

    fn page_cache(&self) -> Option<std::sync::Arc<crate::vfs::PageCache>> {
        self.inner.page_cache()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{KIB, MIB};
    use crate::vfs::real::RealFs;
    use crate::vfs::testutil::scratch;

    #[test]
    fn writes_are_throttled_to_the_configured_bandwidth() {
        let dir = scratch("rate_w");
        let fs_ = RateLimitedFs::new(
            RealFs::new(&dir).unwrap(),
            1e9,
            20.0 * MIB as f64, // 20 MiB/s writes
        );
        let payload = vec![0u8; 10 * MIB as usize];
        let t0 = Instant::now();
        fs_.write(Path::new("a.dat"), &payload).unwrap();
        fs_.write(Path::new("b.dat"), &payload).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        // 20 MiB at 20 MiB/s ≈ 1s (bucket gives ~0.25s head start)
        assert!(dt > 0.6, "dt = {dt}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reads_are_throttled_too() {
        let dir = scratch("rate_r");
        let fs_ = RateLimitedFs::new(
            RealFs::new(&dir).unwrap(),
            20.0 * MIB as f64,
            1e9,
        );
        fs_.write(Path::new("a.dat"), &vec![0u8; 10 * MIB as usize]).unwrap();
        let t0 = Instant::now();
        let _ = fs_.read(Path::new("a.dat")).unwrap();
        let _ = fs_.read(Path::new("a.dat")).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.6, "dt = {dt}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn partial_reads_pay_only_their_bytes() {
        let dir = scratch("rate_partial");
        let fs_ = RateLimitedFs::new(
            RealFs::new(&dir).unwrap(),
            20.0 * MIB as f64, // 20 MiB/s reads
            1e9,
        );
        fs_.write(Path::new("big.dat"), &vec![0u8; 8 * MIB as usize]).unwrap();
        // a single 64 KiB pread from an 8 MiB file must cost ~64 KiB of
        // budget (within burst: instant), not the whole file (~0.4 s)
        let mut f = fs_.open(Path::new("big.dat"), OpenMode::Read).unwrap();
        let mut buf = vec![0u8; 64 * KIB as usize];
        let t0 = Instant::now();
        f.pread_exact(&mut buf, MIB).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt < 0.2, "64 KiB pread cost whole-file time: {dt}s");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn elapsed_time_respects_bandwidth_cap_for_streamed_transfer() {
        let dir = scratch("rate_cap");
        let fs_ = RateLimitedFs::new(
            RealFs::new(&dir).unwrap(),
            1e9,
            20.0 * MIB as f64, // 20 MiB/s writes
        );
        // 10 MiB streamed as 160 x 64 KiB pwrites: cap implies >= ~0.45 s
        // (10 MiB minus the 1 MiB burst headroom, at 20 MiB/s)
        let chunk = vec![7u8; 64 * KIB as usize];
        let t0 = Instant::now();
        {
            let mut f = fs_.open(Path::new("s.dat"), OpenMode::Write).unwrap();
            for k in 0..160u64 {
                f.pwrite_all(&chunk, k * 64 * KIB).unwrap();
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.3, "streamed transfer beat the cap: dt = {dt}");
        assert_eq!(fs_.size(Path::new("s.dat")).unwrap(), 10 * MIB);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn per_request_accounting_matches_whole_file_totals() {
        // the same K bytes cost the same total budget whether moved as
        // one whole-file request or as many small ones
        let dir = scratch("rate_match");
        let payload = vec![3u8; 4 * MIB as usize];

        let whole = RateLimitedFs::new(
            RealFs::new(dir.join("w")).unwrap(),
            1e9,
            20.0 * MIB as f64,
        );
        let t0 = Instant::now();
        whole.write(Path::new("x.dat"), &payload).unwrap();
        let dt_whole = t0.elapsed().as_secs_f64();

        let chunked = RateLimitedFs::new(
            RealFs::new(dir.join("c")).unwrap(),
            1e9,
            20.0 * MIB as f64,
        );
        let t0 = Instant::now();
        {
            let mut f = chunked.open(Path::new("x.dat"), OpenMode::Write).unwrap();
            for (k, part) in payload.chunks(256 * KIB as usize).enumerate() {
                f.pwrite_all(part, k as u64 * 256 * KIB).unwrap();
            }
        }
        let dt_chunked = t0.elapsed().as_secs_f64();

        // identical bytes land on disk...
        assert_eq!(
            whole.inner().read(Path::new("x.dat")).unwrap(),
            chunked.inner().read(Path::new("x.dat")).unwrap(),
        );
        // ...and both pay at least the cap-implied floor:
        // (4 MiB - 1 MiB burst) / 20 MiB/s = 0.15 s
        assert!(dt_whole > 0.1, "whole dt = {dt_whole}");
        assert!(dt_chunked > 0.1, "chunked dt = {dt_chunked}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn datamover_chunks_pay_per_request_through_the_cap() {
        // ISSUE 4 satellite: per-chunk accounting must hold for the
        // DataMover's pipelined transfers — every chunk debits the
        // bucket for exactly its bytes, so the streamed total respects
        // the bandwidth cap and the read-ahead thread cannot bypass it
        use crate::vfs::mover::{DataMover, MovePath, MoverCfg};
        let dir = scratch("rate_mover");
        let src_fs = RealFs::new(dir.join("src")).unwrap();
        src_fs.write(Path::new("big.dat"), &vec![0x42u8; 4 * MIB as usize]).unwrap();
        let dst_fs = RateLimitedFs::new(
            RealFs::new(dir.join("dst")).unwrap(),
            1e9,
            20.0 * MIB as f64, // 20 MiB/s writes
        );
        let mut src = src_fs.open(Path::new("big.dat"), OpenMode::Read).unwrap();
        let mut dst = dst_fs.open(Path::new("big.dat"), OpenMode::Write).unwrap();
        let cfg =
            MoverCfg { chunk_bytes: 256 * KIB as usize, copy_window: 2, ..MoverCfg::default() };
        let t0 = Instant::now();
        let n = DataMover::new(cfg, MovePath::Flush)
            .copy(src.as_mut(), dst.as_mut(), 4 * MIB)
            .unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert_eq!(n, 4 * MIB);
        drop(dst);
        // cap floor: (4 MiB - 1 MiB burst) / 20 MiB/s = 0.15 s
        assert!(dt > 0.1, "streamed transfer beat the cap: dt = {dt}");
        assert_eq!(
            dst_fs.inner().read(Path::new("big.dat")).unwrap(),
            vec![0x42u8; 4 * MIB as usize]
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mapped_faults_pay_only_their_pages() {
        // ISSUE 5: a mapped view over a rate-limited backend charges the
        // bucket per *fault* (one page), never the whole file — the same
        // guarantee the partial-read test gives for plain pread
        use crate::vfs::pages::{MapMode, PageCache};
        use std::sync::Arc;
        let dir = scratch("rate_map");
        let fs_ = RateLimitedFs::new(
            RealFs::new(&dir).unwrap(),
            20.0 * MIB as f64, // 20 MiB/s reads
            1e9,
        );
        fs_.write(Path::new("big.dat"), &vec![0u8; 8 * MIB as usize]).unwrap();
        let cache = Arc::new(PageCache::new(64 * KIB as usize, MIB));
        let mut f = fs_.open(Path::new("big.dat"), OpenMode::Read).unwrap();
        let mut view = f.map(&cache, 0, 8 * MIB, MapMode::Read).unwrap();
        // one 4 KiB read faults one 64 KiB page: within burst => instant
        let mut buf = vec![0u8; 4 * KIB as usize];
        let t0 = Instant::now();
        view.read_at(&mut buf, MIB).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt < 0.2, "one-page fault cost whole-file time: {dt}s");
        assert_eq!(cache.stats().faults, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn metadata_ops_pass_through() {
        let dir = scratch("rate_md");
        let fs_ = RateLimitedFs::new(RealFs::new(&dir).unwrap(), 1e9, 1e9);
        fs_.write(Path::new("x"), b"1").unwrap();
        assert!(fs_.exists(Path::new("x")));
        assert_eq!(fs_.size(Path::new("x")).unwrap(), 1);
        fs_.rename(Path::new("x"), Path::new("y")).unwrap();
        fs_.unlink(Path::new("y")).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
