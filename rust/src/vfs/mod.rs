//! The interception layer: a small POSIX-ish file-system abstraction.
//!
//! The paper intercepts glibc calls with `LD_PRELOAD`; the library-level
//! equivalent here is a [`Vfs`] trait every workload I/O goes through:
//!
//! * [`RealFs`] — plain `std::fs` against a root directory;
//! * [`rate::RateLimitedFs`] — a decorator imposing read/write bandwidth
//!   caps (stands in for a loaded PFS on this single machine);
//! * [`sea::SeaFs`] — **the paper's library**: mountpoint translation to
//!   the fastest eligible device directory, rule-driven flush/evict via a
//!   background daemon, prefetch support.
//!
//! A separate `cdylib` (`sea-interpose`) provides the literal
//! `LD_PRELOAD` mechanism for unmodified binaries; it reuses the same
//! translation logic.

pub mod rate;
pub mod real;
pub mod sea;

pub use rate::RateLimitedFs;
pub use real::RealFs;
pub use sea::{SeaFs, SeaFsConfig};

use std::path::Path;

use crate::error::Result;

/// Whole-file POSIX-ish operations (the granularity of the paper's
/// workloads: scientific tools read and write whole block files).
pub trait Vfs: Send + Sync {
    /// Read the entire file at `path`.
    fn read(&self, path: &Path) -> Result<Vec<u8>>;

    /// Create/overwrite the file at `path` with `data`.
    fn write(&self, path: &Path, data: &[u8]) -> Result<()>;

    /// Remove the file at `path`.
    fn unlink(&self, path: &Path) -> Result<()>;

    /// Does `path` exist?
    fn exists(&self, path: &Path) -> bool;

    /// Size in bytes of the file at `path`.
    fn size(&self, path: &Path) -> Result<u64>;

    /// Rename `from` to `to` (same mount).
    fn rename(&self, from: &Path, to: &Path) -> Result<()>;

    /// List file names (not paths) under directory `path`.
    fn readdir(&self, path: &Path) -> Result<Vec<String>>;

    /// Block until background management work (flush/evict) is complete.
    /// No-op for backends without daemons.
    fn sync_mgmt(&self) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    static COUNTER: AtomicU64 = AtomicU64::new(0);

    /// A unique scratch directory under the system temp dir.
    pub fn scratch(prefix: &str) -> PathBuf {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let d = std::env::temp_dir().join(format!(
            "sea_test_{prefix}_{}_{n}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }
}
