//! The interception layer: a handle-based, offset-aware POSIX-ish
//! file-system abstraction.
//!
//! The paper intercepts glibc calls (`open`/`read`/`write`/`lseek`/
//! `close`) with `LD_PRELOAD`; the library-level equivalent here is the
//! [`Vfs`] trait every workload I/O goes through. Mirroring the paper's
//! request granularity, the core primitive is [`Vfs::open`], which yields
//! a [`VfsFile`] handle supporting positioned I/O:
//!
//! * [`VfsFile::pread`] / [`VfsFile::pwrite`] — offset-addressed reads
//!   and writes (partial-block access, streaming writes);
//! * [`VfsFile::set_len`], [`VfsFile::fsync`], [`VfsFile::len`] — the
//!   rest of the handle lifecycle;
//! * dropping a handle closes it — backends may defer management work
//!   (placement bookkeeping, flush/evict scheduling) to that point.
//!
//! Whole-file [`Vfs::read`] / [`Vfs::write`] remain as default-method
//! conveniences implemented on top of `open`, so code written against
//! the original whole-file API keeps working while hot paths migrate to
//! bounded-buffer streaming.
//!
//! Backends:
//!
//! * [`RealFs`] — plain `std::fs` against a root directory, positioned
//!   I/O via `FileExt`;
//! * [`rate::RateLimitedFs`] — a decorator imposing read/write bandwidth
//!   caps with **per-request** byte accounting (stands in for a loaded
//!   PFS on this single machine);
//! * [`striped::StripedFs`] — shards files across N member `Vfs` roots
//!   by path hash (stand-in for a Lustre deployment striped across
//!   OSTs); exposes its member topology via [`Vfs::shard_count`] /
//!   [`Vfs::shard_of`] so flush schedulers can respect per-member
//!   concurrency limits;
//! * [`sea::SeaFs`] — **the paper's library**: mountpoint translation at
//!   `open` (every placement target, device tiers and the PFS alike, is
//!   a `Vfs`), open-handle tracking, and a multi-worker flush pool over
//!   a sharded registry. Every decision — device pick, Table 1
//!   management at last close, spill-victim choice under device
//!   pressure, promotion when space frees, mount-time prefetch — flows
//!   through one [`crate::placement::PlacementEngine`]
//!   (`SeaTuning::engine` selects `paper` or `temperature`), the same
//!   trait the simulator policies drive.
//!
//! * [`remote::RemoteFs`] — the **service transport**: every operation
//!   rides the [`crate::serve`] wire protocol to a `sea serve` daemon
//!   over a Unix socket, so many processes share one mounted `SeaFs`
//!   (one placement brain, one ledger, one page budget).
//!
//! Decorators compose: a `SeaFs` mounted over
//! `RateLimitedFs<StripedFs>` emulates a loaded, OST-striped Lustre.
//!
//! On top of the handle API sits the **[`pages`] layer** — the shared
//! page cache: a process/mount-wide [`pages::PageCache`] (global byte
//! budget, sharded LRU) serving mmap-style [`pages::MappedView`]
//! windows over any handle — copy-on-read page fault-in via `pread`,
//! dirty-range tracking, write-back through `pwrite` on `msync` /
//! eviction / view drop. Frames are keyed by `(file identity, map
//! generation, page index)`: [`VfsFile::map_identity`] names the file,
//! so every view of it (any handle, any window) faults a page once and
//! hits the same frame thereafter; dirty bytes stored through one view
//! are visible to sibling readers before write-back; write-back
//! happens once; and a [`VfsFile::map_sync`] generation bump (a Sea
//! mid-stream spill) orphans all of an identity's stale frames at
//! once. Every backend gets [`VfsFile::map`] for free; `SeaFs` hooks
//! in deliberately (faults heat the placement engine on reader and
//! writer handles alike, views follow mid-stream spills, dirty
//! write-back of spilled files lands on the PFS replica).
//!
//! Below the handle API sits the **[`compress`] layer** — transparent
//! cold-tier compression: an LZ-style block codec (hand-rolled, no
//! external crates) that the [`mover::DataMover`] can run in its
//! read-ahead thread on Flush/Spill transfers
//! ([`mover::CodecMode::Encode`], `SeaTuning::compress`). Cold PFS
//! replicas become framed containers (per-chunk header: codec id,
//! logical/physical lengths, checksum; per-file frame index + trailer)
//! and reads back come through a seekable
//! [`compress::CompressedReader`] that decompresses only the frames a
//! `pread` touches. Sizes split into *logical* (what `len()`/`size`/
//! readdir and every read path report) and *physical* (what the space
//! ledger debits and the PFS actually stores); incompressible chunks
//! are stored raw, so the worst case is one 13-byte header per chunk.
//!
//! A separate `cdylib` (`sea-interpose`) provides the literal
//! `LD_PRELOAD` mechanism for unmodified binaries; it reuses the same
//! translation logic (offset ops like `pread`/`pwrite` ride on
//! descriptors whose path was translated at `open`) and carries its
//! own user-space mapping path: `mmap(MAP_PRIVATE|PROT_READ)` and
//! writable `MAP_SHARED` on translated descriptors are emulated over a
//! shim-global `(device, inode, page)` frame pool with write-back on
//! `msync`/`munmap` — see the `sea-interpose` crate docs for exact
//! coverage and remaining gaps.

pub mod compress;
pub mod mover;
pub mod pages;
pub mod rate;
pub mod real;
pub mod remote;
pub mod sea;
pub mod striped;

pub use compress::{Codec, CompressedReader, Lz};
pub use mover::{copy_range, CodecMode, DataMover, MovePath, MoverCfg, MoverMetrics};
pub use pages::{MapMode, MappedView, PageCache, PageCacheStats};
pub use rate::RateLimitedFs;
pub use real::RealFs;
pub use remote::{RemoteFile, RemoteFs, RetryCfg};
pub use sea::{DeviceLedger, DeviceSpec, MgmtCounters, SeaFs, SeaFsConfig, SeaTuning};
pub use striped::StripedFs;

use std::path::Path;
use std::sync::Arc;

use crate::error::{Error, Result};

/// How a [`VfsFile`] handle is opened.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpenMode {
    /// Read-only; the file must exist.
    Read,
    /// Create or truncate, then read/write (POSIX `O_CREAT|O_TRUNC`).
    Write,
    /// Create if missing, keep existing contents, read/write.
    ReadWrite,
    /// Create if missing, keep existing contents; every write lands at
    /// the current end-of-file and the caller's offset is ignored
    /// (POSIX `O_APPEND`). Backends must resolve the offset per request
    /// so concurrent appenders never interleave within one write.
    Append,
}

impl OpenMode {
    /// Does this mode permit writes?
    pub fn writable(self) -> bool {
        !matches!(self, OpenMode::Read)
    }

    /// Does this mode truncate an existing file?
    pub fn truncates(self) -> bool {
        matches!(self, OpenMode::Write)
    }

    /// Do writes ignore the caller's offset and land at end-of-file?
    pub fn appends(self) -> bool {
        matches!(self, OpenMode::Append)
    }
}

/// An open file handle with positioned (offset-addressed) I/O.
///
/// Handles are independent cursors-free views: every operation names its
/// offset explicitly, so concurrent handles never race on a shared file
/// position. Dropping the handle closes it; backends may run deferred
/// management (e.g. Sea's flush/evict) at that point.
pub trait VfsFile: Send {
    /// Read up to `buf.len()` bytes at `off`; returns bytes read
    /// (0 at end-of-file).
    fn pread(&mut self, buf: &mut [u8], off: u64) -> Result<usize>;

    /// Write `data` at `off`, extending the file as needed; returns
    /// bytes written.
    fn pwrite(&mut self, data: &[u8], off: u64) -> Result<usize>;

    /// Truncate or extend the file to exactly `len` bytes.
    fn set_len(&mut self, len: u64) -> Result<()>;

    /// Durably persist the handle's data to its backing store.
    fn fsync(&mut self) -> Result<()>;

    /// Current size of the file in bytes.
    fn len(&self) -> Result<u64>;

    /// True when the file is empty.
    fn is_empty(&self) -> Result<bool> {
        Ok(self.len()? == 0)
    }

    /// Read exactly `buf.len()` bytes at `off`, failing on short reads.
    fn pread_exact(&mut self, buf: &mut [u8], off: u64) -> Result<()> {
        let mut filled = 0usize;
        while filled < buf.len() {
            let n = self.pread(&mut buf[filled..], off + filled as u64)?;
            if n == 0 {
                return Err(Error::io(
                    "<vfs-handle>",
                    std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        format!("short read: {filled}/{} bytes", buf.len()),
                    ),
                ));
            }
            filled += n;
        }
        Ok(())
    }

    /// Write all of `data` at `off`, retrying partial writes.
    fn pwrite_all(&mut self, data: &[u8], off: u64) -> Result<()> {
        let mut done = 0usize;
        while done < data.len() {
            let n = self.pwrite(&data[done..], off + done as u64)?;
            if n == 0 {
                return Err(Error::io(
                    "<vfs-handle>",
                    std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        format!("short write: {done}/{} bytes", data.len()),
                    ),
                ));
            }
            done += n;
        }
        Ok(())
    }

    /// Current **map generation** of this handle, refreshing the fault
    /// source first if it moved. [`MappedView`]s compare it on every
    /// access: a change means cached pages may be stale (the view
    /// writes its dirty ranges back through the refreshed handle, then
    /// re-faults clean pages lazily). Plain backends never relocate, so
    /// the default is a constant; `SeaFs` writer handles report the
    /// registry entry's generation and reopen on the PFS after a
    /// mid-stream spill.
    fn map_sync(&mut self) -> Result<u64> {
        Ok(0)
    }

    /// Observe a page fault about to `pread` `[off, off + len)`.
    /// Default: no-op. `SeaFs` feeds faults into
    /// [`crate::placement::PlacementEngine::on_access`] so mapped reads
    /// heat files exactly like handle reads.
    fn note_map_fault(&mut self, off: u64, len: u64) {
        let _ = (off, len);
    }

    /// Surface a dup'd read-only fd on the handle's *current resident
    /// replica*, for the `sea serve` data plane to lease to a client
    /// over `SCM_RIGHTS` (see [`crate::serve::fdpass`]). `None` — the
    /// default — means the bytes are not addressable as one raw local
    /// fd: writable handles, striped or compressed replicas, decorators
    /// whose policy (e.g. rate caps) must observe every read. Only
    /// backends whose `pread` is byte-for-byte a `pread(2)` on one fd
    /// should implement this; the daemon pairs the fd with the map
    /// generation at mint time so relocation revokes the lease.
    fn lease_fd(&self) -> Option<std::fs::File> {
        None
    }

    /// A stable identity for the *file* this handle addresses, shared
    /// by every handle open on the same file, or `None` when the
    /// backend cannot name one. [`MappedView`]s key cache frames by
    /// it: handles reporting the same identity share frames — a fault
    /// through one view is a hit for every sibling (see [`pages`]) —
    /// while `None` falls back to a private per-view namespace.
    /// Backends derive it from coordinates that survive reopens but
    /// never outlive the file: device + inode for `RealFs`, instance +
    /// path for stripe-mode `StripedFs`, mount + path + registry epoch
    /// for `SeaFs` — folded through the 128-bit
    /// [`pages::identity_hash`], wide enough that two distinct files
    /// colliding onto one frame key (silent cross-file corruption) is
    /// not a practical event.
    fn map_identity(&self) -> Option<u128> {
        None
    }

    /// Map `[off, off + len)` of this handle as an mmap-style
    /// [`MappedView`] through `cache` (see [`pages`]). Works over any
    /// backend — faults ride on [`VfsFile::pread`], write-back on
    /// [`VfsFile::pwrite`]. (`Box<dyn VfsFile>` callers get the
    /// equivalent inherent method on `dyn VfsFile`.)
    fn map<'f>(
        &'f mut self,
        cache: &Arc<PageCache>,
        off: u64,
        len: u64,
        mode: MapMode,
    ) -> Result<MappedView<'f>>
    where
        Self: Sized,
    {
        MappedView::new(cache.clone(), self, off, len, mode)
    }
}

impl dyn VfsFile {
    /// [`VfsFile::map`] for trait objects: every `Vfs::open` handle is
    /// a `Box<dyn VfsFile>`, and `Sized`-bounded trait defaults are not
    /// in the vtable.
    pub fn map<'f>(
        &'f mut self,
        cache: &Arc<PageCache>,
        off: u64,
        len: u64,
        mode: MapMode,
    ) -> Result<MappedView<'f>> {
        MappedView::new(cache.clone(), self, off, len, mode)
    }
}

/// Handle-based POSIX-ish file-system operations. Whole-file `read` /
/// `write` are conveniences layered over [`Vfs::open`].
pub trait Vfs: Send + Sync {
    /// Open a handle on `path` in the given mode.
    fn open(&self, path: &Path, mode: OpenMode) -> Result<Box<dyn VfsFile>>;

    /// Remove the file at `path`.
    fn unlink(&self, path: &Path) -> Result<()>;

    /// Does `path` exist?
    fn exists(&self, path: &Path) -> bool;

    /// Size in bytes of the file at `path`.
    fn size(&self, path: &Path) -> Result<u64>;

    /// Rename `from` to `to` (same mount).
    fn rename(&self, from: &Path, to: &Path) -> Result<()>;

    /// List file names (not paths) under directory `path`.
    fn readdir(&self, path: &Path) -> Result<Vec<String>>;

    /// Ensure directory `path` exists (`create_dir_all` semantics:
    /// succeeds when it already does). Backends with a purely virtual
    /// namespace — where files materialize parents implicitly — keep
    /// the default no-op; directory-backed ones create it for real so
    /// daemon-served workloads laying out output trees see them on the
    /// mount.
    fn mkdir(&self, path: &Path) -> Result<()> {
        let _ = path;
        Ok(())
    }

    /// Block until background management work (flush/evict) is complete.
    /// No-op for backends without daemons.
    fn sync_mgmt(&self) -> Result<()> {
        Ok(())
    }

    /// Number of independent storage shards (e.g. striped-PFS members /
    /// OSTs) behind this backend, or `None` for monolithic backends.
    /// Decorators should delegate so topology survives wrapping.
    fn shard_count(&self) -> Option<usize> {
        None
    }

    /// Which shard `path` maps to (stable for a given path), when the
    /// backend is sharded. Schedulers use this to cap in-flight work
    /// per shard.
    fn shard_of(&self, path: &Path) -> Option<usize> {
        let _ = path;
        None
    }

    /// Stripe unit in bytes when the backend stripes *single files*
    /// across its shards at block granularity ([`StripedFs`] in stripe
    /// mode); `None` for whole-file placement. Bulk-copy engines
    /// ([`mover::DataMover`]) align their chunking to it so consecutive
    /// chunks of one large file fan out across members. Decorators
    /// should delegate so the hint survives wrapping.
    fn stripe_bytes(&self) -> Option<u64> {
        None
    }

    /// The backend's own [`PageCache`], when it carries one (`SeaFs`
    /// builds a per-mount cache from `SeaTuning::{page_bytes,
    /// page_budget}` so mapped-I/O gauges land on its counters).
    /// Decorators should delegate; callers without a backend cache fall
    /// back to [`pages::global`] or their own.
    fn page_cache(&self) -> Option<Arc<PageCache>> {
        None
    }

    /// Read the entire file at `path` (convenience over [`Vfs::open`]).
    fn read(&self, path: &Path) -> Result<Vec<u8>> {
        let mut f = self.open(path, OpenMode::Read)?;
        let len = f.len()? as usize;
        let mut buf = vec![0u8; len];
        let mut filled = 0usize;
        while filled < len {
            let n = f.pread(&mut buf[filled..], filled as u64)?;
            if n == 0 {
                break; // racing truncation: return what we got
            }
            filled += n;
        }
        buf.truncate(filled);
        Ok(buf)
    }

    /// Create/overwrite the file at `path` with `data` (convenience over
    /// [`Vfs::open`]).
    fn write(&self, path: &Path, data: &[u8]) -> Result<()> {
        let mut f = self.open(path, OpenMode::Write)?;
        f.pwrite_all(data, 0)
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    static COUNTER: AtomicU64 = AtomicU64::new(0);

    /// A unique scratch directory under the system temp dir.
    pub fn scratch(prefix: &str) -> PathBuf {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let d = std::env::temp_dir().join(format!(
            "sea_test_{prefix}_{}_{n}",
            std::process::id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }
}
