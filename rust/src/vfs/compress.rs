//! Transparent cold-tier compression: the block codec and the framed
//! on-disk container management transfers write to the PFS.
//!
//! The paper's thesis is that bytes moved between tiers dominate
//! end-to-end cost; the slow edge of this stack is the (rate-limited,
//! striped) PFS, so the [`crate::vfs::DataMover`] can compress chunks
//! *before* they cross that edge (`MoverCfg::codec`,
//! `SeaTuning::compress`). Like the hand-rolled error/rand/serde
//! substitutes elsewhere in the crate, the codec is written here from
//! scratch — no external crates.
//!
//! # Container format
//!
//! A compressed replica is a sequence of self-describing **frames**
//! (one per mover chunk), followed by a **frame index** and a fixed
//! **trailer**:
//!
//! ```text
//! [frame 0][frame 1]...[frame N-1][index: N x 16 B][trailer: 44 B]
//!
//! frame   = codec id (1 B) | logical len (4 B LE) | physical len
//!           (4 B LE) | checksum of the logical bytes (4 B LE)
//!           | payload (physical len bytes)
//! index   = per frame: physical offset (8 B LE) | logical len (4 B LE)
//!           | physical len (4 B LE)
//! trailer = index offset (8) | frame count (8) | logical length (8)
//!           | chunk size (8) | index checksum (4) | MAGIC (8)
//! ```
//!
//! Every frame holds exactly `chunk` logical bytes except the last, so
//! a logical offset maps to its frame by division — [`CompressedReader`]
//! `pread`s into a replica by seeking to the right frame and
//! decompressing only it, never the whole file. The trailer carries the
//! **logical length**, so the file is self-describing even after its
//! registry entry is evicted: `Vfs::size` and read paths report logical
//! bytes while the bytes on the PFS stay physical.
//!
//! # Codec
//!
//! [`Lz`] is an LZ77 byte-oriented block codec (LZ4-flavoured framing:
//! token nibbles for literal/match lengths with 255-run extensions,
//! 16-bit match offsets, minimum match 4). `compress_bounded` gives up
//! as soon as the output would exceed the caller's budget, which is how
//! the **incompressible passthrough** works: a chunk that does not beat
//! `min_ratio` is stored raw ([`CODEC_STORE`]), so the worst-case
//! overhead of a compressed replica is one 13-byte header per chunk
//! plus the index/trailer. Corrupted or truncated frames surface as
//! [`Error::Integrity`] — never a panic or a silent short read.

use crate::error::{Error, Result};
use crate::vfs::VfsFile;

/// Frame header bytes: codec id + logical len + physical len + checksum.
pub const FRAME_HDR: usize = 13;
/// Bytes per frame-index entry: physical offset + logical + physical.
pub const INDEX_ENTRY: usize = 16;
/// Fixed trailer at the end of every compressed replica.
pub const TRAILER_LEN: usize = 44;
/// Trailer magic (`"SEACOMPZ"`, little-endian).
pub const MAGIC: u64 = u64::from_le_bytes(*b"SEACOMPZ");

/// Frame payload is stored raw (the incompressible passthrough).
pub const CODEC_STORE: u8 = 0;
/// Frame payload is [`Lz`]-compressed.
pub const CODEC_LZ: u8 = 1;

const MIN_MATCH: usize = 4;
const MAX_OFFSET: usize = 65_535;
/// Matches never extend into the last bytes of a block, so the final
/// sequence is always literal-only and the decoder needs no wild-copy
/// guard (the same rule LZ4 uses).
const END_MARGIN: usize = 5;
const HASH_BITS: u32 = 14;

/// FNV-1a over `data` (integrity, not cryptography: it catches the
/// truncations and bit-rot a storage path produces).
pub fn checksum(data: &[u8]) -> u32 {
    let mut h = 0x811c_9dc5u32;
    for &b in data {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// A block codec: compresses one mover chunk into one frame payload.
///
/// Implementations are identified by a stable one-byte id stored in
/// every frame header, so replicas written by one codec stay readable
/// after the default changes.
pub trait Codec: Send + Sync {
    /// The id written into frame headers.
    fn id(&self) -> u8;

    /// Append a compressed form of `src` to `dst`, giving up (and
    /// returning `false`) as soon as `dst` would exceed `limit` bytes —
    /// the ratio gate for the store-raw passthrough.
    fn compress_bounded(&self, src: &[u8], dst: &mut Vec<u8>, limit: usize) -> bool;

    /// Decompress `src` into exactly `logical` bytes appended to a
    /// cleared `dst`. Malformed input is [`Error::Integrity`].
    fn decompress(&self, src: &[u8], logical: usize, dst: &mut Vec<u8>) -> Result<()>;
}

/// The hand-rolled LZ77 block codec (see the module doc for the wire
/// format). `level` trades search effort for ratio: it bounds how many
/// hash-chain candidates each position examines.
#[derive(Debug, Clone, Copy)]
pub struct Lz {
    level: u8,
}

impl Lz {
    /// A codec searching `level * 4` match candidates per position
    /// (`level` clamped to 1..=9; 1 keeps only a single-slot hash
    /// table and is the fast greedy mode).
    pub fn new(level: u8) -> Lz {
        Lz { level: level.clamp(1, 9) }
    }
}

impl Default for Lz {
    fn default() -> Lz {
        Lz::new(3)
    }
}

#[inline]
fn load4(s: &[u8], i: usize) -> u32 {
    u32::from_le_bytes([s[i], s[i + 1], s[i + 2], s[i + 3]])
}

#[inline]
fn hash4(v: u32) -> usize {
    (v.wrapping_mul(2_654_435_761) >> (32 - HASH_BITS)) as usize
}

/// Append one sequence (literal run + optional back-reference) to
/// `dst`; `false` when it would push `dst` past `limit`.
fn emit_seq(
    dst: &mut Vec<u8>,
    lits: &[u8],
    mat: Option<(usize, usize)>,
    limit: usize,
) -> bool {
    if lits.is_empty() && mat.is_none() {
        return true;
    }
    let lit_ext = if lits.len() >= 15 { (lits.len() - 15) / 255 + 1 } else { 0 };
    let mat_bytes = match mat {
        Some((_, len)) => {
            let ml = len - MIN_MATCH;
            2 + if ml >= 15 { (ml - 15) / 255 + 1 } else { 0 }
        }
        None => 0,
    };
    if dst.len() + 1 + lit_ext + lits.len() + mat_bytes > limit {
        return false;
    }
    let lit_nib = lits.len().min(15) as u8;
    let mat_nib = match mat {
        Some((_, len)) => (len - MIN_MATCH).min(15) as u8,
        None => 0,
    };
    dst.push((lit_nib << 4) | mat_nib);
    if lits.len() >= 15 {
        let mut rem = lits.len() - 15;
        while rem >= 255 {
            dst.push(255);
            rem -= 255;
        }
        dst.push(rem as u8);
    }
    dst.extend_from_slice(lits);
    if let Some((off, len)) = mat {
        dst.extend_from_slice(&(off as u16).to_le_bytes());
        let mut rem = len - MIN_MATCH;
        if rem >= 15 {
            rem -= 15;
            while rem >= 255 {
                dst.push(255);
                rem -= 255;
            }
            dst.push(rem as u8);
        }
    }
    true
}

impl Codec for Lz {
    fn id(&self) -> u8 {
        CODEC_LZ
    }

    fn compress_bounded(&self, src: &[u8], dst: &mut Vec<u8>, limit: usize) -> bool {
        let n = src.len();
        if n < MIN_MATCH + END_MARGIN {
            return emit_seq(dst, src, None, limit);
        }
        let match_zone = n - END_MARGIN;
        let mut head = vec![u32::MAX; 1 << HASH_BITS];
        // level 1 keeps no chain: only the newest position per bucket
        let mut prev = if self.level > 1 { vec![u32::MAX; n] } else { Vec::new() };
        let depth = self.level as usize * 4;
        let mut i = 0usize;
        let mut anchor = 0usize;
        while i + MIN_MATCH <= match_zone {
            let h = hash4(load4(src, i));
            let mut cand = head[h];
            let mut best_len = 0usize;
            let mut best_off = 0usize;
            let mut probes = depth;
            while cand != u32::MAX && probes > 0 {
                let c = cand as usize;
                if i - c > MAX_OFFSET {
                    break; // chain positions only get older
                }
                if load4(src, c) == load4(src, i) {
                    let max_len = match_zone - i;
                    let mut l = MIN_MATCH.min(max_len);
                    if src[c..c + l] == src[i..i + l] {
                        while l < max_len && src[c + l] == src[i + l] {
                            l += 1;
                        }
                        if l >= MIN_MATCH && l > best_len {
                            best_len = l;
                            best_off = i - c;
                        }
                    }
                }
                cand = if prev.is_empty() { u32::MAX } else { prev[c] };
                probes -= 1;
            }
            if best_len >= MIN_MATCH {
                if !emit_seq(dst, &src[anchor..i], Some((best_off, best_len)), limit) {
                    return false;
                }
                let end = i + best_len;
                // index the covered region so later matches reach into it
                while i < end && i + MIN_MATCH <= match_zone {
                    let h2 = hash4(load4(src, i));
                    if !prev.is_empty() {
                        prev[i] = head[h2];
                    }
                    head[h2] = i as u32;
                    i += 1;
                }
                i = end;
                anchor = end;
            } else {
                if !prev.is_empty() {
                    prev[i] = head[h];
                }
                head[h] = i as u32;
                i += 1;
            }
        }
        emit_seq(dst, &src[anchor..], None, limit)
    }

    fn decompress(&self, src: &[u8], logical: usize, dst: &mut Vec<u8>) -> Result<()> {
        let bad = |m: &str| Error::Integrity(format!("lz frame: {m}"));
        dst.clear();
        dst.reserve(logical);
        let mut ip = 0usize;
        while dst.len() < logical {
            let Some(&token) = src.get(ip) else {
                return Err(bad("truncated stream"));
            };
            ip += 1;
            let mut lit = (token >> 4) as usize;
            if lit == 15 {
                loop {
                    let Some(&b) = src.get(ip) else {
                        return Err(bad("truncated literal length"));
                    };
                    ip += 1;
                    lit += b as usize;
                    if b != 255 {
                        break;
                    }
                }
            }
            if lit > 0 {
                if ip + lit > src.len() {
                    return Err(bad("literal run past input"));
                }
                if dst.len() + lit > logical {
                    return Err(bad("literal run past logical size"));
                }
                dst.extend_from_slice(&src[ip..ip + lit]);
                ip += lit;
            }
            if dst.len() == logical {
                if token & 0x0F != 0 {
                    return Err(bad("match after logical end"));
                }
                break; // terminal literal-only sequence omits the match
            }
            if ip + 2 > src.len() {
                return Err(bad("truncated match offset"));
            }
            let off = u16::from_le_bytes([src[ip], src[ip + 1]]) as usize;
            ip += 2;
            if off == 0 || off > dst.len() {
                return Err(bad("match offset out of range"));
            }
            let mut mlen = (token & 0x0F) as usize;
            if mlen == 15 {
                loop {
                    let Some(&b) = src.get(ip) else {
                        return Err(bad("truncated match length"));
                    };
                    ip += 1;
                    mlen += b as usize;
                    if b != 255 {
                        break;
                    }
                }
            }
            let mlen = mlen + MIN_MATCH;
            if dst.len() + mlen > logical {
                return Err(bad("match run past logical size"));
            }
            // byte-by-byte: overlapping matches (off < mlen) are the
            // RLE case and must see their own freshly written bytes
            let start = dst.len() - off;
            for k in 0..mlen {
                let b = dst[start + k];
                dst.push(b);
            }
        }
        if ip != src.len() {
            return Err(bad("trailing bytes after stream"));
        }
        Ok(())
    }
}

/// The decoder for a frame's codec id, or `None` for an id this build
/// does not know.
pub fn decoder_for(id: u8) -> Option<&'static dyn Codec> {
    static LZ: Lz = Lz { level: 1 }; // level only affects encoding
    match id {
        CODEC_LZ => Some(&LZ),
        _ => None,
    }
}

/// One parsed frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHdr {
    /// Codec id ([`CODEC_STORE`] / [`CODEC_LZ`]).
    pub codec: u8,
    /// Logical (decompressed) bytes of this frame.
    pub logical: u32,
    /// Physical payload bytes following the header.
    pub physical: u32,
    /// Checksum of the logical bytes.
    pub checksum: u32,
}

impl FrameHdr {
    /// Parse the 13 header bytes.
    pub fn parse(b: &[u8; FRAME_HDR]) -> FrameHdr {
        FrameHdr {
            codec: b[0],
            logical: u32::from_le_bytes([b[1], b[2], b[3], b[4]]),
            physical: u32::from_le_bytes([b[5], b[6], b[7], b[8]]),
            checksum: u32::from_le_bytes([b[9], b[10], b[11], b[12]]),
        }
    }
}

/// Encode one mover chunk into a framed `out` (cleared first): header
/// plus either a compressed payload or — when compression cannot beat
/// `min_ratio_pct` percent of the logical size — the raw bytes
/// ([`CODEC_STORE`] passthrough, worst case one header of overhead).
pub fn encode_frame(codec: &dyn Codec, chunk: &[u8], min_ratio_pct: u16, out: &mut Vec<u8>) {
    out.clear();
    out.extend_from_slice(&[0u8; FRAME_HDR]);
    // keep the compressed form only when strictly under the gate
    let gate = ((chunk.len() as u128 * min_ratio_pct as u128) / 100) as usize;
    let fit = gate > 0 && codec.compress_bounded(chunk, out, FRAME_HDR + gate - 1);
    let (id, physical) = if fit {
        (codec.id(), out.len() - FRAME_HDR)
    } else {
        out.truncate(FRAME_HDR);
        out.extend_from_slice(chunk);
        (CODEC_STORE, chunk.len())
    };
    out[0] = id;
    out[1..5].copy_from_slice(&(chunk.len() as u32).to_le_bytes());
    out[5..9].copy_from_slice(&(physical as u32).to_le_bytes());
    out[9..13].copy_from_slice(&checksum(chunk).to_le_bytes());
}

/// Decode one frame given its parsed header and payload, into a
/// cleared `out`; verifies the checksum of the logical bytes.
pub fn decode_frame(hdr: &FrameHdr, payload: &[u8], out: &mut Vec<u8>) -> Result<()> {
    if payload.len() != hdr.physical as usize {
        return Err(Error::Integrity(format!(
            "frame payload is {} B, header says {}",
            payload.len(),
            hdr.physical
        )));
    }
    match hdr.codec {
        CODEC_STORE => {
            if hdr.physical != hdr.logical {
                return Err(Error::Integrity(
                    "stored frame: physical != logical".into(),
                ));
            }
            out.clear();
            out.extend_from_slice(payload);
        }
        id => {
            let codec = decoder_for(id).ok_or_else(|| {
                Error::Integrity(format!("unknown codec id {id} in frame header"))
            })?;
            codec.decompress(payload, hdr.logical as usize, out)?;
        }
    }
    if checksum(out) != hdr.checksum {
        return Err(Error::Integrity("frame checksum mismatch".into()));
    }
    Ok(())
}

/// One frame's index entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameInfo {
    /// Physical offset of the frame header in the replica.
    pub phys_off: u64,
    /// Logical bytes the frame decodes to.
    pub logical: u32,
    /// Physical payload bytes (header excluded).
    pub physical: u32,
}

/// Parsed shape of a compressed replica (from its index + trailer).
#[derive(Debug, Clone)]
pub struct Meta {
    /// Logical (decompressed) length of the whole file.
    pub logical_len: u64,
    /// Logical bytes per frame (all frames but the last).
    pub chunk: u64,
    /// Per-frame index, in file order.
    pub frames: Vec<FrameInfo>,
}

/// Accumulates the frame index while an encoder appends frames, then
/// renders the index + trailer bytes.
#[derive(Debug, Default)]
pub struct IndexBuilder {
    entries: Vec<u8>,
    count: u64,
    logical: u64,
}

impl IndexBuilder {
    /// An empty index.
    pub fn new() -> IndexBuilder {
        IndexBuilder::default()
    }

    /// Record one appended frame.
    pub fn push(&mut self, phys_off: u64, logical: u32, physical: u32) {
        self.entries.extend_from_slice(&phys_off.to_le_bytes());
        self.entries.extend_from_slice(&logical.to_le_bytes());
        self.entries.extend_from_slice(&physical.to_le_bytes());
        self.count += 1;
        self.logical += logical as u64;
    }

    /// Logical bytes indexed so far.
    pub fn logical(&self) -> u64 {
        self.logical
    }

    /// Render the index + trailer to append after the last frame at
    /// physical offset `index_off`.
    pub fn finish(self, chunk: u64, index_off: u64) -> Vec<u8> {
        let mut out = self.entries;
        let ck = checksum(&out[..]);
        out.extend_from_slice(&index_off.to_le_bytes());
        out.extend_from_slice(&self.count.to_le_bytes());
        out.extend_from_slice(&self.logical.to_le_bytes());
        out.extend_from_slice(&chunk.to_le_bytes());
        out.extend_from_slice(&ck.to_le_bytes());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out
    }
}

/// Write a whole compressed replica of `data` through `dst` (frames of
/// `chunk` logical bytes, index, trailer); returns physical bytes
/// written. The streaming paths live in the `DataMover`; this helper
/// serves tests and small in-place rewrites.
pub fn write_compressed(
    dst: &mut dyn VfsFile,
    data: &[u8],
    chunk: usize,
    codec: &dyn Codec,
    min_ratio_pct: u16,
) -> Result<u64> {
    let chunk = chunk.max(1);
    let mut index = IndexBuilder::new();
    let mut off = 0u64;
    let mut frame = Vec::new();
    for piece in data.chunks(chunk) {
        encode_frame(codec, piece, min_ratio_pct, &mut frame);
        dst.pwrite_all(&frame, off)?;
        index.push(off, piece.len() as u32, (frame.len() - FRAME_HDR) as u32);
        off += frame.len() as u64;
    }
    let tail = index.finish(chunk as u64, off);
    dst.pwrite_all(&tail, off)?;
    Ok(off + tail.len() as u64)
}

fn read_u64(b: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(b[at..at + 8].try_into().expect("8 bytes"))
}

fn read_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(b[at..at + 4].try_into().expect("4 bytes"))
}

/// Cheap trailer-only probe: `Some(logical length)` when `file` is a
/// compressed replica, `None` when it is a plain file. Magic mismatch
/// is `None` (not an error — most files are plain); a matching magic
/// with an inconsistent trailer is [`Error::Integrity`].
pub fn logical_len(file: &mut dyn VfsFile) -> Result<Option<u64>> {
    Ok(trailer(file)?.map(|t| t.logical_len))
}

#[derive(Debug, Clone, Copy)]
struct Trailer {
    index_off: u64,
    frame_count: u64,
    logical_len: u64,
    chunk: u64,
    index_ck: u32,
    file_len: u64,
}

fn trailer(file: &mut dyn VfsFile) -> Result<Option<Trailer>> {
    let file_len = file.len()?;
    if file_len < TRAILER_LEN as u64 {
        return Ok(None);
    }
    let mut b = [0u8; TRAILER_LEN];
    file.pread_exact(&mut b, file_len - TRAILER_LEN as u64)?;
    if read_u64(&b, 36) != MAGIC {
        return Ok(None);
    }
    let t = Trailer {
        index_off: read_u64(&b, 0),
        frame_count: read_u64(&b, 8),
        logical_len: read_u64(&b, 16),
        chunk: read_u64(&b, 24),
        index_ck: read_u32(&b, 32),
        file_len,
    };
    let bad = |m: &str| Error::Integrity(format!("compressed trailer: {m}"));
    if t.chunk == 0 {
        return Err(bad("zero chunk size"));
    }
    let index_bytes = t
        .frame_count
        .checked_mul(INDEX_ENTRY as u64)
        .ok_or_else(|| bad("frame count overflows"))?;
    if t.index_off
        .checked_add(index_bytes)
        .and_then(|v| v.checked_add(TRAILER_LEN as u64))
        != Some(file_len)
    {
        return Err(bad("index does not tile the file"));
    }
    let want_frames = t
        .logical_len
        .checked_add(t.chunk - 1)
        .ok_or_else(|| bad("logical length overflows"))?
        / t.chunk;
    if t.frame_count != want_frames {
        return Err(bad("frame count disagrees with logical length"));
    }
    Ok(Some(t))
}

/// Full probe: parse and verify the frame index. `Ok(None)` for plain
/// files, `Ok(Some(meta))` for a well-formed compressed replica,
/// [`Error::Integrity`] for a replica whose trailer or index is
/// corrupt.
pub fn probe(file: &mut dyn VfsFile) -> Result<Option<Meta>> {
    let Some(t) = trailer(file)? else {
        return Ok(None);
    };
    let bad = |m: &str| Error::Integrity(format!("compressed index: {m}"));
    let index_bytes = (t.frame_count * INDEX_ENTRY as u64) as usize;
    let mut raw = vec![0u8; index_bytes];
    file.pread_exact(&mut raw, t.index_off)?;
    if checksum(&raw) != t.index_ck {
        return Err(bad("checksum mismatch"));
    }
    let mut frames = Vec::with_capacity(t.frame_count as usize);
    let mut logical_sum = 0u64;
    let mut next_off = 0u64;
    for (i, e) in raw.chunks_exact(INDEX_ENTRY).enumerate() {
        let f = FrameInfo {
            phys_off: read_u64(e, 0),
            logical: read_u32(e, 8),
            physical: read_u32(e, 12),
        };
        if f.phys_off != next_off {
            return Err(bad("frames do not tile the data region"));
        }
        if f.logical == 0 || f.logical as u64 > t.chunk {
            return Err(bad("frame logical length out of range"));
        }
        let last = i as u64 == t.frame_count - 1;
        if !last && f.logical as u64 != t.chunk {
            return Err(bad("interior frame is not chunk-sized"));
        }
        next_off = f.phys_off + (FRAME_HDR as u64 + f.physical as u64);
        logical_sum += f.logical as u64;
        frames.push(f);
    }
    if next_off != t.index_off {
        return Err(bad("data region does not meet the index"));
    }
    if logical_sum != t.logical_len {
        return Err(bad("frame logical lengths disagree with the trailer"));
    }
    Ok(Some(Meta { logical_len: t.logical_len, chunk: t.chunk, frames }))
}

/// A seekable logical view over a compressed replica: `pread(off)`
/// locates `off / chunk` in the frame index, decompresses that frame
/// only (with a one-frame cache for sequential streams), and serves
/// logical bytes. `len()` is the logical length. Writes are refused —
/// replicas are rewritten whole by the management paths.
pub struct CompressedReader {
    inner: Box<dyn VfsFile>,
    meta: Meta,
    /// `(frame index, logical bytes)` of the last decoded frame.
    cached: Option<(usize, Vec<u8>)>,
    payload: Vec<u8>,
}

impl CompressedReader {
    /// Wrap an open replica whose shape was read by [`probe`].
    pub fn new(inner: Box<dyn VfsFile>, meta: Meta) -> CompressedReader {
        CompressedReader { inner, meta, cached: None, payload: Vec::new() }
    }

    /// The replica's parsed shape.
    pub fn meta(&self) -> &Meta {
        &self.meta
    }

    fn load_frame(&mut self, fi: usize) -> Result<()> {
        if matches!(self.cached, Some((idx, _)) if idx == fi) {
            return Ok(());
        }
        let info = self.meta.frames[fi];
        let mut hdr_raw = [0u8; FRAME_HDR];
        self.inner.pread_exact(&mut hdr_raw, info.phys_off)?;
        let hdr = FrameHdr::parse(&hdr_raw);
        if hdr.logical != info.logical || hdr.physical != info.physical {
            return Err(Error::Integrity(format!(
                "frame {fi}: header disagrees with the index"
            )));
        }
        self.payload.resize(hdr.physical as usize, 0);
        self.inner.pread_exact(&mut self.payload, info.phys_off + FRAME_HDR as u64)?;
        let mut out = match self.cached.take() {
            Some((_, buf)) => buf,
            None => Vec::new(),
        };
        decode_frame(&hdr, &self.payload, &mut out)?;
        self.cached = Some((fi, out));
        Ok(())
    }
}

impl VfsFile for CompressedReader {
    fn pread(&mut self, buf: &mut [u8], off: u64) -> Result<usize> {
        if buf.is_empty() || off >= self.meta.logical_len {
            return Ok(0);
        }
        let fi = (off / self.meta.chunk) as usize;
        self.load_frame(fi)?;
        let (_, data) = self.cached.as_ref().expect("frame just loaded");
        let within = (off - fi as u64 * self.meta.chunk) as usize;
        let n = buf.len().min(data.len() - within);
        buf[..n].copy_from_slice(&data[within..within + n]);
        Ok(n)
    }

    fn pwrite(&mut self, _data: &[u8], _off: u64) -> Result<usize> {
        Err(Error::InvalidArg(
            "write through a compressed-replica reader".into(),
        ))
    }

    fn set_len(&mut self, _len: u64) -> Result<()> {
        Err(Error::InvalidArg(
            "truncate through a compressed-replica reader".into(),
        ))
    }

    fn fsync(&mut self) -> Result<()> {
        self.inner.fsync()
    }

    fn len(&self) -> Result<u64> {
        Ok(self.meta.logical_len)
    }

    fn map_sync(&mut self) -> Result<u64> {
        self.inner.map_sync()
    }

    fn note_map_fault(&mut self, off: u64, len: u64) {
        self.inner.note_map_fault(off, len);
    }

    fn map_identity(&self) -> Option<u128> {
        self.inner.map_identity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vfs::testutil::scratch;
    use crate::vfs::{OpenMode, RealFs, Vfs};
    use std::path::PathBuf;

    const CHUNK: usize = 4096;

    /// A deterministic pseudo-random byte stream (no rand crate).
    fn noise(len: usize, mut seed: u64) -> Vec<u8> {
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            seed = seed
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            out.push((seed >> 33) as u8);
        }
        out
    }

    /// Repetitive, text-like corpus that compresses well.
    fn prose(len: usize) -> Vec<u8> {
        let line = b"the quick brown fox jumps over the lazy dog 0123456789\n";
        line.iter().copied().cycle().take(len).collect()
    }

    fn codec_roundtrip(codec: &Lz, data: &[u8]) {
        let mut comp = Vec::new();
        // an unbounded budget: always completes
        assert!(codec.compress_bounded(data, &mut comp, usize::MAX));
        let mut back = Vec::new();
        codec.decompress(&comp, data.len(), &mut back).unwrap();
        assert_eq!(back, data, "codec round trip ({} B)", data.len());
    }

    #[test]
    fn codec_roundtrips_every_size_class() {
        for level in [1u8, 3, 9] {
            let lz = Lz::new(level);
            codec_roundtrip(&lz, b"");
            codec_roundtrip(&lz, b"x");
            codec_roundtrip(&lz, &prose(CHUNK - 1));
            codec_roundtrip(&lz, &prose(CHUNK));
            codec_roundtrip(&lz, &prose(CHUNK + 1));
            codec_roundtrip(&lz, &noise(CHUNK, 7));
            codec_roundtrip(&lz, &vec![0u8; 3 * CHUNK]); // extreme RLE
            codec_roundtrip(&lz, &prose(3 * CHUNK + 17)); // multi-frame sized
        }
    }

    #[test]
    fn compressible_corpus_actually_shrinks() {
        let lz = Lz::default();
        let data = prose(CHUNK);
        let mut comp = Vec::new();
        assert!(lz.compress_bounded(&data, &mut comp, usize::MAX));
        assert!(
            comp.len() < data.len() / 2,
            "prose should at least halve: {} -> {}",
            data.len(),
            comp.len()
        );
    }

    #[test]
    fn incompressible_chunks_fall_back_to_store() {
        let data = noise(CHUNK, 99);
        let mut frame = Vec::new();
        encode_frame(&Lz::default(), &data, 100, &mut frame);
        assert_eq!(frame[0], CODEC_STORE, "noise stores raw");
        assert_eq!(frame.len(), FRAME_HDR + CHUNK, "one header of overhead");
        let hdr = FrameHdr::parse(frame[..FRAME_HDR].try_into().unwrap());
        let mut back = Vec::new();
        decode_frame(&hdr, &frame[FRAME_HDR..], &mut back).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn min_ratio_gate_stores_marginal_chunks() {
        let data = prose(CHUNK);
        // prose compresses to well under half; a 10% gate still refuses it
        let mut frame = Vec::new();
        encode_frame(&Lz::default(), &data, 1, &mut frame);
        assert_eq!(frame[0], CODEC_STORE, "1% gate is unreachable");
        encode_frame(&Lz::default(), &data, 100, &mut frame);
        assert_eq!(frame[0], CODEC_LZ, "default gate keeps the win");
        assert!(frame.len() < FRAME_HDR + CHUNK);
    }

    #[test]
    fn corrupted_frames_surface_typed_errors() {
        let data = prose(CHUNK);
        let mut frame = Vec::new();
        encode_frame(&Lz::default(), &data, 100, &mut frame);
        let hdr = FrameHdr::parse(frame[..FRAME_HDR].try_into().unwrap());
        let mut out = Vec::new();
        // flip a payload byte: checksum or structure must catch it
        for at in [FRAME_HDR, FRAME_HDR + 1, frame.len() - 1] {
            let mut bent = frame.clone();
            bent[at] ^= 0x5A;
            assert!(
                matches!(
                    decode_frame(&hdr, &bent[FRAME_HDR..], &mut out),
                    Err(Error::Integrity(_))
                ),
                "flip at {at}"
            );
        }
        // truncated payload
        assert!(matches!(
            decode_frame(&hdr, &frame[FRAME_HDR..frame.len() - 1], &mut out),
            Err(Error::Integrity(_))
        ));
        // unknown codec id
        let mut wild = hdr;
        wild.codec = 0x7F;
        assert!(matches!(
            decode_frame(&wild, &frame[FRAME_HDR..], &mut out),
            Err(Error::Integrity(_))
        ));
    }

    #[test]
    fn container_roundtrip_and_seek() {
        let dir = scratch("compress_container");
        let fs_ = RealFs::new(&dir).unwrap();
        let data = prose(3 * CHUNK + 17);
        let p = PathBuf::from("replica.z");
        {
            let mut f = fs_.open(&p, OpenMode::Write).unwrap();
            let phys =
                write_compressed(f.as_mut(), &data, CHUNK, &Lz::default(), 100).unwrap();
            assert_eq!(phys, f.len().unwrap());
            assert!(phys < data.len() as u64, "prose replica shrinks");
        }
        let mut f = fs_.open(&p, OpenMode::Read).unwrap();
        let meta = probe(f.as_mut()).unwrap().expect("magic present");
        assert_eq!(meta.logical_len, data.len() as u64);
        assert_eq!(meta.frames.len(), 4);
        let mut r = CompressedReader::new(f, meta);
        assert_eq!(r.len().unwrap(), data.len() as u64);
        // seeked reads hit one frame, never the whole file
        let mut mid = vec![0u8; 64];
        r.pread_exact(&mut mid, (2 * CHUNK + 100) as u64).unwrap();
        assert_eq!(&mid[..], &data[2 * CHUNK + 100..2 * CHUNK + 164]);
        // cross-frame read via pread_exact's loop
        let mut span = vec![0u8; 200];
        r.pread_exact(&mut span, (CHUNK - 100) as u64).unwrap();
        assert_eq!(&span[..], &data[CHUNK - 100..CHUNK + 100]);
        // whole-file stream
        let mut all = vec![0u8; data.len()];
        r.pread_exact(&mut all, 0).unwrap();
        assert_eq!(all, data);
        // past-eof reads return 0
        let mut none = [0u8; 8];
        assert_eq!(r.pread(&mut none, data.len() as u64).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_container_roundtrips() {
        let dir = scratch("compress_empty");
        let fs_ = RealFs::new(&dir).unwrap();
        let p = PathBuf::from("empty.z");
        {
            let mut f = fs_.open(&p, OpenMode::Write).unwrap();
            write_compressed(f.as_mut(), b"", CHUNK, &Lz::default(), 100).unwrap();
        }
        let mut f = fs_.open(&p, OpenMode::Read).unwrap();
        assert_eq!(logical_len(f.as_mut()).unwrap(), Some(0));
        let meta = probe(f.as_mut()).unwrap().unwrap();
        assert_eq!(meta.frames.len(), 0);
        let mut r = CompressedReader::new(f, meta);
        let mut buf = [0u8; 4];
        assert_eq!(r.pread(&mut buf, 0).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn plain_files_probe_as_none() {
        let dir = scratch("compress_plain");
        let fs_ = RealFs::new(&dir).unwrap();
        let p = PathBuf::from("plain.dat");
        fs_.write(&p, &noise(2 * CHUNK, 3)).unwrap();
        let mut f = fs_.open(&p, OpenMode::Read).unwrap();
        assert!(probe(f.as_mut()).unwrap().is_none());
        assert_eq!(logical_len(f.as_mut()).unwrap(), None);
        // too-short files can't even hold a trailer
        fs_.write(&p, b"tiny").unwrap();
        let mut f = fs_.open(&p, OpenMode::Read).unwrap();
        assert!(probe(f.as_mut()).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_container_is_a_typed_error() {
        let dir = scratch("compress_corrupt");
        let fs_ = RealFs::new(&dir).unwrap();
        let data = prose(2 * CHUNK);
        let p = PathBuf::from("replica.z");
        let phys = {
            let mut f = fs_.open(&p, OpenMode::Write).unwrap();
            write_compressed(f.as_mut(), &data, CHUNK, &Lz::default(), 100).unwrap()
        };
        // bend one index byte: probe must fail, not misread
        {
            let mut f = fs_.open(&p, OpenMode::ReadWrite).unwrap();
            let at = phys - TRAILER_LEN as u64 - 10;
            let mut b = [0u8; 1];
            f.pread_exact(&mut b, at).unwrap();
            f.pwrite_all(&[b[0] ^ 0xFF], at).unwrap();
        }
        let mut f = fs_.open(&p, OpenMode::Read).unwrap();
        assert!(matches!(probe(f.as_mut()), Err(Error::Integrity(_))));
        // truncate mid-index: trailer geometry no longer tiles
        {
            let mut f = fs_.open(&p, OpenMode::ReadWrite).unwrap();
            let cut = phys - TRAILER_LEN as u64 - 1;
            let mut tail = vec![0u8; TRAILER_LEN];
            f.pread_exact(&mut tail, phys - TRAILER_LEN as u64).unwrap();
            f.set_len(cut).unwrap();
            f.pwrite_all(&tail, cut - TRAILER_LEN as u64 + 1).unwrap();
            let keep = cut + 1;
            f.set_len(keep).unwrap();
        }
        let mut f = fs_.open(&p, OpenMode::Read).unwrap();
        assert!(matches!(probe(f.as_mut()), Err(Error::Integrity(_))));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
