//! The streaming **DataMover**: bounded-memory, pipelined bulk copies
//! between [`VfsFile`] handles.
//!
//! Every management transfer in a Sea mount — flush-pool flushes,
//! mid-stream self-spills, victim spills, promotions, and the
//! mount-time prefetch pass — moves whole files between tiers. The
//! seed implementation materialized each file as one `Vec<u8>`, so
//! peak memory scaled with file size × in-flight jobs (617 MiB
//! BigBrain blocks × 4 flush workers ≈ 2.4 GiB of copy buffers), and
//! the read had to finish before the write began. The DataMover
//! replaces that with chunked, double-buffered transfers: a reader
//! thread preads `chunk_bytes`-sized chunks ahead while the caller's
//! thread writes completed chunks behind, with at most `copy_window`
//! chunk buffers allocated per transfer. Peak copy memory is
//! `chunk_bytes × copy_window` regardless of file size, and reads
//! overlap writes — exactly the data-movement cost the paper's library
//! exists to minimize.
//!
//! When the destination advertises a stripe unit
//! ([`crate::vfs::Vfs::stripe_bytes`], e.g. a chunk-striped
//! [`crate::vfs::StripedFs`]), [`MoverCfg::aligned_to`] snaps the
//! chunk size to whole stripes so consecutive chunks of one large file
//! land on *different* members — a single file's flush aggregates
//! bandwidth across OSTs instead of queuing on one.
//!
//! [`MoverMetrics`] tracks bytes moved per management path and the
//! high-water mark of allocated copy-buffer bytes, so the
//! bounded-memory claim is observable (`sea stat`,
//! [`crate::vfs::MgmtCounters`]).
//!
//! With [`MoverCfg::codec`] set to [`CodecMode::Encode`], the reader
//! thread additionally compresses each chunk into a
//! [`crate::vfs::compress`] frame before handing it to the writer —
//! compression overlaps the destination writes exactly like the
//! read-ahead does, and the buffer budget stays one read buffer plus
//! `copy_window - 1` frame buffers. Decompression needs no mover mode:
//! compressed sources are wrapped in a
//! [`crate::vfs::compress::CompressedReader`], so the reader thread's
//! `pread`s decompress in the read-ahead thread on Promote /
//! read-through paths. [`MoverMetrics`] then tracks *logical* bytes on
//! the per-path gauges and *physical* (post-codec) bytes on the
//! physical gauges.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;

use crate::error::{Error, Result};
use crate::obs::{Metric, Timer};
use crate::vfs::compress::{encode_frame, IndexBuilder, Lz, FRAME_HDR};
use crate::vfs::VfsFile;

/// Default chunk size for streamed transfers: large enough to amortize
/// per-request overhead, small enough that a pool of concurrent
/// transfers stays far below one BigBrain block.
pub const DEFAULT_CHUNK_BYTES: usize = 1 << 20;

/// Default in-flight chunk window: double buffering — one chunk being
/// read ahead while the previous one is written behind.
pub const DEFAULT_COPY_WINDOW: usize = 2;

/// What the mover does to chunks on their way to the destination.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CodecMode {
    /// Plain byte-for-byte copy.
    Off,
    /// Compress each chunk into a [`crate::vfs::compress`] frame in
    /// the read-ahead thread; the destination becomes a framed
    /// compressed replica (frames + index + trailer). There is no
    /// decode mode — compressed *sources* are wrapped in a
    /// [`crate::vfs::compress::CompressedReader`] instead, so the
    /// read-ahead thread decompresses on its `pread`s.
    Encode {
        /// [`Lz`] search effort, 1..=9.
        level: u8,
        /// Keep a compressed chunk only when its physical size is
        /// strictly under `min_ratio_pct` percent of the logical size;
        /// otherwise store raw (100 = store unless it actually
        /// shrinks).
        min_ratio_pct: u16,
    },
}

/// Tuning for streamed transfers (`[sea] chunk_bytes` / `copy_window`,
/// `sea run --chunk-bytes / --copy-window`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MoverCfg {
    /// Bytes per chunk (min 1).
    pub chunk_bytes: usize,
    /// Max chunk buffers in flight per transfer (min 1; 1 disables
    /// read-ahead and degenerates to a synchronous chunked loop).
    pub copy_window: usize,
    /// Per-chunk codec stage (default [`CodecMode::Off`]).
    pub codec: CodecMode,
}

impl Default for MoverCfg {
    fn default() -> MoverCfg {
        MoverCfg {
            chunk_bytes: DEFAULT_CHUNK_BYTES,
            copy_window: DEFAULT_COPY_WINDOW,
            codec: CodecMode::Off,
        }
    }
}

impl MoverCfg {
    /// Align the chunk size to a destination's stripe unit, when it
    /// advertises one: chunks that are whole stripes map 1:1 onto
    /// striped members, so consecutive in-flight chunks of one large
    /// file fan out across OSTs instead of splitting every request at
    /// a member boundary. Alignment only ever rounds *down* (to a
    /// whole number of stripes) — `chunk_bytes` is a memory budget,
    /// and the `chunk_bytes × copy_window` bound must hold whatever
    /// stripe unit the destination uses. A chunk smaller than one
    /// stripe is left alone: each write then stays within a single
    /// member and the fan-out happens at chunk granularity anyway.
    pub fn aligned_to(mut self, stripe: Option<u64>) -> MoverCfg {
        if let Some(s) = stripe {
            let s = s.max(1) as usize;
            if self.chunk_bytes >= s {
                self.chunk_bytes -= self.chunk_bytes % s;
            }
        }
        self
    }
}

/// Which management path a transfer serves (per-path byte gauges).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MovePath {
    /// Close-time flush of a device copy to the PFS.
    Flush,
    /// Mid-stream self-spill or victim spill under device pressure.
    Spill,
    /// Pull of a PFS-resident file back onto a fast tier.
    Promote,
    /// Mount-time / explicit prefetch of PFS inputs.
    Prefetch,
}

/// Cumulative DataMover gauges for one mount. All fields are atomics:
/// transfers run concurrently on flush-pool workers and writer threads.
#[derive(Debug, Default)]
pub struct MoverMetrics {
    flush_bytes: AtomicU64,
    spill_bytes: AtomicU64,
    promote_bytes: AtomicU64,
    prefetch_bytes: AtomicU64,
    /// Post-codec bytes that actually crossed the tier edge, per path.
    /// Equal to the logical gauges when no codec is involved; smaller
    /// on compressed Flush/Spill (bytes written), and the compressed
    /// replica's size on Promote/Prefetch reads through a
    /// `CompressedReader`.
    flush_physical: AtomicU64,
    spill_physical: AtomicU64,
    promote_physical: AtomicU64,
    prefetch_physical: AtomicU64,
    /// Copy-buffer bytes currently allocated across live transfers.
    buffer_bytes: AtomicU64,
    /// High-water mark of `buffer_bytes`.
    peak_buffer_bytes: AtomicU64,
}

impl MoverMetrics {
    /// Record `bytes` moved on `path`.
    pub fn record(&self, path: MovePath, bytes: u64) {
        self.gauge(path).fetch_add(bytes, Ordering::Relaxed);
    }

    /// Bytes moved on `path` so far.
    pub fn moved(&self, path: MovePath) -> u64 {
        self.gauge(path).load(Ordering::Relaxed)
    }

    /// Record `bytes` of post-codec traffic on `path`.
    pub fn record_physical(&self, path: MovePath, bytes: u64) {
        self.physical_gauge(path).fetch_add(bytes, Ordering::Relaxed);
    }

    /// Post-codec bytes moved on `path` so far.
    pub fn moved_physical(&self, path: MovePath) -> u64 {
        self.physical_gauge(path).load(Ordering::Relaxed)
    }

    fn physical_gauge(&self, path: MovePath) -> &AtomicU64 {
        match path {
            MovePath::Flush => &self.flush_physical,
            MovePath::Spill => &self.spill_physical,
            MovePath::Promote => &self.promote_physical,
            MovePath::Prefetch => &self.prefetch_physical,
        }
    }

    /// High-water mark of allocated copy-buffer bytes across all
    /// concurrent transfers (the bounded-memory gauge: one transfer
    /// never exceeds `chunk_bytes × copy_window`).
    pub fn peak_buffer_bytes(&self) -> u64 {
        self.peak_buffer_bytes.load(Ordering::Relaxed)
    }

    fn gauge(&self, path: MovePath) -> &AtomicU64 {
        match path {
            MovePath::Flush => &self.flush_bytes,
            MovePath::Spill => &self.spill_bytes,
            MovePath::Promote => &self.promote_bytes,
            MovePath::Prefetch => &self.prefetch_bytes,
        }
    }

    fn buffers_grew(&self, by: u64) {
        let now = self.buffer_bytes.fetch_add(by, Ordering::Relaxed) + by;
        self.peak_buffer_bytes.fetch_max(now, Ordering::Relaxed);
    }

    fn buffers_shrank(&self, by: u64) {
        self.buffer_bytes.fetch_sub(by, Ordering::Relaxed);
    }
}

/// RAII registration of a transfer's buffer allocation in the metrics,
/// so early error returns never leak the in-flight count.
struct BufferLease<'a> {
    metrics: Option<&'a MoverMetrics>,
    bytes: u64,
}

impl<'a> BufferLease<'a> {
    fn new(metrics: Option<&'a MoverMetrics>, bytes: u64) -> BufferLease<'a> {
        if let Some(m) = metrics {
            m.buffers_grew(bytes);
        }
        BufferLease { metrics, bytes }
    }
}

impl Drop for BufferLease<'_> {
    fn drop(&mut self) {
        if let Some(m) = self.metrics {
            m.buffers_shrank(self.bytes);
        }
    }
}

/// Synchronous chunked copy of `[off, off + len)` from `src` to `dst`
/// (same offsets on both sides), one bounded buffer, no read-ahead.
/// Returns the bytes actually copied — a short count means the source
/// ended early (racing truncation or a sparse reserved-but-unwritten
/// tail). Used standalone for small ranges (spill re-copy under the
/// shard lock, where spawning a reader thread is not an option) and as
/// the `copy_window = 1` degenerate case of [`DataMover::copy`].
pub fn copy_range(
    src: &mut dyn VfsFile,
    dst: &mut dyn VfsFile,
    off: u64,
    len: u64,
    chunk_bytes: usize,
    metrics: Option<&MoverMetrics>,
) -> Result<u64> {
    if len == 0 {
        return Ok(0);
    }
    let chunk = (chunk_bytes.max(1) as u64).min(len) as usize;
    let _lease = BufferLease::new(metrics, chunk as u64);
    let mut buf = vec![0u8; chunk];
    let mut done = 0u64;
    while done < len {
        let want = ((len - done) as usize).min(chunk);
        let n = src.pread(&mut buf[..want], off + done)?;
        if n == 0 {
            break;
        }
        let t = Timer::start();
        dst.pwrite_all(&buf[..n], off + done)?;
        t.stop(Metric::MoverChunk);
        done += n as u64;
    }
    Ok(done)
}

/// One streamed transfer job: a pipelined (read-ahead / write-behind)
/// chunked copy with a bounded in-flight window.
pub struct DataMover<'a> {
    cfg: MoverCfg,
    class: MovePath,
    metrics: Option<&'a MoverMetrics>,
    /// Known physical size of the source's backing bytes, when the
    /// caller reads through a decoding wrapper (a `CompressedReader`):
    /// the physical gauges then record what actually crossed the slow
    /// edge instead of the logical byte count.
    physical_hint: Option<u64>,
}

impl<'a> DataMover<'a> {
    /// A mover for one transfer on the given management path.
    pub fn new(cfg: MoverCfg, class: MovePath) -> DataMover<'a> {
        DataMover { cfg, class, metrics: None, physical_hint: None }
    }

    /// Attach per-mount gauges.
    pub fn with_metrics(mut self, m: &'a MoverMetrics) -> DataMover<'a> {
        self.metrics = Some(m);
        self
    }

    /// Declare the physical size behind a decoding source wrapper (see
    /// `physical_hint`).
    pub fn with_physical(mut self, bytes: u64) -> DataMover<'a> {
        self.physical_hint = Some(bytes);
        self
    }

    /// Copy the first `len` bytes of `src` into `dst` (offset 0 on
    /// both sides). Returns the *logical* bytes copied; a short count
    /// means the source ended early (racing truncation or a sparse
    /// reserved-but-unwritten tail) — callers decide whether that is
    /// fatal. Peak buffer memory is `chunk_bytes × copy_window`.
    ///
    /// In [`CodecMode::Encode`] the destination becomes a framed
    /// compressed replica; its index + trailer are only written when
    /// the full `len` bytes arrived, so a short encoded copy leaves a
    /// probe-invalid destination (callers on management paths already
    /// treat short as fatal and unlink).
    pub fn copy(
        &self,
        src: &mut dyn VfsFile,
        dst: &mut dyn VfsFile,
        len: u64,
    ) -> Result<u64> {
        self.copy_counted(src, dst, len).map(|(logical, _)| logical)
    }

    /// [`DataMover::copy`], also returning the physical bytes written
    /// to (or, with a physical hint, read from) the slow side.
    pub fn copy_counted(
        &self,
        src: &mut dyn VfsFile,
        dst: &mut dyn VfsFile,
        len: u64,
    ) -> Result<(u64, u64)> {
        let chunk = self.cfg.chunk_bytes.max(1);
        let window = self.cfg.copy_window.max(1);
        let nchunks = if len == 0 {
            0
        } else {
            (len + chunk as u64 - 1) / chunk as u64
        };
        let (done, physical) = match self.cfg.codec {
            CodecMode::Off => {
                let done = if window == 1 || nchunks <= 1 {
                    // single chunk or no read-ahead budget: plain loop
                    copy_range(src, dst, 0, len, chunk, self.metrics)?
                } else {
                    self.copy_pipelined(src, dst, len, chunk, window.min(nchunks as usize))?
                };
                // the hint describes the whole source: only meaningful
                // when the transfer completed
                let physical = match self.physical_hint {
                    Some(p) if done == len => p,
                    _ => done,
                };
                (done, physical)
            }
            CodecMode::Encode { level, min_ratio_pct } => {
                let codec = Lz::new(level);
                if window == 1 || nchunks <= 1 {
                    self.copy_encoded_sync(src, dst, len, chunk, &codec, min_ratio_pct)?
                } else {
                    self.copy_encoded_pipelined(
                        src,
                        dst,
                        len,
                        chunk,
                        window.min(nchunks as usize).max(2),
                        &codec,
                        min_ratio_pct,
                    )?
                }
            }
        };
        if let Some(m) = self.metrics {
            m.record(self.class, done);
            m.record_physical(self.class, physical);
        }
        Ok((done, physical))
    }

    /// Encoded copy without a reader thread: read chunk, frame it,
    /// append. One read buffer + one frame buffer.
    fn copy_encoded_sync(
        &self,
        src: &mut dyn VfsFile,
        dst: &mut dyn VfsFile,
        len: u64,
        chunk: usize,
        codec: &Lz,
        min_ratio_pct: u16,
    ) -> Result<(u64, u64)> {
        let _lease = BufferLease::new(self.metrics, (2 * chunk + FRAME_HDR) as u64);
        let mut read_buf = vec![0u8; chunk];
        let mut frame = Vec::with_capacity(chunk + FRAME_HDR);
        let mut index = IndexBuilder::new();
        let mut done = 0u64;
        let mut phys = 0u64;
        while done < len {
            let want = ((len - done) as usize).min(chunk);
            let mut filled = 0usize;
            while filled < want {
                let n = src.pread(&mut read_buf[filled..want], done + filled as u64)?;
                if n == 0 {
                    break; // EOF: racing truncation / sparse tail
                }
                filled += n;
            }
            if filled == 0 {
                break;
            }
            encode_frame(codec, &read_buf[..filled], min_ratio_pct, &mut frame);
            let t = Timer::start();
            dst.pwrite_all(&frame, phys)?;
            t.stop(Metric::MoverChunk);
            index.push(phys, filled as u32, (frame.len() - FRAME_HDR) as u32);
            phys += frame.len() as u64;
            done += filled as u64;
            if filled < want {
                break;
            }
        }
        if done == len {
            let tail = index.finish(chunk as u64, phys);
            dst.pwrite_all(&tail, phys)?;
            phys += tail.len() as u64;
        }
        Ok((done, phys))
    }

    /// Pipelined encoded copy: the reader thread preads a chunk and
    /// compresses it into a recycled frame buffer while this thread
    /// appends completed frames and builds the index. Buffers: one
    /// read buffer + `window - 1` frame buffers, so the budget stays
    /// within `chunk_bytes × copy_window` (plus a frame header each).
    #[allow(clippy::too_many_arguments)]
    fn copy_encoded_pipelined(
        &self,
        src: &mut dyn VfsFile,
        dst: &mut dyn VfsFile,
        len: u64,
        chunk: usize,
        window: usize,
        codec: &Lz,
        min_ratio_pct: u16,
    ) -> Result<(u64, u64)> {
        let nbufs = window - 1;
        let _lease =
            BufferLease::new(self.metrics, (chunk + nbufs * (chunk + FRAME_HDR)) as u64);
        std::thread::scope(|scope| -> Result<(u64, u64)> {
            let (data_tx, data_rx) = mpsc::sync_channel::<(Vec<u8>, usize)>(nbufs);
            let (free_tx, free_rx) = mpsc::channel::<Vec<u8>>();
            for _ in 0..nbufs {
                free_tx
                    .send(Vec::with_capacity(chunk + FRAME_HDR))
                    .expect("free receiver alive");
            }
            let reader = scope.spawn(move || -> Result<()> {
                let mut read_buf = vec![0u8; chunk];
                let mut off = 0u64;
                while off < len {
                    // a recycled frame buffer, or the writer bailed
                    let Ok(mut frame) = free_rx.recv() else { return Ok(()) };
                    let want = ((len - off) as usize).min(chunk);
                    let mut filled = 0usize;
                    while filled < want {
                        let n =
                            src.pread(&mut read_buf[filled..want], off + filled as u64)?;
                        if n == 0 {
                            break; // EOF: racing truncation / sparse tail
                        }
                        filled += n;
                    }
                    if filled == 0 {
                        return Ok(());
                    }
                    encode_frame(codec, &read_buf[..filled], min_ratio_pct, &mut frame);
                    let short = filled < want;
                    if data_tx.send((frame, filled)).is_err() {
                        return Ok(()); // writer bailed
                    }
                    off += filled as u64;
                    if short {
                        return Ok(());
                    }
                }
                Ok(())
            });
            let mut index = IndexBuilder::new();
            let mut done = 0u64;
            let mut phys = 0u64;
            let mut werr: Option<Error> = None;
            while let Ok((frame, logical)) = data_rx.recv() {
                let t = Timer::start();
                if let Err(e) = dst.pwrite_all(&frame, phys) {
                    werr = Some(e);
                    break;
                }
                t.stop(Metric::MoverChunk);
                index.push(phys, logical as u32, (frame.len() - FRAME_HDR) as u32);
                phys += frame.len() as u64;
                done += logical as u64;
                let _ = free_tx.send(frame); // reader may already be done
            }
            drop(free_tx);
            drop(data_rx);
            match reader.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => return Err(werr.unwrap_or(e)),
                Err(_) => {
                    return Err(Error::io(
                        "<datamover>",
                        std::io::Error::new(
                            std::io::ErrorKind::Other,
                            "datamover reader thread panicked",
                        ),
                    ))
                }
            }
            if let Some(e) = werr {
                return Err(e);
            }
            if done == len {
                let tail = index.finish(chunk as u64, phys);
                dst.pwrite_all(&tail, phys)?;
                phys += tail.len() as u64;
            }
            Ok((done, phys))
        })
    }

    /// Pipelined body: a scoped reader thread preads chunks ahead into
    /// a bounded channel while this thread writes them behind. `nbufs`
    /// buffers circulate between the two sides (a free-list channel),
    /// so allocation is `chunk × nbufs` for the whole transfer.
    fn copy_pipelined(
        &self,
        src: &mut dyn VfsFile,
        dst: &mut dyn VfsFile,
        len: u64,
        chunk: usize,
        nbufs: usize,
    ) -> Result<u64> {
        let _lease = BufferLease::new(self.metrics, (chunk * nbufs) as u64);
        std::thread::scope(|scope| -> Result<u64> {
            let (data_tx, data_rx) = mpsc::sync_channel::<(u64, Vec<u8>, usize)>(nbufs);
            let (free_tx, free_rx) = mpsc::channel::<Vec<u8>>();
            for _ in 0..nbufs {
                free_tx.send(vec![0u8; chunk]).expect("free receiver alive");
            }
            let reader = scope.spawn(move || -> Result<()> {
                let mut off = 0u64;
                while off < len {
                    // a recycled buffer, or the writer bailed on error
                    let Ok(mut buf) = free_rx.recv() else { return Ok(()) };
                    let want = ((len - off) as usize).min(chunk);
                    let mut filled = 0usize;
                    while filled < want {
                        let n = src.pread(&mut buf[filled..want], off + filled as u64)?;
                        if n == 0 {
                            break; // EOF: racing truncation / sparse tail
                        }
                        filled += n;
                    }
                    if filled == 0 {
                        return Ok(());
                    }
                    let short = filled < want;
                    if data_tx.send((off, buf, filled)).is_err() {
                        return Ok(()); // writer bailed
                    }
                    off += filled as u64;
                    if short {
                        return Ok(());
                    }
                }
                Ok(())
            });
            let mut done = 0u64;
            let mut werr: Option<Error> = None;
            while let Ok((off, buf, n)) = data_rx.recv() {
                let t = Timer::start();
                if let Err(e) = dst.pwrite_all(&buf[..n], off) {
                    werr = Some(e);
                    break;
                }
                t.stop(Metric::MoverChunk);
                done += n as u64;
                let _ = free_tx.send(buf); // reader may already be done
            }
            // dropping our channel ends unblocks the reader, whichever
            // side stopped first
            drop(free_tx);
            drop(data_rx);
            match reader.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => return Err(werr.unwrap_or(e)),
                Err(_) => {
                    return Err(Error::io(
                        "<datamover>",
                        std::io::Error::new(
                            std::io::ErrorKind::Other,
                            "datamover reader thread panicked",
                        ),
                    ))
                }
            }
            match werr {
                Some(e) => Err(e),
                None => Ok(done),
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;
    use crate::vfs::real::RealFs;
    use crate::vfs::testutil::scratch;
    use crate::vfs::{OpenMode, Vfs};
    use std::path::PathBuf;

    const CHUNK: usize = 4096;

    /// ISSUE 4 property test: a streamed copy is byte-identical to the
    /// legacy whole-file copy at every chunk-boundary size.
    #[test]
    fn streamed_copy_matches_wholefile_at_boundary_sizes() {
        let dir = scratch("mover_prop");
        let fs_ = RealFs::new(&dir).unwrap();
        let sizes = [0usize, 1, CHUNK - 1, CHUNK, CHUNK + 1, 3 * CHUNK + 7];
        for (i, &size) in sizes.iter().enumerate() {
            let payload: Vec<u8> = (0..size).map(|k| (k * 31 + i * 7) as u8).collect();
            let src_p = PathBuf::from(format!("src{i}.dat"));
            fs_.write(&src_p, &payload).unwrap();
            // legacy path: whole-file materialization
            let legacy = fs_.read(&src_p).unwrap();
            for window in [1usize, 2, 3] {
                let dst_p = PathBuf::from(format!("dst{i}_w{window}.dat"));
                let mut src = fs_.open(&src_p, OpenMode::Read).unwrap();
                let mut dst = fs_.open(&dst_p, OpenMode::Write).unwrap();
                let cfg =
                    MoverCfg { chunk_bytes: CHUNK, copy_window: window, ..MoverCfg::default() };
                let n = DataMover::new(cfg, MovePath::Flush)
                    .copy(src.as_mut(), dst.as_mut(), size as u64)
                    .unwrap();
                assert_eq!(n, size as u64, "size {size} window {window}");
                drop(dst);
                assert_eq!(
                    fs_.read(&dst_p).unwrap(),
                    legacy,
                    "size {size} window {window}"
                );
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn copy_buffers_stay_within_the_window() {
        let dir = scratch("mover_window");
        let fs_ = RealFs::new(&dir).unwrap();
        let p = PathBuf::from("big.dat");
        fs_.write(&p, &vec![0xA7u8; MIB as usize]).unwrap();
        let metrics = MoverMetrics::default();
        let mut src = fs_.open(&p, OpenMode::Read).unwrap();
        let mut dst = fs_.open(&PathBuf::from("out.dat"), OpenMode::Write).unwrap();
        let cfg = MoverCfg { chunk_bytes: CHUNK, copy_window: 2, ..MoverCfg::default() };
        let n = DataMover::new(cfg, MovePath::Spill)
            .with_metrics(&metrics)
            .copy(src.as_mut(), dst.as_mut(), MIB)
            .unwrap();
        assert_eq!(n, MIB);
        assert_eq!(metrics.moved(MovePath::Spill), MIB);
        assert_eq!(metrics.moved(MovePath::Flush), 0);
        let peak = metrics.peak_buffer_bytes();
        assert!(peak > 0, "lease recorded");
        assert!(
            peak <= (CHUNK * 2) as u64,
            "peak {peak} exceeds chunk_bytes x copy_window"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn copy_range_copies_exactly_the_requested_window() {
        let dir = scratch("mover_range");
        let fs_ = RealFs::new(&dir).unwrap();
        let payload: Vec<u8> = (0..2 * CHUNK).map(|k| k as u8).collect();
        fs_.write(&PathBuf::from("src.dat"), &payload).unwrap();
        // pre-size the destination so the middle range lands in place
        fs_.write(&PathBuf::from("dst.dat"), &vec![0u8; 2 * CHUNK]).unwrap();
        let mut src = fs_.open(&PathBuf::from("src.dat"), OpenMode::Read).unwrap();
        let mut dst = fs_
            .open(&PathBuf::from("dst.dat"), OpenMode::ReadWrite)
            .unwrap();
        let n = copy_range(
            src.as_mut(),
            dst.as_mut(),
            100,
            (CHUNK + 11) as u64,
            64,
            None,
        )
        .unwrap();
        assert_eq!(n, (CHUNK + 11) as u64);
        drop(dst);
        let got = fs_.read(&PathBuf::from("dst.dat")).unwrap();
        assert_eq!(&got[100..100 + CHUNK + 11], &payload[100..100 + CHUNK + 11]);
        assert!(got[..100].iter().all(|&b| b == 0), "prefix untouched");
        assert!(
            got[100 + CHUNK + 11..].iter().all(|&b| b == 0),
            "suffix untouched"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn chunk_size_aligns_to_the_destination_stripe() {
        let base =
            MoverCfg { chunk_bytes: 1_000_000, copy_window: 2, ..MoverCfg::default() };
        assert_eq!(base.aligned_to(None).chunk_bytes, 1_000_000);
        // snaps down to a whole number of stripes
        assert_eq!(base.aligned_to(Some(262_144)).chunk_bytes, 786_432);
        // a chunk below one stripe is a memory budget — never grown
        let small = MoverCfg { chunk_bytes: 4096, copy_window: 2, ..MoverCfg::default() };
        assert_eq!(small.aligned_to(Some(262_144)).chunk_bytes, 4096);
        // alignment never disturbs the codec stage
        let enc = MoverCfg {
            codec: CodecMode::Encode { level: 3, min_ratio_pct: 100 },
            ..base
        };
        assert_eq!(enc.aligned_to(Some(262_144)).codec, enc.codec);
    }

    use crate::vfs::compress::{self, CompressedReader};

    fn encode_cfg(window: usize) -> MoverCfg {
        MoverCfg {
            chunk_bytes: CHUNK,
            copy_window: window,
            codec: CodecMode::Encode { level: 3, min_ratio_pct: 100 },
        }
    }

    /// Deterministic incompressible bytes (no rand crate).
    fn noise(len: usize, mut seed: u64) -> Vec<u8> {
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            seed = seed
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            out.push((seed >> 33) as u8);
        }
        out
    }

    #[test]
    fn encoded_copy_roundtrips_at_every_boundary_size() {
        let dir = scratch("mover_encode");
        let fs_ = RealFs::new(&dir).unwrap();
        let line = b"sea moves bytes between tiers so you do not have to\n";
        let sizes = [0usize, 1, CHUNK - 1, CHUNK, CHUNK + 1, 3 * CHUNK + 7];
        for (i, &size) in sizes.iter().enumerate() {
            let payload: Vec<u8> = line.iter().copied().cycle().take(size).collect();
            let src_p = PathBuf::from(format!("src{i}.dat"));
            fs_.write(&src_p, &payload).unwrap();
            for window in [1usize, 2, 3] {
                let dst_p = PathBuf::from(format!("dst{i}_w{window}.z"));
                let mut src = fs_.open(&src_p, OpenMode::Read).unwrap();
                let mut dst = fs_.open(&dst_p, OpenMode::Write).unwrap();
                let metrics = MoverMetrics::default();
                let (logical, phys) = DataMover::new(encode_cfg(window), MovePath::Flush)
                    .with_metrics(&metrics)
                    .copy_counted(src.as_mut(), dst.as_mut(), size as u64)
                    .unwrap();
                assert_eq!(logical, size as u64, "size {size} window {window}");
                drop(dst);
                assert_eq!(metrics.moved(MovePath::Flush), size as u64);
                assert_eq!(metrics.moved_physical(MovePath::Flush), phys);
                let mut f = fs_.open(&dst_p, OpenMode::Read).unwrap();
                assert_eq!(phys, f.len().unwrap(), "container is exactly phys bytes");
                let meta = compress::probe(f.as_mut())
                    .unwrap()
                    .expect("encoded dst has the magic");
                assert_eq!(meta.logical_len, size as u64);
                let mut r = CompressedReader::new(f, meta);
                let mut back = vec![0u8; size];
                r.pread_exact(&mut back, 0).unwrap();
                assert_eq!(back, payload, "size {size} window {window}");
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn encoded_copy_shrinks_prose_and_caps_noise_overhead() {
        let dir = scratch("mover_encode_ratio");
        let fs_ = RealFs::new(&dir).unwrap();
        let size = 8 * CHUNK;
        let prose: Vec<u8> = b"all work and no play makes sea a dull library\n"
            .iter()
            .copied()
            .cycle()
            .take(size)
            .collect();
        for (name, payload) in [("prose", prose), ("noise", noise(size, 42))] {
            let src_p = PathBuf::from(format!("{name}.dat"));
            let dst_p = PathBuf::from(format!("{name}.z"));
            fs_.write(&src_p, &payload).unwrap();
            let mut src = fs_.open(&src_p, OpenMode::Read).unwrap();
            let mut dst = fs_.open(&dst_p, OpenMode::Write).unwrap();
            let (logical, phys) = DataMover::new(encode_cfg(3), MovePath::Spill)
                .copy_counted(src.as_mut(), dst.as_mut(), size as u64)
                .unwrap();
            assert_eq!(logical, size as u64);
            if name == "prose" {
                assert!(phys < logical / 2, "prose at least halves: {phys}");
            } else {
                // raw passthrough: one header per chunk + index/trailer
                let cap = size
                    + 8 * (compress::FRAME_HDR + compress::INDEX_ENTRY)
                    + compress::TRAILER_LEN;
                assert!(phys <= cap as u64, "noise overhead {phys} > {cap}");
            }
            drop(dst);
            let mut f = fs_.open(&dst_p, OpenMode::Read).unwrap();
            let meta = compress::probe(f.as_mut()).unwrap().unwrap();
            let mut r = CompressedReader::new(f, meta);
            let mut back = vec![0u8; size];
            r.pread_exact(&mut back, 0).unwrap();
            assert_eq!(back, payload, "{name} read-back");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// TSan target: concurrent encoded transfers share one metrics
    /// block (the compress-in-mover parallel path).
    #[test]
    fn parallel_encoded_copies_share_metrics_safely() {
        let dir = scratch("mover_encode_par");
        let fs_ = RealFs::new(&dir).unwrap();
        let size = 2 * CHUNK + 13;
        let metrics = MoverMetrics::default();
        std::thread::scope(|scope| {
            for t in 0..4usize {
                let fs_ = &fs_;
                let metrics = &metrics;
                scope.spawn(move || {
                    let payload: Vec<u8> =
                        (0..size).map(|k| ((k * 131 + t * 17) % 251) as u8).collect();
                    let src_p = PathBuf::from(format!("par{t}.dat"));
                    let dst_p = PathBuf::from(format!("par{t}.z"));
                    fs_.write(&src_p, &payload).unwrap();
                    let mut src = fs_.open(&src_p, OpenMode::Read).unwrap();
                    let mut dst = fs_.open(&dst_p, OpenMode::Write).unwrap();
                    let (logical, _) = DataMover::new(encode_cfg(2), MovePath::Flush)
                        .with_metrics(metrics)
                        .copy_counted(src.as_mut(), dst.as_mut(), size as u64)
                        .unwrap();
                    assert_eq!(logical, size as u64);
                    drop(dst);
                    let mut f = fs_.open(&dst_p, OpenMode::Read).unwrap();
                    let meta = compress::probe(f.as_mut()).unwrap().unwrap();
                    let mut r = CompressedReader::new(f, meta);
                    let mut back = vec![0u8; size];
                    r.pread_exact(&mut back, 0).unwrap();
                    assert_eq!(back, payload);
                });
            }
        });
        assert_eq!(metrics.moved(MovePath::Flush), 4 * size as u64);
        assert!(metrics.moved_physical(MovePath::Flush) > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn physical_hint_is_recorded_for_decode_through_reads() {
        let dir = scratch("mover_hint");
        let fs_ = RealFs::new(&dir).unwrap();
        let p = PathBuf::from("src.dat");
        fs_.write(&p, &vec![7u8; CHUNK]).unwrap();
        let metrics = MoverMetrics::default();
        let mut src = fs_.open(&p, OpenMode::Read).unwrap();
        let mut dst = fs_.open(&PathBuf::from("dst.dat"), OpenMode::Write).unwrap();
        let cfg = MoverCfg { chunk_bytes: CHUNK, copy_window: 2, ..MoverCfg::default() };
        let (logical, phys) = DataMover::new(cfg, MovePath::Promote)
            .with_metrics(&metrics)
            .with_physical(100)
            .copy_counted(src.as_mut(), dst.as_mut(), CHUNK as u64)
            .unwrap();
        assert_eq!(logical, CHUNK as u64);
        assert_eq!(phys, 100, "hint wins when the transfer completed");
        assert_eq!(metrics.moved(MovePath::Promote), CHUNK as u64);
        assert_eq!(metrics.moved_physical(MovePath::Promote), 100);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
