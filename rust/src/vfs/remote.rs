//! `RemoteFs`: the client side of [`crate::serve`] — a [`Vfs`] whose
//! every operation rides the Sea service wire protocol to a `sea
//! serve` daemon over a Unix domain socket.
//!
//! One `RemoteFs` is one OS-level connection (plus the handshake); all
//! of its [`RemoteFile`] handles multiplex over it behind a mutex, so
//! a process that opens fifty files still costs the daemon one
//! connection thread. Separate `RemoteFs` instances are fully
//! independent clients — the integration tests use eight of them to
//! prove cross-process append atomicity.
//!
//! ## Frame format (see [`crate::serve::protocol`] for the encoding)
//!
//! | frame    | layout                                         |
//! |----------|------------------------------------------------|
//! | any      | `[u32 len][payload…]`, little-endian           |
//! | request  | `[opcode u8][operands…]`                       |
//! | response | `[status u8][gen u64][body…]`                  |
//!
//! Every response piggybacks the daemon-side map generation of the
//! touched handle ([`RemoteFile::generation`] caches it); a bump means
//! another client's write spilled the file and any locally cached
//! pages for it are stale. [`RemoteFile::map_sync`] forwards the
//! explicit `MapSync` round trip, so [`MappedView`]s over a
//! `RemoteFile` invalidate exactly like local views over a `SeaFile`.
//!
//! ## Failure semantics
//!
//! Connects retry with capped exponential backoff + jitter
//! ([`RetryCfg`]). After a mid-request connection loss, *idempotent*
//! requests (pread/len/stat/readdir/map-sync) transparently reconnect
//! and retry once — read-only handles even reopen themselves by path —
//! while mutating requests surface [`Error::DaemonGone`] immediately:
//! a lost pwrite may or may not have been applied, and guessing is
//! worse than failing. Nothing in this module blocks forever on a dead
//! daemon and nothing panics.

use std::io::{BufReader, BufWriter, Write};
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::serve::protocol::{
    read_frame, write_frame, Body, CountersReply, Request, Response, MAX_IO,
    PROTOCOL_VERSION,
};
use crate::util::rng::Rng;
use crate::vfs::{OpenMode, Vfs, VfsFile};

/// Connect/retry policy: capped exponential backoff with jitter.
#[derive(Debug, Clone, Copy)]
pub struct RetryCfg {
    /// Connection attempts before giving up (min 1).
    pub attempts: u32,
    /// Backoff before the second attempt; doubles each retry.
    pub base: Duration,
    /// Ceiling on any single backoff sleep.
    pub cap: Duration,
}

impl Default for RetryCfg {
    fn default() -> RetryCfg {
        RetryCfg {
            attempts: 5,
            base: Duration::from_millis(20),
            cap: Duration::from_millis(500),
        }
    }
}

impl RetryCfg {
    /// Backoff before attempt `i` (0-based): `min(cap, base·2^(i-1))`
    /// plus up to 50% jitter so a herd of clients reconnecting to a
    /// restarted daemon does not stampede in lockstep.
    fn backoff(&self, i: u32, rng: &mut Rng) -> Duration {
        if i == 0 {
            return Duration::ZERO;
        }
        let exp = self.base.saturating_mul(1u32 << (i - 1).min(16));
        let capped = exp.min(self.cap);
        let jitter_ns = (capped.as_nanos() as u64 / 2).max(1);
        capped + Duration::from_nanos(rng.next_u64() % jitter_ns)
    }
}

/// One live, handshaken connection.
struct Conn {
    reader: BufReader<UnixStream>,
    writer: BufWriter<UnixStream>,
}

impl Conn {
    fn dial_once(socket: &Path) -> std::io::Result<Conn> {
        let stream = UnixStream::connect(socket)?;
        let reader = BufReader::new(stream.try_clone()?);
        let mut conn = Conn { reader, writer: BufWriter::new(stream) };
        let resp = conn.call(&Request::Hello { version: PROTOCOL_VERSION })?;
        match resp.body {
            Ok(Body::Hello { .. }) => Ok(conn),
            Ok(other) => Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad handshake reply: {other:?}"),
            )),
            // Version mismatch & co.: surface the daemon's words.
            Err(we) => Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionRefused,
                we.into_error().to_string(),
            )),
        }
    }

    /// One request/response round trip. Any I/O error means the
    /// connection is dead and must be discarded.
    fn call(&mut self, req: &Request) -> std::io::Result<Response> {
        write_frame(&mut self.writer, &req.encode())?;
        self.writer.flush()?;
        let frame = read_frame(&mut self.reader)?;
        Response::decode(&frame)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
    }
}

/// The connection slot shared by a `RemoteFs` and its files. `epoch`
/// counts successful dials: a handle opened on epoch N is dead once
/// the slot moves past N (the daemon's per-connection handle table
/// died with the old socket).
struct Slot {
    conn: Option<Conn>,
    epoch: u64,
}

struct Inner {
    socket: PathBuf,
    retry: RetryCfg,
    slot: Mutex<Slot>,
    rng: Mutex<Rng>,
}

impl Inner {
    /// Ensure the slot holds a live connection, dialing with backoff
    /// if not. Returns the slot's current epoch.
    fn ensure_connected(&self, slot: &mut Slot) -> Result<u64> {
        if slot.conn.is_some() {
            return Ok(slot.epoch);
        }
        let mut last: Option<std::io::Error> = None;
        for i in 0..self.retry.attempts.max(1) {
            let nap = { self.retry.backoff(i, &mut self.rng.lock().unwrap()) };
            if !nap.is_zero() {
                std::thread::sleep(nap);
            }
            match Conn::dial_once(&self.socket) {
                Ok(c) => {
                    slot.conn = Some(c);
                    slot.epoch += 1;
                    return Ok(slot.epoch);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(Error::DaemonGone(format!(
            "connect to {} failed after {} attempts: {}",
            self.socket.display(),
            self.retry.attempts.max(1),
            last.map(|e| e.to_string()).unwrap_or_default()
        )))
    }

    /// One round trip with reconnect-and-retry-once semantics for
    /// idempotent requests. Mutating requests that lose the connection
    /// mid-flight surface [`Error::DaemonGone`].
    fn call(&self, req: &Request) -> Result<Response> {
        let mut slot = self.slot.lock().unwrap();
        self.call_locked(&mut slot, req)
    }

    fn call_locked(&self, slot: &mut Slot, req: &Request) -> Result<Response> {
        self.ensure_connected(slot)?;
        match slot.conn.as_mut().unwrap().call(req) {
            Ok(resp) => Ok(resp),
            Err(first) => {
                slot.conn = None;
                if !req.idempotent() {
                    return Err(Error::DaemonGone(format!(
                        "connection lost mid-request ({first}); not retrying a mutating op"
                    )));
                }
                self.ensure_connected(slot)?;
                slot.conn.as_mut().unwrap().call(req).map_err(|e| {
                    slot.conn = None;
                    Error::DaemonGone(format!("retry after reconnect failed: {e}"))
                })
            }
        }
    }
}

/// A [`Vfs`] served by a `sea serve` daemon over a Unix socket.
pub struct RemoteFs {
    inner: Arc<Inner>,
}

impl RemoteFs {
    /// Connect to the daemon at `socket` with default retry policy.
    pub fn connect(socket: impl Into<PathBuf>) -> Result<RemoteFs> {
        RemoteFs::connect_with(socket, RetryCfg::default())
    }

    /// Connect with an explicit retry policy.
    pub fn connect_with(socket: impl Into<PathBuf>, retry: RetryCfg) -> Result<RemoteFs> {
        let socket = socket.into();
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() as u64)
            .unwrap_or(0);
        let seed = (std::process::id() as u64) << 32 | nanos;
        let inner = Arc::new(Inner {
            socket,
            retry,
            slot: Mutex::new(Slot { conn: None, epoch: 0 }),
            rng: Mutex::new(Rng::new(seed)),
        });
        // Dial eagerly so a bad socket path fails at construction, not
        // on the first I/O.
        {
            let mut slot = inner.slot.lock().unwrap();
            inner.ensure_connected(&mut slot)?;
        }
        Ok(RemoteFs { inner })
    }

    /// The socket this client targets.
    pub fn socket(&self) -> &Path {
        &self.inner.socket
    }

    /// [`Vfs::open`] returning the concrete handle type — the
    /// interposer needs [`RemoteFile::generation`] /
    /// [`RemoteFile::identity`], which a `Box<dyn VfsFile>` hides.
    pub fn open_remote(&self, path: &Path, mode: OpenMode) -> Result<RemoteFile> {
        open_on(&self.inner, path_str(path), mode)
    }

    /// Fetch the daemon's live counters, ledger, and client gauges
    /// (`sea stat --connect`).
    pub fn counters(&self) -> Result<CountersReply> {
        match self.inner.call(&Request::Counters)?.body {
            Ok(Body::Counters(c)) => Ok(*c),
            Ok(other) => Err(Error::Daemon(format!("bad Counters reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }
}

fn path_str(p: &Path) -> String {
    p.to_string_lossy().into_owned()
}

/// Open `path` on the shared connection and build the handle.
fn open_on(inner: &Arc<Inner>, path: String, mode: OpenMode) -> Result<RemoteFile> {
    let req = Request::Open { mode, path: path.clone() };
    let mut slot = inner.slot.lock().unwrap();
    let resp = inner.call_locked(&mut slot, &req)?;
    let epoch = slot.epoch;
    drop(slot);
    match resp.body {
        Ok(Body::Open { handle, ident }) => Ok(RemoteFile {
            inner: inner.clone(),
            handle,
            epoch,
            path,
            mode,
            gen: resp.gen,
            ident,
        }),
        Ok(other) => Err(Error::Daemon(format!("bad Open reply: {other:?}"))),
        Err(we) => Err(we.into_error()),
    }
}

impl Vfs for RemoteFs {
    fn open(&self, path: &Path, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
        Ok(Box::new(self.open_remote(path, mode)?))
    }

    fn unlink(&self, path: &Path) -> Result<()> {
        match self.inner.call(&Request::Unlink { path: path_str(path) })?.body {
            Ok(_) => Ok(()),
            Err(we) => Err(we.into_error()),
        }
    }

    fn exists(&self, path: &Path) -> bool {
        match self.inner.call(&Request::Stat { path: path_str(path) }) {
            Ok(resp) => resp.body.is_ok(),
            Err(_) => false,
        }
    }

    fn size(&self, path: &Path) -> Result<u64> {
        match self.inner.call(&Request::Stat { path: path_str(path) })?.body {
            Ok(Body::Size(n)) => Ok(n),
            Ok(other) => Err(Error::Daemon(format!("bad Stat reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        let req = Request::Rename { from: path_str(from), to: path_str(to) };
        match self.inner.call(&req)?.body {
            Ok(_) => Ok(()),
            Err(we) => Err(we.into_error()),
        }
    }

    fn readdir(&self, path: &Path) -> Result<Vec<String>> {
        match self.inner.call(&Request::Readdir { path: path_str(path) })?.body {
            Ok(Body::Names(names)) => Ok(names),
            Ok(other) => Err(Error::Daemon(format!("bad Readdir reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }

    fn sync_mgmt(&self) -> Result<()> {
        match self.inner.call(&Request::SyncMgmt)?.body {
            Ok(_) => Ok(()),
            Err(we) => Err(we.into_error()),
        }
    }
}

/// A [`VfsFile`] whose bytes live behind the daemon.
pub struct RemoteFile {
    inner: Arc<Inner>,
    handle: u64,
    /// Slot epoch this handle was opened on; a later epoch means the
    /// daemon-side handle table died with the old connection.
    epoch: u64,
    path: String,
    mode: OpenMode,
    /// Last piggybacked daemon-side map generation.
    gen: u64,
    /// Daemon-side frame-sharing identity from `Open`.
    ident: Option<u128>,
}

impl RemoteFile {
    /// Last daemon-side map generation piggybacked on a response. A
    /// change since the caller last looked means another client moved
    /// the file (spill) — locally cached pages for it are stale.
    pub fn generation(&self) -> u64 {
        self.gen
    }

    /// The daemon handle's frame-sharing identity (see
    /// [`VfsFile::map_identity`]); `None` when the daemon backend
    /// could not name one.
    pub fn identity(&self) -> Option<u128> {
        self.ident
    }

    /// Open an independent handle to the same path over the same
    /// connection. The interposer's mmap emulation uses this for
    /// write-back handles that must outlive the caller's descriptor
    /// (correct across spills, too: the daemon-side handle follows the
    /// registry to the file's new device).
    pub fn sibling(&self, mode: OpenMode) -> Result<RemoteFile> {
        open_on(&self.inner, self.path.clone(), mode)
    }

    /// Run `req` against this handle, healing a dead connection when
    /// allowed: read-only handles reopen themselves by path and retry
    /// idempotent requests once; writable handles surface
    /// [`Error::DaemonGone`] (their daemon-side state is gone, and
    /// silently reopening would drop append/truncate semantics).
    fn call(&mut self, req: Request) -> Result<Response> {
        let mut slot = self.inner.slot.lock().unwrap();
        let cur = self.inner.ensure_connected(&mut slot)?;
        if cur != self.epoch {
            self.reopen(&mut slot)?;
        }
        // The reopen above may have changed our daemon-side handle id.
        let req = req.rehandle(self.handle);
        let resp = match slot.conn.as_mut().unwrap().call(&req) {
            Ok(resp) => resp,
            Err(first) => {
                slot.conn = None;
                if !(req.idempotent() && self.mode == OpenMode::Read) {
                    return Err(Error::DaemonGone(format!(
                        "connection lost mid-request on {} ({first})",
                        self.path
                    )));
                }
                self.inner.ensure_connected(&mut slot)?;
                self.reopen(&mut slot)?;
                let req = req.rehandle(self.handle);
                slot.conn.as_mut().unwrap().call(&req).map_err(|e| {
                    slot.conn = None;
                    Error::DaemonGone(format!("retry after reconnect failed: {e}"))
                })?
            }
        };
        self.gen = resp.gen;
        Ok(resp)
    }

    /// Re-open this handle's path on the current connection (read-only
    /// handles after a reconnect).
    fn reopen(&mut self, slot: &mut Slot) -> Result<()> {
        if self.mode != OpenMode::Read {
            return Err(Error::DaemonGone(format!(
                "writable handle on {} lost with its connection",
                self.path
            )));
        }
        let req = Request::Open { mode: self.mode, path: self.path.clone() };
        let resp = slot.conn.as_mut().unwrap().call(&req).map_err(|e| {
            slot.conn = None;
            Error::DaemonGone(format!("reopen of {} failed: {e}", self.path))
        })?;
        match resp.body {
            Ok(Body::Open { handle, ident }) => {
                self.handle = handle;
                self.ident = ident;
                self.epoch = slot.epoch;
                self.gen = resp.gen;
                Ok(())
            }
            Ok(other) => Err(Error::Daemon(format!("bad reopen reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }
}

impl VfsFile for RemoteFile {
    fn pread(&mut self, buf: &mut [u8], off: u64) -> Result<usize> {
        let want = buf.len().min(MAX_IO) as u32;
        let resp = self.call(Request::Pread { handle: self.handle, off, len: want })?;
        match resp.body {
            Ok(Body::Data(d)) => {
                let n = d.len().min(buf.len());
                buf[..n].copy_from_slice(&d[..n]);
                Ok(n)
            }
            Ok(other) => Err(Error::Daemon(format!("bad Pread reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }

    fn pwrite(&mut self, data: &[u8], off: u64) -> Result<usize> {
        // Clamp to one frame; `pwrite_all` loops over the short write.
        let chunk = &data[..data.len().min(MAX_IO)];
        let req =
            Request::Pwrite { handle: self.handle, off, data: chunk.to_vec() };
        match self.call(req)?.body {
            Ok(Body::Written(n)) => Ok(n as usize),
            Ok(other) => Err(Error::Daemon(format!("bad Pwrite reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }

    fn set_len(&mut self, len: u64) -> Result<()> {
        match self.call(Request::SetLen { handle: self.handle, len })?.body {
            Ok(_) => Ok(()),
            Err(we) => Err(we.into_error()),
        }
    }

    fn fsync(&mut self) -> Result<()> {
        match self.call(Request::Fsync { handle: self.handle })?.body {
            Ok(_) => Ok(()),
            Err(we) => Err(we.into_error()),
        }
    }

    fn len(&self) -> Result<u64> {
        // `len` takes `&self`; route through the shared slot directly.
        let req = Request::Len { handle: self.handle };
        match self.inner.call(&req)?.body {
            Ok(Body::Size(n)) => Ok(n),
            Ok(other) => Err(Error::Daemon(format!("bad Len reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }

    fn map_sync(&mut self) -> Result<u64> {
        let resp = self.call(Request::MapSync { handle: self.handle })?;
        match resp.body {
            Ok(_) => Ok(resp.gen),
            Err(we) => Err(we.into_error()),
        }
    }

    fn note_map_fault(&mut self, off: u64, len: u64) {
        let _ = self.call(Request::NoteFault { handle: self.handle, off, len });
    }

    fn map_identity(&self) -> Option<u128> {
        self.ident
    }
}

impl Drop for RemoteFile {
    fn drop(&mut self) {
        // Best-effort close; the daemon reaps the handle with the
        // connection anyway if this races a dead socket.
        if let Ok(mut slot) = self.inner.slot.lock() {
            if slot.epoch == self.epoch {
                if let Some(conn) = slot.conn.as_mut() {
                    if conn.call(&Request::Close { handle: self.handle }).is_err() {
                        slot.conn = None;
                    }
                }
            }
        }
    }
}

impl Request {
    /// The same request aimed at a different handle id (retry after a
    /// reconnect re-opened the file under a new daemon-side id).
    fn rehandle(self, handle: u64) -> Request {
        match self {
            Request::Pread { off, len, .. } => Request::Pread { handle, off, len },
            Request::Pwrite { off, data, .. } => Request::Pwrite { handle, off, data },
            Request::SetLen { len, .. } => Request::SetLen { handle, len },
            Request::Fsync { .. } => Request::Fsync { handle },
            Request::Close { .. } => Request::Close { handle },
            Request::Len { .. } => Request::Len { handle },
            Request::MapSync { .. } => Request::MapSync { handle },
            Request::NoteFault { off, len, .. } => Request::NoteFault { handle, off, len },
            other => other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_and_caps() {
        let cfg = RetryCfg {
            attempts: 8,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(80),
        };
        let mut rng = Rng::new(7);
        assert_eq!(cfg.backoff(0, &mut rng), Duration::ZERO);
        let b1 = cfg.backoff(1, &mut rng);
        assert!(b1 >= Duration::from_millis(10) && b1 < Duration::from_millis(16));
        let b4 = cfg.backoff(4, &mut rng);
        assert!(b4 >= Duration::from_millis(80), "uncapped exp: {b4:?}");
        // cap + 50% jitter ceiling
        assert!(b4 <= Duration::from_millis(121), "cap violated: {b4:?}");
        let b30 = cfg.backoff(30, &mut rng); // shift clamp: no overflow
        assert!(b30 <= Duration::from_millis(121));
    }

    #[test]
    fn connect_to_missing_socket_is_typed_and_bounded() {
        let cfg = RetryCfg {
            attempts: 2,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(2),
        };
        let t0 = std::time::Instant::now();
        let err = RemoteFs::connect_with("/nonexistent/sea.sock", cfg);
        match err {
            Err(Error::DaemonGone(msg)) => {
                assert!(msg.contains("2 attempts"), "got: {msg}")
            }
            other => panic!("expected DaemonGone, got {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "retry must be bounded");
    }
}
