//! `RemoteFs`: the client side of [`crate::serve`] — a [`Vfs`] whose
//! operations ride the Sea service wire protocol to a `sea serve`
//! daemon over a Unix domain socket.
//!
//! One `RemoteFs` is one OS-level connection (plus the handshake); all
//! of its [`RemoteFile`] handles multiplex over it. Separate `RemoteFs`
//! instances are fully independent clients — the integration tests use
//! eight of them to prove cross-process append atomicity.
//!
//! ## Frame format (see [`crate::serve::protocol`] for the encoding)
//!
//! | frame    | layout                                         |
//! |----------|------------------------------------------------|
//! | any      | `[u32 len][u64 req-id][payload…]`, little-endian |
//! | request  | `[opcode u8][operands…]`                       |
//! | response | `[status u8][gen u64][body…]`                  |
//!
//! ## The data plane
//!
//! Three mechanisms take the common read path off the request/response
//! wire (or overlap it when it must stay there):
//!
//! * **Fd leases.** A read-only `Open` whose resident replica is a
//!   plain local file comes back with a dup'd `O_RDONLY` fd riding the
//!   reply frame as `SCM_RIGHTS` ancillary data, plus the map
//!   generation the lease was minted at. While the lease holds,
//!   [`RemoteFile::pread`] is a raw `pread(2)` — zero round trips,
//!   zero copies through the daemon. Any later response piggybacking a
//!   *newer* generation revokes the lease (the file moved tiers); the
//!   old inode stays valid for in-flight reads because spills replace
//!   the name, not the data, so a revoked-but-racing read still
//!   returns a consistent snapshot.
//!
//! * **Pipelining.** Every frame carries a request id and responses
//!   may arrive out of order. A connection is a shared [`Conn`]: a
//!   dedicated reader thread routes each response to the waiting
//!   caller by id, so many `RemoteFile` handles (or threads) keep
//!   requests in flight on one socket concurrently instead of queueing
//!   behind a single round trip.
//!
//! * **Readahead.** A `RemoteFile` that observes back-to-back
//!   sequential reads prefetches the next window (the daemon's
//!   `chunk_bytes` from the handshake, overridable with
//!   `SEA_READAHEAD`; `0` disables) through the mux, so the wire
//!   round trip overlaps the caller's compute. Readahead applies only
//!   to read-only handles and is skipped entirely while a lease holds
//!   (the lease path is already cheaper than a buffer copy).
//!
//! All client-side socket I/O uses raw `sendmsg(2)` / `recvmsg(2)`
//! ([`crate::serve::fdpass`]): writes get `MSG_NOSIGNAL` (no `SIGPIPE`
//! when the daemon dies mid-frame) and neither direction routes
//! through libc `read`/`write`, which matters when this code runs
//! inside the `LD_PRELOAD` interposer.
//!
//! ## Every response piggybacks a generation
//!
//! The daemon-side map generation of the touched handle rides every
//! response ([`RemoteFile::generation`] caches it); a bump means
//! another client's write spilled the file and any locally cached
//! pages — including our readahead buffer and lease — are stale.
//! [`RemoteFile::map_sync`] forwards the explicit `MapSync` round
//! trip, so [`MappedView`]s over a `RemoteFile` invalidate exactly
//! like local views over a `SeaFile`.
//!
//! ## Failure semantics
//!
//! Connects retry with capped exponential backoff + jitter
//! ([`RetryCfg`]). After a mid-request connection loss, *idempotent*
//! requests (pread/len/stat/readdir/mkdir/map-sync) transparently
//! reconnect and retry once — read-only handles even reopen themselves
//! by path — while mutating requests surface [`Error::DaemonGone`]
//! immediately: a lost pwrite may or may not have been applied, and
//! guessing is worse than failing. Nothing in this module blocks
//! forever on a dead daemon and nothing panics.
//!
//! [`MappedView`]: crate::vfs::pages::MappedView

use std::collections::{HashMap, VecDeque};
use std::io;
use std::os::fd::{AsRawFd, OwnedFd, RawFd};
use std::os::unix::fs::FileExt;
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, Weak};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::serve::fdpass;
use crate::serve::protocol::{
    frame_header, Body, CountersReply, Request, Response, FRAME_HDR, MAX_FRAME,
    MAX_IO, PROTOCOL_VERSION,
};
use crate::util::rng::Rng;
use crate::vfs::{OpenMode, Vfs, VfsFile};

/// Connect/retry policy: capped exponential backoff with jitter.
#[derive(Debug, Clone, Copy)]
pub struct RetryCfg {
    /// Connection attempts before giving up (min 1).
    pub attempts: u32,
    /// Backoff before the second attempt; doubles each retry.
    pub base: Duration,
    /// Ceiling on any single backoff sleep.
    pub cap: Duration,
}

impl Default for RetryCfg {
    fn default() -> RetryCfg {
        RetryCfg {
            attempts: 5,
            base: Duration::from_millis(20),
            cap: Duration::from_millis(500),
        }
    }
}

impl RetryCfg {
    /// Backoff before attempt `i` (0-based): `min(cap, base·2^(i-1))`
    /// plus up to 50% jitter so a herd of clients reconnecting to a
    /// restarted daemon does not stampede in lockstep.
    fn backoff(&self, i: u32, rng: &mut Rng) -> Duration {
        if i == 0 {
            return Duration::ZERO;
        }
        let exp = self.base.saturating_mul(1u32 << (i - 1).min(16));
        let capped = exp.min(self.cap);
        let jitter_ns = (capped.as_nanos() as u64 / 2).max(1);
        capped + Duration::from_nanos(rng.next_u64() % jitter_ns)
    }
}

/// A routed response: the decoded frame plus the fd that rode it (only
/// ever present on lease-flagged `Open` replies).
type Reply = (Response, Option<std::fs::File>);

/// Accumulating frame parser over raw `recvmsg(2)`. Both the handshake
/// and the reader thread use it, so no client-side receive ever routes
/// through an interposed libc `read`. Fds arriving as ancillary data
/// queue up in arrival order; stream order pairs each with the
/// lease-flagged reply it rode (the daemon sends fd + frame in one
/// `sendmsg`).
struct FrameReader {
    fd: RawFd,
    buf: Vec<u8>,
    fds: VecDeque<OwnedFd>,
}

impl FrameReader {
    fn new(fd: RawFd) -> FrameReader {
        FrameReader { fd, buf: Vec::new(), fds: VecDeque::new() }
    }

    /// Next complete frame, or `Ok(None)` on orderly EOF between
    /// frames. EOF mid-frame is an error.
    fn next(&mut self) -> io::Result<Option<(u64, Vec<u8>)>> {
        loop {
            if let Some(frame) = self.try_parse()? {
                return Ok(Some(frame));
            }
            let mut chunk = [0u8; 64 * 1024];
            let mut got = Vec::new();
            let n = fdpass::recv_with_fds(self.fd, &mut chunk, &mut got)?;
            self.fds.extend(got);
            if n == 0 {
                if self.buf.is_empty() {
                    return Ok(None);
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed mid-frame",
                ));
            }
            self.buf.extend_from_slice(&chunk[..n]);
        }
    }

    fn try_parse(&mut self) -> io::Result<Option<(u64, Vec<u8>)>> {
        if self.buf.len() < FRAME_HDR {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.buf[0..4].try_into().unwrap()) as usize;
        if len > MAX_FRAME {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("oversized frame: {len} B"),
            ));
        }
        if self.buf.len() < FRAME_HDR + len {
            return Ok(None);
        }
        let id = u64::from_le_bytes(self.buf[4..12].try_into().unwrap());
        let payload = self.buf[FRAME_HDR..FRAME_HDR + len].to_vec();
        self.buf.drain(..FRAME_HDR + len);
        Ok(Some((id, payload)))
    }

    /// Claim the oldest unclaimed received fd (a lease).
    fn take_fd(&mut self) -> Option<OwnedFd> {
        self.fds.pop_front()
    }
}

/// One live, handshaken connection, shared by every handle that was
/// opened on it. Callers register a oneshot channel under a fresh
/// request id, write their frame (serialized by `write_lock`, vectored
/// header+payload in one `sendmsg`), and block on their own receiver;
/// the reader thread routes responses by id, so any number of requests
/// overlap on the socket.
struct Conn {
    stream: UnixStream,
    /// The [`Slot`] epoch this connection was dialed on; handles
    /// compare it to detect that their daemon-side handle table died
    /// with an older connection.
    epoch: u64,
    /// The daemon's streamed-transfer chunk size from the handshake —
    /// adopted as the default readahead window.
    chunk_hint: u64,
    next_id: AtomicU64,
    write_lock: Mutex<()>,
    pending: Mutex<HashMap<u64, mpsc::Sender<Reply>>>,
    /// Set by the reader thread (before it drains `pending`) once the
    /// socket is unusable.
    dead: AtomicBool,
}

impl Conn {
    /// Fire `req` and return the receiver its response will land on.
    /// The readahead path uses this directly to overlap the round trip
    /// with the caller's compute.
    fn send(&self, req: &Request) -> io::Result<mpsc::Receiver<Reply>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        self.pending.lock().unwrap().insert(id, tx);
        // The reader marks `dead` *before* draining `pending`; checking
        // after our insert means a request racing the teardown either
        // gets drained (our recv errors) or bails right here — never a
        // lost wakeup.
        if self.dead.load(Ordering::Acquire) {
            self.pending.lock().unwrap().remove(&id);
            return Err(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "connection is dead",
            ));
        }
        let payload = req.encode();
        let hdr = frame_header(id, payload.len());
        let wrote = {
            let _serialized = self.write_lock.lock().unwrap();
            fdpass::send_frame_fd(
                self.stream.as_raw_fd(),
                &[&hdr[..], &payload[..]],
                None,
            )
        };
        if let Err(e) = wrote {
            self.pending.lock().unwrap().remove(&id);
            return Err(e);
        }
        Ok(rx)
    }

    /// One request/response round trip over the mux. Any error means
    /// this connection must be discarded.
    fn call_raw(&self, req: &Request) -> io::Result<Reply> {
        let t = crate::obs::Timer::start();
        let rx = self.send(req)?;
        let reply = rx.recv().map_err(|_| {
            io::Error::new(
                io::ErrorKind::BrokenPipe,
                "connection closed with the request in flight",
            )
        })?;
        t.stop(crate::obs::Metric::WireRtt);
        Ok(reply)
    }
}

impl Drop for Conn {
    fn drop(&mut self) {
        // The reader thread holds only a `Weak` to us plus its own
        // dup'd fd; shutting the socket down (not merely closing our
        // fd) unblocks its recvmsg so it can exit.
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

/// Reader-thread body: route responses (and any fds riding them) to
/// the registered callers until the socket dies, then mark the
/// connection dead and drain the pending map so every waiter errors
/// out instead of blocking forever.
fn reader_loop(conn: Weak<Conn>, stream: UnixStream) {
    let mut frames = FrameReader::new(stream.as_raw_fd());
    loop {
        let (id, payload) = match frames.next() {
            Ok(Some(f)) => f,
            // Orderly EOF, socket error, or poisoned framing: done.
            Ok(None) | Err(_) => break,
        };
        let resp = match Response::decode(&payload) {
            Ok(r) => r,
            Err(_) => break,
        };
        let lease = match &resp.body {
            Ok(Body::Open { lease: Some(_), .. }) => {
                frames.take_fd().map(std::fs::File::from)
            }
            _ => None,
        };
        match conn.upgrade() {
            Some(c) => {
                let tx = c.pending.lock().unwrap().remove(&id);
                if let Some(tx) = tx {
                    // A dropped receiver (abandoned readahead) is fine.
                    let _ = tx.send((resp, lease));
                }
            }
            // Every handle and the `RemoteFs` are gone; nobody is
            // waiting on anything.
            None => return,
        }
    }
    if let Some(c) = conn.upgrade() {
        c.dead.store(true, Ordering::Release);
        c.pending.lock().unwrap().clear();
    }
}

/// Dial + handshake. `epoch` is stamped into the connection for handle
/// staleness checks.
fn dial_once(socket: &Path, epoch: u64) -> io::Result<Arc<Conn>> {
    let stream = UnixStream::connect(socket)?;
    let payload = Request::Hello { version: PROTOCOL_VERSION }.encode();
    let hdr = frame_header(0, payload.len());
    fdpass::send_frame_fd(stream.as_raw_fd(), &[&hdr[..], &payload[..]], None)?;
    // Synchronous handshake read on the caller's thread; the daemon
    // sends nothing unsolicited, so no bytes can be buffered past the
    // reply and the reader thread can start from a clean stream.
    let chunk_hint = {
        let mut frames = FrameReader::new(stream.as_raw_fd());
        let (_, frame) = frames.next()?.ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "daemon closed the connection during the handshake",
            )
        })?;
        let resp = Response::decode(&frame).map_err(|e| {
            io::Error::new(io::ErrorKind::InvalidData, e.to_string())
        })?;
        match resp.body {
            Ok(Body::Hello { chunk_bytes, .. }) => chunk_bytes,
            Ok(other) => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("bad handshake reply: {other:?}"),
                ))
            }
            // Version mismatch & co.: surface the daemon's words.
            Err(we) => {
                return Err(io::Error::new(
                    io::ErrorKind::ConnectionRefused,
                    we.into_error().to_string(),
                ))
            }
        }
    };
    let reader = stream.try_clone()?;
    let conn = Arc::new(Conn {
        stream,
        epoch,
        chunk_hint,
        next_id: AtomicU64::new(1),
        write_lock: Mutex::new(()),
        pending: Mutex::new(HashMap::new()),
        dead: AtomicBool::new(false),
    });
    let weak = Arc::downgrade(&conn);
    std::thread::Builder::new()
        .name("sea-remote-reader".into())
        .spawn(move || reader_loop(weak, reader))?;
    Ok(conn)
}

/// The connection slot shared by a `RemoteFs` and its files. `epoch`
/// counts successful dials: a handle opened on epoch N is dead once
/// the slot moves past N (the daemon's per-connection handle table
/// died with the old socket).
struct Slot {
    conn: Option<Arc<Conn>>,
    epoch: u64,
}

struct Inner {
    socket: PathBuf,
    retry: RetryCfg,
    slot: Mutex<Slot>,
    rng: Mutex<Rng>,
    /// `SEA_READAHEAD` override in bytes (`0` disables readahead);
    /// `None` adopts the daemon's handshake hint.
    ra_override: Option<u64>,
}

impl Inner {
    /// Ensure the slot holds a live connection, dialing with backoff
    /// if not.
    fn ensure_connected(&self, slot: &mut Slot) -> Result<Arc<Conn>> {
        if let Some(c) = &slot.conn {
            if !c.dead.load(Ordering::Acquire) {
                return Ok(c.clone());
            }
            slot.conn = None;
        }
        let mut last: Option<io::Error> = None;
        for i in 0..self.retry.attempts.max(1) {
            let nap = { self.retry.backoff(i, &mut self.rng.lock().unwrap()) };
            if !nap.is_zero() {
                std::thread::sleep(nap);
            }
            match dial_once(&self.socket, slot.epoch + 1) {
                Ok(c) => {
                    slot.epoch += 1;
                    slot.conn = Some(c.clone());
                    return Ok(c);
                }
                Err(e) => last = Some(e),
            }
        }
        Err(Error::DaemonGone(format!(
            "connect to {} failed after {} attempts: {}",
            self.socket.display(),
            self.retry.attempts.max(1),
            last.map(|e| e.to_string()).unwrap_or_default()
        )))
    }

    /// The live connection, dialing if needed. The slot lock is held
    /// only for the lookup/dial — never across a round trip, or there
    /// would be no pipelining.
    fn conn(&self) -> Result<Arc<Conn>> {
        let mut slot = self.slot.lock().unwrap();
        self.ensure_connected(&mut slot)
    }

    /// The live connection if there is one — never dials. Readahead
    /// and `Drop` use this: neither should ever pay for a reconnect.
    fn connected(&self) -> Option<Arc<Conn>> {
        let slot = self.slot.lock().unwrap();
        slot.conn.as_ref().filter(|c| !c.dead.load(Ordering::Acquire)).cloned()
    }

    /// Drop `failed` from the slot unless someone already replaced it.
    fn discard(&self, failed: &Arc<Conn>) {
        let mut slot = self.slot.lock().unwrap();
        if let Some(cur) = &slot.conn {
            if Arc::ptr_eq(cur, failed) {
                slot.conn = None;
            }
        }
    }

    /// One round trip that also surfaces the connection it ran on and
    /// any fd that rode the reply — `Open` needs all three. Idempotent
    /// requests that lose the connection mid-flight reconnect and
    /// retry once; mutating ones surface [`Error::DaemonGone`].
    fn call_on_conn(&self, req: &Request) -> Result<(Arc<Conn>, Reply)> {
        let conn = self.conn()?;
        match conn.call_raw(req) {
            Ok(reply) => Ok((conn, reply)),
            Err(first) => {
                self.discard(&conn);
                if !req.idempotent() {
                    return Err(Error::DaemonGone(format!(
                        "connection lost mid-request ({first}); not retrying a mutating op"
                    )));
                }
                let conn = self.conn()?;
                match conn.call_raw(req) {
                    Ok(reply) => Ok((conn, reply)),
                    Err(e) => {
                        self.discard(&conn);
                        Err(Error::DaemonGone(format!(
                            "retry after reconnect failed: {e}"
                        )))
                    }
                }
            }
        }
    }

    fn call(&self, req: &Request) -> Result<Response> {
        self.call_on_conn(req).map(|(_, (resp, _))| resp)
    }
}

/// A [`Vfs`] served by a `sea serve` daemon over a Unix socket.
pub struct RemoteFs {
    inner: Arc<Inner>,
}

impl RemoteFs {
    /// Connect to the daemon at `socket` with default retry policy.
    pub fn connect(socket: impl Into<PathBuf>) -> Result<RemoteFs> {
        RemoteFs::connect_with(socket, RetryCfg::default())
    }

    /// Connect with an explicit retry policy.
    pub fn connect_with(socket: impl Into<PathBuf>, retry: RetryCfg) -> Result<RemoteFs> {
        let socket = socket.into();
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() as u64)
            .unwrap_or(0);
        let seed = (std::process::id() as u64) << 32 | nanos;
        let ra_override = std::env::var("SEA_READAHEAD")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok());
        let inner = Arc::new(Inner {
            socket,
            retry,
            slot: Mutex::new(Slot { conn: None, epoch: 0 }),
            rng: Mutex::new(Rng::new(seed)),
            ra_override,
        });
        // Dial eagerly so a bad socket path fails at construction, not
        // on the first I/O.
        inner.conn()?;
        Ok(RemoteFs { inner })
    }

    /// The socket this client targets.
    pub fn socket(&self) -> &Path {
        &self.inner.socket
    }

    /// [`Vfs::open`] returning the concrete handle type — the
    /// interposer needs [`RemoteFile::generation`] /
    /// [`RemoteFile::identity`], which a `Box<dyn VfsFile>` hides.
    pub fn open_remote(&self, path: &Path, mode: OpenMode) -> Result<RemoteFile> {
        open_on(&self.inner, path_str(path), mode)
    }

    /// Fetch the daemon's live counters, ledger, and client gauges
    /// (`sea stat --connect`).
    pub fn counters(&self) -> Result<CountersReply> {
        match self.inner.call(&Request::Counters)?.body {
            Ok(Body::Counters(c)) => Ok(*c),
            Ok(other) => Err(Error::Daemon(format!("bad Counters reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }
}

fn path_str(p: &Path) -> String {
    p.to_string_lossy().into_owned()
}

/// Open `path` on the shared connection and build the handle. A
/// lease-flagged reply carries the dup'd fd that the reader thread
/// paired with the frame.
fn open_on(inner: &Arc<Inner>, path: String, mode: OpenMode) -> Result<RemoteFile> {
    let req = Request::Open { mode, path: path.clone() };
    let (conn, (resp, fd)) = inner.call_on_conn(&req)?;
    match resp.body {
        Ok(Body::Open { handle, ident, lease }) => {
            let ra_window =
                inner.ra_override.unwrap_or(conn.chunk_hint).min(MAX_IO as u64);
            Ok(RemoteFile {
                inner: inner.clone(),
                handle,
                epoch: conn.epoch,
                path,
                mode,
                gen: resp.gen,
                ident,
                lease: match (lease, fd) {
                    (Some(at_gen), Some(f)) => Some((f, at_gen)),
                    // A flag without an fd (or vice versa) degrades to
                    // the wire path; the stray fd closes on drop.
                    _ => None,
                },
                ra_window,
                seq_last_end: 0,
                seq_streak: 0,
                ra_pending: None,
                ra_buf: None,
            })
        }
        Ok(other) => Err(Error::Daemon(format!("bad Open reply: {other:?}"))),
        Err(we) => Err(we.into_error()),
    }
}

impl Vfs for RemoteFs {
    fn open(&self, path: &Path, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
        Ok(Box::new(self.open_remote(path, mode)?))
    }

    fn unlink(&self, path: &Path) -> Result<()> {
        match self.inner.call(&Request::Unlink { path: path_str(path) })?.body {
            Ok(_) => Ok(()),
            Err(we) => Err(we.into_error()),
        }
    }

    fn exists(&self, path: &Path) -> bool {
        match self.inner.call(&Request::Stat { path: path_str(path) }) {
            Ok(resp) => resp.body.is_ok(),
            Err(_) => false,
        }
    }

    fn size(&self, path: &Path) -> Result<u64> {
        match self.inner.call(&Request::Stat { path: path_str(path) })?.body {
            Ok(Body::Size(n)) => Ok(n),
            Ok(other) => Err(Error::Daemon(format!("bad Stat reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        let req = Request::Rename { from: path_str(from), to: path_str(to) };
        match self.inner.call(&req)?.body {
            Ok(_) => Ok(()),
            Err(we) => Err(we.into_error()),
        }
    }

    /// Accumulate the full listing page by page: each reply carries a
    /// continuation token (`0` = done) so one huge directory cannot
    /// monopolize the connection — or blow the frame cap — between
    /// pages of other clients' traffic.
    fn readdir(&self, path: &Path) -> Result<Vec<String>> {
        let p = path_str(path);
        let mut all = Vec::new();
        let mut token = 0u64;
        loop {
            let req = Request::Readdir { path: p.clone(), token };
            match self.inner.call(&req)?.body {
                Ok(Body::Names { names, next }) => {
                    all.extend(names);
                    if next == 0 {
                        return Ok(all);
                    }
                    if next <= token {
                        return Err(Error::Daemon(format!(
                            "readdir token did not advance ({token} -> {next})"
                        )));
                    }
                    token = next;
                }
                Ok(other) => {
                    return Err(Error::Daemon(format!("bad Readdir reply: {other:?}")))
                }
                Err(we) => return Err(we.into_error()),
            }
        }
    }

    fn mkdir(&self, path: &Path) -> Result<()> {
        match self.inner.call(&Request::Mkdir { path: path_str(path) })?.body {
            Ok(_) => Ok(()),
            Err(we) => Err(we.into_error()),
        }
    }

    fn sync_mgmt(&self) -> Result<()> {
        match self.inner.call(&Request::SyncMgmt)?.body {
            Ok(_) => Ok(()),
            Err(we) => Err(we.into_error()),
        }
    }
}

/// A [`VfsFile`] whose bytes live behind the daemon.
pub struct RemoteFile {
    inner: Arc<Inner>,
    handle: u64,
    /// Slot epoch this handle was opened on; a later epoch means the
    /// daemon-side handle table died with the old connection.
    epoch: u64,
    path: String,
    mode: OpenMode,
    /// Last piggybacked daemon-side map generation.
    gen: u64,
    /// Daemon-side frame-sharing identity from `Open`.
    ident: Option<u128>,
    /// Leased local fd + the map generation it was minted at. While
    /// present, `pread` is a raw `pread(2)` on it.
    lease: Option<(std::fs::File, u64)>,
    /// Readahead window in bytes (0 = disabled).
    ra_window: u64,
    /// End offset of the last read — the next offset a sequential
    /// consumer would ask for.
    seq_last_end: u64,
    /// Consecutive reads that continued exactly at `seq_last_end`.
    seq_streak: u32,
    /// In-flight prefetch: starting offset + the mux receiver its
    /// response will land on.
    ra_pending: Option<(u64, mpsc::Receiver<Reply>)>,
    /// Landed prefetch window: starting offset + bytes.
    ra_buf: Option<(u64, Vec<u8>)>,
}

impl RemoteFile {
    /// Last daemon-side map generation piggybacked on a response. A
    /// change since the caller last looked means another client moved
    /// the file (spill) — locally cached pages for it are stale.
    pub fn generation(&self) -> u64 {
        self.gen
    }

    /// The daemon handle's frame-sharing identity (see
    /// [`VfsFile::map_identity`]); `None` when the daemon backend
    /// could not name one.
    pub fn identity(&self) -> Option<u128> {
        self.ident
    }

    /// Does this handle currently hold an fd lease (reads bypass the
    /// wire entirely)?
    pub fn has_lease(&self) -> bool {
        self.lease.is_some()
    }

    /// Open an independent handle to the same path over the same
    /// connection. The interposer's mmap emulation uses this for
    /// write-back handles that must outlive the caller's descriptor
    /// (correct across spills, too: the daemon-side handle follows the
    /// registry to the file's new device).
    pub fn sibling(&self, mode: OpenMode) -> Result<RemoteFile> {
        open_on(&self.inner, self.path.clone(), mode)
    }

    /// Fold a piggybacked generation into the handle. A change means
    /// the file moved tiers: the lease (if any) is revoked back to the
    /// wire path and prefetched windows are dropped — both predate the
    /// move.
    fn observe_gen(&mut self, gen: u64) {
        if gen == self.gen {
            return;
        }
        self.ra_buf = None;
        self.ra_pending = None;
        if let Some((_, minted_at)) = &self.lease {
            if gen > *minted_at {
                self.lease = None;
                crate::obs::trace::instant("lease-revoke", "daemon", "gen-bump", 0);
            }
        }
        self.gen = gen;
    }

    /// Run `req` against this handle, healing a dead connection when
    /// allowed: read-only handles reopen themselves by path and retry
    /// idempotent requests once; writable handles surface
    /// [`Error::DaemonGone`] (their daemon-side state is gone, and
    /// silently reopening would drop append/truncate semantics).
    fn call(&mut self, req: Request) -> Result<Response> {
        let conn = self.inner.conn()?;
        if conn.epoch != self.epoch {
            self.reopen(&conn)?;
        }
        // The reopen above may have changed our daemon-side handle id.
        let req = req.rehandle(self.handle);
        let resp = match conn.call_raw(&req) {
            Ok((resp, _)) => resp,
            Err(first) => {
                self.inner.discard(&conn);
                if !(req.idempotent() && self.mode == OpenMode::Read) {
                    return Err(Error::DaemonGone(format!(
                        "connection lost mid-request on {} ({first})",
                        self.path
                    )));
                }
                let conn = self.inner.conn()?;
                self.reopen(&conn)?;
                let req = req.rehandle(self.handle);
                match conn.call_raw(&req) {
                    Ok((resp, _)) => resp,
                    Err(e) => {
                        self.inner.discard(&conn);
                        return Err(Error::DaemonGone(format!(
                            "retry after reconnect failed: {e}"
                        )));
                    }
                }
            }
        };
        self.observe_gen(resp.gen);
        Ok(resp)
    }

    /// Re-open this handle's path on the current connection (read-only
    /// handles after a reconnect). A fresh lease may ride the reply.
    fn reopen(&mut self, conn: &Arc<Conn>) -> Result<()> {
        if self.mode != OpenMode::Read {
            return Err(Error::DaemonGone(format!(
                "writable handle on {} lost with its connection",
                self.path
            )));
        }
        let req = Request::Open { mode: self.mode, path: self.path.clone() };
        let (resp, fd) = conn.call_raw(&req).map_err(|e| {
            self.inner.discard(conn);
            Error::DaemonGone(format!("reopen of {} failed: {e}", self.path))
        })?;
        match resp.body {
            Ok(Body::Open { handle, ident, lease }) => {
                self.handle = handle;
                self.ident = ident;
                self.epoch = conn.epoch;
                self.gen = resp.gen;
                self.lease = match (lease, fd) {
                    (Some(at_gen), Some(f)) => Some((f, at_gen)),
                    _ => None,
                };
                self.ra_pending = None;
                self.ra_buf = None;
                Ok(())
            }
            Ok(other) => Err(Error::Daemon(format!("bad reopen reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }

    /// Track the access pattern after a completed read.
    fn note_read(&mut self, off: u64, n: u64) {
        if n == 0 {
            // EOF: stop prefetching past the end.
            self.seq_streak = 0;
        } else if off == self.seq_last_end {
            self.seq_streak = self.seq_streak.saturating_add(1);
        } else {
            // First read of a (potential) new sequential run.
            self.seq_streak = 1;
        }
        self.seq_last_end = off + n;
    }

    /// Serve a read from the landed prefetch window, if it covers
    /// `off`. A miss drops the window — the consumer moved on.
    fn take_from_ra(&mut self, buf: &mut [u8], off: u64) -> Option<usize> {
        let hit = match &self.ra_buf {
            Some((start, data)) => {
                off >= *start && off < *start + data.len() as u64
            }
            None => return None,
        };
        if !hit {
            self.ra_buf = None;
            return None;
        }
        let (start, data) = self.ra_buf.as_ref().unwrap();
        let at = (off - *start) as usize;
        let n = buf.len().min(data.len() - at);
        buf[..n].copy_from_slice(&data[at..at + n]);
        Some(n)
    }

    /// If a prefetch for exactly `off` is in flight, wait for it and
    /// promote its data to the window. Returns whether the window may
    /// now serve. A pending prefetch for a *different* offset is
    /// abandoned (its response routes to a dropped receiver).
    fn claim_pending(&mut self, off: u64) -> bool {
        let matches = match &self.ra_pending {
            None => return false,
            Some((at, _)) => *at == off,
        };
        if !matches {
            self.ra_pending = None;
            return false;
        }
        let (at, rx) = self.ra_pending.take().unwrap();
        match rx.recv() {
            Ok((resp, _)) => {
                // Observe first: a generation bump means this data was
                // read after the move and is current *for that gen* —
                // but set the window only after the bump cleared any
                // stale one.
                self.observe_gen(resp.gen);
                if let Ok(Body::Data(d)) = resp.body {
                    if !d.is_empty() {
                        self.ra_buf = Some((at, d));
                    }
                }
                true
            }
            // Connection died with the prefetch; the wire path heals.
            Err(_) => false,
        }
    }

    /// Fire the next prefetch when the pattern warrants one: read-only
    /// handle, readahead enabled, no lease (leased reads are already
    /// local), at least two back-to-back sequential reads, nothing in
    /// flight, and the landed window exhausted.
    fn maybe_prefetch(&mut self) {
        if self.mode != OpenMode::Read || self.ra_window == 0 || self.lease.is_some()
        {
            return;
        }
        if self.seq_streak < 2 || self.ra_pending.is_some() {
            return;
        }
        let next = self.seq_last_end;
        if let Some((start, data)) = &self.ra_buf {
            if next < *start + data.len() as u64 {
                return;
            }
        }
        // Never dial for a prefetch, and never prefetch across an
        // epoch boundary (our handle id died with the old connection).
        let Some(conn) = self.inner.connected() else { return };
        if conn.epoch != self.epoch {
            return;
        }
        let want = self.ra_window.min(MAX_IO as u64) as u32;
        let req = Request::Pread { handle: self.handle, off: next, len: want };
        if let Ok(rx) = conn.send(&req) {
            self.ra_pending = Some((next, rx));
        }
    }
}

impl VfsFile for RemoteFile {
    fn pread(&mut self, buf: &mut [u8], off: u64) -> Result<usize> {
        // Leased fast path: a raw pread(2) on the local replica fd —
        // no round trip, no copy through the daemon. Deliberately no
        // readahead either: the kernel's own is already closer.
        if let Some((f, _)) = &self.lease {
            return f.read_at(buf, off).map_err(|e| Error::io(self.path.clone(), e));
        }
        // Landed prefetch window.
        if let Some(n) = self.take_from_ra(buf, off) {
            self.note_read(off, n as u64);
            self.maybe_prefetch();
            return Ok(n);
        }
        // In-flight prefetch for exactly this offset.
        if self.claim_pending(off) {
            if let Some(n) = self.take_from_ra(buf, off) {
                self.note_read(off, n as u64);
                self.maybe_prefetch();
                return Ok(n);
            }
        }
        // Wire.
        let want = buf.len().min(MAX_IO) as u32;
        let resp = self.call(Request::Pread { handle: self.handle, off, len: want })?;
        match resp.body {
            Ok(Body::Data(d)) => {
                let n = d.len().min(buf.len());
                buf[..n].copy_from_slice(&d[..n]);
                self.note_read(off, n as u64);
                self.maybe_prefetch();
                Ok(n)
            }
            Ok(other) => Err(Error::Daemon(format!("bad Pread reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }

    fn pwrite(&mut self, data: &[u8], off: u64) -> Result<usize> {
        // Clamp to one frame; `pwrite_all` loops over the short write.
        let chunk = &data[..data.len().min(MAX_IO)];
        let req =
            Request::Pwrite { handle: self.handle, off, data: chunk.to_vec() };
        match self.call(req)?.body {
            Ok(Body::Written(n)) => Ok(n as usize),
            Ok(other) => Err(Error::Daemon(format!("bad Pwrite reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }

    fn set_len(&mut self, len: u64) -> Result<()> {
        match self.call(Request::SetLen { handle: self.handle, len })?.body {
            Ok(_) => Ok(()),
            Err(we) => Err(we.into_error()),
        }
    }

    fn fsync(&mut self) -> Result<()> {
        match self.call(Request::Fsync { handle: self.handle })?.body {
            Ok(_) => Ok(()),
            Err(we) => Err(we.into_error()),
        }
    }

    fn len(&self) -> Result<u64> {
        // `len` takes `&self`; route through the shared slot directly.
        let req = Request::Len { handle: self.handle };
        match self.inner.call(&req)?.body {
            Ok(Body::Size(n)) => Ok(n),
            Ok(other) => Err(Error::Daemon(format!("bad Len reply: {other:?}"))),
            Err(we) => Err(we.into_error()),
        }
    }

    fn map_sync(&mut self) -> Result<u64> {
        let resp = self.call(Request::MapSync { handle: self.handle })?;
        match resp.body {
            Ok(_) => Ok(resp.gen),
            Err(we) => Err(we.into_error()),
        }
    }

    fn note_map_fault(&mut self, off: u64, len: u64) {
        let _ = self.call(Request::NoteFault { handle: self.handle, off, len });
    }

    fn map_identity(&self) -> Option<u128> {
        self.ident
    }
}

impl Drop for RemoteFile {
    fn drop(&mut self) {
        // Best-effort close; the daemon reaps the handle with the
        // connection anyway if this races a dead socket. Never dials.
        if let Some(conn) = self.inner.connected() {
            if conn.epoch == self.epoch {
                let _ = conn.call_raw(&Request::Close { handle: self.handle });
            }
        }
    }
}

impl Request {
    /// The same request aimed at a different handle id (retry after a
    /// reconnect re-opened the file under a new daemon-side id).
    fn rehandle(self, handle: u64) -> Request {
        match self {
            Request::Pread { off, len, .. } => Request::Pread { handle, off, len },
            Request::Pwrite { off, data, .. } => Request::Pwrite { handle, off, data },
            Request::SetLen { len, .. } => Request::SetLen { handle, len },
            Request::Fsync { .. } => Request::Fsync { handle },
            Request::Close { .. } => Request::Close { handle },
            Request::Len { .. } => Request::Len { handle },
            Request::MapSync { .. } => Request::MapSync { handle },
            Request::NoteFault { off, len, .. } => Request::NoteFault { handle, off, len },
            other => other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{ServeCfg, Server};
    use crate::vfs::RealFs;

    fn scratch(prefix: &str) -> PathBuf {
        crate::vfs::testutil::scratch(prefix)
    }

    /// Spawn a daemon over a `RealFs` rooted at `dir`.
    fn spawn_real(dir: &Path, socket: &Path, leases: bool) -> Server {
        let fs = Arc::new(RealFs::new(dir).unwrap());
        let mut cfg = ServeCfg::new(socket);
        cfg.lease_fds = leases;
        Server::spawn_vfs(fs, None, cfg).unwrap()
    }

    /// Deterministic content byte for offset `i` of the test files.
    fn pat(i: u64) -> u8 {
        (i % 251) as u8
    }

    fn patterned(len: u64) -> Vec<u8> {
        (0..len).map(pat).collect()
    }

    #[test]
    fn backoff_grows_and_caps() {
        let cfg = RetryCfg {
            attempts: 8,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(80),
        };
        let mut rng = Rng::new(7);
        assert_eq!(cfg.backoff(0, &mut rng), Duration::ZERO);
        let b1 = cfg.backoff(1, &mut rng);
        assert!(b1 >= Duration::from_millis(10) && b1 < Duration::from_millis(16));
        let b4 = cfg.backoff(4, &mut rng);
        assert!(b4 >= Duration::from_millis(80), "uncapped exp: {b4:?}");
        // cap + 50% jitter ceiling
        assert!(b4 <= Duration::from_millis(121), "cap violated: {b4:?}");
        let b30 = cfg.backoff(30, &mut rng); // shift clamp: no overflow
        assert!(b30 <= Duration::from_millis(121));
    }

    #[test]
    fn connect_to_missing_socket_is_typed_and_bounded() {
        let cfg = RetryCfg {
            attempts: 2,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(2),
        };
        let t0 = std::time::Instant::now();
        let err = RemoteFs::connect_with("/nonexistent/sea.sock", cfg);
        match err {
            Err(Error::DaemonGone(msg)) => {
                assert!(msg.contains("2 attempts"), "got: {msg}")
            }
            other => panic!("expected DaemonGone, got {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "retry must be bounded");
    }

    #[test]
    fn leased_reads_bypass_the_wire_and_survive_unlink() {
        let d = scratch("remote_lease");
        let sock = d.join("sea.sock");
        let data = patterned(128 * 1024);
        std::fs::write(d.join("a.dat"), &data).unwrap();
        let srv = spawn_real(&d, &sock, true);
        let fs = RemoteFs::connect(&sock).unwrap();

        let mut f = fs.open_remote(Path::new("a.dat"), OpenMode::Read).unwrap();
        assert!(f.has_lease(), "read-only open on RealFs must come leased");
        let mut buf = vec![0u8; 4096];
        let n = f.pread(&mut buf, 8192).unwrap();
        assert_eq!(n, 4096);
        assert_eq!(buf, data[8192..12288]);

        // A writable handle must NOT be leased (its writes have to go
        // through the daemon for append/spill accounting).
        let w = fs.open_remote(Path::new("a.dat"), OpenMode::ReadWrite).unwrap();
        assert!(!w.has_lease(), "writable handles never lease");
        drop(w);

        // The name goes away; the leased inode does not.
        fs.unlink(Path::new("a.dat")).unwrap();
        let n = f.pread(&mut buf, 0).unwrap();
        assert_eq!(n, 4096);
        assert_eq!(buf, data[..4096], "lease must serve the snapshot after unlink");

        drop(f);
        srv.shutdown().unwrap();
    }

    #[test]
    fn no_lease_mode_serves_identical_bytes() {
        let d = scratch("remote_nolease");
        let sock = d.join("sea.sock");
        let data = patterned(64 * 1024);
        std::fs::write(d.join("w.dat"), &data).unwrap();
        let srv = spawn_real(&d, &sock, false);
        let fs = RemoteFs::connect(&sock).unwrap();
        let mut f = fs.open_remote(Path::new("w.dat"), OpenMode::Read).unwrap();
        assert!(!f.has_lease(), "daemon with --no-leases must not lease");
        let mut buf = vec![0u8; data.len()];
        let mut filled = 0usize;
        while filled < buf.len() {
            let n = f.pread(&mut buf[filled..], filled as u64).unwrap();
            assert!(n > 0);
            filled += n;
        }
        assert_eq!(buf, data);
        drop(f);
        srv.shutdown().unwrap();
    }

    /// Eight handles on ONE connection, each hammering preads from its
    /// own thread: requests overlap in flight on the shared socket
    /// (this is the pipelining the request ids exist for). Leases off
    /// so every read actually rides the wire. Runs under TSan in CI.
    #[test]
    fn eight_handles_pipeline_concurrent_preads_on_one_connection() {
        let d = scratch("remote_mux");
        let sock = d.join("sea.sock");
        const LEN: u64 = 1 << 20;
        let data = Arc::new(patterned(LEN));
        std::fs::write(d.join("big.dat"), &data[..]).unwrap();
        let srv = spawn_real(&d, &sock, false);
        let fs = RemoteFs::connect(&sock).unwrap();

        let mut threads = Vec::new();
        for t in 0..8u64 {
            let mut f =
                fs.open_remote(Path::new("big.dat"), OpenMode::Read).unwrap();
            assert!(!f.has_lease());
            let data = data.clone();
            threads.push(std::thread::spawn(move || {
                let mut buf = vec![0u8; 4096];
                for k in 0..64u64 {
                    // Deterministic scatter, distinct per thread.
                    let page = (k * 37 + t * 101) % (LEN / 4096);
                    let off = page * 4096;
                    let n = f.pread(&mut buf, off).unwrap();
                    assert_eq!(n, 4096, "thread {t} read {k} at {off}");
                    assert_eq!(
                        buf[..],
                        data[off as usize..off as usize + 4096],
                        "thread {t} read {k} at {off} returned wrong bytes"
                    );
                }
            }));
        }
        for th in threads {
            th.join().unwrap();
        }
        // The daemon saw real overlap on this connection.
        let c = fs.counters().unwrap();
        assert!(c.ops_served >= 8 * 64, "ops_served={}", c.ops_served);
        srv.shutdown().unwrap();
    }

    /// A strictly sequential consumer triggers readahead: the whole
    /// file is fetched in a handful of wire round trips instead of one
    /// per small read.
    #[test]
    fn sequential_reads_prefetch_the_next_window() {
        let d = scratch("remote_ra");
        let sock = d.join("sea.sock");
        const LEN: u64 = 256 * 1024;
        let data = patterned(LEN);
        std::fs::write(d.join("seq.dat"), &data).unwrap();
        // Leases off so reads would otherwise each cost a round trip.
        let srv = spawn_real(&d, &sock, false);
        let fs = RemoteFs::connect(&sock).unwrap();
        let mut f = fs.open_remote(Path::new("seq.dat"), OpenMode::Read).unwrap();

        let before = fs.counters().unwrap().ops_served;
        let mut buf = vec![0u8; 4096];
        let mut off = 0u64;
        while off < LEN {
            let n = f.pread(&mut buf, off).unwrap();
            assert!(n > 0, "unexpected EOF at {off}");
            assert_eq!(
                buf[..n],
                data[off as usize..off as usize + n],
                "bytes diverge at {off}"
            );
            off += n as u64;
        }
        let after = fs.counters().unwrap().ops_served;
        // 64 blind reads would cost 64 preads; with the daemon's 1 MiB
        // default window the run costs ~2 wire reads + 1 prefetch.
        let wire_ops = after - before;
        assert!(
            wire_ops <= 10,
            "sequential scan of 64 blocks took {wire_ops} wire ops — readahead dead?"
        );
        drop(f);
        srv.shutdown().unwrap();
    }

    /// Readahead must not serve stale bytes when the access pattern
    /// jumps around (window misses drop the buffer).
    #[test]
    fn random_access_after_sequential_stays_correct() {
        let d = scratch("remote_ra_jump");
        let sock = d.join("sea.sock");
        const LEN: u64 = 512 * 1024;
        let data = patterned(LEN);
        std::fs::write(d.join("j.dat"), &data).unwrap();
        let srv = spawn_real(&d, &sock, false);
        let fs = RemoteFs::connect(&sock).unwrap();
        let mut f = fs.open_remote(Path::new("j.dat"), OpenMode::Read).unwrap();

        let mut buf = vec![0u8; 8192];
        // Warm up sequentially (starts a prefetch)…
        for i in 0..4u64 {
            let off = i * 8192;
            let n = f.pread(&mut buf, off).unwrap();
            assert_eq!(buf[..n], data[off as usize..off as usize + n]);
        }
        // …then leap: backwards, far forwards, unaligned.
        for &off in &[0u64, LEN - 8192, 100_003, 32 * 1024, LEN - 1] {
            let n = f.pread(&mut buf, off).unwrap();
            assert!(n > 0);
            assert_eq!(
                buf[..n],
                data[off as usize..off as usize + n],
                "wrong bytes at jump offset {off}"
            );
        }
        drop(f);
        srv.shutdown().unwrap();
    }

    /// Directory listing stub big enough to force Readdir pagination
    /// (the daemon pages at 256 KiB of encoded names).
    struct HugeDir {
        names: Vec<String>,
    }

    impl Vfs for HugeDir {
        fn open(&self, path: &Path, _: OpenMode) -> Result<Box<dyn VfsFile>> {
            Err(Error::NotFound(path.to_path_buf()))
        }
        fn unlink(&self, path: &Path) -> Result<()> {
            Err(Error::NotFound(path.to_path_buf()))
        }
        fn exists(&self, _: &Path) -> bool {
            true
        }
        fn size(&self, _: &Path) -> Result<u64> {
            Ok(0)
        }
        fn rename(&self, from: &Path, _: &Path) -> Result<()> {
            Err(Error::NotFound(from.to_path_buf()))
        }
        fn readdir(&self, _: &Path) -> Result<Vec<String>> {
            Ok(self.names.clone())
        }
    }

    #[test]
    fn readdir_reassembles_paginated_listings_in_order() {
        let d = scratch("remote_readdir");
        let sock = d.join("sea.sock");
        // ~5000 × 68 B ≈ 340 KiB encoded — two pages minimum.
        let names: Vec<String> =
            (0..5000).map(|i| format!("entry_{i:05}_{}", "x".repeat(52))).collect();
        let fs = Arc::new(HugeDir { names: names.clone() });
        let srv = Server::spawn_vfs(fs, None, ServeCfg::new(&sock)).unwrap();
        let remote = RemoteFs::connect(&sock).unwrap();
        let got = remote.readdir(Path::new("/")).unwrap();
        assert_eq!(got.len(), names.len(), "pagination lost or duplicated names");
        assert_eq!(got, names, "pages reassembled out of order");
        srv.shutdown().unwrap();
    }

    #[test]
    fn mkdir_rides_the_wire_to_the_real_tree() {
        let d = scratch("remote_mkdir");
        let sock = d.join("sea.sock");
        let srv = spawn_real(&d, &sock, true);
        let fs = RemoteFs::connect(&sock).unwrap();
        fs.mkdir(Path::new("out/run_1/logs")).unwrap();
        assert!(d.join("out/run_1/logs").is_dir(), "daemon must create the tree");
        // create_dir_all semantics: repeat succeeds.
        fs.mkdir(Path::new("out/run_1/logs")).unwrap();
        srv.shutdown().unwrap();
    }
}
