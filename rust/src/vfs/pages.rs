//! The **PageCache**: mmap-style windowed views over any [`VfsFile`].
//!
//! The paper's target applications (nibabel/numpy-style array libraries
//! over BigBrain blocks) routinely `mmap` their block files and touch
//! small windows of them. The VFS stack only speaks `pread`/`pwrite`
//! handles, so a mapped workload would otherwise have to materialize
//! whole files — defeating the bounded-memory work of the streaming
//! DataMover. This module puts the missing layer back in user space:
//!
//! * [`PageCache`] — a process-wide (or per-mount) cache of fixed-size
//!   pages with a **global byte budget**. Pages live in [`PAGE_SHARDS`]
//!   independently-locked shards (like the Sea registry and the
//!   temperature heat map) so concurrent views never serialise on one
//!   mutex; eviction is approximate-LRU (coldest clean page, sweeping
//!   shards from the faulting one).
//! * [`MappedView`] — a window `[off, off + len)` over a [`VfsFile`]
//!   handle. Reads **fault pages in copy-on-read** via `pread` (never
//!   more than one page per miss); writes land in cache pages, are
//!   tracked as **dirty byte ranges**, and are written back through the
//!   handle's `pwrite` on [`MappedView::msync`], on view drop, and when
//!   the budget forces the view to reclaim its own dirty pages.
//!
//! Peak resident memory is bounded by the cache budget however large
//! the mapped files are: before a fault allocates a page, clean pages
//! are evicted until the new page fits (dirty pages are pinned — they
//! are only reclaimed through write-back, never dropped).
//!
//! **Frames are shared across views.** Pages are keyed by
//! `(file identity, map generation, page index)`, where the identity
//! comes from [`VfsFile::map_identity`]: every view of one file — any
//! handle, any window — faults a given page once; later views hit the
//! same frame ([`PageCacheStats::shared_hits`]), and two views racing
//! to fault one page collapse onto a single frame at insert
//! ([`PageCacheStats::frames_deduped`]). Writes are coherent: a dirty
//! range stored through one view is immediately visible to every
//! reader of the frame, and write-back happens once (the first flusher
//! clears the frame's dirty range — guarded by a per-frame write
//! stamp, so a store racing with the flush keeps the frame dirty for
//! its own flusher; siblings that find it clean skip). A
//! [`VfsFile::map_sync`] generation bump re-keys the whole
//! identity — every stale frame is orphaned at once (spill
//! invalidation), to be collected by LRU eviction and by the purge at
//! last unmap. Handles without an identity fall back to a private
//! per-view key namespace and behave exactly as before.
//!
//! Backends hook in through three [`VfsFile`] methods with no-op
//! defaults:
//!
//! * [`VfsFile::map_sync`] returns the handle's **map generation**; a
//!   change invalidates the view's cached pages (they transparently
//!   re-fault) after its dirty pages were written back through the
//!   refreshed handle. Sea *writer* handles (`Write` / `ReadWrite` /
//!   `Append` opens) implement it against the registry entry's
//!   generation, so a mid-stream spill relocates a live view onto the
//!   PFS replica instead of losing dirty bytes to an orphaned device
//!   inode.
//! * [`VfsFile::note_map_fault`] observes every fault; Sea handles —
//!   reader and writer alike — feed it into
//!   [`crate::placement::PlacementEngine::on_access`], so mapped reads
//!   heat files for the `TemperatureEngine` exactly like handle reads.
//! * [`VfsFile::map_identity`] names the *file* behind the handle
//!   (device/inode for `RealFs`, mount + path + entry epoch for
//!   `SeaFs`); handles agreeing on it share frames. `None` keys pages
//!   privately per view.
//!
//! Because the machinery runs on the plain handle API, `RealFs`,
//! `RateLimitedFs` and `StripedFs` (both layouts) get mapping for free;
//! a rate-limited backend charges each *fault* for one page, not the
//! whole file.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::error::{Error, Result};
use crate::obs::{trace, Metric, Timer};
use crate::vfs::VfsFile;

/// Default page size: matches the workload drivers' 64 KiB strides.
pub const DEFAULT_PAGE_BYTES: usize = 64 * 1024;

/// Default global budget: small next to one BigBrain block, large
/// enough that a strided pass keeps its working set resident.
pub const DEFAULT_PAGE_BUDGET: u64 = 64 * 1024 * 1024;

/// Page-map shard count (like the registry and the heat map).
pub const PAGE_SHARDS: usize = 16;

/// How a [`MappedView`] may be used.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapMode {
    /// Faults only; writes through the view are refused.
    Read,
    /// Copy-on-read pages accept writes; dirty ranges are written back
    /// on `msync`, view drop, and budget pressure.
    Write,
}

/// Cumulative cache activity (merged into
/// [`crate::vfs::MgmtCounters`] for Sea mounts, printed by `sea stat`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PageCacheStats {
    /// Pages faulted in via `pread`.
    pub faults: u64,
    /// Page lookups served from cache.
    pub hits: u64,
    /// Clean pages dropped to make room.
    pub evictions: u64,
    /// Dirty bytes written back through handles.
    pub writeback_bytes: u64,
    /// Hits served to a view other than the one that faulted the frame
    /// in — cross-view frame sharing at work.
    pub shared_hits: u64,
    /// Duplicate concurrent faults collapsed at insert: the losing
    /// faulter dropped its copy and adopted the winner's frame.
    pub frames_deduped: u64,
    /// Page bytes currently resident.
    pub resident_bytes: u64,
    /// High-water mark of resident page bytes — the bounded-memory
    /// gauge (never exceeds the budget while no dirty pages pin it).
    pub peak_resident_bytes: u64,
}

/// `(file identity, map generation, page index)`: views of one file
/// share frames — the identity comes from [`VfsFile::map_identity`]
/// (a 128-bit digest, shifted into an even namespace; wide enough
/// that two distinct files aliasing onto one frame key is not a
/// practical event), or a private per-view odd fallback when the
/// backend cannot name the file. A `map_sync` generation bump re-keys
/// the whole identity, orphaning every stale frame at once.
type PageKey = (u128, u64, u64);

struct Page {
    /// Exactly `page_bytes` long; the tail past end-of-file is zeros.
    data: Vec<u8>,
    /// View id that faulted the frame in; a hit from any *other* view
    /// counts as [`PageCacheStats::shared_hits`].
    owner: u64,
    /// Current position in the shard's LRU index.
    tick: u64,
    /// Dirty byte range within the page (`start..end`), if any. Dirty
    /// pages are pinned: eviction skips them until written back.
    dirty: Option<(usize, usize)>,
    /// Stamp of the last store into the frame (drawn from the cache
    /// clock, so it never repeats). Flushers snapshot it with the
    /// dirty range and clear the range only if it is unchanged after
    /// the `pwrite`: a concurrent store strictly *inside* the
    /// snapshot range leaves the merged range identical but must
    /// still keep the frame dirty, or its bytes would never be
    /// written back.
    seq: u64,
}

#[derive(Default)]
struct Shard {
    pages: HashMap<PageKey, Page>,
    /// LRU index: tick → key (ticks are unique, from the cache clock).
    lru: BTreeMap<u64, PageKey>,
}

/// A sharded, budgeted page store shared by any number of views.
pub struct PageCache {
    page_bytes: usize,
    budget: u64,
    shards: Vec<Mutex<Shard>>,
    /// Serialises budget admission (evict-until-it-fits + reserve):
    /// without it two concurrent faults could both pass the budget
    /// check and jointly overshoot. Held only while evicting/counting,
    /// never during fault I/O.
    admission: Mutex<()>,
    /// Live-view refcount per identity: frames persist across sibling
    /// views and are purged only when the *last* view of an identity
    /// unmaps (private identities trivially count one view).
    maps: Mutex<HashMap<u128, usize>>,
    clock: AtomicU64,
    ids: AtomicU64,
    resident: AtomicU64,
    peak_resident: AtomicU64,
    faults: AtomicU64,
    hits: AtomicU64,
    evictions: AtomicU64,
    writeback_bytes: AtomicU64,
    shared_hits: AtomicU64,
    frames_deduped: AtomicU64,
}

impl PageCache {
    /// A cache of `page_bytes` pages under a `budget`-byte global
    /// ceiling. The budget is clamped to at least one page, or no
    /// fault could ever succeed.
    pub fn new(page_bytes: usize, budget: u64) -> PageCache {
        let page_bytes = page_bytes.max(1);
        PageCache {
            page_bytes,
            budget: budget.max(page_bytes as u64),
            shards: (0..PAGE_SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            admission: Mutex::new(()),
            maps: Mutex::new(HashMap::new()),
            clock: AtomicU64::new(0),
            ids: AtomicU64::new(0),
            resident: AtomicU64::new(0),
            peak_resident: AtomicU64::new(0),
            faults: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            writeback_bytes: AtomicU64::new(0),
            shared_hits: AtomicU64::new(0),
            frames_deduped: AtomicU64::new(0),
        }
    }

    /// The configured page size.
    pub fn page_bytes(&self) -> usize {
        self.page_bytes
    }

    /// The configured global byte budget.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Snapshot of the cache gauges.
    pub fn stats(&self) -> PageCacheStats {
        PageCacheStats {
            faults: self.faults.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            writeback_bytes: self.writeback_bytes.load(Ordering::Relaxed),
            shared_hits: self.shared_hits.load(Ordering::Relaxed),
            frames_deduped: self.frames_deduped.load(Ordering::Relaxed),
            resident_bytes: self.resident.load(Ordering::Relaxed),
            peak_resident_bytes: self.peak_resident.load(Ordering::Relaxed),
        }
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn shard_of(&self, key: &PageKey) -> usize {
        // page indices are contiguous and generations small; fold the
        // 128-bit identity and mix all three coordinates so one file's
        // pages spread over the shards
        let ident = (key.0 as u64) ^ ((key.0 >> 64) as u64);
        let h = ident
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(key.1.wrapping_mul(0xc2b2_ae3d_27d4_eb4f))
            .wrapping_add(key.2.wrapping_mul(0xff51_afd7_ed55_8ccd));
        (h >> 32) as usize % self.shards.len()
    }

    fn grow_resident(&self) {
        let now = self
            .resident
            .fetch_add(self.page_bytes as u64, Ordering::Relaxed)
            + self.page_bytes as u64;
        self.peak_resident.fetch_max(now, Ordering::Relaxed);
    }

    fn shrink_resident(&self, pages: u64) {
        self.resident
            .fetch_sub(pages * self.page_bytes as u64, Ordering::Relaxed);
    }

    /// Drop one clean page, sweeping shards from `start`. `false` when
    /// every resident page is dirty-pinned.
    fn evict_one(&self, start: usize) -> bool {
        let n = self.shards.len();
        for k in 0..n {
            let mut guard = self.shards[(start + k) % n].lock().expect("page shard poisoned");
            let sh = &mut *guard;
            let victim = sh
                .lru
                .iter()
                .find(|&(_, key)| sh.pages.get(key).is_some_and(|p| p.dirty.is_none()))
                .map(|(t, key)| (*t, *key));
            if let Some((t, key)) = victim {
                sh.lru.remove(&t);
                sh.pages.remove(&key);
                drop(guard);
                self.shrink_resident(1);
                self.evictions.fetch_add(1, Ordering::Relaxed);
                trace::instant("page-evict", "pages", "budget", self.page_bytes as u64);
                return true;
            }
        }
        false
    }

    /// Forget every frame of identity `ident`, across all generations
    /// (last unmap). Dirty ranges are assumed already written back by
    /// the caller, and the caller must hold the `maps` lock so no new
    /// view of the identity can register (and fault frames this purge
    /// would then drop) while the sweep runs.
    fn purge(&self, ident: u128) {
        let mut dropped = 0u64;
        for shard in &self.shards {
            let mut guard = shard.lock().expect("page shard poisoned");
            let sh = &mut *guard;
            let ticks: Vec<u64> = sh
                .pages
                .iter()
                .filter(|(key, _)| key.0 == ident)
                .map(|(_, p)| p.tick)
                .collect();
            if ticks.is_empty() {
                continue;
            }
            dropped += ticks.len() as u64;
            for t in &ticks {
                if let Some(key) = sh.lru.remove(t) {
                    sh.pages.remove(&key);
                }
            }
        }
        if dropped > 0 {
            self.shrink_resident(dropped);
        }
    }
}

/// The process-wide default cache ([`DEFAULT_PAGE_BYTES`] /
/// [`DEFAULT_PAGE_BUDGET`]); Sea mounts carry their own, tuned via
/// `SeaTuning::{page_bytes, page_budget}`.
pub fn global() -> &'static Arc<PageCache> {
    static GLOBAL: OnceLock<Arc<PageCache>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(PageCache::new(DEFAULT_PAGE_BYTES, DEFAULT_PAGE_BUDGET)))
}

/// 128-bit FNV-1a over a sequence of byte strings — the house hash
/// for [`VfsFile::map_identity`] implementations. Backends mix a
/// stable per-source nonce (mount/instance) with the file's
/// coordinates (device + inode, or path + epoch) so identities agree
/// across handles of one file but never across distinct sources. The
/// width matters: frame keys are built from this digest, so a
/// collision would silently serve one file's bytes to readers of
/// another — at 128 bits (127 after the namespace shift) that is not
/// a practical event, where a folded 64-bit key would leave a
/// small-but-silent corruption path.
pub(crate) fn identity_hash(parts: &[&[u8]]) -> u128 {
    const PRIME: u128 = 0x0000_0000_0100_0000_0000_0000_0000_013b;
    let mut h = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58du128;
    for part in parts {
        for &b in *part {
            h ^= b as u128;
            h = h.wrapping_mul(PRIME);
        }
        // length separator, so ("ab", "c") never equals ("a", "bc")
        h ^= part.len() as u128;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// What a page access does with the page's bytes.
enum PageOp<'a> {
    /// Copy `out.len()` bytes starting at `intra` out of the page.
    Read { intra: usize, out: &'a mut [u8] },
    /// Copy `data` into the page at `intra` and extend its dirty range.
    Write { intra: usize, data: &'a [u8] },
}

/// An mmap-style window over a [`VfsFile`] handle.
///
/// The view borrows the handle for its lifetime, so the handle cannot
/// be repositioned or closed while pages reference it — the library
/// analogue of an mmap keeping its backing file pinned.
pub struct MappedView<'f> {
    cache: Arc<PageCache>,
    file: &'f mut (dyn VfsFile + 'f),
    /// Unique per view — the frame-ownership tag behind
    /// [`PageCacheStats::shared_hits`].
    id: u64,
    /// Frame-key namespace: the handle's 128-bit
    /// [`VfsFile::map_identity`] shifted even (shared with every
    /// sibling view of the file), or this view's id shifted odd
    /// (private fallback).
    ident: u128,
    base: u64,
    len: u64,
    mode: MapMode,
    /// Map generation from [`VfsFile::map_sync`]; a change flushes
    /// dirty pages through the refreshed handle, then moves the view
    /// onto the new generation's key space — the old generation's
    /// frames are orphaned wholesale and age out via LRU / last-unmap
    /// purge.
    gen: u64,
    /// Page indices this view has dirtied (for msync / drop / budget
    /// self-reclaim without scanning the shards). Always refers to
    /// `(ident, gen)` keys: dirty pages are flushed before the view
    /// adopts a new generation.
    dirty: BTreeSet<u64>,
}

impl<'f> MappedView<'f> {
    /// Map `[off, off + len)` of `file` through `cache`.
    pub fn new(
        cache: Arc<PageCache>,
        file: &'f mut (dyn VfsFile + 'f),
        off: u64,
        len: u64,
        mode: MapMode,
    ) -> Result<MappedView<'f>> {
        if off.checked_add(len).is_none() {
            return Err(Error::InvalidArg(format!(
                "mapped window [{off}, {off} + {len}) overflows the file offset space"
            )));
        }
        let gen = file.map_sync()?;
        let id = cache.ids.fetch_add(1, Ordering::Relaxed) + 1;
        let ident = match file.map_identity() {
            // shared namespace (even): every view of this file lands
            // on the same frame keys
            Some(h) => h << 1,
            // no identity: a private namespace (odd) that can never
            // collide with a shared one
            None => ((id as u128) << 1) | 1,
        };
        {
            let mut maps = cache.maps.lock().expect("page maps poisoned");
            *maps.entry(ident).or_insert(0) += 1;
        }
        Ok(MappedView {
            cache,
            file,
            id,
            ident,
            base: off,
            len,
            mode,
            gen,
            dirty: BTreeSet::new(),
        })
    }

    /// The view's length in bytes.
    pub fn len(&self) -> u64 {
        self.len
    }

    /// True when the view covers zero bytes.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The view's mode.
    pub fn mode(&self) -> MapMode {
        self.mode
    }

    /// Bytes currently pinned by this view's dirty pages (an upper
    /// bound: whole pages). Dirty pages of one view cannot be reclaimed
    /// by another view's faults, so a writer sharing a tight budget
    /// should `msync` once this approaches its share.
    pub fn dirty_bytes(&self) -> u64 {
        self.dirty.len() as u64 * self.cache.page_bytes as u64
    }

    /// Read up to `out.len()` bytes at view-relative `off`. Like a real
    /// mapping, bytes past end-of-file within the window read as zeros;
    /// the count is only short at the end of the *view*.
    pub fn read_at(&mut self, out: &mut [u8], off: u64) -> Result<usize> {
        self.sync_generation()?;
        if off >= self.len || out.is_empty() {
            return Ok(0);
        }
        let n = (out.len() as u64).min(self.len - off) as usize;
        let pb = self.cache.page_bytes;
        let mut done = 0usize;
        while done < n {
            let fo = self.base + off + done as u64;
            let idx = fo / pb as u64;
            let intra = (fo % pb as u64) as usize;
            let span = (pb - intra).min(n - done);
            let (a, b) = (done, done + span);
            self.page_op(idx, PageOp::Read { intra, out: &mut out[a..b] })?;
            done += span;
        }
        Ok(n)
    }

    /// Write `data` at view-relative `off` into cache pages (no file
    /// I/O until write-back). The range must lie within the view — a
    /// mapping cannot be grown by storing past its end.
    pub fn write_at(&mut self, data: &[u8], off: u64) -> Result<usize> {
        if self.mode != MapMode::Write {
            return Err(Error::InvalidArg("write through a MapMode::Read view".into()));
        }
        // checked: a wrapping `off + len` must not sneak past the bound
        // in release builds and land bytes at a wrapped page index
        let end = off.checked_add(data.len() as u64);
        if end.is_none() || end.unwrap_or(u64::MAX) > self.len {
            return Err(Error::InvalidArg(format!(
                "mapped write at {off} (+{}) exceeds the {}-byte view",
                data.len(),
                self.len
            )));
        }
        self.sync_generation()?;
        if data.is_empty() {
            return Ok(0);
        }
        let pb = self.cache.page_bytes;
        let mut done = 0usize;
        while done < data.len() {
            let fo = self.base + off + done as u64;
            let idx = fo / pb as u64;
            let intra = (fo % pb as u64) as usize;
            let span = (pb - intra).min(data.len() - done);
            self.page_op(idx, PageOp::Write { intra, data: &data[done..done + span] })?;
            self.dirty.insert(idx);
            done += span;
        }
        Ok(data.len())
    }

    /// Write every dirty page back through the handle (the mapping
    /// analogue of `msync(2)`). Pages stay resident and become clean —
    /// and therefore evictable.
    pub fn msync(&mut self) -> Result<()> {
        self.sync_generation()?;
        self.flush_dirty()
    }

    /// `madvise(MADV_DONTNEED)` analogue: release the *clean* pages
    /// wholly contained in view-relative `[off, off + len)` right now,
    /// instead of waiting for LRU pressure — a sequential scan frees
    /// its wake as it goes. Like the kernel's, partial boundary pages
    /// are left alone (a scan advancing in sub-page strides would
    /// otherwise re-fault its boundary page once per stride), and
    /// dirty pages are kept (their bytes only exist here until
    /// write-back); a released page simply re-faults if touched again.
    pub fn advise_dontneed(&mut self, off: u64, len: u64) {
        if len == 0 || off >= self.len {
            return;
        }
        let pb = self.cache.page_bytes as u64;
        let lo = self.base + off;
        let hi = self.base + off + len.min(self.len - off);
        // whole pages only: first fully-covered page .. last one
        let first = (lo + pb - 1) / pb;
        let last_excl = hi / pb;
        if first >= last_excl {
            return;
        }
        let last = last_excl - 1;
        let mut dropped = 0u64;
        for idx in first..=last {
            let key = (self.ident, self.gen, idx);
            let mut guard = self.cache.shards[self.cache.shard_of(&key)]
                .lock()
                .expect("page shard poisoned");
            let sh = &mut *guard;
            if let Some(p) = sh.pages.get(&key) {
                if p.dirty.is_none() {
                    let t = p.tick;
                    sh.pages.remove(&key);
                    sh.lru.remove(&t);
                    dropped += 1;
                }
            }
        }
        if dropped > 0 {
            self.cache.shrink_resident(dropped);
        }
    }

    /// Refresh the handle's map generation; on a change (e.g. a Sea
    /// mid-stream spill relocated the file), dirty pages are written
    /// back through the refreshed handle — at the *old* generation's
    /// keys, where they live — and only then does the view adopt the
    /// new generation. The generation sits in the frame key, so the
    /// bump orphans every stale frame of this identity at once: no
    /// view, this one or a sibling, can resurrect device bytes through
    /// them; they age out via LRU eviction and the last-unmap purge.
    fn sync_generation(&mut self) -> Result<()> {
        let gen = self.file.map_sync()?;
        if gen != self.gen {
            self.flush_dirty()?;
            self.gen = gen;
        }
        Ok(())
    }

    /// Write back this view's dirty ranges via `pwrite`.
    fn flush_dirty(&mut self) -> Result<()> {
        if self.dirty.is_empty() {
            return Ok(());
        }
        let pb = self.cache.page_bytes as u64;
        let idxs: Vec<u64> = self.dirty.iter().copied().collect();
        for idx in idxs {
            let key = (self.ident, self.gen, idx);
            let shard = &self.cache.shards[self.cache.shard_of(&key)];
            // copy the dirty range out under the shard lock — the page
            // stays dirty (and therefore eviction-pinned) until the
            // pwrite succeeds, so a failed or interrupted write-back
            // can never lose the only copy of the bytes. Frames are
            // shared: the first flusher writes the merged range and
            // clears the flag; a sibling that also dirtied the page
            // finds it clean and skips — write-back happens once.
            let pending = {
                let mut sh = shard.lock().expect("page shard poisoned");
                sh.pages
                    .get_mut(&key)
                    .and_then(|p| p.dirty.map(|(a, b)| (a, b, p.seq, p.data[a..b].to_vec())))
            };
            if let Some((a, b, seq, seg)) = pending {
                let file_off = idx * pb + a as u64;
                // on error the page is still dirty and `idx` is still
                // in the view's dirty set: a later msync (or the drop
                // flush) retries the write-back
                self.file.pwrite_all(&seg, file_off)?;
                self.cache
                    .writeback_bytes
                    .fetch_add(seg.len() as u64, Ordering::Relaxed);
                trace::instant("page-writeback", "pages", "dirty", seg.len() as u64);
                let mut sh = shard.lock().expect("page shard poisoned");
                if let Some(p) = sh.pages.get_mut(&key) {
                    // clear only if no store landed since the
                    // snapshot. Comparing the *range* is not enough: a
                    // sibling's write strictly inside [a, b) changes
                    // the bytes but not the merged range, and clearing
                    // the flag then would make the sibling's own flush
                    // skip — those bytes would never reach the file.
                    // The stamp comes from the cache clock, so a
                    // clean→evict→re-fault→re-dirty cycle between our
                    // two lock sections can never reproduce it either.
                    if p.seq == seq {
                        p.dirty = None;
                    }
                }
            }
            self.dirty.remove(&idx);
        }
        Ok(())
    }

    /// Serve one page access: cache hit (on any sibling view's frame),
    /// or fault the page in (making room under the budget first).
    fn page_op(&mut self, idx: u64, op: PageOp<'_>) -> Result<()> {
        let pb = self.cache.page_bytes;
        let key = (self.ident, self.gen, idx);
        let shard_idx = self.cache.shard_of(&key);
        // fast path: the frame is resident — faulted by this view or
        // by any sibling of the same identity + generation. Stale
        // generations never reach this probe: the bump moved the view
        // onto fresh keys, so orphaned frames are simply unreachable.
        {
            let mut guard = self.cache.shards[shard_idx].lock().expect("page shard poisoned");
            let sh = &mut *guard;
            if let Some(p) = sh.pages.get_mut(&key) {
                let t = self.cache.tick();
                sh.lru.remove(&p.tick);
                p.tick = t;
                sh.lru.insert(t, key);
                if p.owner != self.id {
                    self.cache.shared_hits.fetch_add(1, Ordering::Relaxed);
                }
                apply_op(p, op, t);
                self.cache.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(());
            }
        }
        // miss: make room under the budget and *reserve* the incoming
        // page's bytes before faulting. Admission is serialised so two
        // concurrent faults can't both pass the check and jointly
        // overshoot — the counter is bumped under the same lock that
        // evicted down to `budget - page`, so `resident` (and the peak
        // gauge) only ever exceed the budget on the documented
        // dirty-pinned path. Write-back of this view's own dirty pages
        // happens with the lock *released* (backend pwrite can be slow
        // — rate-limited, or spill-retrying under Sea), then admission
        // is retried.
        let cache = self.cache.clone();
        let mut flushed_own = false;
        // bounded patience for transient pressure: a concurrent fault
        // holds its reservation while its pread runs, so "nothing
        // evictable" often resolves itself in microseconds once that
        // page lands (and becomes evictable). Only a budget pinned by
        // *other views' dirty pages* outlasts this, and that is the one
        // documented overshoot case.
        let mut patience = 200u32; // ≈10 ms of 50 µs waits
        loop {
            let reserved = {
                let _admission = cache.admission.lock().expect("page admission poisoned");
                while cache.resident.load(Ordering::Relaxed) + pb as u64 > cache.budget {
                    if !cache.evict_one(shard_idx) {
                        break; // nothing clean left to evict
                    }
                }
                if cache.resident.load(Ordering::Relaxed) + pb as u64 <= cache.budget
                    || (flushed_own || self.dirty.is_empty()) && patience == 0
                {
                    cache.grow_resident();
                    true
                } else {
                    false
                }
            };
            if reserved {
                break;
            }
            if !flushed_own && !self.dirty.is_empty() {
                // every evictable page is gone and our own dirty pages
                // pin the budget: write them back (outside the
                // admission lock) so they become evictable, then retry
                self.flush_dirty()?;
                flushed_own = true;
                continue;
            }
            // nothing left on our side: wait briefly for in-flight
            // faults to land (their pages then evict), overshoot only
            // when the pressure persists
            patience = patience.saturating_sub(1);
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
        // fault outside the admission lock; a failed pread returns the
        // reservation so the budget never leaks
        let mut data = vec![0u8; pb];
        // a write covering the whole page needs no read-in
        let whole_page_write = matches!(&op, PageOp::Write { intra: 0, data: d } if d.len() == pb);
        if !whole_page_write {
            let file_off = idx * pb as u64;
            self.file.note_map_fault(file_off, pb as u64);
            let t = Timer::start();
            let mut filled = 0usize;
            while filled < pb {
                let n = match self.file.pread(&mut data[filled..], file_off + filled as u64) {
                    Ok(n) => n,
                    Err(e) => {
                        cache.shrink_resident(1);
                        return Err(e);
                    }
                };
                if n == 0 {
                    break; // end of file: the tail reads as zeros
                }
                filled += n;
            }
            t.stop(Metric::PageFaultFill);
        }
        cache.faults.fetch_add(1, Ordering::Relaxed);
        let mut page = Page { data, owner: self.id, tick: 0, dirty: None, seq: 0 };
        {
            let mut guard = cache.shards[shard_idx].lock().expect("page shard poisoned");
            let sh = &mut *guard;
            if let Some(winner) = sh.pages.get_mut(&key) {
                // a sibling view faulted the same page while our pread
                // ran: keep the installed frame (it may already carry
                // dirty bytes), apply our op to it, drop our copy and
                // return the budget reservation
                let t = cache.tick();
                sh.lru.remove(&winner.tick);
                winner.tick = t;
                sh.lru.insert(t, key);
                apply_op(winner, op, t);
                drop(guard);
                cache.shrink_resident(1);
                cache.frames_deduped.fetch_add(1, Ordering::Relaxed);
                return Ok(());
            }
            let t = cache.tick();
            apply_op(&mut page, op, t);
            page.tick = t;
            sh.lru.insert(t, key);
            sh.pages.insert(key, page);
        }
        Ok(())
    }
}

fn merge_range(existing: Option<(usize, usize)>, a: usize, b: usize) -> (usize, usize) {
    match existing {
        Some((x, y)) => (x.min(a), y.max(b)),
        None => (a, b),
    }
}

/// Apply one access to a frame (under its shard lock). `stamp` is the
/// caller's cache-clock tick: stores record it in [`Page::seq`] so a
/// flusher can tell "no write landed since my snapshot" apart from "a
/// write landed inside the range I just flushed".
fn apply_op(p: &mut Page, op: PageOp<'_>, stamp: u64) {
    match op {
        PageOp::Read { intra, out } => {
            let n = out.len();
            out.copy_from_slice(&p.data[intra..intra + n]);
        }
        PageOp::Write { intra, data } => {
            p.data[intra..intra + data.len()].copy_from_slice(data);
            p.dirty = Some(merge_range(p.dirty, intra, intra + data.len()));
            p.seq = stamp;
        }
    }
}

impl Drop for MappedView<'_> {
    fn drop(&mut self) {
        // best-effort msync: refresh the handle (a relocated Sea file
        // redirects the write-back) but keep `self.gen` — the dirty
        // frames live at the pre-refresh generation's keys. Errors are
        // swallowed — drop has nowhere to report them; call `msync` to
        // observe.
        if !self.dirty.is_empty() {
            let _ = self.file.map_sync();
            let _ = self.flush_dirty();
        }
        // frames persist while sibling views live; the last view of an
        // identity to unmap purges every generation's frames. The maps
        // lock is held ACROSS the purge: a racing new view of the same
        // identity either registers before the refcount check (then we
        // are not last and skip the purge) or blocks in
        // `MappedView::new` until the purge finishes — it can never
        // register and fault fresh (possibly dirty) frames in between
        // for a stale purge to drop. Safe lock order: `maps` is only
        // ever taken without a shard lock held, and `purge` takes the
        // shard locks one at a time underneath it.
        let mut maps = self.cache.maps.lock().expect("page maps poisoned");
        match maps.get_mut(&self.ident) {
            Some(n) if *n > 1 => {
                *n -= 1;
            }
            _ => {
                maps.remove(&self.ident);
                self.cache.purge(self.ident);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vfs::real::RealFs;
    use crate::vfs::testutil::scratch;
    use crate::vfs::{OpenMode, Vfs};
    use std::path::Path;

    const PAGE: usize = 4096;

    fn cache(pages: u64) -> Arc<PageCache> {
        Arc::new(PageCache::new(PAGE, pages * PAGE as u64))
    }

    fn payload(len: usize, salt: usize) -> Vec<u8> {
        (0..len).map(|k| (k.wrapping_mul(31) ^ salt) as u8).collect()
    }

    /// ISSUE 5 satellite: mapped reads are byte-identical to `pread`
    /// across page-boundary offsets and lengths.
    #[test]
    fn mapped_reads_match_pread_across_page_boundaries() {
        let dir = scratch("pages_prop");
        let fs_ = RealFs::new(&dir).unwrap();
        let size = 3 * PAGE + 7;
        let data = payload(size, 5);
        fs_.write(Path::new("p.dat"), &data).unwrap();
        let offsets = [0u64, PAGE as u64 - 1, PAGE as u64, PAGE as u64 + 1, (3 * PAGE + 6) as u64];
        let lens = [1usize, 17, PAGE - 1, PAGE, PAGE + 1, 2 * PAGE + 3];
        let cache = cache(64);
        for &off in &offsets {
            for &len in &lens {
                // reference: plain pread through a fresh handle
                let mut reference = vec![0u8; len];
                let want = {
                    let mut f = fs_.open(Path::new("p.dat"), OpenMode::Read).unwrap();
                    f.pread(&mut reference, off).unwrap_or(0);
                    (size as u64).saturating_sub(off).min(len as u64) as usize
                };
                let mut f = fs_.open(Path::new("p.dat"), OpenMode::Read).unwrap();
                let mut view = f.map(&cache, 0, size as u64, MapMode::Read).unwrap();
                let mut got = vec![0u8; len];
                let n = view.read_at(&mut got, off).unwrap();
                assert_eq!(n, want, "count at off {off} len {len}");
                assert_eq!(&got[..n], &reference[..n], "bytes at off {off} len {len}");
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// ISSUE 5 acceptance: mapping a 64-page file under a 4-page budget
    /// never materializes the file — peak residency stays within the
    /// budget while every byte reads back identical to `pread`.
    #[test]
    fn budget_bounds_resident_pages_without_changing_bytes() {
        let dir = scratch("pages_budget");
        let fs_ = RealFs::new(&dir).unwrap();
        let size = 64 * PAGE;
        let data = payload(size, 11);
        fs_.write(Path::new("big.dat"), &data).unwrap();
        let cache = cache(4); // budget = 4 pages << file size
        let mut f = fs_.open(Path::new("big.dat"), OpenMode::Read).unwrap();
        let mut view = f.map(&cache, 0, size as u64, MapMode::Read).unwrap();
        // a strided sweep plus a re-read of the start (forces misses)
        let mut buf = vec![0u8; PAGE / 2];
        for pass in 0..2 {
            for k in 0..(2 * size / buf.len()) {
                let off = ((k * buf.len() / 2) % (size - buf.len())) as u64;
                let n = view.read_at(&mut buf, off).unwrap();
                assert_eq!(n, buf.len());
                assert_eq!(
                    &buf[..],
                    &data[off as usize..off as usize + buf.len()],
                    "pass {pass} read {k} at {off}"
                );
            }
        }
        let st = cache.stats();
        assert!(st.faults > 64, "budget forced re-faults: {st:?}");
        assert!(st.evictions > 0, "pages were evicted: {st:?}");
        assert!(
            st.peak_resident_bytes <= cache.budget(),
            "peak {} exceeds budget {}",
            st.peak_resident_bytes,
            cache.budget()
        );
        drop(view);
        assert_eq!(cache.stats().resident_bytes, 0, "view drop purges its pages");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mapped_writes_land_on_msync_and_drop() {
        let dir = scratch("pages_write");
        let fs_ = RealFs::new(&dir).unwrap();
        let size = 2 * PAGE + 100;
        fs_.write(Path::new("w.dat"), &vec![0u8; size]).unwrap();
        let cache = cache(8);
        {
            let mut f = fs_.open(Path::new("w.dat"), OpenMode::ReadWrite).unwrap();
            let mut view = f.map(&cache, 0, size as u64, MapMode::Write).unwrap();
            view.write_at(b"hello", 10).unwrap();
            view.write_at(&[7u8; 200], (PAGE - 100) as u64).unwrap(); // page-crossing
            // nothing on disk until msync
            assert_eq!(&fs_.read(Path::new("w.dat")).unwrap()[10..15], &[0u8; 5]);
            view.msync().unwrap();
            let on_disk = fs_.read(Path::new("w.dat")).unwrap();
            assert_eq!(&on_disk[10..15], b"hello");
            assert!(on_disk[PAGE - 100..PAGE + 100].iter().all(|&b| b == 7));
            // a post-msync write flushes on drop
            view.write_at(b"bye", (size - 3) as u64).unwrap();
        }
        let on_disk = fs_.read(Path::new("w.dat")).unwrap();
        assert_eq!(&on_disk[size - 3..], b"bye");
        assert_eq!(on_disk.len(), size, "partial-page write-back keeps the length");
        assert!(cache.stats().writeback_bytes >= 208);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dirty_pages_self_reclaim_under_budget_pressure() {
        // a writer dirtying more pages than the budget holds must keep
        // making progress: its own dirty pages are written back (and
        // become evictable) instead of wedging the cache
        let dir = scratch("pages_dirty");
        let fs_ = RealFs::new(&dir).unwrap();
        let size = 16 * PAGE;
        fs_.write(Path::new("d.dat"), &vec![0u8; size]).unwrap();
        let cache = cache(2); // 2-page budget, 16 dirty pages coming
        let expect: Vec<u8> = (0..size).map(|k| (k / PAGE + 1) as u8).collect();
        {
            let mut f = fs_.open(Path::new("d.dat"), OpenMode::ReadWrite).unwrap();
            let mut view = f.map(&cache, 0, size as u64, MapMode::Write).unwrap();
            for p in 0..16usize {
                view.write_at(&vec![(p + 1) as u8; PAGE], (p * PAGE) as u64).unwrap();
            }
        }
        assert_eq!(fs_.read(Path::new("d.dat")).unwrap(), expect);
        let st = cache.stats();
        assert!(
            st.peak_resident_bytes <= cache.budget(),
            "peak {} exceeds budget {}",
            st.peak_resident_bytes,
            cache.budget()
        );
        assert_eq!(st.writeback_bytes, size as u64);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn whole_page_writes_skip_the_read_in() {
        let dir = scratch("pages_wpw");
        let fs_ = RealFs::new(&dir).unwrap();
        fs_.write(Path::new("n.dat"), &vec![0u8; 4 * PAGE]).unwrap();
        let cache = cache(8);
        let mut f = fs_.open(Path::new("n.dat"), OpenMode::ReadWrite).unwrap();
        let mut view = f.map(&cache, 0, (4 * PAGE) as u64, MapMode::Write).unwrap();
        view.write_at(&vec![9u8; PAGE], PAGE as u64).unwrap();
        view.msync().unwrap();
        drop(view);
        let st = cache.stats();
        assert_eq!(st.faults, 1, "a whole-page write allocates without pread: {st:?}");
        assert!(fs_
            .read(Path::new("n.dat"))
            .unwrap()[PAGE..2 * PAGE]
            .iter()
            .all(|&b| b == 9));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_mode_views_refuse_writes_and_clamp_at_eof() {
        let dir = scratch("pages_ro");
        let fs_ = RealFs::new(&dir).unwrap();
        fs_.write(Path::new("r.dat"), &vec![3u8; 100]).unwrap();
        let cache = cache(4);
        let mut f = fs_.open(Path::new("r.dat"), OpenMode::Read).unwrap();
        // the view window is larger than the file: the tail reads zero
        let mut view = f.map(&cache, 0, (PAGE * 2) as u64, MapMode::Read).unwrap();
        assert!(matches!(view.write_at(b"x", 0), Err(Error::InvalidArg(_))));
        let mut buf = vec![0xFFu8; 200];
        let n = view.read_at(&mut buf, 50).unwrap();
        assert_eq!(n, 200);
        assert!(buf[..50].iter().all(|&b| b == 3));
        assert!(buf[50..].iter().all(|&b| b == 0), "past EOF reads as zeros");
        // reads past the view end return 0
        assert_eq!(view.read_at(&mut buf, (PAGE * 2) as u64).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn second_pass_hits_the_cache() {
        let dir = scratch("pages_hits");
        let fs_ = RealFs::new(&dir).unwrap();
        let data = payload(4 * PAGE, 3);
        fs_.write(Path::new("h.dat"), &data).unwrap();
        let cache = cache(8);
        let mut f = fs_.open(Path::new("h.dat"), OpenMode::Read).unwrap();
        let mut view = f.map(&cache, 0, (4 * PAGE) as u64, MapMode::Read).unwrap();
        let mut buf = vec![0u8; 4 * PAGE];
        view.read_at(&mut buf, 0).unwrap();
        let cold = cache.stats();
        assert_eq!(cold.faults, 4);
        view.read_at(&mut buf, 0).unwrap();
        let warm = cache.stats();
        assert_eq!(warm.faults, 4, "no re-faults within budget");
        assert_eq!(warm.hits - cold.hits, 4);
        assert_eq!(buf, data);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// ISSUE 6 tentpole: two views of one file share frames — the
    /// second view's pass is all hits on the first view's frames, no
    /// re-faults — and frames persist until the *last* view unmaps.
    #[test]
    fn two_views_share_frames_and_fault_once() {
        let dir = scratch("pages_share");
        let fs_ = RealFs::new(&dir).unwrap();
        let data = payload(4 * PAGE, 21);
        fs_.write(Path::new("s.dat"), &data).unwrap();
        let cache = cache(16);
        let mut fa = fs_.open(Path::new("s.dat"), OpenMode::Read).unwrap();
        let mut fb = fs_.open(Path::new("s.dat"), OpenMode::Read).unwrap();
        let mut va = fa.map(&cache, 0, (4 * PAGE) as u64, MapMode::Read).unwrap();
        let mut vb = fb.map(&cache, 0, (4 * PAGE) as u64, MapMode::Read).unwrap();
        let mut buf = vec![0u8; 4 * PAGE];
        va.read_at(&mut buf, 0).unwrap();
        assert_eq!(buf, data);
        assert_eq!(cache.stats().faults, 4);
        buf.fill(0);
        vb.read_at(&mut buf, 0).unwrap();
        assert_eq!(buf, data);
        let st = cache.stats();
        assert_eq!(st.faults, 4, "second view re-used the first view's frames: {st:?}");
        assert_eq!(st.shared_hits, 4, "hits on another view's frames: {st:?}");
        drop(va);
        assert!(
            cache.stats().resident_bytes > 0,
            "frames persist while a sibling view lives"
        );
        drop(vb);
        assert_eq!(cache.stats().resident_bytes, 0, "last unmap purges the identity");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// ISSUE 6 satellite: a write through view A is read back through
    /// view B from the same frame — no re-fault — and write-back of
    /// the shared dirty range happens once.
    #[test]
    fn writes_are_coherent_across_views_and_flush_once() {
        let dir = scratch("pages_coherent");
        let fs_ = RealFs::new(&dir).unwrap();
        fs_.write(Path::new("c.dat"), &vec![0u8; 2 * PAGE]).unwrap();
        let cache = cache(8);
        let mut fa = fs_.open(Path::new("c.dat"), OpenMode::ReadWrite).unwrap();
        let mut fb = fs_.open(Path::new("c.dat"), OpenMode::ReadWrite).unwrap();
        let mut va = fa.map(&cache, 0, (2 * PAGE) as u64, MapMode::Write).unwrap();
        let mut vb = fb.map(&cache, 0, (2 * PAGE) as u64, MapMode::Write).unwrap();
        va.write_at(b"coherent", 100).unwrap();
        let after_write = cache.stats().faults;
        let mut got = [0u8; 8];
        vb.read_at(&mut got, 100).unwrap();
        assert_eq!(&got, b"coherent", "B sees A's not-yet-written-back bytes");
        assert_eq!(cache.stats().faults, after_write, "B hit A's frame, no re-fault");
        // nothing reached the file yet
        assert_eq!(&fs_.read(Path::new("c.dat")).unwrap()[100..108], &[0u8; 8]);
        // B extends the shared dirty range, then both flush: the first
        // flusher writes the merged range, the second finds it clean
        vb.write_at(b"!", 108).unwrap();
        va.msync().unwrap();
        let wb = cache.stats().writeback_bytes;
        vb.msync().unwrap();
        assert_eq!(cache.stats().writeback_bytes, wb, "second flusher skipped a clean frame");
        let on_disk = fs_.read(Path::new("c.dat")).unwrap();
        assert_eq!(&on_disk[100..109], b"coherent!");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// ISSUE 6 satellite (race-checked under TSan in CI): concurrent
    /// views fault each page effectively once — duplicate concurrent
    /// faults collapse onto one frame at insert (`frames_deduped`), so
    /// installed frames never exceed the page count.
    #[test]
    fn concurrent_views_fault_each_page_at_most_once() {
        let dir = scratch("pages_race");
        let fs_ = RealFs::new(&dir).unwrap();
        let data = payload(8 * PAGE, 13);
        fs_.write(Path::new("r.dat"), &data).unwrap();
        let cache = cache(32);
        std::thread::scope(|s| {
            for _ in 0..2 {
                let cache = cache.clone();
                let fs_ = &fs_;
                let data = &data;
                s.spawn(move || {
                    let mut f = fs_.open(Path::new("r.dat"), OpenMode::Read).unwrap();
                    let mut view = f.map(&cache, 0, (8 * PAGE) as u64, MapMode::Read).unwrap();
                    let mut buf = vec![0u8; PAGE];
                    for p in 0..8usize {
                        let n = view.read_at(&mut buf, (p * PAGE) as u64).unwrap();
                        assert_eq!(n, PAGE);
                        assert_eq!(&buf[..], &data[p * PAGE..(p + 1) * PAGE]);
                    }
                });
            }
        });
        let st = cache.stats();
        assert_eq!(st.hits + st.faults, 16, "every access is a hit or a fault: {st:?}");
        assert_eq!(
            st.faults - st.frames_deduped,
            8,
            "one installed frame per page across both views: {st:?}"
        );
        assert_eq!(cache.stats().resident_bytes, 0, "both views unmapped");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Review regression (high): a store landing strictly *inside* a
    /// flusher's snapshotted dirty range — after the snapshot, while
    /// the pwrite runs unlocked — leaves the merged range unchanged.
    /// The range-equality guard alone would clear the flag and the
    /// storing view's own msync would then skip the "clean" frame,
    /// silently losing the bytes; the per-frame write stamp keeps the
    /// frame dirty for the storing view's flusher.
    #[test]
    fn store_inside_inflight_flush_range_is_not_lost() {
        use std::sync::mpsc::{channel, Receiver, Sender};

        /// Two handles over one buffer, agreeing on an identity; one
        /// can park inside `pwrite` so the test can interleave a
        /// sibling store with a write-back deterministically.
        struct SharedFile {
            data: Arc<Mutex<Vec<u8>>>,
            // park-once plumbing: signal entry, then wait for release
            entered: Option<Sender<()>>,
            release: Option<Receiver<()>>,
        }

        impl VfsFile for SharedFile {
            fn pread(&mut self, buf: &mut [u8], off: u64) -> crate::error::Result<usize> {
                let d = self.data.lock().unwrap();
                let off = off as usize;
                if off >= d.len() {
                    return Ok(0);
                }
                let n = buf.len().min(d.len() - off);
                buf[..n].copy_from_slice(&d[off..off + n]);
                Ok(n)
            }
            fn pwrite(&mut self, data: &[u8], off: u64) -> crate::error::Result<usize> {
                if let (Some(tx), Some(rx)) = (self.entered.take(), self.release.take()) {
                    // flusher parked mid-write-back, snapshot taken
                    tx.send(()).unwrap();
                    rx.recv().unwrap();
                }
                let mut d = self.data.lock().unwrap();
                let end = off as usize + data.len();
                if d.len() < end {
                    d.resize(end, 0);
                }
                d[off as usize..end].copy_from_slice(data);
                Ok(data.len())
            }
            fn set_len(&mut self, len: u64) -> crate::error::Result<()> {
                self.data.lock().unwrap().resize(len as usize, 0);
                Ok(())
            }
            fn fsync(&mut self) -> crate::error::Result<()> {
                Ok(())
            }
            fn len(&self) -> crate::error::Result<u64> {
                Ok(self.data.lock().unwrap().len() as u64)
            }
            fn map_identity(&self) -> Option<u128> {
                Some(7)
            }
        }

        let data = Arc::new(Mutex::new(vec![0u8; PAGE]));
        let (entered_tx, entered_rx) = channel();
        let (release_tx, release_rx) = channel();
        let mut fa = SharedFile {
            data: data.clone(),
            entered: Some(entered_tx),
            release: Some(release_rx),
        };
        let mut fb = SharedFile { data: data.clone(), entered: None, release: None };
        let cache = cache(8);
        std::thread::scope(|s| {
            let cache_a = cache.clone();
            s.spawn(move || {
                let mut va = (&mut fa as &mut dyn VfsFile)
                    .map(&cache_a, 0, PAGE as u64, MapMode::Write)
                    .unwrap();
                va.write_at(b"AAAAAAAA", 0).unwrap();
                // snapshots dirty (0, 8), then parks inside pwrite
                va.msync().unwrap();
            });
            entered_rx.recv().unwrap();
            // A's flusher holds its snapshot; store *inside* [0, 8) —
            // the merged dirty range stays (0, 8), only the stamp moves
            let mut vb = (&mut fb as &mut dyn VfsFile)
                .map(&cache, 0, PAGE as u64, MapMode::Write)
                .unwrap();
            vb.write_at(b"BB", 3).unwrap();
            release_tx.send(()).unwrap();
            // B's bytes must survive A's completed flush
            vb.msync().unwrap();
        });
        assert_eq!(
            &data.lock().unwrap()[..8],
            b"AAABBAAA",
            "a store inside an in-flight flush range reaches the file"
        );
    }

    /// An in-memory handle with no `map_identity`: each view keeps a
    /// private frame namespace (the PR 5 behaviour).
    struct AnonFile(Vec<u8>);

    impl VfsFile for AnonFile {
        fn pread(&mut self, buf: &mut [u8], off: u64) -> crate::error::Result<usize> {
            let off = off as usize;
            if off >= self.0.len() {
                return Ok(0);
            }
            let n = buf.len().min(self.0.len() - off);
            buf[..n].copy_from_slice(&self.0[off..off + n]);
            Ok(n)
        }
        fn pwrite(&mut self, data: &[u8], off: u64) -> crate::error::Result<usize> {
            let end = off as usize + data.len();
            if self.0.len() < end {
                self.0.resize(end, 0);
            }
            self.0[off as usize..end].copy_from_slice(data);
            Ok(data.len())
        }
        fn set_len(&mut self, len: u64) -> crate::error::Result<()> {
            self.0.resize(len as usize, 0);
            Ok(())
        }
        fn fsync(&mut self) -> crate::error::Result<()> {
            Ok(())
        }
        fn len(&self) -> crate::error::Result<u64> {
            Ok(self.0.len() as u64)
        }
    }

    #[test]
    fn identityless_handles_fall_back_to_private_frames() {
        let cache = cache(16);
        let bytes = payload(2 * PAGE, 17);
        let mut fa = AnonFile(bytes.clone());
        let mut fb = AnonFile(bytes.clone());
        let mut va =
            (&mut fa as &mut dyn VfsFile).map(&cache, 0, (2 * PAGE) as u64, MapMode::Read).unwrap();
        let mut vb =
            (&mut fb as &mut dyn VfsFile).map(&cache, 0, (2 * PAGE) as u64, MapMode::Read).unwrap();
        let mut buf = vec![0u8; 2 * PAGE];
        va.read_at(&mut buf, 0).unwrap();
        vb.read_at(&mut buf, 0).unwrap();
        let st = cache.stats();
        assert_eq!(st.faults, 4, "no identity, no sharing: {st:?}");
        assert_eq!(st.shared_hits, 0);
        va.read_at(&mut buf, 0).unwrap();
        vb.read_at(&mut buf, 0).unwrap();
        assert_eq!(cache.stats().hits, 4, "each view still hits its own frames");
    }

    #[test]
    fn views_of_a_base_offset_window_address_relative_bytes() {
        let dir = scratch("pages_window");
        let fs_ = RealFs::new(&dir).unwrap();
        let data = payload(4 * PAGE, 9);
        fs_.write(Path::new("win.dat"), &data).unwrap();
        let cache = cache(8);
        let mut f = fs_.open(Path::new("win.dat"), OpenMode::Read).unwrap();
        // a window starting mid-page: view offset 0 = file offset 100
        let mut view = f.map(&cache, 100, PAGE as u64, MapMode::Read).unwrap();
        let mut buf = vec![0u8; 64];
        let n = view.read_at(&mut buf, 0).unwrap();
        assert_eq!(n, 64);
        assert_eq!(&buf[..], &data[100..164]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
