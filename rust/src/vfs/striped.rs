//! [`StripedFs`] — a [`Vfs`] that shards files across N member backends.
//!
//! Real Lustre deployments stripe across OSTs, each with its own
//! bandwidth and concurrency limits; the paper treats "the PFS" as one
//! opaque pool. `StripedFs` is the stand-in that puts the members back:
//! every file maps to exactly one member by a stable hash of its path
//! (file-granularity striping — one file never spans members, matching
//! `stripe_count=1` Lustre, the common default for many-file workloads).
//!
//! Members are themselves `Vfs` backends, so they can be plain
//! [`crate::vfs::RealFs`] directories, rate-limited decorators (per-OST
//! bandwidth caps), or anything else. The member topology is exposed
//! through [`Vfs::shard_count`] / [`Vfs::shard_of`], which survive
//! wrapping in [`crate::vfs::RateLimitedFs`]; `SeaFs`'s flush pool uses
//! it to cap in-flight flushes per member (OST-aware scheduling).
//!
//! `rename` between members streams the bytes through bounded buffers
//! and then unlinks the source — the only cross-member operation.
//!
//! **Stripe mode** ([`StripedFs::striped`]) puts block-granularity
//! striping back (`stripe_count > 1` Lustre): every file is cut into
//! fixed `stripe_bytes` units, stripe `s` lands on member `s % N` at
//! the RAID-0-compacted local offset `(s / N) * stripe_bytes`, so one
//! large file spans *all* members and a chunked bulk copy
//! ([`crate::vfs::DataMover`]) round-robins their bandwidth. The unit
//! is advertised via [`Vfs::stripe_bytes`] so copy engines align their
//! chunks to whole stripes. The two layouts are mount-level choices
//! and not interchangeable on the same directory tree.

use std::path::Path;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::vfs::{OpenMode, Vfs, VfsFile};

/// Copy buffer for cross-member renames.
const COPY_CHUNK: usize = 1 << 20;

/// FNV-1a, hand-rolled: the member mapping is *durable* (it decides
/// where bytes live on disk), so it must not depend on
/// `DefaultHasher`'s algorithm, which is explicitly unstable across
/// Rust releases.
fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Per-member part length for a logical file of `len` bytes striped in
/// `stripe`-byte units over `n` members: member `m` holds every stripe
/// `s` with `s % n == m`, compacted (stripe `s` at local offset
/// `(s / n) * stripe`).
fn part_len(len: u64, stripe: u64, n: u64, m: u64) -> u64 {
    let full = len / stripe;
    let rem = len % stripe;
    // full stripes on member m: |{ j : j*n + m < full }|
    let fulls = if full > m { (full - m + n - 1) / n } else { 0 };
    fulls * stripe + if rem > 0 && full % n == m { rem } else { 0 }
}

/// Inverse of [`part_len`]: the logical length implied by member `m`
/// holding `plen` part bytes (its highest stored logical offset, plus
/// one).
fn logical_len(plen: u64, stripe: u64, n: u64, m: u64) -> u64 {
    if plen == 0 {
        return 0;
    }
    let last = plen - 1;
    let local_stripe = last / stripe;
    let intra = last % stripe;
    (local_stripe * n + m) * stripe + intra + 1
}

/// A striped backend over N member [`Vfs`] roots: file-granularity by
/// default, block-granularity in stripe mode.
pub struct StripedFs {
    members: Vec<Arc<dyn Vfs>>,
    /// `Some(unit)`: block-granularity striping; `None`: whole files.
    stripe: Option<u64>,
    /// Per-instance salt for stripe-handle [`VfsFile::map_identity`]:
    /// handles of one file on one mount share frames, while two
    /// `StripedFs` instances over different directories can never
    /// collide on a path name alone.
    nonce: u64,
}

impl StripedFs {
    /// Build from member backends (at least one), whole-file layout.
    pub fn new(members: Vec<Arc<dyn Vfs>>) -> Result<StripedFs> {
        static NONCE: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);
        if members.is_empty() {
            return Err(Error::Config("striped fs requires at least one member".into()));
        }
        let nonce = NONCE.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(StripedFs { members, stripe: None, nonce })
    }

    /// Build in **stripe mode**: files are cut into `stripe_bytes`
    /// units RAID-0'd across the members, so a single large file's
    /// bandwidth aggregates across OSTs.
    pub fn striped(members: Vec<Arc<dyn Vfs>>, stripe_bytes: u64) -> Result<StripedFs> {
        if stripe_bytes == 0 {
            return Err(Error::Config("stripe_bytes must be positive".into()));
        }
        let mut fs_ = StripedFs::new(members)?;
        fs_.stripe = Some(stripe_bytes);
        Ok(fs_)
    }

    /// Convenience: one [`crate::vfs::RealFs`] member per directory.
    pub fn from_dirs<P: Into<std::path::PathBuf>>(dirs: Vec<P>) -> Result<StripedFs> {
        let mut members: Vec<Arc<dyn Vfs>> = Vec::new();
        for d in dirs {
            members.push(Arc::new(crate::vfs::RealFs::new(d)?));
        }
        StripedFs::new(members)
    }

    /// Convenience: stripe mode over one [`crate::vfs::RealFs`] member
    /// per directory.
    pub fn from_dirs_striped<P: Into<std::path::PathBuf>>(
        dirs: Vec<P>,
        stripe_bytes: u64,
    ) -> Result<StripedFs> {
        let mut members: Vec<Arc<dyn Vfs>> = Vec::new();
        for d in dirs {
            members.push(Arc::new(crate::vfs::RealFs::new(d)?));
        }
        StripedFs::striped(members, stripe_bytes)
    }

    /// Open a stripe-mode handle: one part handle per member. Writable
    /// modes create every part up front (Write truncates them all);
    /// read opens tolerate missing trailing parts (short files only
    /// touch the first members).
    fn open_striped(&self, path: &Path, mode: OpenMode, stripe: u64) -> Result<Box<dyn VfsFile>> {
        let mut parts: Vec<Option<Box<dyn VfsFile>>> = Vec::with_capacity(self.members.len());
        match mode {
            OpenMode::Read => {
                let mut any = false;
                for m in &self.members {
                    match m.open(path, OpenMode::Read) {
                        Ok(h) => {
                            any = true;
                            parts.push(Some(h));
                        }
                        Err(Error::NotFound(_)) => parts.push(None),
                        Err(e) => return Err(e),
                    }
                }
                if !any {
                    return Err(Error::NotFound(path.to_path_buf()));
                }
            }
            OpenMode::Write | OpenMode::ReadWrite | OpenMode::Append => {
                let inner = if mode == OpenMode::Write {
                    OpenMode::Write
                } else {
                    OpenMode::ReadWrite
                };
                for m in &self.members {
                    parts.push(Some(m.open(path, inner)?));
                }
            }
        }
        // identity = instance nonce + normalized path: every stripe
        // handle of one file on this mount shares page-cache frames
        // (whole-file mode inherits the member handle's identity)
        let key = path.to_string_lossy();
        let ident = crate::vfs::pages::identity_hash(&[
            &self.nonce.to_le_bytes(),
            key.trim_start_matches('/').as_bytes(),
        ]);
        Ok(Box::new(StripedFile { parts, stripe, append: mode == OpenMode::Append, ident }))
    }

    /// Number of members.
    pub fn member_count(&self) -> usize {
        self.members.len()
    }

    /// Stable member index for `path` (leading slashes are ignored so
    /// `/x/y` and `x/y` land on the same member). FNV-1a keeps the
    /// mapping identical across builds and Rust versions — files placed
    /// by one binary stay findable by the next.
    pub fn member_of(&self, path: &Path) -> usize {
        let key = path.to_string_lossy();
        let key = key.trim_start_matches('/');
        (fnv1a(key) as usize) % self.members.len()
    }

    fn member(&self, path: &Path) -> &Arc<dyn Vfs> {
        &self.members[self.member_of(path)]
    }
}

/// Handle over a stripe-mode file: positioned ops split at stripe
/// boundaries and fan out to per-member part handles.
struct StripedFile {
    /// One handle per member; `None` when a read-only open found no
    /// part there (short file: only the first members hold stripes).
    parts: Vec<Option<Box<dyn VfsFile>>>,
    stripe: u64,
    /// Append emulation: the offset is resolved from the current
    /// logical length per write (single-process semantics — stripe
    /// parts have no shared O_APPEND cursor).
    append: bool,
    /// [`VfsFile::map_identity`]: instance nonce + path hash, shared by
    /// every handle of this file on the owning mount.
    ident: u128,
}

impl StripedFile {
    fn n(&self) -> u64 {
        self.parts.len() as u64
    }

    /// `(member, local offset, span)` of the stripe segment starting
    /// at logical `off`, capped at `len` bytes.
    fn segment(&self, off: u64, len: usize) -> (usize, u64, usize) {
        let s = off / self.stripe;
        let intra = off % self.stripe;
        let member = (s % self.n()) as usize;
        let local = (s / self.n()) * self.stripe + intra;
        let span = (self.stripe - intra).min(len as u64) as usize;
        (member, local, span)
    }

    fn logical_len(&self) -> Result<u64> {
        let n = self.n();
        let mut len = 0u64;
        for (m, p) in self.parts.iter().enumerate() {
            if let Some(h) = p {
                len = len.max(logical_len(h.len()?, self.stripe, n, m as u64));
            }
        }
        Ok(len)
    }
}

impl VfsFile for StripedFile {
    fn pread(&mut self, buf: &mut [u8], off: u64) -> Result<usize> {
        // The reconstructed length (one len() per member) is computed
        // lazily, only when a member segment comes back short — reads
        // inside fully-written regions never pay the extra stats.
        let mut flen: Option<u64> = None;
        let mut done = 0usize;
        while done < buf.len() {
            let (m, local, span) = self.segment(off + done as u64, buf.len() - done);
            let mut got = 0usize;
            if let Some(h) = &mut self.parts[m] {
                while got < span {
                    let n = h.pread(&mut buf[done + got..done + span], local + got as u64)?;
                    if n == 0 {
                        break; // member EOF
                    }
                    got += n;
                }
            }
            done += got;
            if got == span {
                continue;
            }
            // short member segment: a hole (a later stripe was written
            // first — the missing bytes read as zeros) or logical EOF?
            let end = match flen {
                Some(l) => l,
                None => {
                    let l = self.logical_len()?;
                    flen = Some(l);
                    l
                }
            };
            let pos = off + done as u64;
            if pos >= end {
                break; // logical EOF
            }
            let fill = (end - pos).min((span - got) as u64) as usize;
            buf[done..done + fill].fill(0);
            done += fill;
            if got + fill < span {
                break; // the zero-fill ran into logical EOF mid-segment
            }
        }
        Ok(done)
    }

    fn pwrite(&mut self, data: &[u8], off: u64) -> Result<usize> {
        let off = if self.append { self.logical_len()? } else { off };
        let mut done = 0usize;
        while done < data.len() {
            let (m, local, span) = self.segment(off + done as u64, data.len() - done);
            // writable opens create every part; a None here means the
            // handle was opened read-only — error, like any other
            // read-only handle, instead of aborting the thread
            let Some(h) = self.parts[m].as_mut() else {
                return Err(Error::io(
                    "<striped-handle>",
                    std::io::Error::new(
                        std::io::ErrorKind::PermissionDenied,
                        "pwrite on a read-only stripe handle",
                    ),
                ));
            };
            h.pwrite_all(&data[done..done + span], local)?;
            done += span;
        }
        Ok(data.len())
    }

    fn set_len(&mut self, len: u64) -> Result<()> {
        let (stripe, n) = (self.stripe, self.n());
        for (m, p) in self.parts.iter_mut().enumerate() {
            let target = part_len(len, stripe, n, m as u64);
            match p {
                Some(h) => h.set_len(target)?,
                None => {
                    if target > 0 {
                        return Err(Error::io(
                            "<striped-handle>",
                            std::io::Error::new(
                                std::io::ErrorKind::PermissionDenied,
                                "set_len on a read-only stripe handle",
                            ),
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    fn fsync(&mut self) -> Result<()> {
        for p in self.parts.iter_mut().flatten() {
            p.fsync()?;
        }
        Ok(())
    }

    fn len(&self) -> Result<u64> {
        self.logical_len()
    }

    fn map_identity(&self) -> Option<u128> {
        Some(self.ident)
    }
}

impl Vfs for StripedFs {
    fn open(&self, path: &Path, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
        match self.stripe {
            None => self.member(path).open(path, mode),
            Some(stripe) => self.open_striped(path, mode, stripe),
        }
    }

    // whole-file read/write use the trait defaults (layered over open),
    // so both layouts share one code path

    fn unlink(&self, path: &Path) -> Result<()> {
        if self.stripe.is_none() {
            return self.member(path).unlink(path);
        }
        let mut any = false;
        for m in &self.members {
            match m.unlink(path) {
                Ok(()) => any = true,
                Err(Error::NotFound(_)) => {}
                Err(e) => return Err(e),
            }
        }
        if any {
            Ok(())
        } else {
            Err(Error::NotFound(path.to_path_buf()))
        }
    }

    fn exists(&self, path: &Path) -> bool {
        match self.stripe {
            None => self.member(path).exists(path),
            Some(_) => self.members.iter().any(|m| m.exists(path)),
        }
    }

    fn size(&self, path: &Path) -> Result<u64> {
        let Some(stripe) = self.stripe else {
            return self.member(path).size(path);
        };
        let n = self.members.len() as u64;
        let mut found = false;
        let mut len = 0u64;
        for (m, member) in self.members.iter().enumerate() {
            match member.size(path) {
                Ok(plen) => {
                    found = true;
                    len = len.max(logical_len(plen, stripe, n, m as u64));
                }
                Err(Error::NotFound(_)) => {}
                Err(e) => return Err(e),
            }
        }
        if found {
            Ok(len)
        } else {
            Err(Error::NotFound(path.to_path_buf()))
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        if self.stripe.is_some() {
            // stripe mode: parts keep their member (layout is
            // position-based, not name-based). Phase 1 moves every
            // source part, undoing already-moved parts if a member
            // fails mid-loop so the source never ends up split across
            // two names; stale destination parts are cleared only
            // after every rename committed.
            let have: Vec<bool> = self.members.iter().map(|m| m.exists(from)).collect();
            if !have.iter().any(|&b| b) {
                return Err(Error::NotFound(from.to_path_buf()));
            }
            for (i, m) in self.members.iter().enumerate() {
                if !have[i] {
                    continue;
                }
                if let Err(e) = m.rename(from, to) {
                    // best-effort rollback: restore the parts renamed
                    // so far, then drop every surviving destination
                    // part — members already renamed-over lost theirs,
                    // so a half-replaced destination would read as a
                    // silently corrupt file; cleanly absent is
                    // detectable. The source stays whole and readable.
                    for (j, mj) in self.members.iter().enumerate() {
                        let restored = if j < i && have[j] {
                            mj.rename(to, from).is_ok()
                        } else {
                            true
                        };
                        // never unlink a source part stranded under the
                        // destination name by a failed restore
                        if restored {
                            let _ = mj.unlink(to);
                        }
                    }
                    return Err(e);
                }
            }
            for (i, m) in self.members.iter().enumerate() {
                if !have[i] {
                    match m.unlink(to) {
                        Ok(()) | Err(Error::NotFound(_)) => {}
                        Err(e) => return Err(e),
                    }
                }
            }
            return Ok(());
        }
        let (mf, mt) = (self.member_of(from), self.member_of(to));
        if mf == mt {
            return self.members[mf].rename(from, to);
        }
        // cross-member: stream-copy, then unlink the source only once
        // the copy is complete
        let copy = (|| -> Result<()> {
            let mut src = self.members[mf].open(from, OpenMode::Read)?;
            let mut dst = self.members[mt].open(to, OpenMode::Write)?;
            let mut buf = vec![0u8; COPY_CHUNK];
            let mut off = 0u64;
            loop {
                let n = src.pread(&mut buf, off)?;
                if n == 0 {
                    return Ok(());
                }
                dst.pwrite_all(&buf[..n], off)?;
                off += n as u64;
            }
        })();
        if let Err(e) = copy {
            // don't leave a truncated destination behind: a later read
            // falling through to it would see silent corruption
            let _ = self.members[mt].unlink(to);
            return Err(e);
        }
        self.members[mf].unlink(from)
    }

    fn readdir(&self, path: &Path) -> Result<Vec<String>> {
        let mut names = Vec::new();
        let mut first_err = None;
        let mut any_ok = false;
        for m in &self.members {
            match m.readdir(path) {
                Ok(mut n) => {
                    any_ok = true;
                    names.append(&mut n);
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if !any_ok {
            return Err(first_err.expect("at least one member"));
        }
        names.sort();
        names.dedup();
        Ok(names)
    }

    fn mkdir(&self, path: &Path) -> Result<()> {
        // Any member may end up holding a file under this directory
        // (hash placement / striping), so create it on all of them.
        for m in &self.members {
            m.mkdir(path)?;
        }
        Ok(())
    }

    fn sync_mgmt(&self) -> Result<()> {
        for m in &self.members {
            m.sync_mgmt()?;
        }
        Ok(())
    }

    fn shard_count(&self) -> Option<usize> {
        Some(self.members.len())
    }

    fn shard_of(&self, path: &Path) -> Option<usize> {
        // in stripe mode a file spans all members; the hash pick still
        // spreads *scheduling* (flush-gate slots) evenly
        Some(self.member_of(path))
    }

    fn stripe_bytes(&self) -> Option<u64> {
        self.stripe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vfs::real::RealFs;
    use crate::vfs::testutil::scratch;
    use std::path::PathBuf;

    fn striped(n: usize) -> (StripedFs, PathBuf) {
        let root = scratch("striped");
        let dirs: Vec<PathBuf> = (0..n).map(|i| root.join(format!("ost{i}"))).collect();
        (StripedFs::from_dirs(dirs).unwrap(), root)
    }

    #[test]
    fn round_trip_and_member_stability() {
        let (fs_, root) = striped(4);
        for i in 0..32 {
            let p = PathBuf::from(format!("d/f{i}.dat"));
            fs_.write(&p, format!("payload-{i}").as_bytes()).unwrap();
            assert!(fs_.exists(&p));
            assert_eq!(fs_.read(&p).unwrap(), format!("payload-{i}").as_bytes());
            assert_eq!(fs_.size(&p).unwrap(), format!("payload-{i}").len() as u64);
            // the mapping is stable and slash-insensitive
            assert_eq!(fs_.member_of(&p), fs_.member_of(&PathBuf::from(format!("/d/f{i}.dat"))));
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn files_spread_across_members() {
        let (fs_, root) = striped(4);
        let mut seen = std::collections::HashSet::new();
        for i in 0..64 {
            seen.insert(fs_.member_of(&PathBuf::from(format!("x/{i}.dat"))));
        }
        assert_eq!(seen.len(), 4, "64 hashed paths should hit all 4 members");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn rename_crosses_members_when_hashes_differ() {
        let (fs_, root) = striped(3);
        // find two names that land on different members
        let from = PathBuf::from("a.dat");
        let mut to = None;
        for i in 0..64 {
            let cand = PathBuf::from(format!("b{i}.dat"));
            if fs_.member_of(&cand) != fs_.member_of(&from) {
                to = Some(cand);
                break;
            }
        }
        let to = to.expect("some name must hash elsewhere");
        let payload = vec![7u8; 3 * COPY_CHUNK / 2]; // force a multi-chunk copy
        fs_.write(&from, &payload).unwrap();
        fs_.rename(&from, &to).unwrap();
        assert!(!fs_.exists(&from));
        assert_eq!(fs_.read(&to).unwrap(), payload);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn readdir_merges_members() {
        let (fs_, root) = striped(4);
        for i in 0..16 {
            fs_.write(&PathBuf::from(format!("dir/f{i:02}")), b"1").unwrap();
        }
        let names = fs_.readdir(Path::new("dir")).unwrap();
        assert_eq!(names.len(), 16);
        assert_eq!(names[0], "f00");
        assert_eq!(names[15], "f15");
        // a directory no member has errors out
        assert!(fs_.readdir(Path::new("missing")).is_err());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn shard_introspection_exposed_and_survives_rate_limit() {
        let (fs_, root) = striped(4);
        assert_eq!(fs_.shard_count(), Some(4));
        let p = Path::new("q.dat");
        let m = fs_.shard_of(p);
        assert!(m.unwrap() < 4);
        let wrapped = crate::vfs::RateLimitedFs::new(fs_, 1e9, 1e9);
        assert_eq!(wrapped.shard_count(), Some(4));
        assert_eq!(wrapped.shard_of(p), m);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn empty_members_rejected() {
        assert!(StripedFs::new(Vec::new()).is_err());
    }

    #[test]
    fn member_hash_is_pinned() {
        // the mapping is durable on-disk state: pin the FNV-1a value so
        // an accidental algorithm change can't strand existing files
        assert_eq!(fnv1a("inputs/block_0001.dat"), 0x9195_4b05_3a28_ce5b);
        let (fs_, root) = striped(4);
        assert_eq!(fs_.member_of(Path::new("inputs/block_0001.dat")), 3);
        let _ = std::fs::remove_dir_all(&root);
    }

    // --- stripe mode ---------------------------------------------------------

    fn stripe_mode(n: usize, stripe: u64) -> (StripedFs, PathBuf) {
        let root = scratch("striped_blocks");
        let dirs: Vec<PathBuf> = (0..n).map(|i| root.join(format!("ost{i}"))).collect();
        (StripedFs::from_dirs_striped(dirs, stripe).unwrap(), root)
    }

    #[test]
    fn stripe_part_math_round_trips() {
        let (stripe, n) = (4096u64, 4u64);
        for len in [0u64, 1, 4095, 4096, 4097, 3 * 4096 + 7, 16 * 4096, 17 * 4096 + 1] {
            let parts: Vec<u64> = (0..n).map(|m| part_len(len, stripe, n, m)).collect();
            assert_eq!(parts.iter().sum::<u64>(), len, "parts cover len {len}");
            let back = (0..n)
                .map(|m| logical_len(parts[m as usize], stripe, n, m))
                .max()
                .unwrap();
            assert_eq!(back, len, "logical_len inverts part_len for {len}");
        }
    }

    #[test]
    fn stripe_mode_round_trips_and_spans_all_members() {
        const STRIPE: u64 = 4096;
        let (fs_, root) = stripe_mode(4, STRIPE);
        let p = Path::new("big.dat");
        // 6.5 stripes: every member holds at least one part
        let payload: Vec<u8> = (0..(6 * STRIPE + STRIPE / 2) as usize)
            .map(|k| (k / STRIPE as usize) as u8)
            .collect();
        {
            let mut f = fs_.open(p, OpenMode::Write).unwrap();
            f.pwrite_all(&payload, 0).unwrap();
            assert_eq!(f.len().unwrap(), payload.len() as u64);
        }
        assert!(fs_.exists(p));
        assert_eq!(fs_.size(p).unwrap(), payload.len() as u64);
        assert_eq!(fs_.read(p).unwrap(), payload);
        // the parts really are distributed: every member dir has bytes
        for i in 0..4 {
            let part = root.join(format!("ost{i}")).join("big.dat");
            let plen = std::fs::metadata(&part).map(|m| m.len()).unwrap_or(0);
            assert!(plen > 0, "member {i} holds no part");
            assert_eq!(
                plen,
                part_len(payload.len() as u64, STRIPE, 4, i as u64),
                "member {i} part length"
            );
        }
        // unaligned positioned read across a stripe boundary
        let mut f = fs_.open(p, OpenMode::Read).unwrap();
        let mut buf = vec![0u8; 100];
        f.pread_exact(&mut buf, STRIPE - 50).unwrap();
        assert_eq!(&buf[..50], &payload[(STRIPE - 50) as usize..STRIPE as usize]);
        assert_eq!(&buf[50..], &payload[STRIPE as usize..(STRIPE + 50) as usize]);
        // shrink: every member's part truncates to its share
        {
            let mut f = fs_.open(p, OpenMode::ReadWrite).unwrap();
            f.set_len(STRIPE + 10).unwrap();
            assert_eq!(f.len().unwrap(), STRIPE + 10);
        }
        assert_eq!(fs_.size(p).unwrap(), STRIPE + 10);
        assert_eq!(fs_.read(p).unwrap(), &payload[..(STRIPE + 10) as usize]);
        // unlink removes every part
        fs_.unlink(p).unwrap();
        assert!(!fs_.exists(p));
        assert!(matches!(fs_.unlink(p), Err(Error::NotFound(_))));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn stripe_mode_sparse_writes_read_back_with_zero_holes() {
        const STRIPE: u64 = 1024;
        let (fs_, root) = stripe_mode(4, STRIPE);
        let p = Path::new("sparse.dat");
        {
            let mut f = fs_.open(p, OpenMode::Write).unwrap();
            // write stripe 5 only: stripes 0–4 are holes, some on
            // members whose parts stay shorter than the logical length
            f.pwrite_all(&[7u8; 1024], 5 * STRIPE).unwrap();
            assert_eq!(f.len().unwrap(), 6 * STRIPE);
        }
        let data = fs_.read(p).unwrap();
        assert_eq!(data.len(), (6 * STRIPE) as usize);
        assert!(data[..(5 * STRIPE) as usize].iter().all(|&b| b == 0), "holes read as zeros");
        assert!(data[(5 * STRIPE) as usize..].iter().all(|&b| b == 7));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn stripe_mode_rename_moves_all_parts_and_clears_stale_destination() {
        const STRIPE: u64 = 1024;
        let (fs_, root) = stripe_mode(3, STRIPE);
        let a = Path::new("a.dat");
        let b = Path::new("b.dat");
        // destination pre-exists and is *longer* than the source: stale
        // tail parts must not survive the rename
        fs_.write(b, &vec![9u8; (7 * STRIPE) as usize]).unwrap();
        let payload = vec![3u8; (STRIPE + 11) as usize];
        fs_.write(a, &payload).unwrap();
        fs_.rename(a, b).unwrap();
        assert!(!fs_.exists(a));
        assert_eq!(fs_.size(b).unwrap(), payload.len() as u64);
        assert_eq!(fs_.read(b).unwrap(), payload);
        assert!(matches!(
            fs_.rename(Path::new("missing"), b),
            Err(Error::NotFound(_))
        ));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn stripe_mode_advertises_its_unit_through_decorators() {
        let (fs_, root) = stripe_mode(2, 8192);
        assert_eq!(fs_.stripe_bytes(), Some(8192));
        assert_eq!(fs_.shard_count(), Some(2));
        let wrapped = crate::vfs::RateLimitedFs::new(fs_, 1e9, 1e9);
        assert_eq!(wrapped.stripe_bytes(), Some(8192));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn mapped_views_read_stripe_mode_files_byte_exact() {
        // ISSUE 5: the PageCache layer rides the handle API, so a page
        // fault spanning stripe boundaries reassembles member parts
        // transparently — no stripe awareness in the mapping layer
        use crate::vfs::pages::{MapMode, PageCache};
        const STRIPE: u64 = 1024;
        let (fs_, root) = stripe_mode(4, STRIPE);
        let p = Path::new("mapped.dat");
        let payload: Vec<u8> = (0..(6 * STRIPE + STRIPE / 2) as usize)
            .map(|k| (k % 251) as u8)
            .collect();
        fs_.write(p, &payload).unwrap();
        // page size deliberately misaligned with the stripe unit
        let cache = Arc::new(PageCache::new(1536, 4 * 1536));
        let mut f = fs_.open(p, OpenMode::Read).unwrap();
        let mut view = f
            .map(&cache, 0, payload.len() as u64, MapMode::Read)
            .unwrap();
        let mut got = vec![0u8; payload.len()];
        let n = view.read_at(&mut got, 0).unwrap();
        assert_eq!(n, payload.len());
        assert_eq!(got, payload);
        // an unaligned window crossing members
        let mut mid = vec![0u8; 200];
        view.read_at(&mut mid, STRIPE - 100).unwrap();
        assert_eq!(
            &mid[..],
            &payload[(STRIPE - 100) as usize..(STRIPE + 100) as usize]
        );
        assert!(cache.stats().peak_resident_bytes <= cache.budget());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn stripe_handles_share_page_frames() {
        // ISSUE 6: stripe-mode handles carry a mount-scoped identity,
        // so two views of one striped file share page-cache frames
        use crate::vfs::pages::{MapMode, PageCache};
        const STRIPE: u64 = 1024;
        let (fs_, root) = stripe_mode(2, STRIPE);
        let p = Path::new("share.dat");
        let payload = vec![5u8; 4 * 1536];
        fs_.write(p, &payload).unwrap();
        let cache = Arc::new(PageCache::new(1536, 32 * 1536));
        let mut fa = fs_.open(p, OpenMode::Read).unwrap();
        let mut fb = fs_.open(p, OpenMode::Read).unwrap();
        let mut va = fa.map(&cache, 0, payload.len() as u64, MapMode::Read).unwrap();
        let mut vb = fb.map(&cache, 0, payload.len() as u64, MapMode::Read).unwrap();
        let mut buf = vec![0u8; payload.len()];
        va.read_at(&mut buf, 0).unwrap();
        let faults = cache.stats().faults;
        vb.read_at(&mut buf, 0).unwrap();
        let st = cache.stats();
        assert_eq!(st.faults, faults, "second stripe view hit shared frames: {st:?}");
        assert!(st.shared_hits > 0, "{st:?}");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn positioned_handles_work_through_members() {
        let (fs_, root) = striped(2);
        let p = Path::new("h.dat");
        {
            let mut f = fs_.open(p, OpenMode::Write).unwrap();
            f.pwrite_all(b"BBBB", 4).unwrap();
            f.pwrite_all(b"AAAA", 0).unwrap();
        }
        assert_eq!(fs_.read(p).unwrap(), b"AAAABBBB");
        let mut f = fs_.open(p, OpenMode::Read).unwrap();
        let mut buf = [0u8; 4];
        f.pread_exact(&mut buf, 2).unwrap();
        assert_eq!(&buf, b"AABB");
        let _ = std::fs::remove_dir_all(&root);
    }
}
