//! [`StripedFs`] — a [`Vfs`] that shards files across N member backends.
//!
//! Real Lustre deployments stripe across OSTs, each with its own
//! bandwidth and concurrency limits; the paper treats "the PFS" as one
//! opaque pool. `StripedFs` is the stand-in that puts the members back:
//! every file maps to exactly one member by a stable hash of its path
//! (file-granularity striping — one file never spans members, matching
//! `stripe_count=1` Lustre, the common default for many-file workloads).
//!
//! Members are themselves `Vfs` backends, so they can be plain
//! [`crate::vfs::RealFs`] directories, rate-limited decorators (per-OST
//! bandwidth caps), or anything else. The member topology is exposed
//! through [`Vfs::shard_count`] / [`Vfs::shard_of`], which survive
//! wrapping in [`crate::vfs::RateLimitedFs`]; `SeaFs`'s flush pool uses
//! it to cap in-flight flushes per member (OST-aware scheduling).
//!
//! `rename` between members streams the bytes through bounded buffers
//! and then unlinks the source — the only cross-member operation.

use std::path::Path;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::vfs::{OpenMode, Vfs, VfsFile};

/// Copy buffer for cross-member renames.
const COPY_CHUNK: usize = 1 << 20;

/// FNV-1a, hand-rolled: the member mapping is *durable* (it decides
/// where bytes live on disk), so it must not depend on
/// `DefaultHasher`'s algorithm, which is explicitly unstable across
/// Rust releases.
fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// A file-granularity striped backend over N member [`Vfs`] roots.
pub struct StripedFs {
    members: Vec<Arc<dyn Vfs>>,
}

impl StripedFs {
    /// Build from member backends (at least one).
    pub fn new(members: Vec<Arc<dyn Vfs>>) -> Result<StripedFs> {
        if members.is_empty() {
            return Err(Error::Config("striped fs requires at least one member".into()));
        }
        Ok(StripedFs { members })
    }

    /// Convenience: one [`crate::vfs::RealFs`] member per directory.
    pub fn from_dirs<P: Into<std::path::PathBuf>>(dirs: Vec<P>) -> Result<StripedFs> {
        let mut members: Vec<Arc<dyn Vfs>> = Vec::new();
        for d in dirs {
            members.push(Arc::new(crate::vfs::RealFs::new(d)?));
        }
        StripedFs::new(members)
    }

    /// Number of members.
    pub fn member_count(&self) -> usize {
        self.members.len()
    }

    /// Stable member index for `path` (leading slashes are ignored so
    /// `/x/y` and `x/y` land on the same member). FNV-1a keeps the
    /// mapping identical across builds and Rust versions — files placed
    /// by one binary stay findable by the next.
    pub fn member_of(&self, path: &Path) -> usize {
        let key = path.to_string_lossy();
        let key = key.trim_start_matches('/');
        (fnv1a(key) as usize) % self.members.len()
    }

    fn member(&self, path: &Path) -> &Arc<dyn Vfs> {
        &self.members[self.member_of(path)]
    }
}

impl Vfs for StripedFs {
    fn open(&self, path: &Path, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
        self.member(path).open(path, mode)
    }

    fn read(&self, path: &Path) -> Result<Vec<u8>> {
        self.member(path).read(path)
    }

    fn write(&self, path: &Path, data: &[u8]) -> Result<()> {
        self.member(path).write(path, data)
    }

    fn unlink(&self, path: &Path) -> Result<()> {
        self.member(path).unlink(path)
    }

    fn exists(&self, path: &Path) -> bool {
        self.member(path).exists(path)
    }

    fn size(&self, path: &Path) -> Result<u64> {
        self.member(path).size(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        let (mf, mt) = (self.member_of(from), self.member_of(to));
        if mf == mt {
            return self.members[mf].rename(from, to);
        }
        // cross-member: stream-copy, then unlink the source only once
        // the copy is complete
        let copy = (|| -> Result<()> {
            let mut src = self.members[mf].open(from, OpenMode::Read)?;
            let mut dst = self.members[mt].open(to, OpenMode::Write)?;
            let mut buf = vec![0u8; COPY_CHUNK];
            let mut off = 0u64;
            loop {
                let n = src.pread(&mut buf, off)?;
                if n == 0 {
                    return Ok(());
                }
                dst.pwrite_all(&buf[..n], off)?;
                off += n as u64;
            }
        })();
        if let Err(e) = copy {
            // don't leave a truncated destination behind: a later read
            // falling through to it would see silent corruption
            let _ = self.members[mt].unlink(to);
            return Err(e);
        }
        self.members[mf].unlink(from)
    }

    fn readdir(&self, path: &Path) -> Result<Vec<String>> {
        let mut names = Vec::new();
        let mut first_err = None;
        let mut any_ok = false;
        for m in &self.members {
            match m.readdir(path) {
                Ok(mut n) => {
                    any_ok = true;
                    names.append(&mut n);
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if !any_ok {
            return Err(first_err.expect("at least one member"));
        }
        names.sort();
        names.dedup();
        Ok(names)
    }

    fn sync_mgmt(&self) -> Result<()> {
        for m in &self.members {
            m.sync_mgmt()?;
        }
        Ok(())
    }

    fn shard_count(&self) -> Option<usize> {
        Some(self.members.len())
    }

    fn shard_of(&self, path: &Path) -> Option<usize> {
        Some(self.member_of(path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vfs::real::RealFs;
    use crate::vfs::testutil::scratch;
    use std::path::PathBuf;

    fn striped(n: usize) -> (StripedFs, PathBuf) {
        let root = scratch("striped");
        let dirs: Vec<PathBuf> = (0..n).map(|i| root.join(format!("ost{i}"))).collect();
        (StripedFs::from_dirs(dirs).unwrap(), root)
    }

    #[test]
    fn round_trip_and_member_stability() {
        let (fs_, root) = striped(4);
        for i in 0..32 {
            let p = PathBuf::from(format!("d/f{i}.dat"));
            fs_.write(&p, format!("payload-{i}").as_bytes()).unwrap();
            assert!(fs_.exists(&p));
            assert_eq!(fs_.read(&p).unwrap(), format!("payload-{i}").as_bytes());
            assert_eq!(fs_.size(&p).unwrap(), format!("payload-{i}").len() as u64);
            // the mapping is stable and slash-insensitive
            assert_eq!(fs_.member_of(&p), fs_.member_of(&PathBuf::from(format!("/d/f{i}.dat"))));
        }
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn files_spread_across_members() {
        let (fs_, root) = striped(4);
        let mut seen = std::collections::HashSet::new();
        for i in 0..64 {
            seen.insert(fs_.member_of(&PathBuf::from(format!("x/{i}.dat"))));
        }
        assert_eq!(seen.len(), 4, "64 hashed paths should hit all 4 members");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn rename_crosses_members_when_hashes_differ() {
        let (fs_, root) = striped(3);
        // find two names that land on different members
        let from = PathBuf::from("a.dat");
        let mut to = None;
        for i in 0..64 {
            let cand = PathBuf::from(format!("b{i}.dat"));
            if fs_.member_of(&cand) != fs_.member_of(&from) {
                to = Some(cand);
                break;
            }
        }
        let to = to.expect("some name must hash elsewhere");
        let payload = vec![7u8; 3 * COPY_CHUNK / 2]; // force a multi-chunk copy
        fs_.write(&from, &payload).unwrap();
        fs_.rename(&from, &to).unwrap();
        assert!(!fs_.exists(&from));
        assert_eq!(fs_.read(&to).unwrap(), payload);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn readdir_merges_members() {
        let (fs_, root) = striped(4);
        for i in 0..16 {
            fs_.write(&PathBuf::from(format!("dir/f{i:02}")), b"1").unwrap();
        }
        let names = fs_.readdir(Path::new("dir")).unwrap();
        assert_eq!(names.len(), 16);
        assert_eq!(names[0], "f00");
        assert_eq!(names[15], "f15");
        // a directory no member has errors out
        assert!(fs_.readdir(Path::new("missing")).is_err());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn shard_introspection_exposed_and_survives_rate_limit() {
        let (fs_, root) = striped(4);
        assert_eq!(fs_.shard_count(), Some(4));
        let p = Path::new("q.dat");
        let m = fs_.shard_of(p);
        assert!(m.unwrap() < 4);
        let wrapped = crate::vfs::RateLimitedFs::new(fs_, 1e9, 1e9);
        assert_eq!(wrapped.shard_count(), Some(4));
        assert_eq!(wrapped.shard_of(p), m);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn empty_members_rejected() {
        assert!(StripedFs::new(Vec::new()).is_err());
    }

    #[test]
    fn member_hash_is_pinned() {
        // the mapping is durable on-disk state: pin the FNV-1a value so
        // an accidental algorithm change can't strand existing files
        assert_eq!(fnv1a("inputs/block_0001.dat"), 0x9195_4b05_3a28_ce5b);
        let (fs_, root) = striped(4);
        assert_eq!(fs_.member_of(Path::new("inputs/block_0001.dat")), 3);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn positioned_handles_work_through_members() {
        let (fs_, root) = striped(2);
        let p = Path::new("h.dat");
        {
            let mut f = fs_.open(p, OpenMode::Write).unwrap();
            f.pwrite_all(b"BBBB", 4).unwrap();
            f.pwrite_all(b"AAAA", 0).unwrap();
        }
        assert_eq!(fs_.read(p).unwrap(), b"AAAABBBB");
        let mut f = fs_.open(p, OpenMode::Read).unwrap();
        let mut buf = [0u8; 4];
        f.pread_exact(&mut buf, 2).unwrap();
        assert_eq!(&buf, b"AABB");
        let _ = std::fs::remove_dir_all(&root);
    }
}
