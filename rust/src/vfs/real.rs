//! `std::fs`-backed [`Vfs`] rooted at a directory.

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::vfs::Vfs;

/// A real directory tree; all paths are interpreted relative to `root`
/// (absolute inputs are re-rooted by stripping the leading `/`).
#[derive(Debug, Clone)]
pub struct RealFs {
    root: PathBuf,
}

impl RealFs {
    /// Create (and mkdir) a RealFs rooted at `root`.
    pub fn new(root: impl Into<PathBuf>) -> Result<RealFs> {
        let root = root.into();
        fs::create_dir_all(&root).map_err(|e| Error::io(&root, e))?;
        Ok(RealFs { root })
    }

    /// The root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    fn resolve(&self, path: &Path) -> PathBuf {
        let rel = path.strip_prefix("/").unwrap_or(path);
        self.root.join(rel)
    }
}

impl Vfs for RealFs {
    fn read(&self, path: &Path) -> Result<Vec<u8>> {
        let p = self.resolve(path);
        fs::read(&p).map_err(|e| match e.kind() {
            std::io::ErrorKind::NotFound => Error::NotFound(path.to_path_buf()),
            _ => Error::io(&p, e),
        })
    }

    fn write(&self, path: &Path, data: &[u8]) -> Result<()> {
        let p = self.resolve(path);
        if let Some(dir) = p.parent() {
            fs::create_dir_all(dir).map_err(|e| Error::io(dir, e))?;
        }
        fs::write(&p, data).map_err(|e| Error::io(&p, e))
    }

    fn unlink(&self, path: &Path) -> Result<()> {
        let p = self.resolve(path);
        fs::remove_file(&p).map_err(|e| match e.kind() {
            std::io::ErrorKind::NotFound => Error::NotFound(path.to_path_buf()),
            _ => Error::io(&p, e),
        })
    }

    fn exists(&self, path: &Path) -> bool {
        self.resolve(path).exists()
    }

    fn size(&self, path: &Path) -> Result<u64> {
        let p = self.resolve(path);
        fs::metadata(&p)
            .map(|m| m.len())
            .map_err(|e| match e.kind() {
                std::io::ErrorKind::NotFound => Error::NotFound(path.to_path_buf()),
                _ => Error::io(&p, e),
            })
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        let (f, t) = (self.resolve(from), self.resolve(to));
        if let Some(dir) = t.parent() {
            fs::create_dir_all(dir).map_err(|e| Error::io(dir, e))?;
        }
        fs::rename(&f, &t).map_err(|e| Error::io(&f, e))
    }

    fn readdir(&self, path: &Path) -> Result<Vec<String>> {
        let p = self.resolve(path);
        let mut names = Vec::new();
        for entry in fs::read_dir(&p).map_err(|e| Error::io(&p, e))? {
            let entry = entry.map_err(|e| Error::io(&p, e))?;
            names.push(entry.file_name().to_string_lossy().into_owned());
        }
        names.sort();
        Ok(names)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vfs::testutil::scratch;

    #[test]
    fn crud_round_trip() {
        let dir = scratch("realfs");
        let fs_ = RealFs::new(&dir).unwrap();
        let p = Path::new("a/b/file.dat");
        assert!(!fs_.exists(p));
        fs_.write(p, b"hello").unwrap();
        assert!(fs_.exists(p));
        assert_eq!(fs_.size(p).unwrap(), 5);
        assert_eq!(fs_.read(p).unwrap(), b"hello");
        fs_.rename(p, Path::new("a/c.dat")).unwrap();
        assert!(!fs_.exists(p));
        assert_eq!(fs_.read(Path::new("a/c.dat")).unwrap(), b"hello");
        fs_.unlink(Path::new("a/c.dat")).unwrap();
        assert!(!fs_.exists(Path::new("a/c.dat")));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_files_are_notfound() {
        let dir = scratch("realfs_nf");
        let fs_ = RealFs::new(&dir).unwrap();
        assert!(matches!(
            fs_.read(Path::new("nope")),
            Err(Error::NotFound(_))
        ));
        assert!(matches!(
            fs_.unlink(Path::new("nope")),
            Err(Error::NotFound(_))
        ));
        assert!(matches!(
            fs_.size(Path::new("nope")),
            Err(Error::NotFound(_))
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn absolute_paths_are_rerooted() {
        let dir = scratch("realfs_abs");
        let fs_ = RealFs::new(&dir).unwrap();
        fs_.write(Path::new("/x.dat"), b"abs").unwrap();
        assert_eq!(fs_.read(Path::new("x.dat")).unwrap(), b"abs");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn readdir_lists_sorted() {
        let dir = scratch("realfs_ls");
        let fs_ = RealFs::new(&dir).unwrap();
        fs_.write(Path::new("d/b"), b"1").unwrap();
        fs_.write(Path::new("d/a"), b"2").unwrap();
        assert_eq!(fs_.readdir(Path::new("d")).unwrap(), vec!["a", "b"]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
