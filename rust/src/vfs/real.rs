//! `std::fs`-backed [`Vfs`] rooted at a directory, with positioned I/O
//! handles over `std::os::unix::fs::FileExt`.

use std::fs;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::vfs::{OpenMode, Vfs, VfsFile};

/// A real directory tree; all paths are interpreted relative to `root`
/// (absolute inputs are re-rooted by stripping the leading `/`).
#[derive(Debug, Clone)]
pub struct RealFs {
    root: PathBuf,
}

impl RealFs {
    /// Create (and mkdir) a RealFs rooted at `root`.
    pub fn new(root: impl Into<PathBuf>) -> Result<RealFs> {
        let root = root.into();
        fs::create_dir_all(&root).map_err(|e| Error::io(&root, e))?;
        Ok(RealFs { root })
    }

    /// The root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    fn resolve(&self, path: &Path) -> PathBuf {
        let rel = path.strip_prefix("/").unwrap_or(path);
        self.root.join(rel)
    }
}

/// Handle over a real file: offset-addressed reads and writes never
/// touch a shared cursor, so concurrent handles are race-free.
pub struct RealFile {
    path: PathBuf,
    file: fs::File,
    /// `O_APPEND` handle: writes go through the kernel's atomic
    /// end-of-file placement instead of `write_at`.
    append: bool,
    /// Opened [`OpenMode::Read`]: the underlying fd is `O_RDONLY`, so
    /// it is safe to lease to a remote client as-is.
    read_only: bool,
}

impl RealFile {
    /// Open `path` (already fully resolved — no root translation) in
    /// `mode`. Used by [`RealFs`] for files under its root.
    pub(crate) fn open_at(path: PathBuf, mode: OpenMode) -> Result<RealFile> {
        if mode.writable() {
            if let Some(dir) = path.parent() {
                fs::create_dir_all(dir).map_err(|e| Error::io(dir, e))?;
            }
        }
        let mut opts = fs::OpenOptions::new();
        opts.read(true);
        match mode {
            OpenMode::Read => {}
            OpenMode::Write => {
                opts.write(true).create(true).truncate(true);
            }
            OpenMode::ReadWrite => {
                opts.write(true).create(true);
            }
            OpenMode::Append => {
                opts.append(true).create(true);
            }
        }
        let file = opts.open(&path).map_err(|e| match e.kind() {
            std::io::ErrorKind::NotFound => Error::NotFound(path.clone()),
            _ => Error::io(&path, e),
        })?;
        Ok(RealFile {
            path,
            file,
            append: mode.appends(),
            read_only: !mode.writable(),
        })
    }
}

impl VfsFile for RealFile {
    fn pread(&mut self, buf: &mut [u8], off: u64) -> Result<usize> {
        self.file.read_at(buf, off).map_err(|e| Error::io(&self.path, e))
    }

    fn pwrite(&mut self, data: &[u8], off: u64) -> Result<usize> {
        if self.append {
            // the kernel serialises concurrent appends: each write_all
            // lands contiguously at the file's current end
            use std::io::Write;
            (&self.file)
                .write_all(data)
                .map_err(|e| Error::io(&self.path, e))?;
            return Ok(data.len());
        }
        self.file
            .write_all_at(data, off)
            .map_err(|e| Error::io(&self.path, e))?;
        Ok(data.len())
    }

    fn set_len(&mut self, len: u64) -> Result<()> {
        self.file.set_len(len).map_err(|e| Error::io(&self.path, e))
    }

    fn fsync(&mut self) -> Result<()> {
        self.file.sync_all().map_err(|e| Error::io(&self.path, e))
    }

    fn len(&self) -> Result<u64> {
        self.file
            .metadata()
            .map(|m| m.len())
            .map_err(|e| Error::io(&self.path, e))
    }

    fn lease_fd(&self) -> Option<std::fs::File> {
        // A read-only RealFile *is* one O_RDONLY fd whose pread is a
        // raw pread(2): dup it (try_clone) and let the daemon lease the
        // dup. Unlink/rename/spill leave the inode intact under the
        // dup, so a revoked-but-in-flight read stays a consistent
        // snapshot.
        if self.read_only {
            self.file.try_clone().ok()
        } else {
            None
        }
    }

    fn map_identity(&self) -> Option<u128> {
        // device + inode name the file across every handle (and across
        // renames), exactly like the kernel page cache keys mappings
        use std::os::unix::fs::MetadataExt;
        let md = self.file.metadata().ok()?;
        Some(crate::vfs::pages::identity_hash(&[
            &md.dev().to_le_bytes(),
            &md.ino().to_le_bytes(),
        ]))
    }
}

impl Vfs for RealFs {
    fn open(&self, path: &Path, mode: OpenMode) -> Result<Box<dyn VfsFile>> {
        let p = self.resolve(path);
        let f = RealFile::open_at(p, mode).map_err(|e| match e {
            // report the caller's (un-resolved) path for not-found
            Error::NotFound(_) => Error::NotFound(path.to_path_buf()),
            other => other,
        })?;
        Ok(Box::new(f))
    }

    // whole-file fast paths: skip the handle round trip
    fn read(&self, path: &Path) -> Result<Vec<u8>> {
        let p = self.resolve(path);
        fs::read(&p).map_err(|e| match e.kind() {
            std::io::ErrorKind::NotFound => Error::NotFound(path.to_path_buf()),
            _ => Error::io(&p, e),
        })
    }

    fn write(&self, path: &Path, data: &[u8]) -> Result<()> {
        let p = self.resolve(path);
        if let Some(dir) = p.parent() {
            fs::create_dir_all(dir).map_err(|e| Error::io(dir, e))?;
        }
        fs::write(&p, data).map_err(|e| Error::io(&p, e))
    }

    fn unlink(&self, path: &Path) -> Result<()> {
        let p = self.resolve(path);
        fs::remove_file(&p).map_err(|e| match e.kind() {
            std::io::ErrorKind::NotFound => Error::NotFound(path.to_path_buf()),
            _ => Error::io(&p, e),
        })
    }

    fn exists(&self, path: &Path) -> bool {
        self.resolve(path).exists()
    }

    fn size(&self, path: &Path) -> Result<u64> {
        let p = self.resolve(path);
        fs::metadata(&p)
            .map(|m| m.len())
            .map_err(|e| match e.kind() {
                std::io::ErrorKind::NotFound => Error::NotFound(path.to_path_buf()),
                _ => Error::io(&p, e),
            })
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        let (f, t) = (self.resolve(from), self.resolve(to));
        if let Some(dir) = t.parent() {
            fs::create_dir_all(dir).map_err(|e| Error::io(dir, e))?;
        }
        fs::rename(&f, &t).map_err(|e| Error::io(&f, e))
    }

    fn readdir(&self, path: &Path) -> Result<Vec<String>> {
        let p = self.resolve(path);
        let mut names = Vec::new();
        for entry in fs::read_dir(&p).map_err(|e| Error::io(&p, e))? {
            let entry = entry.map_err(|e| Error::io(&p, e))?;
            names.push(entry.file_name().to_string_lossy().into_owned());
        }
        names.sort();
        Ok(names)
    }

    fn mkdir(&self, path: &Path) -> Result<()> {
        let p = self.resolve(path);
        fs::create_dir_all(&p).map_err(|e| Error::io(&p, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vfs::testutil::scratch;

    #[test]
    fn crud_round_trip() {
        let dir = scratch("realfs");
        let fs_ = RealFs::new(&dir).unwrap();
        let p = Path::new("a/b/file.dat");
        assert!(!fs_.exists(p));
        fs_.write(p, b"hello").unwrap();
        assert!(fs_.exists(p));
        assert_eq!(fs_.size(p).unwrap(), 5);
        assert_eq!(fs_.read(p).unwrap(), b"hello");
        fs_.rename(p, Path::new("a/c.dat")).unwrap();
        assert!(!fs_.exists(p));
        assert_eq!(fs_.read(Path::new("a/c.dat")).unwrap(), b"hello");
        fs_.unlink(Path::new("a/c.dat")).unwrap();
        assert!(!fs_.exists(Path::new("a/c.dat")));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_files_are_notfound() {
        let dir = scratch("realfs_nf");
        let fs_ = RealFs::new(&dir).unwrap();
        assert!(matches!(
            fs_.read(Path::new("nope")),
            Err(Error::NotFound(_))
        ));
        assert!(matches!(
            fs_.open(Path::new("nope"), OpenMode::Read),
            Err(Error::NotFound(_))
        ));
        assert!(matches!(
            fs_.unlink(Path::new("nope")),
            Err(Error::NotFound(_))
        ));
        assert!(matches!(
            fs_.size(Path::new("nope")),
            Err(Error::NotFound(_))
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn absolute_paths_are_rerooted() {
        let dir = scratch("realfs_abs");
        let fs_ = RealFs::new(&dir).unwrap();
        fs_.write(Path::new("/x.dat"), b"abs").unwrap();
        assert_eq!(fs_.read(Path::new("x.dat")).unwrap(), b"abs");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn readdir_lists_sorted() {
        let dir = scratch("realfs_ls");
        let fs_ = RealFs::new(&dir).unwrap();
        fs_.write(Path::new("d/b"), b"1").unwrap();
        fs_.write(Path::new("d/a"), b"2").unwrap();
        assert_eq!(fs_.readdir(Path::new("d")).unwrap(), vec!["a", "b"]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pread_pwrite_at_offsets() {
        let dir = scratch("realfs_prw");
        let fs_ = RealFs::new(&dir).unwrap();
        let p = Path::new("h/strided.dat");
        {
            let mut f = fs_.open(p, OpenMode::Write).unwrap();
            // write strides out of order: [8..12) then [0..4) then [4..8)
            f.pwrite_all(b"CCCC", 8).unwrap();
            f.pwrite_all(b"AAAA", 0).unwrap();
            f.pwrite_all(b"BBBB", 4).unwrap();
            assert_eq!(f.len().unwrap(), 12);
        }
        assert_eq!(fs_.read(p).unwrap(), b"AAAABBBBCCCC");
        let mut f = fs_.open(p, OpenMode::Read).unwrap();
        let mut mid = [0u8; 4];
        f.pread_exact(&mut mid, 4).unwrap();
        assert_eq!(&mid, b"BBBB");
        // pread past EOF returns 0
        let mut tail = [0u8; 4];
        assert_eq!(f.pread(&mut tail, 100).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn readwrite_preserves_write_truncates() {
        let dir = scratch("realfs_rw");
        let fs_ = RealFs::new(&dir).unwrap();
        let p = Path::new("f.dat");
        fs_.write(p, b"0123456789").unwrap();
        {
            let mut f = fs_.open(p, OpenMode::ReadWrite).unwrap();
            assert_eq!(f.len().unwrap(), 10, "ReadWrite keeps contents");
            f.pwrite_all(b"XY", 2).unwrap();
            f.set_len(6).unwrap();
            f.fsync().unwrap();
        }
        assert_eq!(fs_.read(p).unwrap(), b"01XY45");
        let mut f = fs_.open(p, OpenMode::Write).unwrap();
        assert_eq!(f.len().unwrap(), 0, "Write truncates");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn append_mode_ignores_offsets_and_lands_at_eof() {
        let dir = scratch("realfs_append");
        let fs_ = RealFs::new(&dir).unwrap();
        let p = Path::new("log.txt");
        fs_.write(p, b"head;").unwrap();
        {
            let mut a = fs_.open(p, OpenMode::Append).unwrap();
            let mut b = fs_.open(p, OpenMode::Append).unwrap();
            // offsets are ignored: everything appends
            a.pwrite_all(b"a1;", 0).unwrap();
            b.pwrite_all(b"b1;", 0).unwrap();
            a.pwrite_all(b"a2;", 999).unwrap();
            // append handles still read at explicit offsets
            let mut head = [0u8; 5];
            a.pread_exact(&mut head, 0).unwrap();
            assert_eq!(&head, b"head;");
        }
        assert_eq!(fs_.read(p).unwrap(), b"head;a1;b1;a2;");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concrete_handles_map_through_the_trait_default() {
        // ISSUE 5: `VfsFile::map` has a `Self: Sized` default, so a
        // concrete RealFile maps without going through `dyn VfsFile`
        use crate::vfs::pages::{MapMode, PageCache};
        use std::sync::Arc;
        let dir = scratch("realfs_map");
        let fs_ = RealFs::new(&dir).unwrap();
        fs_.write(Path::new("m.dat"), b"mapped-bytes").unwrap();
        let cache = Arc::new(PageCache::new(4096, 16 * 4096));
        let mut f = RealFile::open_at(dir.join("m.dat"), OpenMode::ReadWrite).unwrap();
        {
            let mut view = VfsFile::map(&mut f, &cache, 0, 12, MapMode::Write).unwrap();
            let mut buf = [0u8; 6];
            view.read_at(&mut buf, 0).unwrap();
            assert_eq!(&buf, b"mapped");
            view.write_at(b"MAPPED", 0).unwrap();
        }
        assert_eq!(fs_.read(Path::new("m.dat")).unwrap(), b"MAPPED-bytes");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn only_read_handles_surface_a_lease_fd() {
        let dir = scratch("realfs_lease");
        let fs_ = RealFs::new(&dir).unwrap();
        let p = Path::new("leased.dat");
        fs_.write(p, b"snapshot-bytes").unwrap();
        let reader = fs_.open(p, OpenMode::Read).unwrap();
        let leased = reader.lease_fd().expect("read handle leases its fd");
        // the lease survives unlink: the inode outlives the name
        fs_.unlink(p).unwrap();
        use std::os::unix::fs::FileExt as _;
        let mut buf = [0u8; 14];
        leased.read_at(&mut buf, 0).unwrap();
        assert_eq!(&buf, b"snapshot-bytes");
        // writable handles never lease
        fs_.write(p, b"x").unwrap();
        let writer = fs_.open(p, OpenMode::ReadWrite).unwrap();
        assert!(writer.lease_fd().is_none(), "writable fds must not leak");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mkdir_creates_real_directories() {
        let dir = scratch("realfs_mkdir");
        let fs_ = RealFs::new(&dir).unwrap();
        fs_.mkdir(Path::new("/out/run7/logs")).unwrap();
        assert!(dir.join("out/run7/logs").is_dir());
        // create_dir_all semantics: repeating is fine
        fs_.mkdir(Path::new("/out/run7/logs")).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn whole_file_defaults_match_fast_paths() {
        let dir = scratch("realfs_dflt");
        let fs_ = RealFs::new(&dir).unwrap();
        let p = Path::new("d.dat");
        // trait-default write via a handle, fast-path read back
        {
            let mut f = fs_.open(p, OpenMode::Write).unwrap();
            f.pwrite_all(b"same-bytes", 0).unwrap();
        }
        assert_eq!(fs_.read(p).unwrap(), b"same-bytes");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
