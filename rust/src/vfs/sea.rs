//! [`SeaFs`] — the paper's library, real-bytes flavour.
//!
//! A Sea mount wraps a *long-term* backend (the "PFS": any [`Vfs`],
//! typically rate-limited to emulate a loaded Lustre) plus an ordered set
//! of fast device directories (tmpfs `/dev/shm`, local disk dirs).
//! Every path under the logical mountpoint is translated to the fastest
//! eligible device (the same `hierarchy` selection the simulator uses);
//! paths outside the mountpoint pass through to the PFS untouched —
//! exactly the interception semantics of the paper's glibc wrappers.
//!
//! A single background flush-and-evict daemon per mount (paper §5.1)
//! applies the Table 1 modes after each write, asynchronously:
//! Copy → replicate to PFS; Move → replicate then drop local;
//! Remove → drop local without persisting.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::hierarchy::{select_device, DeviceRef, Hierarchy, SelectCfg, SpaceAccountant};
use crate::placement::rules::{MgmtMode, RuleSet};
use crate::util::Rng;
use crate::vfs::Vfs;

/// Configuration of a real Sea mount.
pub struct SeaFsConfig {
    /// Logical mountpoint prefix (e.g. `/sea`).
    pub mountpoint: PathBuf,
    /// Fast device directories: (directory, tier rank, capacity bytes).
    pub devices: Vec<(PathBuf, u8, u64)>,
    /// Long-term storage backend.
    pub pfs: Arc<dyn Vfs>,
    /// Max file size `F` declared by the user.
    pub max_file_size: u64,
    /// Parallel process count `p` declared by the user.
    pub parallel_procs: u64,
    /// Rule lists.
    pub rules: RuleSet,
    /// PRNG seed for same-tier shuffling.
    pub seed: u64,
}

#[derive(Debug, Clone)]
struct Entry {
    dev: DeviceRef,
    size: u64,
    flushed: bool,
}

enum DaemonMsg {
    Act { mode: MgmtMode, rel: String },
    Drain(mpsc::Sender<()>),
    Shutdown,
}

struct Shared {
    hierarchy: Hierarchy,
    accountant: SpaceAccountant,
    device_dirs: Vec<PathBuf>,
    registry: Mutex<HashMap<String, Entry>>,
    pfs: Arc<dyn Vfs>,
    /// Mgmt statistics: (flushes, evictions).
    counters: Mutex<(u64, u64)>,
}

impl Shared {
    fn local_path(&self, dev: DeviceRef, rel: &str) -> PathBuf {
        self.device_dirs[dev].join(rel)
    }
}

/// The real-bytes Sea mount.
pub struct SeaFs {
    mountpoint: PathBuf,
    shared: Arc<Shared>,
    select: SelectCfg,
    rules: RuleSet,
    rng: Mutex<Rng>,
    daemon_tx: Mutex<mpsc::Sender<DaemonMsg>>,
    daemon: Mutex<Option<JoinHandle<()>>>,
}

impl SeaFs {
    /// Mount: builds the hierarchy, spawns the flush-and-evict daemon.
    pub fn mount(cfg: SeaFsConfig) -> Result<SeaFs> {
        if cfg.devices.is_empty() {
            return Err(Error::Config(
                "sea requires at least one fast device (plus the PFS)".into(),
            ));
        }
        let mut hierarchy = Hierarchy::new();
        let mut device_dirs = Vec::new();
        for (dir, tier, cap) in &cfg.devices {
            fs::create_dir_all(dir).map_err(|e| Error::io(dir, e))?;
            hierarchy.add(*tier, *cap, dir.to_string_lossy().into_owned());
            device_dirs.push(dir.clone());
        }
        let accountant = SpaceAccountant::new(&hierarchy);
        let shared = Arc::new(Shared {
            hierarchy,
            accountant,
            device_dirs,
            registry: Mutex::new(HashMap::new()),
            pfs: cfg.pfs,
            counters: Mutex::new((0, 0)),
        });
        let (tx, rx) = mpsc::channel::<DaemonMsg>();
        let dshared = shared.clone();
        let daemon = std::thread::Builder::new()
            .name("sea-flush-evict".into())
            .spawn(move || daemon_loop(dshared, rx))
            .map_err(|e| Error::io("<thread>", e))?;
        Ok(SeaFs {
            mountpoint: cfg.mountpoint,
            shared,
            select: SelectCfg {
                max_file_size: cfg.max_file_size,
                parallel_procs: cfg.parallel_procs,
            },
            rules: cfg.rules,
            rng: Mutex::new(Rng::new(cfg.seed)),
            daemon_tx: Mutex::new(tx),
            daemon: Mutex::new(Some(daemon)),
        })
    }

    /// Mount-relative form of `path`, or `None` when outside the mount.
    pub fn rel_of(&self, path: &Path) -> Option<String> {
        path.strip_prefix(&self.mountpoint)
            .ok()
            .map(|r| r.to_string_lossy().into_owned())
    }

    /// Where a mount-relative file currently lives (diagnostics).
    pub fn device_of(&self, rel: &str) -> Option<String> {
        let reg = self.shared.registry.lock().expect("registry poisoned");
        reg.get(rel)
            .map(|e| self.shared.hierarchy.info(e.dev).name.clone())
    }

    /// (flushes, evictions) executed by the daemon so far.
    pub fn mgmt_counters(&self) -> (u64, u64) {
        *self.shared.counters.lock().expect("counters poisoned")
    }

    /// Prefetch: copy every PFS file under `dir` (mount-relative)
    /// matching the `.sea_prefetchlist` into fast devices.
    pub fn prefetch_dir(&self, dir: &str) -> Result<usize> {
        let names = self.shared.pfs.readdir(Path::new(dir))?;
        let mut n = 0;
        for name in names {
            let rel = if dir.is_empty() { name.clone() } else { format!("{dir}/{name}") };
            if !self.rules.prefetch.matches(&rel) {
                continue;
            }
            let data = self.shared.pfs.read(Path::new(&rel))?;
            if self.place_and_write(&rel, &data, true)?.is_some() {
                n += 1;
            }
        }
        Ok(n)
    }

    /// Core placement: write `data` to the fastest eligible device.
    /// Returns the chosen device, or `None` when it fell through to the
    /// PFS. `already_flushed` marks prefetched inputs (they came *from*
    /// the PFS, so eviction is always safe).
    fn place_and_write(
        &self,
        rel: &str,
        data: &[u8],
        already_flushed: bool,
    ) -> Result<Option<DeviceRef>> {
        let sh = &self.shared;
        // overwrite: free the previous local copy first
        self.drop_local(rel)?;
        let mut rng = self.rng.lock().expect("rng poisoned");
        let pick = select_device(
            &sh.hierarchy,
            &sh.accountant,
            &self.select,
            data.len() as u64,
            &mut rng,
        );
        drop(rng);
        match pick {
            Some(dev) => {
                let p = sh.local_path(dev, rel);
                if let Some(d) = p.parent() {
                    fs::create_dir_all(d).map_err(|e| Error::io(d, e))?;
                }
                fs::write(&p, data).map_err(|e| Error::io(&p, e))?;
                sh.registry.lock().expect("registry poisoned").insert(
                    rel.to_string(),
                    Entry { dev, size: data.len() as u64, flushed: already_flushed },
                );
                Ok(Some(dev))
            }
            None => {
                sh.pfs.write(Path::new(rel), data)?;
                Ok(None)
            }
        }
    }

    /// Remove the local copy of `rel` if any, crediting its space.
    fn drop_local(&self, rel: &str) -> Result<()> {
        let sh = &self.shared;
        let old = sh.registry.lock().expect("registry poisoned").remove(rel);
        if let Some(e) = old {
            let p = sh.local_path(e.dev, rel);
            match fs::remove_file(&p) {
                Ok(()) => {}
                Err(err) if err.kind() == std::io::ErrorKind::NotFound => {}
                Err(err) => return Err(Error::io(&p, err)),
            }
            sh.accountant.credit(e.dev, e.size);
        }
        Ok(())
    }
}

fn daemon_loop(sh: Arc<Shared>, rx: mpsc::Receiver<DaemonMsg>) {
    // One sequential daemon per mount, as in the paper (§5.1): it is the
    // only flusher, so app threads never pay the PFS write cost in-line.
    while let Ok(msg) = rx.recv() {
        match msg {
            DaemonMsg::Shutdown => break,
            DaemonMsg::Drain(ack) => {
                let _ = ack.send(());
            }
            DaemonMsg::Act { mode, rel } => {
                let entry = {
                    let reg = sh.registry.lock().expect("registry poisoned");
                    reg.get(&rel).cloned()
                };
                let Some(entry) = entry else { continue };
                let local = sh.local_path(entry.dev, &rel);
                let flush = matches!(mode, MgmtMode::Copy | MgmtMode::Move);
                let evict = matches!(mode, MgmtMode::Remove | MgmtMode::Move);
                if flush && !entry.flushed {
                    if let Ok(data) = fs::read(&local) {
                        if sh.pfs.write(Path::new(&rel), &data).is_ok() {
                            let mut reg = sh.registry.lock().expect("registry poisoned");
                            if let Some(e) = reg.get_mut(&rel) {
                                e.flushed = true;
                            }
                            sh.counters.lock().expect("counters").0 += 1;
                        }
                    }
                }
                if evict {
                    // Remove-mode files are dropped unconditionally (the
                    // user declared them disposable); Move-mode files
                    // must have been flushed first.
                    let safe = match mode {
                        MgmtMode::Remove => true,
                        _ => sh
                            .registry
                            .lock()
                            .expect("registry poisoned")
                            .get(&rel)
                            .map(|e| e.flushed)
                            .unwrap_or(false),
                    };
                    if safe {
                        let removed = sh.registry.lock().expect("registry poisoned").remove(&rel);
                        if let Some(e) = removed {
                            let _ = fs::remove_file(sh.local_path(e.dev, &rel));
                            sh.accountant.credit(e.dev, e.size);
                            sh.counters.lock().expect("counters").1 += 1;
                        }
                    }
                }
            }
        }
    }
}

impl Drop for SeaFs {
    fn drop(&mut self) {
        let _ = self
            .daemon_tx
            .lock()
            .expect("tx poisoned")
            .send(DaemonMsg::Shutdown);
        if let Some(h) = self.daemon.lock().expect("daemon poisoned").take() {
            let _ = h.join();
        }
    }
}

impl Vfs for SeaFs {
    fn read(&self, path: &Path) -> Result<Vec<u8>> {
        match self.rel_of(path) {
            None => self.shared.pfs.read(path),
            Some(rel) => {
                let entry = {
                    let reg = self.shared.registry.lock().expect("registry poisoned");
                    reg.get(&rel).cloned()
                };
                match entry {
                    Some(e) => {
                        let p = self.shared.local_path(e.dev, &rel);
                        fs::read(&p).map_err(|err| Error::io(&p, err))
                    }
                    None => self.shared.pfs.read(Path::new(&rel)),
                }
            }
        }
    }

    fn write(&self, path: &Path, data: &[u8]) -> Result<()> {
        match self.rel_of(path) {
            None => self.shared.pfs.write(path, data),
            Some(rel) => {
                self.place_and_write(&rel, data, false)?;
                let mode = self.rules.mode_for(&rel);
                if mode != MgmtMode::Keep {
                    let _ = self
                        .daemon_tx
                        .lock()
                        .expect("tx poisoned")
                        .send(DaemonMsg::Act { mode, rel });
                }
                Ok(())
            }
        }
    }

    fn unlink(&self, path: &Path) -> Result<()> {
        match self.rel_of(path) {
            None => self.shared.pfs.unlink(path),
            Some(rel) => {
                let had_local = {
                    let reg = self.shared.registry.lock().expect("registry poisoned");
                    reg.contains_key(&rel)
                };
                self.drop_local(&rel)?;
                // also remove a flushed/PFS copy if present
                let on_pfs = self.shared.pfs.exists(Path::new(&rel));
                if on_pfs {
                    self.shared.pfs.unlink(Path::new(&rel))?;
                }
                if had_local || on_pfs {
                    Ok(())
                } else {
                    Err(Error::NotFound(path.to_path_buf()))
                }
            }
        }
    }

    fn exists(&self, path: &Path) -> bool {
        match self.rel_of(path) {
            None => self.shared.pfs.exists(path),
            Some(rel) => {
                self.shared
                    .registry
                    .lock()
                    .expect("registry poisoned")
                    .contains_key(&rel)
                    || self.shared.pfs.exists(Path::new(&rel))
            }
        }
    }

    fn size(&self, path: &Path) -> Result<u64> {
        match self.rel_of(path) {
            None => self.shared.pfs.size(path),
            Some(rel) => {
                let entry = {
                    let reg = self.shared.registry.lock().expect("registry poisoned");
                    reg.get(&rel).cloned()
                };
                match entry {
                    Some(e) => Ok(e.size),
                    None => self.shared.pfs.size(Path::new(&rel)),
                }
            }
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> Result<()> {
        match (self.rel_of(from), self.rel_of(to)) {
            (Some(rf), Some(rt)) => {
                let moved = {
                    let mut reg = self.shared.registry.lock().expect("registry poisoned");
                    reg.remove(&rf).map(|e| {
                        let had = (e.dev, e.size, e.flushed);
                        reg.insert(rt.clone(), e);
                        had
                    })
                };
                match moved {
                    Some((dev, _, _)) => {
                        let pf = self.shared.local_path(dev, &rf);
                        let pt = self.shared.local_path(dev, &rt);
                        if let Some(d) = pt.parent() {
                            fs::create_dir_all(d).map_err(|e| Error::io(d, e))?;
                        }
                        fs::rename(&pf, &pt).map_err(|e| Error::io(&pf, e))
                    }
                    None => self.shared.pfs.rename(Path::new(&rf), Path::new(&rt)),
                }
            }
            (None, None) => self.shared.pfs.rename(from, to),
            _ => Err(Error::InvalidArg(
                "rename across the sea mount boundary is not supported".into(),
            )),
        }
    }

    fn readdir(&self, path: &Path) -> Result<Vec<String>> {
        match self.rel_of(path) {
            None => self.shared.pfs.readdir(path),
            Some(rel) => {
                let mut names: Vec<String> = self
                    .shared
                    .pfs
                    .readdir(Path::new(&rel))
                    .unwrap_or_default();
                let prefix = if rel.is_empty() { String::new() } else { format!("{rel}/") };
                let reg = self.shared.registry.lock().expect("registry poisoned");
                for key in reg.keys() {
                    if let Some(rest) = key.strip_prefix(&prefix) {
                        if !rest.is_empty() && !rest.contains('/') {
                            names.push(rest.to_string());
                        }
                    }
                }
                names.sort();
                names.dedup();
                Ok(names)
            }
        }
    }

    fn sync_mgmt(&self) -> Result<()> {
        let (ack_tx, ack_rx) = mpsc::channel();
        self.daemon_tx
            .lock()
            .expect("tx poisoned")
            .send(DaemonMsg::Drain(ack_tx))
            .map_err(|_| Error::Runtime("flush daemon gone".into()))?;
        ack_rx
            .recv()
            .map_err(|_| Error::Runtime("flush daemon gone".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::MIB;
    use crate::vfs::real::RealFs;
    use crate::vfs::testutil::scratch;

    fn mount(rules: RuleSet, tmpfs_cap: u64) -> (SeaFs, PathBuf, Arc<RealFs>) {
        let root = scratch("seafs");
        let pfs = Arc::new(RealFs::new(root.join("pfs")).unwrap());
        let sea = SeaFs::mount(SeaFsConfig {
            mountpoint: PathBuf::from("/sea"),
            devices: vec![
                (root.join("tmpfs"), 0, tmpfs_cap),
                (root.join("disk0"), 1, 100 * MIB),
                (root.join("disk1"), 1, 100 * MIB),
            ],
            pfs: pfs.clone(),
            max_file_size: MIB,
            parallel_procs: 2,
            rules,
            seed: 7,
        })
        .unwrap();
        (sea, root, pfs)
    }

    #[test]
    fn writes_go_to_fastest_device_and_read_back() {
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let p = Path::new("/sea/derived/a.dat");
        sea.write(p, &vec![7u8; MIB as usize]).unwrap();
        assert!(sea.exists(p));
        assert_eq!(sea.size(p).unwrap(), MIB);
        assert_eq!(sea.device_of("derived/a.dat").unwrap(), root.join("tmpfs").to_string_lossy());
        let data = sea.read(p).unwrap();
        assert!(data.iter().all(|&b| b == 7));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn overflow_spills_to_next_tier_then_pfs() {
        let (sea, root, pfs) = mount(RuleSet::default(), 4 * MIB);
        // floor = p*F = 2 MiB; tmpfs 4 MiB holds 2-3 files of 1 MiB
        let mut devices = Vec::new();
        for i in 0..250 {
            let p = PathBuf::from(format!("/sea/d/f{i:03}.dat"));
            sea.write(&p, &vec![1u8; MIB as usize]).unwrap();
            devices.push(sea.device_of(&format!("d/f{i:03}.dat")));
        }
        let on_tmpfs = devices.iter().flatten().filter(|d| d.contains("tmpfs")).count();
        let on_disk = devices.iter().flatten().filter(|d| d.contains("disk")).count();
        let on_pfs = devices.iter().filter(|d| d.is_none()).count();
        assert!(on_tmpfs >= 2 && on_tmpfs <= 3, "tmpfs {on_tmpfs}");
        assert!(on_disk >= 190, "disk {on_disk}");
        assert!(on_pfs >= 40, "pfs {on_pfs}");
        // the pfs fallback files really are on the pfs
        assert!(pfs.exists(Path::new("d/f249.dat")));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn move_mode_flushes_then_evicts() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**_final.dat", "**_final.dat", ""), 10 * MIB);
        let p = Path::new("/sea/out/b_final.dat");
        sea.write(p, &vec![3u8; MIB as usize]).unwrap();
        sea.sync_mgmt().unwrap();
        // after the move: gone locally, present on PFS, still readable
        assert!(sea.device_of("out/b_final.dat").is_none());
        assert!(pfs.exists(Path::new("out/b_final.dat")));
        assert_eq!(sea.read(p).unwrap().len(), MIB as usize);
        assert_eq!(sea.mgmt_counters(), (1, 1));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn copy_mode_keeps_local_copy() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("**", "", ""), 10 * MIB);
        let p = Path::new("/sea/x.dat");
        sea.write(p, &vec![5u8; MIB as usize]).unwrap();
        sea.sync_mgmt().unwrap();
        assert!(sea.device_of("x.dat").is_some(), "local copy kept");
        assert!(pfs.exists(Path::new("x.dat")), "pfs copy exists");
        assert_eq!(sea.mgmt_counters(), (1, 0));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn remove_mode_discards_without_persisting() {
        let (sea, root, pfs) = mount(RuleSet::from_texts("", "*.log", ""), 10 * MIB);
        let p = Path::new("/sea/noise.log");
        sea.write(p, b"scratch").unwrap();
        sea.sync_mgmt().unwrap();
        assert!(!sea.exists(p));
        assert!(!pfs.exists(Path::new("noise.log")));
        assert_eq!(sea.mgmt_counters(), (0, 1));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn eviction_frees_space_for_later_files() {
        // Move everything: space should keep being recycled, so many more
        // files than tmpfs capacity all land on tmpfs eventually
        let (sea, root, _) = mount(RuleSet::from_texts("**", "**", ""), 4 * MIB);
        for i in 0..20 {
            let p = PathBuf::from(format!("/sea/s/f{i}.dat"));
            sea.write(&p, &vec![0u8; MIB as usize]).unwrap();
            sea.sync_mgmt().unwrap(); // drain so space is recycled
        }
        let (fl, ev) = sea.mgmt_counters();
        assert_eq!(fl, 20);
        assert_eq!(ev, 20);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn outside_mount_passes_through_to_pfs() {
        let (sea, root, pfs) = mount(RuleSet::default(), 10 * MIB);
        sea.write(Path::new("plain/file.txt"), b"direct").unwrap();
        assert!(pfs.exists(Path::new("plain/file.txt")));
        assert_eq!(sea.read(Path::new("plain/file.txt")).unwrap(), b"direct");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn unlink_and_rename_within_mount() {
        let (sea, root, _) = mount(RuleSet::default(), 10 * MIB);
        let a = Path::new("/sea/a.dat");
        let b = Path::new("/sea/b.dat");
        sea.write(a, b"x").unwrap();
        sea.rename(a, b).unwrap();
        assert!(!sea.exists(a));
        assert_eq!(sea.read(b).unwrap(), b"x");
        sea.unlink(b).unwrap();
        assert!(!sea.exists(b));
        assert!(matches!(sea.unlink(b), Err(Error::NotFound(_))));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn readdir_merges_local_and_pfs() {
        let (sea, root, pfs) = mount(RuleSet::default(), 10 * MIB);
        pfs.write(Path::new("d/pfs_file"), b"1").unwrap();
        sea.write(Path::new("/sea/d/local_file"), b"2").unwrap();
        let names = sea.readdir(Path::new("/sea/d")).unwrap();
        assert_eq!(names, vec!["local_file", "pfs_file"]);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn prefetch_pulls_matching_inputs() {
        let (sea, root, pfs) = mount(
            RuleSet::from_texts("", "", "inputs/*.dat"),
            10 * MIB,
        );
        pfs.write(Path::new("inputs/a.dat"), &vec![1u8; MIB as usize]).unwrap();
        pfs.write(Path::new("inputs/skip.txt"), b"no").unwrap();
        let n = sea.prefetch_dir("inputs").unwrap();
        assert_eq!(n, 1);
        assert!(sea.device_of("inputs/a.dat").is_some());
        assert!(sea.device_of("inputs/skip.txt").is_none());
        let _ = std::fs::remove_dir_all(&root);
    }
}
